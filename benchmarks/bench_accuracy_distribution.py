"""Fig. 14 — per-query accuracy distribution (min / avg / max F1)."""

from __future__ import annotations

from benchmarks.common import (
    evaluate, gbkmv_engine, load_dataset, lshe_engine, queries_for, write_csv)


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    nq = 30 if quick else 120
    for ds in ("NETFLIX", "ENRON", "WDC"):
        recs, exact_index, total = load_dataset(ds, scale)
        queries = queries_for(recs, nq)
        for name, (fn, _) in {
            "GB-KMV": gbkmv_engine(recs, int(total * 0.1)),
            "LSH-E": lshe_engine(recs, num_hashes=128 if quick else 256),
        }.items():
            res = evaluate(fn, exact_index, queries, 0.5)
            rows.append({"dataset": ds, "engine": name,
                         "f1_min": round(res["f_min"], 4),
                         "f1_avg": round(res["f"], 4),
                         "f1_max": round(res["f_max"], 4)})
    write_csv("fig14_accuracy_distribution.csv", rows)
    return rows
