"""Fig. 5 — effect of buffer size r at a FIXED total space budget:
measured F1 vs the §IV-C6 cost-model variance on NETFLIX/ENRON stand-ins.

The r-grid spans the feasible region (buffer words ≤ budget); the paper's
interior optimum appears because a larger buffer starves the G-KMV tail
(its τ, hence per-pair k, shrinks) while a smaller one wastes the skew.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import evaluate, load_dataset, queries_for, write_csv
from repro import api
from repro.core import cost_model
from repro.core.gbkmv import element_frequencies


def run(quick: bool = True):
    rows = []
    scale = 0.25 if quick else 0.6
    nq = 30 if quick else 100
    budget_frac = 0.3
    for ds in ("NETFLIX", "ENRON"):
        recs, exact_index, total = load_dataset(ds, scale)
        m = len(recs)
        budget = int(total * budget_frac)
        queries = queries_for(recs, nq)
        freq = element_frequencies(recs)
        freqs = np.asarray(sorted(freq.values(), reverse=True), np.int64)
        sizes = np.asarray([len(r) for r in recs], np.int64)
        r_max = int(32 * budget * 0.9 / m)      # feasibility cap
        r_grid = sorted({0, 16, 32, r_max // 2, 3 * r_max // 4, r_max})
        r_star = cost_model.choose_buffer_size(freqs, sizes, budget, m)
        for r in r_grid:
            index = api.get_engine("gbkmv").build(recs, budget, r=r)
            res = evaluate(index.query, exact_index, queries, 0.5)
            var = cost_model.gbkmv_variance(freqs, sizes, budget, m, r)
            rows.append({"dataset": ds, "r": r, "f1": round(res["f"], 4),
                         "precision": round(res["precision"], 4),
                         "recall": round(res["recall"], 4),
                         "model_variance": f"{var:.3e}",
                         "model_pick": r_star})
    write_csv("fig5_buffer_size.csv", rows)
    return rows
