"""Build bench: vectorized construction vs the seed-era per-record oracle.

The paper's headline construction claim (§V-E: one hash function, built
>100× faster than LSH-E) needs a fast build path to mean anything.
This suite measures records/s and elements/s for gbkmv/gkmv/kmv/lshe on
the quick Zipf workload, for both the vectorized pipeline (host CSR ops,
or the fused device hash→τ→pack under ``backend="jnp"|"pallas"``) and
the retained per-record oracles — asserting bit-identical sketches
between the two on every run (a mismatch raises and fails CI).

``run(quick, json_out=..., backend=..., baseline=...)``:

* ``backend`` picks the construction path for the sketch engines
  ("numpy" = host vectorized; "jnp"/"pallas" = fused device build).
  LSH-E's vectorized build is host-side regardless.
* ``baseline`` points at a committed BENCH_BUILD.json; the run FAILS if
  any engine's ``speedup_vs_oracle`` drops below
  ``SPEEDUP_TOLERANCE ×`` that backend's committed speedup. Gating on
  the speedup RATIO — both numerator and denominator measured on the
  same machine in the same run — cancels machine speed the same way the
  planner gate's dense-QPS normalization does.
* Independently of any baseline, the gbkmv numpy-path speedup must
  clear ``MIN_GBKMV_NUMPY_SPEEDUP`` (the PR's ≥10× acceptance floor).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import write_csv
from repro.core import gbkmv, gkmv, kmv, lshe, minhash
from repro.data.synth import generate_dataset

ENGINES = ("gbkmv", "gkmv", "kmv", "lshe")
# ≥ tolerance × committed speedup_vs_oracle. The numpy ratio compares two
# host paths and is stable across machines; the device paths compare a
# Python oracle against XLA-compiled work, whose relative cost varies
# more with core count / BLAS — hence the looser floor.
SPEEDUP_TOLERANCE = {"numpy": 0.8}
SPEEDUP_TOLERANCE_DEFAULT = 0.5
MIN_GBKMV_NUMPY_SPEEDUP = 10.0    # acceptance floor, numpy path
LSHE_HASHES_QUICK = 64
LSHE_HASHES_FULL = 256


def _pack_of(obj):
    """The PackedSketches behind either a pack or a GBKMVIndex."""
    return obj.sketches if hasattr(obj, "sketches") else obj


def _assert_pack_parity(fast, oracle, label: str) -> None:
    f, o = _pack_of(fast), _pack_of(oracle)
    for field in ("values", "lengths", "thresh", "buf", "sizes"):
        a, b = np.asarray(getattr(f, field)), np.asarray(getattr(o, field))
        if a.shape != b.shape or not np.array_equal(a, b):
            raise RuntimeError(
                f"build parity broken: {label}.{field} fast {a.shape} "
                f"vs oracle {b.shape}")


def _builders(engine: str, recs, budget: int, backend: str, seed: int,
              num_hashes: int):
    """(fast_fn, oracle_fn, parity_fn) for one engine."""
    bb = None if backend == "numpy" else backend
    if engine == "gbkmv":
        fast = lambda: gbkmv.build_gbkmv(recs, budget, r="auto", seed=seed,
                                         build_backend=bb)
        oracle = lambda: gbkmv.build_gbkmv_oracle(recs, budget, r="auto",
                                                  seed=seed)

        def parity(f, o):
            _assert_pack_parity(f, o, "gbkmv")
            if int(f.tau) != int(o.tau) or not np.array_equal(
                    f.top_elems, o.top_elems):
                raise RuntimeError("build parity broken: gbkmv tau/top_elems")
        return fast, oracle, parity
    if engine == "gkmv":
        fast = lambda: gkmv.build_gkmv(recs, budget, seed=seed,
                                       build_backend=bb)
        oracle = lambda: gkmv.build_gkmv_oracle(recs, budget, seed=seed)
        return fast, oracle, lambda f, o: _assert_pack_parity(f, o, "gkmv")
    if engine == "kmv":
        fast = lambda: kmv.build_kmv(recs, budget, seed=seed,
                                     build_backend=bb)
        oracle = lambda: kmv.build_kmv_oracle(recs, budget, seed=seed)
        return fast, oracle, lambda f, o: _assert_pack_parity(f, o, "kmv")
    if engine == "lshe":
        # The signature matrix is the entire §V-E construction cost.
        fast = lambda: lshe.build_lshe(recs, num_hashes=num_hashes, seed=seed)
        oracle = lambda: minhash.build_signatures_oracle(
            recs, num_hashes, seed=seed)

        def parity(f, o):
            if not np.array_equal(f.signatures, o):
                raise RuntimeError("build parity broken: lshe signatures")
        return fast, oracle, parity
    raise ValueError(engine)


def _time_fast(fn, repeats: int = 4) -> float:
    """Best-of-``repeats`` seconds after one warmup build (jit caches on
    the device path compile on the warmup, as they would on any repeated
    ingest of the same shape)."""
    fn()
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def check_baseline(rows, baseline_path: str, backend: str) -> list[str]:
    """Per-engine speedup_vs_oracle gate against a committed artifact.

    The artifact carries per-backend rows (``rows_by_backend``); each CI
    matrix cell gates against ITS OWN backend's committed speedups. The
    ratio is machine-normalized by construction (fast and oracle share
    the run), so the tolerance is a genuine regression budget.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    base_rows = {r["engine"]: r
                 for r in base.get("rows_by_backend", {}).get(backend, [])}
    tol = SPEEDUP_TOLERANCE.get(backend, SPEEDUP_TOLERANCE_DEFAULT)
    failures = []
    for r in rows:
        b = base_rows.get(r["engine"])
        if b is None:
            continue
        floor = tol * b["speedup_vs_oracle"]
        if r["speedup_vs_oracle"] < floor:
            failures.append(
                f"{r['engine']}: build speedup {r['speedup_vs_oracle']:.1f}× "
                f"< floor {floor:.1f}× (committed "
                f"{b['speedup_vs_oracle']:.1f}× × {tol})")
    return failures


def run(quick: bool = True, json_out: str | None = None,
        backend: str = "numpy", baseline: str | None = None):
    # Quick profile is sized so the oracle's per-element Python cost
    # dominates its fixed overheads — small-N runs drown the gated ratio
    # in scheduler noise and the shared r="auto" cost-model time.
    m = 2500 if quick else 8000
    n_elems = 25_000 if quick else 60_000
    num_hashes = LSHE_HASHES_QUICK if quick else LSHE_HASHES_FULL
    recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=300, seed=7)
    total = sum(len(r) for r in recs)
    budget = int(total * 0.1)

    rows = []
    for engine in ENGINES:
        fast, oracle, parity = _builders(engine, recs, budget, backend,
                                         seed=3, num_hashes=num_hashes)
        # Oracle best-of-3: one pass would let scheduler noise into the
        # denominator of the gated ratio.
        dt_oracle = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            oracle_out = oracle()
            dt_oracle = min(dt_oracle, time.perf_counter() - t0)
        parity(fast(), oracle_out)
        dt_fast = _time_fast(fast)
        rows.append({
            "engine": engine,
            "backend": backend if engine != "lshe" else "numpy",
            "records_per_s": round(m / dt_fast, 1),
            "elements_per_s": round(total / dt_fast, 1),
            "oracle_records_per_s": round(m / dt_oracle, 1),
            "speedup_vs_oracle": round(dt_oracle / dt_fast, 2),
            "build_s": round(dt_fast, 4),
            "oracle_build_s": round(dt_oracle, 4),
            "parity": True,
        })

    write_csv("build.csv", rows)

    failures = []
    if backend == "numpy":
        gb = next(r for r in rows if r["engine"] == "gbkmv")
        if gb["speedup_vs_oracle"] < MIN_GBKMV_NUMPY_SPEEDUP:
            failures.append(
                f"gbkmv numpy build speedup {gb['speedup_vs_oracle']:.1f}× "
                f"below the {MIN_GBKMV_NUMPY_SPEEDUP}× acceptance floor")
    if baseline and os.path.exists(baseline):
        failures += check_baseline(rows, baseline, backend)

    if json_out:
        by_backend = {}
        if os.path.exists(json_out):
            try:
                with open(json_out) as f:
                    by_backend = dict(json.load(f).get("rows_by_backend", {}))
            except (json.JSONDecodeError, OSError):
                by_backend = {}
        by_backend[backend] = rows
        payload = {
            "suite": "build",
            "profile": "quick" if quick else "full",
            "workload": {
                "generator": "zipf", "m": m, "n_elems": n_elems,
                "alpha_freq": 0.8, "alpha_size": 1.0, "budget": budget,
                "total_elements": total, "lshe_num_hashes": num_hashes,
                "backend": backend,
            },
            "rows": rows,
            "rows_by_backend": by_backend,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise RuntimeError(
            "build gates failed (speedup floor / committed baseline):\n  "
            + "\n  ".join(failures))
    return rows
