"""Build bench: vectorized construction vs the seed-era per-record oracle.

The paper's headline construction claim (§V-E: one hash function, built
>100× faster than LSH-E) needs a fast build path to mean anything.
This suite measures records/s and elements/s for gbkmv/gkmv/kmv/lshe on
the quick Zipf workload, for both the vectorized pipeline (host CSR ops,
or the fused device hash→τ→pack under ``backend="jnp"|"pallas"``) and
the retained per-record oracles — asserting bit-identical sketches
between the two on every run (a mismatch raises and fails CI).

``run(quick, json_out=..., backend=..., baseline=...)``:

* ``backend`` picks the construction path for the sketch engines
  ("numpy" = host vectorized; "jnp"/"pallas" = fused device build).
  LSH-E's vectorized build is host-side regardless.
* ``baseline`` points at a committed BENCH_BUILD.json; the run FAILS if
  any engine's ``speedup_vs_oracle`` drops below
  ``SPEEDUP_TOLERANCE ×`` that backend's committed speedup. Gating on
  the speedup RATIO — both numerator and denominator measured on the
  same machine in the same run — cancels machine speed the same way the
  planner gate's dense-QPS normalization does.
* Independently of any baseline, the gbkmv numpy-path speedup must
  clear ``MIN_GBKMV_NUMPY_SPEEDUP`` (the PR's ≥10× acceptance floor).

The numpy cell additionally benches the windowed-ingest merge path
(``merge_gbkmv``/``merge_gkmv``/``merge_kmv`` over ``MERGE_PARTS``
disjoint epoch sketches): every run asserts the merge bit-identical to
rebuilding from the concatenated records, and the merge-vs-rebuild
speedup is recorded under ``merge_rows`` and gated (a merge may never
lose to a rebuild, nor regress below ``MERGE_TOLERANCE ×`` committed).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import write_csv
from repro.core import gbkmv, gkmv, kmv, lshe, minhash
from repro.data.synth import generate_dataset

ENGINES = ("gbkmv", "gkmv", "kmv", "lshe")
# ≥ tolerance × committed speedup_vs_oracle. The numpy ratio compares two
# host paths and is stable across machines; the device paths compare a
# Python oracle against XLA-compiled work, whose relative cost varies
# more with core count / BLAS — hence the looser floor.
SPEEDUP_TOLERANCE = {"numpy": 0.8}
SPEEDUP_TOLERANCE_DEFAULT = 0.5
MIN_GBKMV_NUMPY_SPEEDUP = 10.0    # acceptance floor, numpy path
LSHE_HASHES_QUICK = 64
LSHE_HASHES_FULL = 256
# Windowed-ingest merge bench (host path, numpy cell only): parts built
# over disjoint record slices with the SHARED budget, merged with
# merge_gbkmv/merge_gkmv/merge_kmv, asserted bit-identical to rebuilding
# from the concatenation, and gated on merge-vs-rebuild speedup — the
# merge skips hashing and re-sorting, so it must not lose to a rebuild.
MERGE_PARTS = 4
MERGE_ENGINES = ("gbkmv", "gkmv", "kmv")
MIN_MERGE_SPEEDUP = 1.0
MERGE_TOLERANCE = 0.5
MERGE_GBKMV_R = 64                # fixed r keeps budget ≥ m·(w+1) — the
                                  # documented merge bit-identity condition


def _pack_of(obj):
    """The PackedSketches behind either a pack or a GBKMVIndex."""
    return obj.sketches if hasattr(obj, "sketches") else obj


def _assert_pack_parity(fast, oracle, label: str) -> None:
    f, o = _pack_of(fast), _pack_of(oracle)
    for field in ("values", "lengths", "thresh", "buf", "sizes"):
        a, b = np.asarray(getattr(f, field)), np.asarray(getattr(o, field))
        if a.shape != b.shape or not np.array_equal(a, b):
            raise RuntimeError(
                f"build parity broken: {label}.{field} fast {a.shape} "
                f"vs oracle {b.shape}")


def _builders(engine: str, recs, budget: int, backend: str, seed: int,
              num_hashes: int):
    """(fast_fn, oracle_fn, parity_fn) for one engine."""
    bb = None if backend == "numpy" else backend
    if engine == "gbkmv":
        fast = lambda: gbkmv.build_gbkmv(recs, budget, r="auto", seed=seed,
                                         build_backend=bb)
        oracle = lambda: gbkmv.build_gbkmv_oracle(recs, budget, r="auto",
                                                  seed=seed)

        def parity(f, o):
            _assert_pack_parity(f, o, "gbkmv")
            if int(f.tau) != int(o.tau) or not np.array_equal(
                    f.top_elems, o.top_elems):
                raise RuntimeError("build parity broken: gbkmv tau/top_elems")
        return fast, oracle, parity
    if engine == "gkmv":
        fast = lambda: gkmv.build_gkmv(recs, budget, seed=seed,
                                       build_backend=bb)
        oracle = lambda: gkmv.build_gkmv_oracle(recs, budget, seed=seed)
        return fast, oracle, lambda f, o: _assert_pack_parity(f, o, "gkmv")
    if engine == "kmv":
        fast = lambda: kmv.build_kmv(recs, budget, seed=seed,
                                     build_backend=bb)
        oracle = lambda: kmv.build_kmv_oracle(recs, budget, seed=seed)
        return fast, oracle, lambda f, o: _assert_pack_parity(f, o, "kmv")
    if engine == "lshe":
        # The signature matrix is the entire §V-E construction cost.
        fast = lambda: lshe.build_lshe(recs, num_hashes=num_hashes, seed=seed)
        oracle = lambda: minhash.build_signatures_oracle(
            recs, num_hashes, seed=seed)

        def parity(f, o):
            if not np.array_equal(f.signatures, o):
                raise RuntimeError("build parity broken: lshe signatures")
        return fast, oracle, parity
    raise ValueError(engine)


def _time_fast(fn, repeats: int = 4) -> float:
    """Best-of-``repeats`` seconds after one warmup build (jit caches on
    the device path compile on the warmup, as they would on any repeated
    ingest of the same shape)."""
    fn()
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _merge_builders(engine: str, recs, budget: int, seed: int):
    """(merge_fn, rebuild_fn, parity_fn) over pre-built disjoint parts.

    Parts are built OUTSIDE the timed region — the bench measures the
    windowed-ingest steady state, where epoch sketches already exist and
    a window query pays only the merge.
    """
    cut = (len(recs) + MERGE_PARTS - 1) // MERGE_PARTS
    slices = [recs[i:i + cut] for i in range(0, len(recs), cut)]
    if engine == "gbkmv":
        first = gbkmv.build_gbkmv(slices[0], budget, r=MERGE_GBKMV_R,
                                  seed=seed)
        parts = [first] + [
            gbkmv.build_gbkmv(s, budget, r=MERGE_GBKMV_R, seed=seed,
                              top_elems=first.top_elems)
            for s in slices[1:]]
        merge = lambda: gbkmv.merge_gbkmv(parts, budget)
        rebuild = lambda: gbkmv.build_gbkmv(recs, budget, r=MERGE_GBKMV_R,
                                            seed=seed,
                                            top_elems=first.top_elems)

        def parity(mg, rb):
            _assert_pack_parity(mg, rb, "gbkmv-merge")
            if int(mg.tau) != int(rb.tau) or not np.array_equal(
                    mg.top_elems, rb.top_elems):
                raise RuntimeError("merge parity broken: gbkmv tau/top_elems")
        return merge, rebuild, parity
    if engine == "gkmv":
        parts = [gkmv.build_gkmv(s, budget, seed=seed) for s in slices]
        return (lambda: gkmv.merge_gkmv(parts, budget),
                lambda: gkmv.build_gkmv(recs, budget, seed=seed),
                lambda mg, rb: _assert_pack_parity(mg, rb, "gkmv-merge"))
    if engine == "kmv":
        parts = [kmv.build_kmv(s, budget, seed=seed) for s in slices]
        return (lambda: kmv.merge_kmv(parts, budget),
                lambda: kmv.build_kmv(recs, budget, seed=seed),
                lambda mg, rb: _assert_pack_parity(mg, rb, "kmv-merge"))
    raise ValueError(engine)


def run_merge(recs, budget: int, seed: int = 3) -> list[dict]:
    """Merge-vs-rebuild rows, parity-asserted (host path)."""
    m = len(recs)
    rows = []
    for engine in MERGE_ENGINES:
        merge, rebuild, parity = _merge_builders(engine, recs, budget, seed)
        dt_rebuild = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            rebuilt = rebuild()
            dt_rebuild = min(dt_rebuild, time.perf_counter() - t0)
        parity(merge(), rebuilt)
        dt_merge = _time_fast(merge)
        rows.append({
            "engine": engine,
            "parts": MERGE_PARTS,
            "merge_records_per_s": round(m / dt_merge, 1),
            "merge_s": round(dt_merge, 4),
            "rebuild_s": round(dt_rebuild, 4),
            "merge_speedup_vs_rebuild": round(dt_rebuild / dt_merge, 2),
            "parity": True,
        })
    return rows


def check_merge_baseline(rows, base: dict) -> list[str]:
    """Merge-speedup gate against the committed ``merge_rows``."""
    base_rows = {r["engine"]: r for r in base.get("merge_rows", [])}
    failures = []
    for r in rows:
        if r["merge_speedup_vs_rebuild"] < MIN_MERGE_SPEEDUP:
            failures.append(
                f"{r['engine']}: merge {r['merge_speedup_vs_rebuild']:.2f}× "
                f"rebuild — a merge slower than rebuilding from scratch")
        b = base_rows.get(r["engine"])
        if b is None:
            continue
        floor = MERGE_TOLERANCE * b["merge_speedup_vs_rebuild"]
        if r["merge_speedup_vs_rebuild"] < floor:
            failures.append(
                f"{r['engine']}: merge speedup "
                f"{r['merge_speedup_vs_rebuild']:.1f}× < floor {floor:.1f}× "
                f"(committed {b['merge_speedup_vs_rebuild']:.1f}× × "
                f"{MERGE_TOLERANCE})")
    return failures


def check_baseline(rows, baseline_path: str, backend: str) -> list[str]:
    """Per-engine speedup_vs_oracle gate against a committed artifact.

    The artifact carries per-backend rows (``rows_by_backend``); each CI
    matrix cell gates against ITS OWN backend's committed speedups. The
    ratio is machine-normalized by construction (fast and oracle share
    the run), so the tolerance is a genuine regression budget.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    base_rows = {r["engine"]: r
                 for r in base.get("rows_by_backend", {}).get(backend, [])}
    tol = SPEEDUP_TOLERANCE.get(backend, SPEEDUP_TOLERANCE_DEFAULT)
    failures = []
    for r in rows:
        b = base_rows.get(r["engine"])
        if b is None:
            continue
        floor = tol * b["speedup_vs_oracle"]
        if r["speedup_vs_oracle"] < floor:
            failures.append(
                f"{r['engine']}: build speedup {r['speedup_vs_oracle']:.1f}× "
                f"< floor {floor:.1f}× (committed "
                f"{b['speedup_vs_oracle']:.1f}× × {tol})")
    return failures


def run(quick: bool = True, json_out: str | None = None,
        backend: str = "numpy", baseline: str | None = None):
    # Quick profile is sized so the oracle's per-element Python cost
    # dominates its fixed overheads — small-N runs drown the gated ratio
    # in scheduler noise and the shared r="auto" cost-model time.
    m = 2500 if quick else 8000
    n_elems = 25_000 if quick else 60_000
    num_hashes = LSHE_HASHES_QUICK if quick else LSHE_HASHES_FULL
    recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=300, seed=7)
    total = sum(len(r) for r in recs)
    budget = int(total * 0.1)

    rows = []
    for engine in ENGINES:
        fast, oracle, parity = _builders(engine, recs, budget, backend,
                                         seed=3, num_hashes=num_hashes)
        # Oracle best-of-3: one pass would let scheduler noise into the
        # denominator of the gated ratio.
        dt_oracle = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            oracle_out = oracle()
            dt_oracle = min(dt_oracle, time.perf_counter() - t0)
        parity(fast(), oracle_out)
        dt_fast = _time_fast(fast)
        rows.append({
            "engine": engine,
            "backend": backend if engine != "lshe" else "numpy",
            "records_per_s": round(m / dt_fast, 1),
            "elements_per_s": round(total / dt_fast, 1),
            "oracle_records_per_s": round(m / dt_oracle, 1),
            "speedup_vs_oracle": round(dt_oracle / dt_fast, 2),
            "build_s": round(dt_fast, 4),
            "oracle_build_s": round(dt_oracle, 4),
            "parity": True,
        })

    write_csv("build.csv", rows)

    failures = []
    merge_rows = []
    if backend == "numpy":
        gb = next(r for r in rows if r["engine"] == "gbkmv")
        if gb["speedup_vs_oracle"] < MIN_GBKMV_NUMPY_SPEEDUP:
            failures.append(
                f"gbkmv numpy build speedup {gb['speedup_vs_oracle']:.1f}× "
                f"below the {MIN_GBKMV_NUMPY_SPEEDUP}× acceptance floor")
        # Merges are host ops regardless of backend — bench them once,
        # in the numpy cell, with bit-parity asserted inside run_merge.
        merge_rows = run_merge(recs, budget, seed=3)
        write_csv("build_merge.csv", merge_rows)
    if baseline and os.path.exists(baseline):
        failures += check_baseline(rows, baseline, backend)
        if merge_rows:
            with open(baseline) as f:
                failures += check_merge_baseline(merge_rows, json.load(f))

    if json_out:
        by_backend = {}
        prev_merge = []
        if os.path.exists(json_out):
            try:
                with open(json_out) as f:
                    prev = json.load(f)
                by_backend = dict(prev.get("rows_by_backend", {}))
                prev_merge = list(prev.get("merge_rows", []))
            except (json.JSONDecodeError, OSError):
                by_backend = {}
        by_backend[backend] = rows
        payload = {
            "suite": "build",
            "profile": "quick" if quick else "full",
            "workload": {
                "generator": "zipf", "m": m, "n_elems": n_elems,
                "alpha_freq": 0.8, "alpha_size": 1.0, "budget": budget,
                "total_elements": total, "lshe_num_hashes": num_hashes,
                "backend": backend,
            },
            "rows": rows,
            "rows_by_backend": by_backend,
            # Windowed-ingest merge path; non-numpy cells carry the
            # previous artifact's rows forward unchanged.
            "merge_rows": merge_rows or prev_merge,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise RuntimeError(
            "build gates failed (speedup floor / committed baseline):\n  "
            + "\n  ".join(failures))
    return rows
