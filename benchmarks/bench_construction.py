"""Fig. 18 + Table III — sketch construction time and space usage.
GB-KMV needs ONE hash pass; LSH-E needs num_hashes MinHash passes."""

from __future__ import annotations

import time

from benchmarks.common import load_dataset, write_csv
from repro import api

DATASETS = ("NETFLIX", "DELIC", "COD", "ENRON", "REUTERS", "WEBSPAM", "WDC")


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    k = 64 if quick else 256
    for ds in DATASETS:
        recs, _, total = load_dataset(ds, scale)
        t0 = time.time()
        gb = api.get_engine("gbkmv").build(recs, int(total * 0.1))
        t_gb = time.time() - t0
        t0 = time.time()
        le = api.get_engine("lshe").build(recs, num_hashes=k)
        t_le = time.time() - t0
        data_bytes = total * 4
        rows.append({
            "dataset": ds, "records": len(recs),
            "gbkmv_build_s": round(t_gb, 3), "lshe_build_s": round(t_le, 3),
            "build_speedup": round(t_le / max(t_gb, 1e-9), 1),
            "gbkmv_space_pct": round(100 * gb.nbytes() / data_bytes, 1),
            "lshe_space_pct": round(100 * le.nbytes() / data_bytes, 1),
        })
    write_csv("fig18_t3_construction.csv", rows)
    return rows
