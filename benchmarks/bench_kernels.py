"""Pallas kernel microbench (interpret mode on CPU — correctness +
relative cost only; TPU timings come from a real pod).

Sweeps the GB-KMV scoring kernel vs the pure-jnp oracle over index sizes
and query-batch sizes Gq; the Gq sweep is the query-batching §Perf knob
(one sweep of the sketch matrix amortized over Gq queries)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro import api
from repro.data.synth import generate_dataset, make_query_workload
from repro.kernels.ops import score_index
from repro.kernels.ref import gbkmv_score_ref
from repro.sketchindex import batch_queries


def run(quick: bool = True):
    rows = []
    m = 256 if quick else 2048
    recs = generate_dataset(m=m, n_elems=20_000, alpha_freq=1.1,
                            alpha_size=2.0, seed=0)
    total = sum(len(r) for r in recs)
    index = api.get_engine("gbkmv").build(recs, int(total * 0.1), r=64).core
    s = index.sketches
    for gq in (1, 4, 16):
        qp = batch_queries(index, make_query_workload(recs, gq))
        args = (s.values, s.thresh,
                s.buf if s.buf.shape[1] else np.zeros((m, 1), np.uint32),
                qp.values, qp.thresh,
                qp.buf if qp.buf.shape[1] else np.zeros((gq, 1), np.uint32),
                qp.sizes)
        out_k = np.asarray(score_index(*args, interpret=True))
        out_r = np.asarray(gbkmv_score_ref(
            args[0], args[1].reshape(-1), args[2],
            args[3], args[4].reshape(-1), args[5], args[6].reshape(-1)))
        err = float(np.abs(out_k[:m] - out_r).max())

        t0 = time.time()
        score_index(*args, interpret=True)
        t_k = time.time() - t0
        t0 = time.time()
        gbkmv_score_ref(args[0], args[1].reshape(-1), args[2],
                        args[3], args[4].reshape(-1), args[5],
                        args[6].reshape(-1))
        t_r = time.time() - t0
        rows.append({"records": m, "gq": gq, "max_abs_err": f"{err:.2e}",
                     "kernel_interp_ms": round(t_k * 1e3, 1),
                     "jnp_ref_ms": round(t_r * 1e3, 1),
                     "note": "interpret-mode timing (correctness gate only)"})
    write_csv("kernel_microbench.csv", rows)
    return rows
