"""Planner bench: dense index sweep vs postings-pruned filter-and-verify.

QPS and candidate-set sizes at thresholds {0.5, 0.7, 0.9} on the Zipf
workload (the Fig. 16 generator) — the perf trajectory for the
candidate-pruning query planner — plus top-k rows at k ∈ {10, 100}
(pruned fused-device/upper-bound path vs the dense full-sweep ranking,
with per-stage splits and their own same-backend regression gate under
``topk_rows_by_backend``). Parity between the two paths is asserted on
every batch and every top-k query: a mismatch raises (and fails the CI
smoke step), because the planner's whole contract is bit-identical
results.

``run(quick, json_out=..., backend=..., baseline=..., calibrate=...)``:

* ``backend`` picks the scoring implementation ("jnp" default; CI also
  smokes "numpy" — with jnp/pallas the pruned path runs device-resident
  over the sketch arena).
* ``baseline`` points at a committed BENCH_PLANNER.json; the run FAILS
  if pruned-path QPS regresses below it — >10% for same-backend runs
  (dense-QPS-ratio normalized, so machine speed cancels out and the
  gate is effectively "block compression may cost at most 10% pruned
  QPS"), >20% for cross-backend runs (raw QPS, inherently noisier).
  Independently of any baseline, the run FAILS if the block-compressed
  postings exceed ``MAX_POSTINGS_RATIO`` × the packed sketch bytes —
  the space claim the compressed format exists to hold.
* ``calibrate`` fits the core/cost_model.py query-path constants from
  the measured QPS (mean_probe_hits feeds the pruned-path model) and
  embeds them under the artifact's "calibration" key —
  ``cost_model.load_calibration`` / $REPRO_COST_CALIBRATION installs
  them so ``plan="auto"`` uses measured instead of hand-set constants.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import write_csv
from repro import api
from repro.data.synth import generate_dataset, make_query_workload
from repro.obs import StageProfiler, attach
from repro.planner import candidates_for
from repro.planner.plan import probe_hits_per_query, unpack_query_rows

THRESHOLDS = (0.5, 0.7, 0.9)
TOPK_KS = (10, 100)
BATCH = 16
REGRESSION_TOLERANCE = 0.8        # cross-backend: ≥ 0.8 × baseline (raw)
COMPRESSION_QPS_TOLERANCE = 0.9   # same-backend: ≥ 0.9 × baseline (scaled)
MAX_POSTINGS_RATIO = 0.6          # compressed postings ≤ 0.6 × sketch bytes


def _batches(queries):
    return [queries[i : i + BATCH] for i in range(0, len(queries), BATCH)]


def _time_path(index, batches, threshold, plan, repeats: int = 3) -> float:
    """Best-of-``repeats`` seconds for one pass over the workload (after
    a warmup pass). Best-of, not mean-of: scheduler noise only ever adds
    time, so the minimum is the stable estimate the QPS gate needs to
    stay reproducible across loaded CI machines."""
    for b in batches:                      # warmup: jit caches, postings
        index.batch_query(b, threshold, plan=plan)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for b in batches:
            index.batch_query(b, threshold, plan=plan)
        best = min(best, time.perf_counter() - t0)
    return best


def _stage_splits(index, batches, threshold, plan) -> dict:
    """Mean per-stage latency (ms) for one pass over the workload, from
    the obs stage profiler. Untimed and separate from ``_time_path`` on
    purpose: observing adds device syncs at stage seams, so the QPS
    gates keep measuring the production (unobserved) path."""
    prof = StageProfiler()
    with attach(None, prof):
        for b in batches:
            index.batch_query(b, threshold, plan=plan)
    return {name: round(s["mean_s"] * 1e3, 4)
            for name, s in sorted(prof.snapshot().items())}


def _time_topk(index, queries, k, plan, repeats: int = 3) -> float:
    """Best-of-``repeats`` seconds for one top-k pass over the workload
    (per-query calls — the api surface is single-query), after a warmup
    pass for jit caches."""
    for q in queries:
        index.topk(q, k, plan=plan)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for q in queries:
            index.topk(q, k, plan=plan)
        best = min(best, time.perf_counter() - t0)
    return best


def _topk_stage_splits(index, queries, k) -> dict:
    """Mean per-stage latency (ms) of the pruned top-k pass (untimed,
    separate from the QPS measurement — same rationale as
    :func:`_stage_splits`)."""
    prof = StageProfiler()
    with attach(None, prof):
        for q in queries:
            index.topk(q, k, plan="pruned")
    return {name: round(s["mean_s"] * 1e3, 4)
            for name, s in sorted(prof.snapshot().items())}


def check_baseline(rows, baseline_path: str, backend: str) -> list[str]:
    """Compare pruned QPS per threshold against a committed artifact.

    Returns human-readable failure strings (empty = pass). The artifact
    carries per-backend baseline rows (``rows_by_backend``) so every CI
    matrix cell gates against ITS OWN backend's committed trajectory,
    scaled by the dense-QPS ratio so a slower/faster CI machine doesn't
    trip the gate (dense is the stable denominator on one backend) —
    at ``COMPRESSION_QPS_TOLERANCE``, the "compression may cost at most
    10% pruned QPS" gate. Backends the artifact has never measured fall
    back to a raw comparison against the primary rows at the looser
    ``REGRESSION_TOLERANCE`` (cross-backend cost structures differ).
    """
    with open(baseline_path) as f:
        base = json.load(f)
    by_backend = base.get("rows_by_backend", {})
    if backend in by_backend:
        base_rows = {r["threshold"]: r for r in by_backend[backend]}
        same = True
    else:
        base_rows = {r["threshold"]: r for r in base.get("rows", [])}
        same = backend == base.get("workload", {}).get("backend", "jnp")
    failures = []
    for r in rows:
        b = base_rows.get(r["threshold"])
        if b is None:
            continue
        scale = r["qps_dense"] / max(b["qps_dense"], 1e-9) if same else 1.0
        tol = COMPRESSION_QPS_TOLERANCE if same else REGRESSION_TOLERANCE
        floor = tol * b["qps_pruned"] * scale
        if r["qps_pruned"] < floor:
            failures.append(
                f"t={r['threshold']}: pruned QPS {r['qps_pruned']:.1f} < "
                f"floor {floor:.1f} (baseline {b['qps_pruned']:.1f} × "
                f"scale {scale:.2f} × {tol})")
    return failures


def check_topk_baseline(topk_rows, baseline_path: str,
                        backend: str) -> list[str]:
    """Same-backend regression gate for the top-k rows, mirroring
    :func:`check_baseline`: pruned top-k QPS per k vs the committed
    ``topk_rows_by_backend``, dense-top-k-ratio scaled. Artifacts
    written before the top-k rows existed simply have no baseline —
    empty result, never a failure."""
    with open(baseline_path) as f:
        base = json.load(f)
    by_backend = base.get("topk_rows_by_backend", {})
    if backend in by_backend:
        base_rows = {r["k"]: r for r in by_backend[backend]}
        same = True
    else:
        base_rows = {r["k"]: r for r in base.get("topk_rows", [])}
        same = backend == base.get("workload", {}).get("backend", "jnp")
    failures = []
    for r in topk_rows:
        b = base_rows.get(r["k"])
        if b is None:
            continue
        scale = (r["qps_dense_topk"] / max(b["qps_dense_topk"], 1e-9)
                 if same else 1.0)
        tol = COMPRESSION_QPS_TOLERANCE if same else REGRESSION_TOLERANCE
        floor = tol * b["qps_pruned_topk"] * scale
        if r["qps_pruned_topk"] < floor:
            failures.append(
                f"k={r['k']}: pruned top-k QPS {r['qps_pruned_topk']:.1f} "
                f"< floor {floor:.1f} (baseline {b['qps_pruned_topk']:.1f} "
                f"× scale {scale:.2f} × {tol})")
    return failures


def run(quick: bool = True, json_out: str | None = None,
        backend: str = "jnp", baseline: str | None = None,
        calibrate: bool = False):
    m = 4000 if quick else 20_000
    n_elems = 20_000 if quick else 100_000
    nq = 64 if quick else 256
    recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=400, seed=5)
    total = sum(len(r) for r in recs)
    budget = int(total * 0.1)
    index = api.get_engine("gbkmv").build(recs, budget, backend=backend)
    queries = make_query_workload(recs, nq, seed=2)
    batches = _batches(queries)

    # Untimed candidate accounting, identical for every backend: the
    # host filter's candidate-set sizes and the probe's posting-entry
    # counts (the device path never materializes candidates on host).
    _, hash_rows, bit_rows, q_sizes = index._plan_queries(queries)
    post = index._postings()
    probe = probe_hits_per_query(post, hash_rows, bit_rows)

    # Space accounting for the block-compressed postings: at-rest bytes
    # vs the packed sketch columns, plus the flat-CSR bytes the same
    # lists would cost (keys + int64 row pointers + int32 entries).
    arena = index._sketch_pack()
    sketch_b = arena.sketch_nbytes()
    post_b = post.nbytes()
    flat_b = (int(post.keys.nbytes) + 8 * (len(post.keys) + 1)
              + 4 * post.nnz + 8 * (post.buf.num_rows + 1)
              + 4 * post.buf.nnz)
    postings_info = {
        "postings_nbytes": int(post_b),
        "sketch_nbytes": int(sketch_b),
        "postings_ratio": round(post_b / max(sketch_b, 1), 4),
        "flat_equiv_nbytes": int(flat_b),
        "compression_vs_flat": round(flat_b / max(post_b, 1), 2),
    }

    rows = []
    for t in THRESHOLDS:
        dense = index.batch_query(queries, t, plan="dense")
        pruned = index.batch_query(queries, t, plan="pruned")
        for j, (d, p) in enumerate(zip(dense, pruned)):
            if not np.array_equal(d, p):
                raise RuntimeError(
                    f"planner parity broken at t={t}, query {j}: "
                    f"dense={d.tolist()} pruned={p.tolist()}")
        cands = [candidates_for(post, qh, qb, t, int(qs))
                 for qh, qb, qs in zip(hash_rows, bit_rows, q_sizes)]
        cand_sizes = [len(c.rec_ids) for c in cands]
        dt_dense = _time_path(index, batches, t, "dense")
        dt_pruned = _time_path(index, batches, t, "pruned")
        stages = _stage_splits(index, batches, t, "pruned")
        rows.append({
            "threshold": t,
            "qps_dense": round(nq / dt_dense, 2),
            "qps_pruned": round(nq / dt_pruned, 2),
            "speedup": round(dt_dense / dt_pruned, 3),
            "mean_candidates": round(float(np.mean(cand_sizes)), 2),
            "candidate_frac": round(float(np.mean(cand_sizes)) / m, 5),
            "mean_probe_hits": round(float(probe.mean()), 2),
            "mean_blocks": round(float(np.mean([c.blocks for c in cands])), 2),
            "mean_skipped_blocks": round(
                float(np.mean([c.skipped_blocks for c in cands])), 2),
            "mean_hits": float(np.mean([len(d) for d in dense])),
            "stages_ms": stages,
            "parity": True,
        })

    # Top-k trajectory: fused device lax.top_k (jnp/pallas) or the
    # host upper-bound-pruned walk, vs the dense full-sweep ranking.
    # Parity is exact — same (-score, id) order entry for entry.
    topk_rows = []
    for k in TOPK_KS:
        for j, q in enumerate(queries):
            di, ds = index.topk(q, k, plan="dense")
            pi, ps = index.topk(q, k, plan="pruned")
            if not (np.array_equal(di, pi) and np.array_equal(ds, ps)):
                raise RuntimeError(
                    f"top-k parity broken at k={k}, query {j}: "
                    f"dense={list(zip(di.tolist(), ds.tolist()))} "
                    f"pruned={list(zip(pi.tolist(), ps.tolist()))}")
        dt_dense = _time_topk(index, queries, k, "dense")
        dt_pruned = _time_topk(index, queries, k, "pruned")
        topk_rows.append({
            "k": k,
            "qps_dense_topk": round(nq / dt_dense, 2),
            "qps_pruned_topk": round(nq / dt_pruned, 2),
            "speedup": round(dt_dense / dt_pruned, 3),
            "stages_ms": _topk_stage_splits(index, queries, k),
            "parity": True,
        })

    write_csv("planner.csv", rows)
    write_csv("planner_topk.csv", topk_rows)
    print(f"  postings: {post_b} B compressed vs {flat_b} B flat "
          f"({postings_info['compression_vs_flat']}×), "
          f"{postings_info['postings_ratio']}× sketch bytes")

    failures = []
    if postings_info["postings_ratio"] > MAX_POSTINGS_RATIO:
        failures.append(
            f"compressed postings are {postings_info['postings_ratio']}× "
            f"the packed sketch bytes (cap {MAX_POSTINGS_RATIO}): "
            f"{post_b} B vs {sketch_b} B")
    if baseline and os.path.exists(baseline):
        failures += check_baseline(rows, baseline, backend)
        failures += check_topk_baseline(topk_rows, baseline, backend)

    if json_out:
        # Carry other backends' committed rows forward so the artifact
        # keeps one same-backend baseline per CI matrix cell.
        by_backend, topk_by_backend = {}, {}
        if os.path.exists(json_out):
            try:
                with open(json_out) as f:
                    prev = json.load(f)
                by_backend = dict(prev.get("rows_by_backend", {}))
                topk_by_backend = dict(prev.get("topk_rows_by_backend", {}))
            except (json.JSONDecodeError, OSError):
                by_backend, topk_by_backend = {}, {}
        by_backend[backend] = rows
        topk_by_backend[backend] = topk_rows
        payload = {
            "suite": "planner",
            "profile": "quick" if quick else "full",
            "workload": {
                "generator": "zipf", "m": m, "n_elems": n_elems,
                "alpha_freq": 0.8, "alpha_size": 1.0, "budget": budget,
                "n_queries": nq, "batch": BATCH, "engine": "gbkmv",
                "backend": backend,
            },
            "postings": postings_info,
            "rows": rows,
            "rows_by_backend": by_backend,
            "topk_rows": topk_rows,
            "topk_rows_by_backend": topk_by_backend,
        }
        if calibrate:
            from repro.core import cost_model

            # Probe hits do not vary with threshold, so the main rows
            # alone cannot separate fixed from per-hit cost. Add
            # calibration-only measurements at truncated query sizes
            # (fewer retained hashes → genuinely different hit counts).
            cal_rows = list(rows)
            for frac in (0.25, 0.5):
                qsub = [np.asarray(q)[: max(2, int(len(q) * frac))]
                        for q in queries]
                bsub = _batches(qsub)
                dt = _time_path(index, bsub, 0.7, "pruned")
                qp_sub = index._query_pack(qsub)
                h_sub, b_sub, _ = unpack_query_rows(qp_sub)
                per = probe_hits_per_query(post, h_sub, b_sub)
                cal_rows.append({
                    "qps_pruned": nq / dt,
                    "mean_probe_hits": float(per.mean()),
                })
            payload["calibration"] = cost_model.fit_query_constants(
                cal_rows, m, index._sketch_pack().capacity)
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise RuntimeError(
            "planner gates failed (QPS baseline / postings-bytes cap):\n  "
            + "\n  ".join(failures))
    return rows
