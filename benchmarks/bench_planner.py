"""Planner bench: dense index sweep vs postings-pruned filter-and-verify.

QPS and candidate-set sizes at thresholds {0.5, 0.7, 0.9} on the Zipf
workload (the Fig. 16 generator) — the start of the perf trajectory for
the candidate-pruning query planner. Parity between the two paths is
asserted on every batch: a mismatch raises (and fails the CI smoke
step), because the planner's whole contract is bit-identical results.

``run(quick, json_out=...)`` additionally writes a machine-readable
summary (BENCH_PLANNER.json at the repo root via ``benchmarks.run
--suite planner --json``).
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import write_csv
from repro import api
from repro.data.synth import generate_dataset, make_query_workload

THRESHOLDS = (0.5, 0.7, 0.9)
BATCH = 16


def _batches(queries):
    return [queries[i : i + BATCH] for i in range(0, len(queries), BATCH)]


def _time_path(index, batches, threshold, plan) -> float:
    """Seconds for one pass over the workload (after a warmup pass)."""
    for b in batches:                      # warmup: jit caches, postings
        index.batch_query(b, threshold, plan=plan)
    t0 = time.perf_counter()
    for b in batches:
        index.batch_query(b, threshold, plan=plan)
    return time.perf_counter() - t0


def run(quick: bool = True, json_out: str | None = None):
    m = 4000 if quick else 20_000
    n_elems = 20_000 if quick else 100_000
    nq = 64 if quick else 256
    recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=400, seed=5)
    total = sum(len(r) for r in recs)
    budget = int(total * 0.1)
    index = api.get_engine("gbkmv").build(recs, budget, backend="jnp")
    queries = make_query_workload(recs, nq, seed=2)
    batches = _batches(queries)

    rows = []
    for t in THRESHOLDS:
        dense = index.batch_query(queries, t, plan="dense")
        pruned = index.batch_query(queries, t, plan="pruned")
        for j, (d, p) in enumerate(zip(dense, pruned)):
            if not np.array_equal(d, p):
                raise RuntimeError(
                    f"planner parity broken at t={t}, query {j}: "
                    f"dense={d.tolist()} pruned={p.tolist()}")
        cand_sizes = []
        for b in batches:
            index.batch_query(b, t, plan="pruned")
            cand_sizes.extend(index.last_candidate_sizes or [])
        dt_dense = _time_path(index, batches, t, "dense")
        dt_pruned = _time_path(index, batches, t, "pruned")
        rows.append({
            "threshold": t,
            "qps_dense": round(nq / dt_dense, 2),
            "qps_pruned": round(nq / dt_pruned, 2),
            "speedup": round(dt_dense / dt_pruned, 3),
            "mean_candidates": round(float(np.mean(cand_sizes)), 2),
            "candidate_frac": round(float(np.mean(cand_sizes)) / m, 5),
            "mean_hits": float(np.mean([len(d) for d in dense])),
            "parity": True,
        })

    write_csv("planner.csv", rows)
    if json_out:
        payload = {
            "suite": "planner",
            "profile": "quick" if quick else "full",
            "workload": {
                "generator": "zipf", "m": m, "n_elems": n_elems,
                "alpha_freq": 0.8, "alpha_size": 1.0, "budget": budget,
                "n_queries": nq, "batch": BATCH, "engine": "gbkmv",
                "backend": "jnp",
            },
            "rows": rows,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows
