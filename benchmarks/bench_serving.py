"""Serving bench: zipfian open-loop load against the HTTP service layer.

Boots the real stack in-process — GB-KMV index → ShardedIndex →
AsyncSketchServer (bounded admission, async flush loop) → ServiceApp →
ThreadingHTTPServer — and drives it with an open-loop Poisson arrival
process of mixed /query, /topk, and streamed /ingest traffic from
``USERS`` (≥100k) simulated users whose activity is zipf-distributed
(so query traffic over records is zipfian, the paper's workload skew).

Latency is measured from each request's *scheduled* arrival (wrk2-style,
immune to coordinated omission: if the client pool falls behind, the
backlog counts). Reported: p50/p99/p999, achieved QPS, shed rate (429s),
deadline-expired rate, mean flush occupancy — plus a **parity phase**
asserting the HTTP path answers bit-identically to direct
``batch_query``/``topk`` on the same index (the serving layer may never
change results), and a direct-path QPS reference used to normalize the
committed-baseline gates across machine speeds.

``run(quick, json_out=..., baseline=...)``: with ``baseline`` the run
FAILS on parity breakage, on QPS dropping below
``QPS_TOLERANCE`` × baseline (direct-QPS-ratio normalized, capped at the
offered rate), on p99 inflating past ``P99_TOLERANCE`` × baseline
(same normalization), or on shed rate exceeding ``MAX_SHED_RATE``.

The observability tax is measured and gated every run: a paired
serve_batch comparison with tracing + stage profiling attached vs the
default no-op path must cost ≤ ``TRACING_OVERHEAD_CAP`` of QPS (the
"off is free, on is cheap" contract from docs/OBSERVABILITY.md). The
load phase itself runs with a live tracer, and the resulting request
traces are exported as a Chrome trace-event artifact
(``reports/bench/serving_trace.json`` — load in chrome://tracing).

The durability tax is gated the same way: a paired ingest comparison
through the mutation lane with a WAL at ``fsync="batch"`` (group
commit) vs no data dir must cost ≤ ``DURABILITY_OVERHEAD_CAP`` of
ingest throughput — crash-safe acks are supposed to ride the existing
batch cadence, not halve it (docs/SERVING.md §Durability).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import REPORT_DIR, write_csv
from repro import api
from repro.data.synth import generate_dataset, make_query_workload
from repro.launch.mesh import make_mesh
from repro.obs import StageProfiler, Tracer, attach
from repro.sketchindex import ShardedIndex
from repro.service import (
    AsyncSketchServer, Durability, ServiceApp, ServiceClient, ServiceError,
    ServiceHandle)

USERS = 100_000            # simulated user population (both profiles)
AUTH_TOKEN = "bench-serving-token"
QPS_TOLERANCE = 0.6        # achieved QPS ≥ 0.6 × normalized baseline
P99_TOLERANCE = 2.5        # p99 ≤ 2.5 × normalized baseline
MAX_SHED_RATE = 0.05       # the un-overloaded profile must not shed
TRACING_OVERHEAD_CAP = 0.05   # tracing+profiling may cost ≤ 5% of QPS
DURABILITY_OVERHEAD_CAP = 0.10  # WAL fsync="batch" may cost ≤ 10% ingest


def _zipf_ranks(n: int, alpha: float, size: int,
                rng: np.random.Generator) -> np.ndarray:
    """``size`` draws over ranks 0..n-1 with zipf(alpha) popularity."""
    w = np.arange(1, n + 1, dtype=np.float64) ** (-alpha)
    cdf = np.cumsum(w / w.sum())
    return np.searchsorted(cdf, rng.random(size), side="left")


def _build_workload(recs, n_req: int, rate: float, mix, rng):
    """Open-loop schedule: (t_send, kind, payload) sorted by send time.

    Each simulated user owns a favorite record; per-request the *user* is
    drawn zipf(1.05) over the 100k-user population, so the induced query
    stream over records is zipfian without any per-record bookkeeping.
    """
    m = len(recs)
    user_pref = rng.integers(0, m, USERS)
    users = _zipf_ranks(USERS, 1.05, n_req, rng)
    kinds = rng.choice(["query", "topk", "ingest"], size=n_req,
                       p=[mix["query"], mix["topk"], mix["ingest"]])
    t_send = np.cumsum(rng.exponential(1.0 / rate, n_req))
    ops = []
    for i in range(n_req):
        kind = str(kinds[i])
        if kind == "ingest":
            payload = [rng.integers(0, 10_000, rng.integers(8, 24))
                       for _ in range(2)]
        else:
            payload = recs[user_pref[users[i]]]
        ops.append((float(t_send[i]), kind, payload))
    return ops


def _drive(address, ops, n_workers: int):
    """Fire the schedule open-loop from a worker pool; returns per-request
    (kind, status, latency_from_scheduled_send)."""
    host, port = address
    results = [None] * len(ops)
    cursor = [0]
    lock = threading.Lock()
    t0 = time.perf_counter() + 0.05        # small lead so op 0 isn't late

    def worker():
        cli = ServiceClient(host, port, token=AUTH_TOKEN)
        while True:
            with lock:
                i = cursor[0]
                if i >= len(ops):
                    break
                cursor[0] += 1
            t_send, kind, payload = ops[i]
            delay = (t0 + t_send) - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            status = 200
            try:
                if kind == "query":
                    cli.query(payload, 0.5)
                elif kind == "topk":
                    cli.topk(payload, 10)
                else:
                    cli.ingest(payload)
            except ServiceError as e:
                status = e.status
            except (ConnectionError, OSError):
                status = -1
            results[i] = (kind, status,
                          time.perf_counter() - (t0 + t_send))
        cli.close()

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def _percentiles(lat_s: np.ndarray) -> dict:
    if lat_s.size == 0:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "p999_ms": 0.0}
    return {"p50_ms": round(float(np.percentile(lat_s, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat_s, 99)) * 1e3, 3),
            "p999_ms": round(float(np.percentile(lat_s, 99.9)) * 1e3, 3)}


def _parity_check(sharded, address, queries, threshold=0.5, k=10):
    """HTTP answers must be bit-identical to the direct protocol calls."""
    host, port = address
    cli = ServiceClient(host, port, token=AUTH_TOKEN)
    direct_hits = sharded.batch_query(queries, threshold)
    for j, q in enumerate(queries):
        got = cli.query(q, threshold)
        if not np.array_equal(got, direct_hits[j]):
            raise RuntimeError(
                f"serving parity broken (query {j}): http={got.tolist()} "
                f"direct={direct_hits[j].tolist()}")
        ids, scores = cli.topk(q, k)
        d_ids, d_scores = sharded.topk(q, k)
        if not (np.array_equal(ids, d_ids)
                and np.array_equal(scores, d_scores.astype(np.float32))):
            raise RuntimeError(
                f"serving topk parity broken (query {j}): "
                f"http=({ids.tolist()}, {scores.tolist()}) "
                f"direct=({d_ids.tolist()}, {d_scores.tolist()})")
    cli.close()
    return len(queries)


def _tracing_overhead(sharded, queries, batch: int = 16,
                      repeats: int = 5) -> dict:
    """Paired serve_batch throughput with observation off vs on.

    "Off" is the production default: no trace/profiler attached, every
    ``obs.stage`` call hits the shared no-op context. "On" attaches a
    live Tracer + StageProfiler around each pass. Interleaved best-of-N
    so scheduler drift hits both arms equally.
    """
    batches = [queries[i:i + batch] for i in range(0, len(queries), batch)]
    tracer = Tracer(capacity=4)
    prof = StageProfiler()

    def pass_off():
        for b in batches:
            sharded.serve_batch(b, 0.5, 10)

    def pass_on():
        tr = tracer.begin("bench_pass")
        with attach(tr, prof):
            for b in batches:
                sharded.serve_batch(b, 0.5, 10)
        tr.end()

    pass_off(), pass_on()                   # warm both arms
    best_off = best_on = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        pass_off()
        best_off = min(best_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        pass_on()
        best_on = min(best_on, time.perf_counter() - t0)
    qps_off = len(queries) / best_off
    qps_on = len(queries) / best_on
    return {"qps_off": round(qps_off, 2), "qps_on": round(qps_on, 2),
            "overhead_frac": round(max(0.0, 1.0 - qps_on / qps_off), 4)}


def _durability_tax(backend: str, groups: int = 2, group_size: int = 8,
                    chunk: int = 8, repeats: int = 5) -> dict:
    """Paired ingest throughput through the mutation lane with the WAL
    on (``fsync="batch"``, i.e. the group-commit production default) vs
    no data dir at all — the durability tax an operator pays for
    crash-safe acks. Same deterministic step-driven schedule on two
    fresh servers over identical indexes; interleaved best-of-N so
    scheduler drift hits both arms equally."""
    recs = generate_dataset(300, 5000, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=100, seed=9)
    total = sum(len(r) for r in recs)
    mesh = make_mesh((1, 1), ("data", "model"))
    tmp = tempfile.mkdtemp(prefix="bench_serving_wal_")

    def make_server(data_dir):
        index = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                              backend=backend)
        sharded = ShardedIndex(index, mesh, backend=backend)
        dur = (Durability(data_dir, fsync="batch")
               if data_dir is not None else None)
        return AsyncSketchServer(sharded, max_batch=group_size, max_wait=0.0,
                                 profile=False, durability=dur)

    try:
        srv_off = make_server(None)
        srv_on = make_server(os.path.join(tmp, "data"))
        rng = np.random.default_rng(7)
        batches = [[rng.integers(0, 10_000, 16) for _ in range(chunk)]
                   for _ in range(groups * group_size)]

        def one_pass(srv):
            # group_size ingests per step → one group-commit fsync each.
            for g in range(0, len(batches), group_size):
                for b in batches[g:g + group_size]:
                    srv.submit_ingest(b)
                srv.step(force=True)

        one_pass(srv_off), one_pass(srv_on)     # warm both arms
        best_off = best_on = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            one_pass(srv_off)
            best_off = min(best_off, time.perf_counter() - t0)
            t0 = time.perf_counter()
            one_pass(srv_on)
            best_on = min(best_on, time.perf_counter() - t0)
        n_records = len(batches) * chunk
        rps_off = n_records / best_off
        rps_on = n_records / best_on
        wal = srv_on.durability.wal
        return {"fsync": "batch",
                "ingest_rps_off": round(rps_off, 2),
                "ingest_rps_on": round(rps_on, 2),
                "overhead_frac": round(max(0.0, 1.0 - rps_on / rps_off), 4),
                "fsyncs_per_pass": groups,
                "wal_nbytes": int(wal.nbytes())}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _direct_qps(sharded, queries, batch: int = 16, repeats: int = 3) -> float:
    """Reference throughput of the same workload through serve_batch
    directly (no HTTP, no batcher) — the machine-speed normalizer."""
    batches = [queries[i:i + batch] for i in range(0, len(queries), batch)]
    for b in batches:
        sharded.serve_batch(b, 0.5, 10)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for b in batches:
            sharded.serve_batch(b, 0.5, 10)
        best = min(best, time.perf_counter() - t0)
    return len(queries) / best


def check_baseline(row, base: dict, direct_qps: float) -> list[str]:
    b = base.get("rows", [{}])[0]
    if not b:
        return []
    failures = []
    # Machine normalization: scale by the direct-path QPS ratio, but an
    # open-loop run can never beat its offered rate, so cap the scaled
    # floor there.
    scale = direct_qps / max(base.get("direct_qps", direct_qps), 1e-9)
    qps_floor = min(QPS_TOLERANCE * b.get("qps", 0) * scale,
                    QPS_TOLERANCE * row["offered_rps"])
    if row["qps"] < qps_floor:
        failures.append(
            f"QPS {row['qps']:.1f} < floor {qps_floor:.1f} "
            f"(baseline {b.get('qps', 0):.1f} × scale {scale:.2f} × "
            f"{QPS_TOLERANCE})")
    p99_cap = P99_TOLERANCE * b.get("p99_ms", np.inf) / min(scale, 1.0)
    if row["p99_ms"] > p99_cap:
        failures.append(
            f"p99 {row['p99_ms']:.1f}ms > cap {p99_cap:.1f}ms "
            f"(baseline {b.get('p99_ms', 0):.1f}ms, scale {scale:.2f})")
    if row["shed_rate"] > MAX_SHED_RATE:
        failures.append(
            f"shed rate {row['shed_rate']:.3f} > {MAX_SHED_RATE} at an "
            f"offered rate the service is provisioned for")
    return failures


def run(quick: bool = True, json_out: str | None = None,
        baseline: str | None = None, backend: str = "jnp"):
    m = 1500 if quick else 12_000
    n_elems = 10_000 if quick else 100_000
    rate_cap = 150.0 if quick else 400.0
    duration = 8.0 if quick else 15.0
    n_workers = 16 if quick else 48
    mix = {"query": 0.86, "topk": 0.12, "ingest": 0.02}
    rng = np.random.default_rng(11)

    recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=1.0,
                            size_min=10, size_max=200, seed=5)
    total = sum(len(r) for r in recs)
    index = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                          backend=backend)
    mesh = make_mesh((1, 1), ("data", "model"))
    sharded = ShardedIndex(index, mesh, backend=backend)
    parity_queries = make_query_workload(recs, 24, seed=3)

    # Size the open-loop arrival rate to THIS machine: 70% of the
    # direct-path throughput keeps the un-overloaded profile honest
    # (queueing delay visible, shed rate ~0) on any hardware. The
    # measured reference doubles as the baseline-gate normalizer.
    direct = _direct_qps(sharded, parity_queries)
    rate = float(np.clip(0.7 * direct, 4.0, rate_cap))

    tracing = _tracing_overhead(sharded, parity_queries)
    durability = _durability_tax(backend)

    server = AsyncSketchServer(sharded, max_batch=16, max_wait=0.003,
                               max_inflight=512, default_deadline=1.0,
                               tracer=Tracer(capacity=128))
    app = ServiceApp(server, auth_token=AUTH_TOKEN, ingest_chunk=256)

    n_req = int(rate * duration)
    ops = _build_workload(recs, n_req, rate, mix, rng)

    with ServiceHandle(app) as handle:
        # Warm every kind once so jit compilation is not inside the
        # measured window (a production server is warm).
        cli = ServiceClient(*handle.address, token=AUTH_TOKEN)
        cli.healthz()
        cli.query(recs[0], 0.5)
        cli.topk(recs[0], 10)
        cli.ingest([np.arange(5)])
        cli.close()

        t0 = time.perf_counter()
        results = _drive(handle.address, ops, n_workers)
        wall = time.perf_counter() - t0

        par_n = _parity_check(sharded, handle.address, parity_queries)
        metrics_text = ServiceClient(
            *handle.address, token=AUTH_TOKEN).metrics_text()

    ok = [r for r in results if r is not None and r[1] == 200]
    shed = sum(1 for r in results if r is not None and r[1] == 429)
    errs = sum(1 for r in results if r is None or r[1] not in (200, 429))
    lat = np.asarray([r[2] for r in ok])
    stats = server.stats
    row = {
        "users": USERS,
        "offered_rps": round(rate, 1),
        "duration_s": round(duration, 1),
        "requests": n_req,
        "completed": len(ok),
        "qps": round(len(ok) / wall, 2),
        **_percentiles(lat),
        "shed_rate": round(shed / max(n_req, 1), 4),
        "error_rate": round(errs / max(n_req, 1), 4),
        "expired_rate": round(server.expired_served / max(len(ok), 1), 4),
        "mean_batch": round(stats.mean_batch, 2),
        "flushes_full": stats.flushes_full,
        "flushes_deadline": stats.flushes_deadline,
        "flushes_expired": stats.flushes_expired,
        "records_ingested": server.records_ingested,
        "parity_queries": par_n,
        "parity": True,
    }
    by_kind = {}
    for kind in ("query", "topk", "ingest"):
        ls = np.asarray([r[2] for r in ok if r[0] == kind])
        if ls.size:
            by_kind[kind] = {"n": int(ls.size), **_percentiles(ls)}

    write_csv("serving.csv", [row])
    print(f"  parity: {par_n} queries bit-identical over HTTP "
          f"(query + topk); direct-path reference {direct:.0f} q/s")
    print(f"  tracing tax: {tracing['overhead_frac']:.1%} "
          f"({tracing['qps_off']:.0f} → {tracing['qps_on']:.0f} q/s with "
          f"trace+profile attached; cap {TRACING_OVERHEAD_CAP:.0%})")
    print(f"  durability tax: {durability['overhead_frac']:.1%} "
          f"({durability['ingest_rps_off']:.0f} → "
          f"{durability['ingest_rps_on']:.0f} rec/s with the WAL at "
          f"fsync=batch; cap {DURABILITY_OVERHEAD_CAP:.0%})")

    # Request traces from the load phase → Chrome trace-event artifact.
    chrome = server.tracer.chrome_trace()
    os.makedirs(REPORT_DIR, exist_ok=True)
    trace_path = os.path.join(REPORT_DIR, "serving_trace.json")
    with open(trace_path, "w") as f:
        json.dump(chrome, f)
    print(f"  {len(chrome['traceEvents'])} trace events → {trace_path}")

    failures = []
    if tracing["overhead_frac"] > TRACING_OVERHEAD_CAP:
        failures.append(
            f"tracing overhead {tracing['overhead_frac']:.1%} > cap "
            f"{TRACING_OVERHEAD_CAP:.0%} ({tracing['qps_off']:.1f} q/s off "
            f"vs {tracing['qps_on']:.1f} q/s on)")
    if durability["overhead_frac"] > DURABILITY_OVERHEAD_CAP:
        failures.append(
            f"durability tax {durability['overhead_frac']:.1%} > cap "
            f"{DURABILITY_OVERHEAD_CAP:.0%} "
            f"({durability['ingest_rps_off']:.1f} rec/s without the WAL "
            f"vs {durability['ingest_rps_on']:.1f} rec/s at fsync=batch)")
    if baseline and os.path.exists(baseline):
        with open(baseline) as f:
            failures += check_baseline(row, json.load(f), direct)

    if json_out:
        payload = {
            "suite": "serving",
            "profile": "quick" if quick else "full",
            "workload": {
                "generator": "zipf", "m": m, "n_elems": n_elems,
                "users": USERS, "user_alpha": 1.05, "rate_rps": rate,
                "duration_s": duration, "mix": mix, "workers": n_workers,
                "engine": "gbkmv", "backend": backend,
            },
            "service": {
                "max_batch": 16, "max_wait_s": 0.003, "max_inflight": 512,
                "default_deadline_s": 1.0, "ingest_chunk": 256,
            },
            "direct_qps": round(direct, 2),
            "tracing": tracing,
            "durability": durability,
            "rows": [row],
            "by_kind": by_kind,
            "metrics_sample": [ln for ln in metrics_text.splitlines()
                               if not ln.startswith("#")][:40],
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise RuntimeError(
            "serving gates failed (QPS / p99 / shed / tracing tax):\n  "
            + "\n  ".join(failures))
    return [row]
