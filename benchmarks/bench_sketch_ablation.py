"""Fig. 6 — KMV vs G-KMV vs GB-KMV at the same space budget, all 7
Table-II dataset stand-ins. The global threshold (G) and the frequent-
element buffer (B) must each add accuracy."""

from __future__ import annotations

from benchmarks.common import (
    evaluate, gbkmv_engine, kmv_engine, load_dataset, queries_for, write_csv)

DATASETS = ("NETFLIX", "DELIC", "COD", "ENRON", "REUTERS", "WEBSPAM", "WDC")


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    nq = 25 if quick else 100
    for ds in DATASETS:
        recs, exact_index, total = load_dataset(ds, scale)
        budget = int(total * 0.1)
        queries = queries_for(recs, nq)
        engines = {
            "KMV": kmv_engine(recs, budget)[0],
            "G-KMV": gbkmv_engine(recs, budget, r=0)[0],
            "GB-KMV": gbkmv_engine(recs, budget, r="auto")[0],
        }
        for name, fn in engines.items():
            res = evaluate(fn, exact_index, queries, 0.5)
            rows.append({"dataset": ds, "engine": name,
                         "f1": round(res["f"], 4),
                         "precision": round(res["precision"], 4),
                         "recall": round(res["recall"], 4)})
    write_csv("fig6_sketch_ablation.csv", rows)
    return rows
