"""Fig. 10-13 — accuracy (F1 and F0.5) versus index space, GB-KMV vs
LSH-E. GB-KMV varies the slot budget; LSH-E varies the MinHash count."""

from __future__ import annotations

from benchmarks.common import (
    evaluate, gbkmv_engine, load_dataset, lshe_engine, queries_for, write_csv)

DATASETS = ("NETFLIX", "DELIC", "ENRON", "WDC")


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    nq = 25 if quick else 100
    for ds in DATASETS:
        recs, exact_index, total = load_dataset(ds, scale)
        queries = queries_for(recs, nq)
        for frac in (0.025, 0.05, 0.1, 0.2):
            fn, nbytes = gbkmv_engine(recs, int(total * frac))
            res = evaluate(fn, exact_index, queries, 0.5)
            res05 = evaluate(fn, exact_index, queries, 0.5, alpha=0.5)
            rows.append({"dataset": ds, "engine": "GB-KMV",
                         "space_frac": round(nbytes / (total * 4), 4),
                         "f1": round(res["f"], 4),
                         "f05": round(res05["f"], 4),
                         "precision": round(res["precision"], 4),
                         "recall": round(res["recall"], 4)})
        for k in ((32, 64, 128) if quick else (32, 64, 128, 256)):
            fn, nbytes = lshe_engine(recs, num_hashes=k)
            res = evaluate(fn, exact_index, queries, 0.5)
            res05 = evaluate(fn, exact_index, queries, 0.5, alpha=0.5)
            rows.append({"dataset": ds, "engine": f"LSH-E(k={k})",
                         "space_frac": round(nbytes / (total * 4), 4),
                         "f1": round(res["f"], 4),
                         "f05": round(res05["f"], 4),
                         "precision": round(res["precision"], 4),
                         "recall": round(res["recall"], 4)})
    write_csv("fig10_13_space_accuracy.csv", rows)
    return rows
