"""Fig. 15 — F1 versus containment threshold t* (NETFLIX & COD)."""

from __future__ import annotations

from benchmarks.common import (
    evaluate, gbkmv_engine, load_dataset, lshe_engine, queries_for, write_csv)


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    nq = 25 if quick else 100
    for ds in ("NETFLIX", "COD"):
        recs, exact_index, total = load_dataset(ds, scale)
        queries = queries_for(recs, nq)
        gb, _ = gbkmv_engine(recs, int(total * 0.1))
        le, _ = lshe_engine(recs, num_hashes=128 if quick else 256)
        for t in (0.5, 0.6, 0.7, 0.8, 0.9):
            for name, fn in (("GB-KMV", gb), ("LSH-E", le)):
                res = evaluate(fn, exact_index, queries, t)
                rows.append({"dataset": ds, "engine": name, "threshold": t,
                             "f1": round(res["f"], 4),
                             "precision": round(res["precision"], 4),
                             "recall": round(res["recall"], 4)})
    write_csv("fig15_threshold.csv", rows)
    return rows
