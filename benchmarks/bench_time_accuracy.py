"""Fig. 17 — time-accuracy trade-off: GB-KMV (vary budget) vs LSH-E (vary
hash count). The paper's headline: ≥100× faster at equal F1 on several
datasets — here we report the measured per-query latency next to F1."""

from __future__ import annotations

from benchmarks.common import (
    evaluate, gbkmv_engine, load_dataset, lshe_engine, queries_for, write_csv)

DATASETS = ("COD", "NETFLIX", "DELIC", "ENRON")


def run(quick: bool = True):
    rows = []
    scale = 0.12 if quick else 0.5
    nq = 20 if quick else 80
    for ds in DATASETS:
        recs, exact_index, total = load_dataset(ds, scale)
        queries = queries_for(recs, nq)
        for frac in (0.05, 0.1, 0.2):
            fn, _ = gbkmv_engine(recs, int(total * frac))
            res = evaluate(fn, exact_index, queries, 0.5)
            rows.append({"dataset": ds, "engine": "GB-KMV",
                         "knob": f"budget={frac}",
                         "f1": round(res["f"], 4),
                         "query_ms": round(res["query_s"] * 1e3, 2)})
        for k in ((32, 128) if quick else (32, 128, 256)):
            fn, _ = lshe_engine(recs, num_hashes=k)
            res = evaluate(fn, exact_index, queries, 0.5)
            rows.append({"dataset": ds, "engine": "LSH-E",
                         "knob": f"hashes={k}",
                         "f1": round(res["f"], 4),
                         "query_ms": round(res["query_s"] * 1e3, 2)})
    write_csv("fig17_time_accuracy.csv", rows)
    return rows
