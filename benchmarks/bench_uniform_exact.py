"""Fig. 19 — (a) uniform-distribution data (α1=α2=0): GB-KMV must still
beat LSH-E (Theorem 5's uniform case); (b) approximate GB-KMV vs the two
exact engines (posting-count 'FreqSet' and PPjoin*-adapted prefix filter)
by record-size group."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    evaluate, gbkmv_engine, lshe_engine, write_csv)
from repro import api
from repro.data.synth import generate_dataset, make_query_workload


def run(quick: bool = True):
    rows = []
    # (a) uniform data
    m = 800 if quick else 5000
    recs = generate_dataset(m, 20_000 if quick else 100_000,
                            alpha_freq=0.0, alpha_size=0.0,
                            size_min=10, size_max=400, seed=5)
    exact_index = api.get_engine("exact").build(recs)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, 20 if quick else 80)
    for name, (fn, _) in {
        "GB-KMV": gbkmv_engine(recs, int(total * 0.1)),
        "LSH-E": lshe_engine(recs, num_hashes=128 if quick else 256),
    }.items():
        res = evaluate(fn, exact_index, queries, 0.5)
        rows.append({"part": "a_uniform", "engine": name, "size_group": "-",
                     "f1": round(res["f"], 4),
                     "query_ms": round(res["query_s"] * 1e3, 2)})

    # (b) vs exact engines, grouped by record size (WEBSPAM-like)
    for size_max in (500, 1000, 2000) if quick else (1000, 2000, 3000, 4000, 5000):
        recs = generate_dataset(300 if quick else 2000, 40_000,
                                alpha_freq=1.33, alpha_size=9.34,
                                size_min=max(size_max // 5, 20),
                                size_max=size_max, seed=6)
        exact_index = api.get_engine("exact").build(recs)
        total = sum(len(r) for r in recs)
        queries = make_query_workload(recs, 10 if quick else 40)
        fn, _ = gbkmv_engine(recs, int(total * 0.1))
        res = evaluate(fn, exact_index, queries, 0.5)
        rows.append({"part": "b_vs_exact", "engine": "GB-KMV",
                     "size_group": size_max, "f1": round(res["f"], 4),
                     "query_ms": round(res["query_s"] * 1e3, 2)})
        for name, eng in (("FreqSet", "exact"), ("PPjoin*", "prefix")):
            # Reuse the inverted index already built for ground truth.
            fn_exact = api.get_engine(eng).wrap(exact_index.core).query
            t0 = time.time()
            for q in queries:
                fn_exact(q, 0.5)
            dt = (time.time() - t0) / len(queries)
            rows.append({"part": "b_vs_exact", "engine": name,
                         "size_group": size_max, "f1": 1.0,
                         "query_ms": round(dt * 1e3, 2)})
    write_csv("fig19_uniform_exact.csv", rows)
    return rows
