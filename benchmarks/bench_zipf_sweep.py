"""Fig. 16 — synthetic zipf skew sweeps: element-frequency z-value 0.4→1.2
at record-size z 1.0; record-size z 0.8→1.4 at element z 0.8."""

from __future__ import annotations

from benchmarks.common import evaluate, gbkmv_engine, lshe_engine, write_csv
from repro import api
from repro.data.synth import generate_dataset, make_query_workload


def _eval_pair(recs, nq, quick):
    exact_index = api.get_engine("exact").build(recs)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, nq)
    gb, _ = gbkmv_engine(recs, int(total * 0.1))
    le, _ = lshe_engine(recs, num_hashes=128 if quick else 256)
    return {name: evaluate(fn, exact_index, queries, 0.5)
            for name, fn in (("GB-KMV", gb), ("LSH-E", le))}


def run(quick: bool = True):
    rows = []
    m = 800 if quick else 5000
    n_elems = 20_000 if quick else 100_000
    nq = 20 if quick else 80
    for a1 in (0.4, 0.8, 1.2):
        recs = generate_dataset(m, n_elems, alpha_freq=a1, alpha_size=1.0,
                                size_min=10, size_max=400, seed=3)
        for name, res in _eval_pair(recs, nq, quick).items():
            rows.append({"sweep": "eleFreq", "z": a1, "engine": name,
                         "f1": round(res["f"], 4)})
    for a2 in (0.8, 1.1, 1.4):
        recs = generate_dataset(m, n_elems, alpha_freq=0.8, alpha_size=a2,
                                size_min=10, size_max=400, seed=4)
        for name, res in _eval_pair(recs, nq, quick).items():
            rows.append({"sweep": "recSize", "z": a2, "engine": name,
                         "f1": round(res["f"], 4)})
    write_csv("fig16_zipf_sweep.csv", rows)
    return rows
