"""Shared benchmark harness: dataset setup, engine adapters, CSV output.

Every bench_*.py module exposes ``run(quick: bool) -> list[dict]`` and
writes a CSV under reports/bench/. ``benchmarks.run`` orchestrates.
"""

from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro import api
from repro.core.search import f_score, precision_recall
from repro.data import datasets, synth

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "bench")


def write_csv(name: str, rows: list[dict]):
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def load_dataset(name: str, scale: float):
    recs = datasets.load(name, scale=scale)
    return recs, api.get_engine("exact").build(recs), sum(len(r) for r in recs)


def queries_for(recs, n, seed=0):
    return synth.make_query_workload(recs, n, seed=seed)


# ---------------------------------------------------------------------------
# engine adapters over repro.api: search(q_ids, threshold) -> candidate ids
# ---------------------------------------------------------------------------

def make_engine(name, recs, budget=None, **cfg):
    """Any registered engine → (search fn, nbytes) benchmark adapter."""
    index = api.get_engine(name).build(recs, budget, **cfg)
    return index.query, index.nbytes()


def gbkmv_engine(recs, budget, r="auto", seed=0, backend="jnp"):
    return make_engine("gbkmv", recs, budget, r=r, seed=seed, backend=backend)


def kmv_engine(recs, budget, seed=0):
    """Plain KMV (Theorem 1 equal allocation, Eq. 8-10 pair estimator)."""
    return make_engine("kmv", recs, budget, seed=seed)


def lshe_engine(recs, num_hashes=256, num_partitions=32, seed=0):
    return make_engine("lshe", recs, num_hashes=num_hashes,
                       num_partitions=num_partitions, seed=seed)


def evaluate(search_fn, exact_index, queries, threshold, alpha=1.0):
    """Mean F_α / precision / recall + per-query latency of an engine."""
    fs, ps, rs = [], [], []
    t0 = time.time()
    for q in queries:
        truth = exact_index.query(q, threshold)
        got = search_fn(q, threshold)
        fs.append(f_score(truth, got, alpha=alpha))
        p, r = precision_recall(truth, got)
        ps.append(p)
        rs.append(r)
    dt = (time.time() - t0) / max(len(queries), 1)
    return {"f": float(np.mean(fs)), "f_min": float(np.min(fs)),
            "f_max": float(np.max(fs)), "precision": float(np.mean(ps)),
            "recall": float(np.mean(rs)), "query_s": dt}
