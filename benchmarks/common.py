"""Shared benchmark harness: dataset setup, engine adapters, CSV output.

Every bench_*.py module exposes ``run(quick: bool) -> list[dict]`` and
writes a CSV under reports/bench/. ``benchmarks.run`` orchestrates.
"""

from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import estimators
from repro.core.exact import build_inverted, exact_search
from repro.core.gbkmv import build_gbkmv
from repro.core.hashing import hash_u32_np
from repro.core.kmv import build_kmv
from repro.core.lshe import build_lshe, query_lshe
from repro.core.search import f_score, precision_recall
from repro.data import datasets, synth

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "bench")


def write_csv(name: str, rows: list[dict]):
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def load_dataset(name: str, scale: float):
    recs = datasets.load(name, scale=scale)
    return recs, build_inverted(recs), sum(len(r) for r in recs)


def queries_for(recs, n, seed=0):
    return synth.make_query_workload(recs, n, seed=seed)


# ---------------------------------------------------------------------------
# engine adapters: search(q_ids, threshold) -> candidate id array
# ---------------------------------------------------------------------------

def gbkmv_engine(recs, budget, r="auto", seed=0):
    index = build_gbkmv(recs, budget=budget, r=r, seed=seed)

    def search(q_ids, threshold):
        from repro.core.gbkmv import search as _s
        return _s(index, q_ids, threshold)

    return search, index.nbytes()


def kmv_engine(recs, budget, seed=0):
    """Plain KMV (Theorem 1 equal allocation, Eq. 8-10 pair estimator)."""
    sk = build_kmv(recs, budget=budget, seed=seed)
    k = sk.capacity

    def search(q_ids, threshold):
        h = np.sort(hash_u32_np(np.asarray(q_ids), seed=seed))[:k]
        import jax.numpy as jnp
        qv = jnp.asarray(np.pad(h, (0, k - len(h)),
                                constant_values=np.uint32(0xFFFFFFFF)))
        d_hat, _, _ = estimators.kmv_pair_estimate(
            qv, jnp.int32(len(h)), jnp.asarray(sk.values), jnp.asarray(sk.lengths))
        scores = np.asarray(d_hat) / max(len(q_ids), 1)
        return np.nonzero(scores >= threshold)[0]

    return search, sk.nbytes()


def lshe_engine(recs, num_hashes=256, num_partitions=32, seed=0):
    index = build_lshe(recs, num_hashes=num_hashes,
                       num_partitions=num_partitions, seed=seed)

    def search(q_ids, threshold):
        return query_lshe(index, q_ids, threshold, seed=seed)

    return search, index.nbytes()


def evaluate(search_fn, exact_index, queries, threshold, alpha=1.0):
    """Mean F_α / precision / recall + per-query latency of an engine."""
    fs, ps, rs = [], [], []
    t0 = time.time()
    for q in queries:
        truth = exact_search(exact_index, q, threshold)
        got = search_fn(q, threshold)
        fs.append(f_score(truth, got, alpha=alpha))
        p, r = precision_recall(truth, got)
        ps.append(p)
        rs.append(r)
    dt = (time.time() - t0) / max(len(queries), 1)
    return {"f": float(np.mean(fs)), "f_min": float(np.min(fs)),
            "f_max": float(np.max(fs)), "precision": float(np.mean(ps)),
            "recall": float(np.mean(rs)), "query_s": dt}
