"""Optimized-HLO parser for roofline reconstruction.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` (while loop) body is costed once regardless of trip count
(verified empirically; see EXPERIMENTS.md §Roofline methodology). This
parser rebuilds true per-step totals:

  1. split the module into computations,
  2. read each while loop's trip count from its condition computation
     (``compare(%iter, %constant(K)), direction=LT``),
  3. propagate call multiplicities entry→leaves (while bodies ×trip,
     fusions/calls ×1 per call site),
  4. weight per-computation dot FLOPs and collective bytes by multiplicity.

Works on the SPMD-partitioned module, so all numbers are per-device.
"""

from __future__ import annotations

import gzip
import re
from collections import defaultdict

_DT = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute", "collective-broadcast")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DT:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT[dt]
    return total


def _result_dims(rhs: str):
    """(dtype, dims list) of the op result (first shape on the rhs)."""
    m = _SHAPE_RE.search(rhs)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def split_computations(text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and not line.startswith("  "):
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and line.strip():
            comps[cur].append(line.strip())
    return comps, entry


def _symbols(lines):
    sym = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            sym[m.group(1)] = m.group(2)
    return sym


def _trip_count(cond_lines) -> int:
    """Trip count from a scan condition: compare(LT) against a constant."""
    sym = _symbols(cond_lines)
    for line in cond_lines:
        m = re.search(r"compare\(%([\w.\-]+),\s*%([\w.\-]+)\).*direction=LT",
                      line)
        if m:
            rhs_def = sym.get(m.group(2), "")
            c = re.search(r"constant\((\d+)\)", rhs_def)
            if c:
                return int(c.group(1))
    # Fallback: largest scalar integer constant in the condition.
    best = 1
    for line in cond_lines:
        c = re.search(r"constant\((\d+)\)", line)
        if c:
            best = max(best, int(c.group(1)))
    return best


def call_multiplicities(comps, entry):
    """(computation -> times executed per step, fusion-internal comps)."""
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    internal: set[str] = set()     # fusion bodies / reducers: no HBM traffic
    for name, lines in comps.items():
        for line in lines:
            wb = (re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", line)
                  or re.search(r"body=%?([\w.\-]+),\s*condition=%?([\w.\-]+)",
                               line))
            if wb:
                a, b = wb.group(1), wb.group(2)
                cond, body = (a, b) if "condition=%" + a in line or \
                    f"condition={a}" in line else (b, a)
                trip = _trip_count(comps.get(cond, []))
                edges[name].append((body, trip))
                edges[name].append((cond, trip + 1))
                continue
            for pat in (r"calls=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)"):
                for callee in re.findall(pat, line):
                    edges[name].append((callee, 1))
                    internal.add(callee)

    # Callees are defined before callers in HLO text, so one pass over
    # names in reverse definition order visits every caller before its
    # callees (the call graph is a DAG).
    mult = defaultdict(float)
    mult[entry] = 1.0
    for name in list(comps.keys())[::-1]:
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        for callee, f in edges.get(name, []):
            mult[callee] += w * f
    return dict(mult), internal


def dot_flops(comps, mult) -> float:
    """Σ over dots: 2 · prod(result) · prod(contracting dims), ×mult."""
    total = 0.0
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        sym = _symbols(lines)
        for line in lines:
            m = _DEF_RE.match(line)
            if not m or " dot(" not in m.group(2):
                continue
            rhs = m.group(2)
            _, rdims = _result_dims(rhs)
            ops = re.search(r"dot\(%([\w.\-]+)", rhs)
            kc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            if not ops or not kc:
                continue
            lhs_def = sym.get(ops.group(1), "")
            _, ldims = _result_dims(lhs_def)
            k = 1
            for ci in kc.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
            n = 1
            for d in rdims:
                n *= d
            total += w * 2.0 * n * k
    return total


def collective_bytes_weighted(comps, mult) -> dict:
    out = {k: 0.0 for k in _COLL}
    out["count_static"] = 0
    out["count_dynamic"] = 0.0
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        for line in lines:
            m = re.search(r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))"
                          r"\s+([\w-]+)\(", line)
            if not m:
                continue
            op = m.group(2)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL and not op.endswith("-done"):
                b = _shape_bytes(m.group(1))
                out[base] += w * b
                out["count_static"] += 1
                out["count_dynamic"] += w
    out["total"] = sum(out[k] for k in _COLL)
    return out


# Ops that do not materialize HBM traffic (or whose traffic is accounted
# elsewhere: while/call bodies count their own internals; loop-carry
# copies are elided by TPU buffer aliasing).
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "iota", "copy",
    "copy-start", "copy-done",
}


_OP_RE = re.compile(r"^((?:\([^)]*\)|\S+))\s+([\w-]+)\((.*)$")


def _parse_def(rhs: str):
    """RHS of '%x = ...' → (result_bytes, opname, operands, rest) or None."""
    m = _OP_RE.match(rhs)
    if not m:
        return None
    shape_part, opname, rest = m.group(1), m.group(2), m.group(3)
    operands = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
    return _shape_bytes(shape_part), opname, operands, rest


# Unary ops that neither move nor combine data — resolved through when
# tracking who really consumes/produces a buffer inside a fusion.
_PASS_THROUGH = {"convert", "bitcast", "reshape", "copy", "transpose"}


def _fusion_io_bytes(comp_lines) -> tuple[dict, float | None]:
    """Effective HBM traffic of a fused computation's boundary.

    Returns (param_idx → effective read bytes, effective write bytes or
    None for "use the call-site result shape"). A parameter consumed only
    by dynamic-slice ops — possibly through convert/bitcast chains —
    reads just the slices (the loop-carry KV-cache pattern); a ROOT that
    resolves to a dynamic-update-slice writes just the update (in-place
    on TPU; CPU XLA's full-buffer f32 round-trip is a backend artifact).
    """
    defs = {}
    param_idx = {}
    uses = defaultdict(list)
    root = None
    for line in comp_lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        p = _parse_def(m.group(2))
        if p is None:
            continue
        name = m.group(1)
        defs[name] = p
        if p[1] == "parameter":
            pidx = re.search(r"parameter\((\d+)\)", m.group(2))
            if pidx:
                param_idx[name] = int(pidx.group(1))
        for pos, a in enumerate(p[2]):
            uses[a].append((name, p[1], pos))
        if line.strip().startswith("ROOT"):
            root = name

    def real_consumers(name, depth=0):
        """(opname, consumer def, operand position) skipping pass-through."""
        out = []
        for cname, cop, pos in uses.get(name, []):
            if cop in _PASS_THROUGH and depth < 8:
                out.extend(real_consumers(cname, depth + 1))
            else:
                out.append((cop, defs[cname], pos))
        return out

    def resolve_producer(name, depth=0):
        while depth < 8 and name in defs and defs[name][1] in _PASS_THROUGH \
                and defs[name][2]:
            name = defs[name][2][0]
            depth += 1
        return name

    eff_params = {}
    for pname, idx in param_idx.items():
        full = defs[pname][0]
        u = real_consumers(pname)
        if u and all(op == "dynamic-slice" and pos == 0 for op, _, pos in u):
            eff_params[idx] = sum(d[0] for _, d, _ in u)
        elif u and all(op == "dynamic-update-slice" and pos == 0
                       for op, _, pos in u):
            # In-place update target: reads nothing beyond the update.
            eff_params[idx] = 0
        else:
            eff_params[idx] = full

    eff_write = None
    if root:
        rname = resolve_producer(root)
        if rname in defs and defs[rname][1] == "dynamic-update-slice":
            ops = defs[rname][2]
            upd = resolve_producer(ops[1]) if len(ops) > 1 else None
            if upd in defs:
                eff_write = float(defs[upd][0])
    return eff_params, eff_write


def bytes_accessed_weighted(comps, mult, internal) -> float:
    """Σ over materialized ops of (result + operand bytes) × multiplicity.

    Fusion-body computations are skipped (their internals never touch
    HBM); the ``fusion(...)`` op at the call site carries the real
    traffic. This mirrors XLA's own per-op bytes-accessed convention but
    re-weighted by while-loop trip counts.
    """
    total = 0.0
    fusion_io_cache: dict[str, tuple] = {}
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0 or name in internal:
            continue
        sym = {}
        parsed = []
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            p = _parse_def(m.group(2))
            if p is None:
                continue
            sym[m.group(1)] = p[0]              # name → result bytes
            parsed.append(p)
        for res_bytes, opname, operands, rest in parsed:
            if opname in _NO_TRAFFIC:
                continue
            if opname == "dynamic-update-slice":
                # In-place on TPU: traffic = write + read of the update
                # slice (operand 1), not the whole buffer.
                upd = sym.get(operands[1], 0) if len(operands) > 1 else 0
                total += w * 2 * upd
                continue
            if opname == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", rest)
                callee = cm.group(1) if cm else None
                if callee and callee in comps:
                    if callee not in fusion_io_cache:
                        fusion_io_cache[callee] = _fusion_io_bytes(comps[callee])
                    eff_params, eff_write = fusion_io_cache[callee]
                    b = eff_write if eff_write is not None else res_bytes
                    for i, a in enumerate(operands):
                        b += eff_params.get(i, sym.get(a, 0))
                    total += w * b
                    continue
            b = res_bytes + sum(sym.get(a, 0) for a in operands)
            total += w * b
    return total


def analyze_hlo_file(path: str) -> dict:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        text = f.read()
    comps, entry = split_computations(text)
    mult, internal = call_multiplicities(comps, entry)
    return {
        "flops_weighted": dot_flops(comps, mult),
        "bytes_weighted": bytes_accessed_weighted(comps, mult, internal),
        "collectives_weighted": collective_bytes_weighted(comps, mult),
        "n_computations": len(comps),
        "n_while": sum(1 for lines in comps.values()
                       for ln in lines if " while(" in ln),
    }
