"""§Roofline: per-(arch × shape) roofline terms from the compiled dry-run.

Methodology (see EXPERIMENTS.md §Roofline):
  * XLA's cost_analysis counts while-loop bodies ONCE (verified); all
    terms here come from benchmarks/hlo_parse.py, which re-weights each
    computation by its true per-step execution count.
  * compute term    = weighted dot FLOPs / 197 TFLOP/s
  * memory term     = weighted bytes accessed / 819 GB/s (fusion-boundary
    convention, loop-carry copies & in-place DUS elided as on TPU; CPU
    f32-convert materialization makes this an upper bound)
  * collective term = weighted collective result bytes / 50 GB/s
  * MODEL_FLOPS     = analytic useful work (6·N_active·D for LM training,
    2·N·D + cache reads for decode, family formulas below); the ratio
    MODEL/HLO exposes remat recompute + replicated-compute waste.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--dryrun reports/dryrun]
Writes reports/roofline.csv and prints the §Roofline table.
"""

from __future__ import annotations

import argparse
import csv
import glob
import json
import os

from benchmarks.hlo_parse import analyze_hlo_file

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS per family (global; divide by chips for per-device)
# ---------------------------------------------------------------------------

def _lm_active_params(cfg) -> float:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    nd, nm, _ = cfg.layer_plan()
    n = 0.0
    n += nd * (attn + 3 * d * (cfg.dense_d_ff or cfg.d_ff))
    if nm:
        m = cfg.moe
        active_ff = 3 * d * m.d_ff * m.top_k
        if m.shared_expert:
            active_ff += 3 * d * m.d_ff
        n += nm * (attn + active_ff + d * m.num_experts)
    n += d * cfg.vocab            # unembed matmul (embed lookup is free)
    return float(n)


def _lm_model_flops(cfg, spec) -> float:
    b, s = spec["batch"], spec["seq"]
    n_act = _lm_active_params(cfg)
    l, hq, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    if spec["kind"] == "train":
        tokens = b * s
        attn = 6.0 * l * b * s * s * hq * hd * 0.5     # fwd+bwd, causal
        return 6.0 * n_act * tokens + attn
    if spec["kind"] == "prefill":
        tokens = b * s
        attn = 2.0 * l * b * s * s * hq * hd * 0.5
        return 2.0 * n_act * tokens + attn
    # decode: one token, full-cache attention
    attn = 4.0 * l * b * s * hq * hd
    return 2.0 * n_act * b + attn


def _gnn_model_flops(cfg, spec) -> float:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    if spec["kind"] == "full":
        n, e = spec["n_nodes"], spec["n_edges"]
        f = sum(2.0 * e * dims[i] + 2.0 * 2.0 * n * dims[i] * dims[i + 1]
                for i in range(cfg.n_layers))
        return 3.0 * f                                  # train: fwd+bwd
    if spec["kind"] == "sampled":
        bn = spec["batch_nodes"]
        f1, f2 = spec["fanout"]
        d = spec["d_feat"]
        h = cfg.d_hidden
        gath = 2.0 * bn * f1 * f2 * d + 2.0 * bn * f1 * d
        mm = 2.0 * 2.0 * (bn + bn * f1) * d * h + 2.0 * 2.0 * bn * h * dims[-1]
        return 3.0 * (gath + mm)
    bsz, n = spec["batch"], spec["n_nodes"]
    f = sum(2.0 * bsz * n * n * dims[i] + 4.0 * bsz * n * dims[i] * dims[i + 1]
            for i in range(cfg.n_layers))
    return 3.0 * f


def _recsys_fwd_flops_per_row(cfg) -> float:
    d = cfg.embed_dim
    if cfg.kind == "fm":
        return 4.0 * cfg.n_fields * d
    if cfg.kind == "wide_deep":
        dims = [cfg.n_fields * d, *cfg.mlp, 1]
        return sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
    if cfg.kind == "din":
        att = [4 * d, *cfg.attn_mlp, 1]
        head = [2 * d, *cfg.mlp, 1]
        per_tok = sum(2.0 * a * b for a, b in zip(att[:-1], att[1:]))
        return cfg.seq_len * (per_tok + 2.0 * d) + \
            sum(2.0 * a * b for a, b in zip(head[:-1], head[1:]))
    # mind: routing iters × (bilinear map + logits) + label attention
    per_tok = 2.0 * d * d + cfg.capsule_iters * 4.0 * d * cfg.n_interests
    return cfg.seq_len * per_tok + 4.0 * d * cfg.n_interests


def _recsys_model_flops(cfg, spec) -> float:
    per_row = _recsys_fwd_flops_per_row(cfg)
    if spec["kind"] == "train":
        return 3.0 * per_row * spec["batch"]
    if spec["kind"] == "serve":
        return per_row * spec["batch"]
    return per_row * spec["n_candidates"]


def model_flops(arch: str, shape_id: str) -> float:
    from repro.configs import registry
    from repro.configs.shapes import FAMILY_SHAPES

    fam = registry.family(arch)
    spec = FAMILY_SHAPES[fam][shape_id]
    mod = registry.get_module(arch)
    if fam == "lm":
        return _lm_model_flops(mod.config(), spec)
    if fam == "gnn":
        return _gnn_model_flops(
            mod.config(d_feat=spec["d_feat"], n_classes=spec["n_classes"]),
            spec)
    return _recsys_model_flops(mod.config(), spec)


_ADVICE = {
    "compute": "compute-bound: raise MFU via MXU-aligned tiles / fewer "
               "rematerialized FLOPs (relax remat policy)",
    "memory": "HBM-bound: batch more work per weight/cache read (larger "
              "microbatch, query batching), cut f32 materialization",
    "collective": "collective-bound: reshard to cut TP/FSDP traffic "
                  "(fewer model-axis all-reduces, gather weights once "
                  "per step, overlap with compute)",
}


def analyze_cell(dryrun_dir: str, arch: str, shape_id: str,
                 mesh: str = "pod16x16") -> dict | None:
    stem = f"{arch}__{shape_id}__{mesh}"
    jpath = os.path.join(dryrun_dir, stem + ".json")
    hpath = os.path.join(dryrun_dir, stem + ".hlo.gz")
    if not (os.path.exists(jpath) and os.path.exists(hpath)):
        return None
    with open(jpath) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return {"arch": arch, "shape": shape_id, "ok": False,
                "error": rec.get("error", "")}
    chips = rec["chips"]
    w = analyze_hlo_file(hpath)

    compute_s = w["flops_weighted"] / PEAK_FLOPS
    memory_s = w["bytes_weighted"] / HBM_BW
    coll_s = w["collectives_weighted"]["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    step_lb = max(terms.values())

    mf = model_flops(arch, shape_id) / chips     # per-device useful flops
    useful_ratio = mf / max(w["flops_weighted"], 1.0)
    # Fraction of chip peak actually achieved if the step runs at its
    # roofline bound — the headline score.
    mfu_at_bound = (mf / PEAK_FLOPS) / max(step_lb, 1e-30)

    return {
        "arch": arch, "shape": shape_id, "mesh": mesh, "ok": True,
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dom,
        "hlo_flops_dev": w["flops_weighted"],
        "model_flops_dev": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu_at_bound,
        "peak_bytes_dev": rec["memory"]["peak_bytes_est"],
        "advice": _ADVICE[dom],
    }


def run(quick: bool = True, dryrun_dir: str = "reports/dryrun",
        out_csv: str = "reports/roofline.csv"):
    from repro.configs import registry
    from repro.configs.shapes import FAMILY_SHAPES

    rows = []
    for arch in registry.ARCH_IDS:
        for shape_id in FAMILY_SHAPES[registry.family(arch)]:
            r = analyze_cell(dryrun_dir, arch, shape_id)
            if r is not None:
                rows.append(r)
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "error": r.get("error", "missing")})
            continue
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"],
            "model_flops_dev": f"{r['model_flops_dev']:.3e}",
            "hlo_flops_dev": f"{r['hlo_flops_dev']:.3e}",
            "useful_ratio": f"{r['useful_flops_ratio']:.3f}",
            "roofline_fraction": f"{r['roofline_fraction']:.4f}",
        })
    os.makedirs("reports", exist_ok=True)
    with open(out_csv, "w", newline="") as f:
        if out:
            w = csv.DictWriter(f, fieldnames=list(out[0].keys()))
            w.writeheader()
            w.writerows(out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.csv")
    args = ap.parse_args()
    rows = run(dryrun_dir=args.dryrun, out_csv=args.out)
    if not rows:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    hdr = f"{'arch':27s} {'shape':15s} {'compute':>10s} {'memory':>10s} " \
          f"{'collective':>11s} {'dominant':>10s} {'useful':>7s} {'RLfrac':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:27s} {r['shape']:15s} ERROR {r['error'][:60]}")
            continue
        print(f"{r['arch']:27s} {r['shape']:15s} {r['compute_s']:>10s} "
              f"{r['memory_s']:>10s} {r['collective_s']:>11s} "
              f"{r['dominant']:>10s} {r['useful_ratio']:>7s} "
              f"{r['roofline_fraction']:>7s}")


if __name__ == "__main__":
    main()
