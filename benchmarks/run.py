"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §7) + the kernel microbench
+ the §Roofline table (from the dry-run artifacts, if present).
``--full`` runs at larger scale; default is the quick CI profile.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks import (
    bench_accuracy_distribution,
    bench_buffer_size,
    bench_build,
    bench_construction,
    bench_kernels,
    bench_planner,
    bench_serving,
    bench_sketch_ablation,
    bench_space_accuracy,
    bench_threshold,
    bench_time_accuracy,
    bench_uniform_exact,
    bench_zipf_sweep,
)

SUITES = [
    ("fig5_buffer_size", bench_buffer_size),
    ("fig6_sketch_ablation", bench_sketch_ablation),
    ("fig10_13_space_accuracy", bench_space_accuracy),
    ("fig14_accuracy_distribution", bench_accuracy_distribution),
    ("fig15_threshold", bench_threshold),
    ("fig16_zipf_sweep", bench_zipf_sweep),
    ("fig17_time_accuracy", bench_time_accuracy),
    ("fig18_t3_construction", bench_construction),
    ("fig19_uniform_exact", bench_uniform_exact),
    ("kernel_microbench", bench_kernels),
    ("planner", bench_planner),
    ("build", bench_build),
    ("serving", bench_serving),
]

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# suite name -> repo-root JSON artifact written under --json.
JSON_ARTIFACTS = {
    "planner": os.path.join(REPO_ROOT, "BENCH_PLANNER.json"),
    "build": os.path.join(REPO_ROOT, "BENCH_BUILD.json"),
    "serving": os.path.join(REPO_ROOT, "BENCH_SERVING.json"),
}


def _print_rows(rows, limit=100):
    if not rows:
        print("  (no rows)")
        return
    cols = list(rows[0].keys())
    print("  " + " | ".join(f"{c}" for c in cols))
    for r in rows[:limit]:
        print("  " + " | ".join(str(r.get(c, "")) for c in cols))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="substring filter over suite names")
    ap.add_argument("--suite", default="",
                    help="run exactly one suite by name (e.g. planner)")
    ap.add_argument("--json", action="store_true",
                    help="also write machine-readable artifacts at the "
                         "repo root (e.g. BENCH_PLANNER.json)")
    ap.add_argument("--backend", default="jnp",
                    choices=("numpy", "jnp", "pallas"),
                    help="scoring backend for the planner suite")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the cost-model query-path constants from "
                         "measured QPS and embed them in the planner "
                         "JSON artifact (requires --json)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail the planner suite if pruned-path QPS "
                         "regresses >20%% below the committed "
                         "BENCH_PLANNER.json (dense-ratio normalized)")
    args = ap.parse_args()

    if args.suite and args.suite not in {n for n, _ in SUITES}:
        # A typo here must not green-light CI with zero suites run.
        ap.error(f"unknown suite {args.suite!r}; "
                 f"available: {[n for n, _ in SUITES]}")

    failures = 0
    for name, mod in SUITES:
        if args.suite and name != args.suite:
            continue
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"\n=== {name} ===")
        try:
            kwargs = {}
            if args.json and name in JSON_ARTIFACTS:
                kwargs["json_out"] = JSON_ARTIFACTS[name]
            if name == "planner":
                kwargs["backend"] = args.backend
                if args.calibrate:
                    kwargs["calibrate"] = True
                if args.check_baseline:
                    kwargs["baseline"] = JSON_ARTIFACTS["planner"]
            if name == "build":
                kwargs["backend"] = args.backend
                if args.check_baseline:
                    kwargs["baseline"] = JSON_ARTIFACTS["build"]
            if name == "serving":
                kwargs["backend"] = args.backend
                if args.check_baseline:
                    kwargs["baseline"] = JSON_ARTIFACTS["serving"]
            rows = mod.run(quick=not args.full, **kwargs)
            _print_rows(rows)
            print(f"  [{time.time()-t0:.1f}s] → reports/bench/{name}.csv")
            if "json_out" in kwargs:
                print(f"  → {kwargs['json_out']}")
        except Exception:
            failures += 1
            print(f"  FAILED after {time.time()-t0:.1f}s")
            traceback.print_exc()

    if args.suite:
        # Targeted smoke run (CI): skip the roofline epilogue.
        print(f"\n{'SUITE OK' if not failures else f'{failures} FAILURES'}")
        sys.exit(1 if failures else 0)

    print("\n=== roofline (from dry-run artifacts) ===")
    try:
        import os

        from benchmarks import roofline
        dd = ("reports/dryrun_v2" if os.path.isdir("reports/dryrun_v2")
              else "reports/dryrun")
        print(f"  source: {dd} (optimized defaults; baseline snapshot in "
              "reports/roofline_baseline.csv)")
        rows = roofline.run(dryrun_dir=dd)
        if rows:
            _print_rows(rows, limit=50)
            print("  → reports/roofline.csv")
        else:
            print("  no dry-run artifacts; run: "
                  "PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes")
    except Exception:
        failures += 1
        traceback.print_exc()

    print(f"\n{'ALL BENCHMARKS OK' if not failures else f'{failures} FAILURES'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
