"""END-TO-END serving driver (the paper's kind of workload): build a
GB-KMV index over a Table-II-style corpus and serve batched containment
queries through the distributed device path — threshold search AND global
top-k — measuring latency and accuracy against exact ground truth.

    PYTHONPATH=src python examples/containment_serve.py [--dataset ENRON]
"""

import argparse
import time

import jax
import numpy as np

from repro.core.exact import build_inverted, exact_search
from repro.core.gbkmv import build_gbkmv
from repro.core.search import f_score
from repro.data import datasets
from repro.data.synth import make_query_workload
from repro.launch.mesh import host_mesh
from repro.sketchindex import (
    batch_queries, distributed_search, distributed_topk, score_batch,
    to_device_index)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="NETFLIX")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.5)
    args = ap.parse_args()

    # --- offline: build + place the index ---
    recs = datasets.load(args.dataset, scale=args.scale)
    total = sum(len(r) for r in recs)
    t0 = time.time()
    index = build_gbkmv(recs, budget=int(total * 0.1), r="auto")
    print(f"[build] {args.dataset}: m={len(recs)} → {index.nbytes()/1e6:.2f} MB "
          f"GB-KMV (r={index.buffer_bits}) in {time.time()-t0:.2f}s")
    mesh = host_mesh()
    didx = to_device_index(index, mesh)
    exact_index = build_inverted(recs)

    # --- online: batched query rounds ---
    queries = make_query_workload(recs, args.batch * args.rounds, seed=1)
    lat, f1s = [], []
    for r in range(args.rounds):
        qs = queries[r * args.batch:(r + 1) * args.batch]
        qp = batch_queries(index, qs)
        t0 = time.time()
        mask, scores = distributed_search(didx, qp, args.threshold)
        vals, ids = distributed_topk(scores, 10, mesh)
        jax.block_until_ready((mask, vals))
        lat.append(time.time() - t0)
        for j, q in enumerate(qs):
            truth = exact_search(exact_index, q, args.threshold)
            got = np.nonzero(np.asarray(mask)[: index.num_records, j])[0]
            f1s.append(f_score(truth, got))
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {args.rounds} rounds × {args.batch} queries: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms "
          f"→ {args.batch/np.mean(lat):.0f} q/s")
    print(f"[accuracy] F1 vs exact: mean={np.mean(f1s):.3f} "
          f"p10={np.percentile(f1s, 10):.3f}")
    print(f"[topk] sample top-3 containment scores: "
          f"{np.asarray(vals[0, :3]).round(3).tolist()}")


if __name__ == "__main__":
    main()
