"""END-TO-END serving driver (the paper's kind of workload): build a
GB-KMV index over a Table-II-style corpus and serve batched containment
queries through the distributed device path — threshold search AND global
top-k — measuring latency and accuracy against exact ground truth.

    PYTHONPATH=src python examples/containment_serve.py [--dataset ENRON]
"""

import argparse
import time

import numpy as np

from repro import api
from repro.core.search import f_score
from repro.data import datasets
from repro.data.synth import make_query_workload
from repro.launch.mesh import host_mesh
from repro.sketchindex import ShardedIndex


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="NETFLIX")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--backend", default="jnp",
                    choices=("numpy", "jnp", "pallas"))
    args = ap.parse_args()

    # --- offline: build, then place on the mesh (same api protocol) ---
    recs = datasets.load(args.dataset, scale=args.scale)
    total = sum(len(r) for r in recs)
    t0 = time.time()
    index = api.get_engine("gbkmv").build(recs, int(total * 0.1), r="auto")
    print(f"[build] {args.dataset}: m={len(recs)} → {index.nbytes()/1e6:.2f} MB "
          f"GB-KMV (r={index.core.buffer_bits}) in {time.time()-t0:.2f}s")
    sharded = ShardedIndex(index, host_mesh(), backend=args.backend)
    exact = api.get_engine("exact").build(recs)

    # --- online: batched query rounds ---
    queries = make_query_workload(recs, args.batch * args.rounds, seed=1)
    lat, f1s = [], []
    for r in range(args.rounds):
        qs = queries[r * args.batch:(r + 1) * args.batch]
        t0 = time.time()
        results = sharded.serve_batch(qs, args.threshold, 10)
        lat.append(time.time() - t0)
        for q, res in zip(qs, results):
            truth = exact.query(q, args.threshold)
            f1s.append(f_score(truth, res["hits"]))
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {args.rounds} rounds × {args.batch} queries: "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms "
          f"→ {args.batch/np.mean(lat):.0f} q/s")
    print(f"[accuracy] F1 vs exact: mean={np.mean(f1s):.3f} "
          f"p10={np.percentile(f1s, 10):.3f}")
    print(f"[topk] sample top-3 containment scores: "
          f"{results[0]['topk_scores'][:3].round(3).tolist()}")


if __name__ == "__main__":
    main()
