"""LM training with a GB-KMV near-duplicate pipeline stage (end-to-end
driver #2): corpus → shingles → containment dedup → token batches →
train a small qwen3-family model with checkpointing + straggler watch.

The corpus is deliberately polluted with sub/superset duplicates —
exactly the case where containment beats Jaccard (paper §I example).

    PYTHONPATH=src python examples/lm_dedup_train.py [--steps 200]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import BatchCursor, dedup_corpus, token_batches
from repro.ft import checkpoint as ckpt_mod
from repro.ft.straggler import StragglerMonitor
from repro.models import transformer as tfm
from repro.train import optim, steps


def polluted_corpus(vocab: int, n_docs: int, seed: int = 0):
    """Docs + exact/near-superset duplicates (~30% pollution)."""
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, size=rng.integers(64, 256))
            for _ in range(n_docs)]
    for i in range(0, n_docs, 3):
        base = docs[i]
        docs.append(np.concatenate(
            [base, rng.integers(0, vocab, size=12)]))   # near-superset dup
    return docs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_dedup_ckpt")
    args = ap.parse_args()

    cfg = registry.get_module("qwen3-0.6b").reduced()
    docs = polluted_corpus(cfg.vocab, 120)
    kept, stats = dedup_corpus(docs, threshold=0.8)
    print(f"[dedup] GB-KMV containment dedup: {stats} "
          f"({stats['dropped']}/{stats['total']} near-dups removed)")
    docs = [docs[i] for i in kept]

    params = tfm.init(jax.random.PRNGKey(0), cfg)
    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = optim.init(params, ocfg)
    step_fn = jax.jit(steps.make_train_step(
        functools.partial(lambda p, b, c: tfm.loss_fn(p, b, c), c=cfg),
        ocfg, microbatches=2), donate_argnums=(0, 1))

    cursor = BatchCursor(seed=0)
    stream = token_batches(docs, args.batch, args.seq, cursor)
    mon = StragglerMonitor()
    first_loss = None
    for step in range(args.steps):
        batch = next(stream)
        t0 = time.time()
        params, opt, met = step_fn(
            params, opt, {k: jnp.asarray(v) for k, v in batch.items()})
        loss = float(met["loss"])
        status = mon.record(time.time() - t0)
        if first_loss is None:
            first_loss = loss
        if status != "ok":
            print(f"[straggler] step {step}: {status} → {mon.action(status)}")
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"({(time.time()-t0)*1e3:.0f} ms)")
        if (step + 1) % 100 == 0:
            ckpt_mod.save_checkpoint(args.ckpt_dir, step + 1,
                                     {"params": params, "opt": opt},
                                     extra={"cursor_step": cursor.step})
    print(f"[train] loss {first_loss:.3f} → {loss:.3f} over {args.steps} steps")
    assert loss < first_loss, "training must reduce loss"
    print(f"[ckpt] latest: step {ckpt_mod.latest_step(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
