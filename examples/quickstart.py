"""Quickstart: build a GB-KMV index, run a containment search, compare
the three sketches (KMV / G-KMV / GB-KMV) against exact ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.exact import build_inverted, exact_search
from repro.core.gbkmv import build_gbkmv, search
from repro.core.gkmv import build_gkmv
from repro.core.kmv import build_kmv
from repro.core.search import f_score
from repro.data.synth import generate_dataset, make_query_workload


def main():
    # A zipf-skewed set-valued dataset (element freq α1=1.1, size α2=2.0;
    # record sizes 64-1000 ≈ the paper's corpora, avg length ~200).
    records = generate_dataset(m=1000, n_elems=50_000, alpha_freq=1.1,
                               alpha_size=2.0, size_min=64, size_max=1000,
                               seed=0)
    total = sum(len(r) for r in records)
    budget = int(total * 0.1)           # 10% space budget, paper default
    print(f"dataset: {len(records)} records, {total} elements; "
          f"budget {budget} slots (10%)")

    # Build the three sketches at the same budget.
    gb = build_gbkmv(records, budget=budget, r="auto")
    print(f"GB-KMV: buffer r={gb.buffer_bits} bits (cost-model pick), "
          f"τ=0x{int(gb.tau):08x}, {gb.nbytes()/1e6:.2f} MB")
    build_gkmv(records, budget=budget)   # G-KMV == GB-KMV with r=0
    build_kmv(records, budget=budget)    # plain KMV (Theorem 1 allocation)

    # Containment search, threshold 0.5 (Definition 3 / Algorithm 2).
    exact_index = build_inverted(records)
    queries = make_query_workload(records, 20)
    f1s = []
    for q in queries:
        truth = exact_search(exact_index, q, 0.5)
        approx = search(gb, q, 0.5)
        f1s.append(f_score(truth, approx))
    print(f"GB-KMV F1 over 20 queries @ t*=0.5: mean={np.mean(f1s):.3f} "
          f"min={np.min(f1s):.3f}")

    q = queries[0]
    got = search(gb, q, 0.5)
    print(f"example query |Q|={len(q)}: {len(got)} records with "
          f"Ĉ(Q→X) ≥ 0.5 → ids {got[:8].tolist()}...")


if __name__ == "__main__":
    main()
