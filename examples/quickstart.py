"""Quickstart for the unified ``repro.api``: build any registered engine
through one protocol, search, rank, insert, and persist.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api
from repro.core.search import f_score
from repro.data.synth import generate_dataset, make_query_workload


def main():
    # A zipf-skewed set-valued dataset (element freq α1=1.1, size α2=2.0;
    # record sizes 64-1000 ≈ the paper's corpora, avg length ~200).
    records = generate_dataset(m=1000, n_elems=50_000, alpha_freq=1.1,
                               alpha_size=2.0, size_min=64, size_max=1000,
                               seed=0)
    total = sum(len(r) for r in records)
    budget = int(total * 0.1)           # 10% space budget, paper default
    print(f"dataset: {len(records)} records, {total} elements; "
          f"budget {budget} slots (10%); engines: {api.list_engines()}")

    # One door for every engine: Engine.build(records, budget) -> Index.
    gb = api.get_engine("gbkmv").build(records, budget, r="auto")
    print(f"GB-KMV: buffer r={gb.core.buffer_bits} bits (cost-model pick), "
          f"τ=0x{int(gb.core.tau):08x}, {gb.nbytes()/1e6:.2f} MB")
    api.get_engine("gkmv").build(records, budget)   # G-KMV == r=0
    api.get_engine("kmv").build(records, budget)    # plain KMV (Theorem 1)

    # Containment search, threshold 0.5 (Definition 3 / Algorithm 2),
    # scored against exact ground truth through the same protocol.
    exact = api.get_engine("exact").build(records)
    queries = make_query_workload(records, 20)
    f1s = [f_score(exact.query(q, 0.5), gb.query(q, 0.5)) for q in queries]
    print(f"GB-KMV F1 over 20 queries @ t*=0.5: mean={np.mean(f1s):.3f} "
          f"min={np.min(f1s):.3f}")

    # Top-k ranking and batched search ride the same index.
    q = queries[0]
    ids, scores = gb.topk(q, k=8)
    got = gb.query(q, 0.5)
    print(f"example query |Q|={len(q)}: {len(got)} records with "
          f"Ĉ(Q→X) ≥ 0.5; top-3 = {list(zip(ids[:3].tolist(), scores[:3].round(3).tolist()))}")

    # Dynamic inserts (GB-KMV: §IV-B τ-retightening, no raw-data access)
    # and npz persistence round-trip.
    gb.insert(records[:10])
    gb.save("/tmp/quickstart_gbkmv.npz")
    gb2 = api.load_index("/tmp/quickstart_gbkmv.npz")
    assert np.array_equal(gb.query(q, 0.5), gb2.query(q, 0.5))
    print(f"after insert: m={gb.num_records}; save/load round-trip ok")


if __name__ == "__main__":
    main()
