"""RecSys retrieval with GB-KMV containment rescoring: FM dense retrieval
proposes candidates from 100k items; the GB-KMV sketch of each item's
interaction-set rescoresthem by containment against the user's history
set (the paper's technique as a retrieval component).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import registry
from repro.models import recsys as recsys_mod


def main():
    cfg = registry.get_module("fm").reduced()
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    n_items = 102_400          # multiple of the FM scoring chunk
    # --- stage 1: FM dense scoring of all candidates (sum-square trick) ---
    user = {"ids": jnp.asarray(
        rng.integers(0, cfg.vocab_rows, (1, cfg.n_fields - 1)), jnp.int32)}
    cand_ids = jnp.asarray(rng.integers(0, cfg.vocab_rows, (n_items,)),
                           jnp.int32)
    t0 = time.time()
    dense = recsys_mod.retrieval_scores(params, user, cand_ids, cfg)
    dense = np.asarray(jax.block_until_ready(dense))
    top = np.argsort(dense)[::-1][:256]
    print(f"[stage1] FM dense scoring of {n_items} candidates: "
          f"{(time.time()-t0)*1e3:.0f} ms → shortlist 256")

    # --- stage 2: GB-KMV containment rescoring of the shortlist ---
    # Each item carries a set of interaction features; the user's history
    # set is the query. Containment (not Jaccard!) ranks items whose
    # feature set COVERS the user's interests regardless of item breadth.
    item_sets = [np.unique(rng.integers(0, 20_000,
                                        size=rng.integers(20, 200)))
                 for _ in range(256)]
    user_hist = np.unique(np.concatenate(
        [item_sets[0][:30], rng.integers(0, 20_000, size=40)]))
    total = sum(len(s) for s in item_sets)
    index = api.get_engine("gbkmv").build(item_sets, int(total * 0.2), r="auto")
    t0 = time.time()
    cscores = index.scores(user_hist)
    t_ms = (time.time() - t0) * 1e3
    order = np.argsort(np.asarray(cscores))[::-1]
    print(f"[stage2] GB-KMV containment rescoring of 256 items: {t_ms:.1f} ms")
    print(f"  top-5 by containment Ĉ(user→item): "
          f"{[(int(top[i]), round(float(cscores[i]), 3)) for i in order[:5]]}")
    # Item 0 contains 30/70 of the user's history by construction — it
    # must rank near the top.
    assert order[0] == 0 or float(cscores[0]) >= 0.3
    print("  (item 0, the planted superset item, ranks first ✓)")


if __name__ == "__main__":
    main()
