"""repro — production-grade JAX/TPU framework for GB-KMV containment similarity search.

Paper: "GB-KMV: An Augmented KMV Sketch for Approximate Containment
Similarity Search" (Yang, Zhang, Zhang, Huang, 2018).

Public API surface:
    repro.core        — KMV / G-KMV / GB-KMV sketches, estimators, search
    repro.sketchindex — packed, distributed sketch index
    repro.models      — assigned architecture model zoo
    repro.configs     — architecture registry (``get_config(arch_id)``)
    repro.launch      — mesh / dryrun / train / serve entry points
"""

__version__ = "0.1.0"
