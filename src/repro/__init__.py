"""repro — production-grade JAX/TPU framework for GB-KMV containment similarity search.

Paper: "GB-KMV: An Augmented KMV Sketch for Approximate Containment
Similarity Search" (Yang, Zhang, Zhang, Huang, 2018).

Public API surface:
    repro.api         — THE door: ``ContainmentEngine`` registry.
                        ``get_engine(name).build(records, budget)`` returns
                        an index with ``query`` / ``batch_query`` / ``topk``
                        / ``insert`` / ``save`` / ``nbytes``; engines:
                        gbkmv, gkmv, kmv, lshe, exact, prefix; sketch
                        scoring via ``backend=`` numpy | jnp | pallas;
                        ``load_index(path)`` restores any saved index.
    repro.core        — sketch/estimator internals the engines are built on
    repro.sketchindex — packed + ``ShardedIndex`` (mesh-sharded, same protocol)
    repro.serving     — deadline-aware micro-batching ``SketchServer``
    repro.models      — assigned architecture model zoo
    repro.configs     — architecture registry (``get_config(arch_id)``)
    repro.launch      — mesh / dryrun / train / serve entry points

Quickstart::

    from repro import api
    index = api.get_engine("gbkmv").build(records, budget=total // 10)
    hits  = index.query(q_ids, threshold=0.5)

See docs/API.md for the legacy-call → new-call migration table.
"""

__version__ = "0.2.0"
