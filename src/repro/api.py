"""``repro.api`` — the unified public API for containment similarity search.

One protocol for every sketch engine, every backend, every deployment
tier (paper §V runs all its experiments through exactly this kind of
single evaluation door):

    engine = repro.api.get_engine("gbkmv")          # registry lookup
    index  = engine.build(records, budget)          # -> ContainmentIndex
    ids    = index.query(q_ids, threshold=0.5)      # Algorithm 2
    hits   = index.batch_query(queries, 0.5)        # one id array per query
    top    = index.topk(q_ids, k=10)                # (ids, scores)
    index.insert(new_records)                       # dynamic maintenance
    index.save(path); repro.api.load_index(path)    # npz round-trip
    index.nbytes()                                  # space accounting

Sketch engines (gbkmv/gkmv/kmv) route ``query``/``batch_query`` through
the candidate-pruning planner (:mod:`repro.planner`): ``plan="auto"``
(default) lets a cost model pick between the dense index sweep and the
inverted-postings filter-and-verify path per batch; ``plan="dense"`` /
``plan="pruned"`` force a path. Both return identical candidate sets —
pruning is exact under the estimator's containment bound.

Registered engines: ``gbkmv``, ``gkmv``, ``kmv`` (the paper's sketches),
``lshe`` (LSH Ensemble baseline), ``exact`` and ``prefix`` (ground-truth
inverted-index engines). Sketch engines accept ``backend=`` ∈ {"numpy",
"jnp", "pallas"} to pick the scoring implementation; engines without a
device path (lshe/exact/prefix) ignore it.

``insert`` is wired to :mod:`repro.sketchindex.dynamic` for GB-KMV
(τ-retightening under the fixed budget, no raw-data access); every other
engine falls back to a full rebuild from the retained records.

For cluster-scale serving, :class:`repro.sketchindex.ShardedIndex` wraps
a built GB-KMV index and implements this same protocol with the record
dim sharded over a device mesh.
"""

from __future__ import annotations

from time import perf_counter
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import exact as exact_mod
from repro.core import gbkmv as gbkmv_mod
from repro.core import gkmv as gkmv_mod
from repro.core import kmv as kmv_mod
from repro.core import lshe as lshe_mod
from repro.core import minhash as minhash_mod
from repro.core.arena import SketchArena
from repro.core.estimators import containment_matrix, normalize_backend
from repro.core.hashing import PAD, hash_u32_np
from repro.core.sketches import PackedSketches


@runtime_checkable
class ContainmentIndex(Protocol):
    """What every engine's index exposes (structural protocol)."""

    def query(self, q_ids, threshold: float) -> np.ndarray: ...
    def batch_query(self, queries, threshold: float) -> list[np.ndarray]: ...
    def topk(self, q_ids, k: int) -> tuple[np.ndarray, np.ndarray]: ...
    def insert(self, new_records) -> "ContainmentIndex": ...
    def save(self, path: str) -> None: ...
    def nbytes(self) -> int: ...


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------

_ENGINES: dict[str, type] = {}


def register_engine(name: str):
    """Class decorator: make an engine reachable as ``get_engine(name)``."""

    def deco(cls):
        cls.name = name
        _ENGINES[name] = cls
        return cls

    return deco


def get_engine(name: str):
    """Engine class for ``name`` (``.build(records, budget, **cfg)``)."""
    try:
        return _ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_ENGINES)}"
        ) from None


def list_engines() -> list[str]:
    """Registered engine names (gbkmv/gkmv/kmv/lshe/exact/prefix/...)."""
    return sorted(_ENGINES)


def build(name: str, records, budget: int | None = None, **cfg):
    """Convenience: ``get_engine(name).build(records, budget, **cfg)``.

    ``records`` is a list of element-id arrays or a pre-ingested
    :class:`repro.core.sketches.RaggedBatch`; ``budget`` counts 32-bit
    hash slots across all records (the paper's space accounting). See
    docs/API.md for the shared ``build`` kwargs (``backend``,
    ``build_backend``, ``tau_mode``, ``postings``, ``windowed``)."""
    return get_engine(name).build(records, budget, **cfg)


def _record_list(records) -> list:
    """Per-record id arrays from a record list or a pre-ingested
    :class:`repro.core.sketches.RaggedBatch` (rebuild-fallback engines
    and the windowed path keep them beyond construction)."""
    if hasattr(records, "offsets"):          # RaggedBatch
        ids = np.asarray(records.ids)
        off = np.asarray(records.offsets)
        return [ids[a:b] for a, b in zip(off[:-1], off[1:])]
    return [np.asarray(r) for r in records]


def _windowed_build(engine: str, records, budget, backend: str,
                    epoch: int, cfg: dict):
    """Shared ``windowed=True`` path of the sketch engines' ``build``:
    wrap construction in a :class:`repro.sketchindex.WindowManager`
    whose first epoch holds ``records``. The manager implements this
    module's index protocol (plus ``window=`` kwargs, ``retire``, and
    directory save/load) — see :mod:`repro.sketchindex.windows`."""
    from repro.sketchindex.windows import WindowManager

    wm = WindowManager(engine=engine, budget=int(budget), backend=backend,
                       **cfg)
    records = _record_list(records)
    if records:
        wm.ingest(records, epoch=int(epoch))
    return wm


class CorruptIndexError(ValueError):
    """A saved index file exists but cannot be decoded (truncated
    download, torn write, wrong file). Subclasses ``ValueError`` so
    pre-existing ``except ValueError`` call sites keep working; a
    missing file still raises ``FileNotFoundError``."""

    def __init__(self, path: str, reason: str):
        super().__init__(f"corrupt or invalid index file {path!r}: {reason}")
        self.path = path
        self.reason = reason


def load_index(path: str):
    """Load any index saved via ``Index.save`` (dispatches on the stored
    engine name). A file that exists but cannot be decoded — truncated
    npz, torn write, non-index zip — raises :class:`CorruptIndexError`
    naming the file instead of leaking a raw ``zipfile``/``KeyError``."""
    import zipfile

    try:
        with np.load(path, allow_pickle=False) as data:
            d = {k: data[k] for k in data.files}
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, ValueError, KeyError, OSError, EOFError) as e:
        raise CorruptIndexError(
            path, f"{type(e).__name__}: {e}") from e
    if "engine" not in d:
        raise CorruptIndexError(path, "not a repro.api index "
                                      "(no 'engine' key)")
    engine = str(d.pop("engine"))
    try:
        cls = get_engine(engine)
    except ValueError as e:
        raise CorruptIndexError(path, str(e)) from e
    if not hasattr(cls, "_load"):
        raise ValueError(f"engine {engine!r} does not support load")
    try:
        return cls._load(d)
    except (KeyError, ValueError, IndexError) as e:
        raise CorruptIndexError(
            path, f"payload missing or malformed ({type(e).__name__}: "
                  f"{e})") from e


# ---------------------------------------------------------------------------
# Shared index behavior
# ---------------------------------------------------------------------------


class _IndexBase:
    """Default protocol plumbing: score-based query/topk, rebuild-insert.

    Subclasses implement ``_scores(q_ids) -> f32[m]`` (estimated
    containment of the query in every record) and, where a cheaper path
    exists, override ``query``/``insert``.
    """

    engine: str = "?"
    backend: str = "jnp"
    _records: list | None = None        # retained for rebuild-fallback insert
    _build_cfg: dict

    # -- abstract-ish --
    def _scores(self, q_ids) -> np.ndarray:
        raise NotImplementedError

    def nbytes(self) -> int:
        raise NotImplementedError

    # -- protocol --
    def scores(self, q_ids) -> np.ndarray:
        """Estimated containment Ĉ(Q→X) for every record (f32[m])."""
        return np.asarray(self._scores(q_ids))

    def query(self, q_ids, threshold: float) -> np.ndarray:
        return np.nonzero(np.asarray(self._scores(q_ids)) >= threshold)[0]

    def batch_query(self, queries, threshold: float) -> list[np.ndarray]:
        return [self.query(q, threshold) for q in queries]

    def topk(self, q_ids, k: int) -> tuple[np.ndarray, np.ndarray]:
        """(record ids, scores) of the k highest estimated containments.

        Deterministic order: score descending, ties by ascending record
        id — the exact ranking the planner-aware pruned top-k reproduces
        (and the tie rule ``lax.top_k`` applies on the sharded path).
        Dense and host-pruned routes share one output head
        (:func:`repro.planner.topk_select`), so the contract cannot
        drift between them.
        """
        from repro.planner import topk_select

        s = np.asarray(self._scores(q_ids))
        return topk_select(np.arange(len(s), dtype=np.int64), s, k, len(s))

    def insert(self, new_records):
        """Full-rebuild fallback (engines without dynamic maintenance)."""
        if self._records is None:
            raise ValueError(
                f"{self.engine}: insert after load needs the original "
                "records (rebuild fallback); rebuild via Engine.build")
        records = list(self._records) + [np.asarray(r) for r in new_records]
        rebuilt = get_engine(self.engine).build(records, **self._build_cfg)
        self.__dict__.update(rebuilt.__dict__)
        # Planner postings describe the pre-rebuild sketches; drop them
        # (the fresh build may not have touched the cache key).
        self._post = None
        return self

    def save(self, path: str) -> None:
        raise NotImplementedError(
            f"{self.engine}: save is supported for sketch-backed indexes "
            "(gbkmv/gkmv/kmv/lshe) only")


_ARENA_VERSION = 3

# Per-store npz key suffixes for the blocked postings (v3 format).
_STORE_FIELDS = ("row_blocks", "first", "last", "meta", "off", "payload")


def _arena_to_npz(s: PackedSketches) -> dict:
    """Arena serialization: the packed columns plus — when they have been
    built — the BLOCKED postings (delta-bitpacked/dense blocks, the same
    arrays that sit in host memory and mirror to device), so a reloaded
    index answers its first pruned query without re-inverting the
    sketches. Column keys are unchanged from the v1 (postings-less)
    format, which is what keeps old files loadable."""
    d = {
        "values": np.asarray(s.values), "lengths": np.asarray(s.lengths),
        "thresh": np.asarray(s.thresh), "buf": np.asarray(s.buf),
        "sizes": np.asarray(s.sizes),
        "arena_version": np.int64(_ARENA_VERSION),
    }
    post = getattr(s, "_post", None)
    if post is not None:
        d["post_keys"] = post.keys
        d["post_tau"] = np.uint32(post.tau)
        for prefix, store in (("post_blk_", post.tail),
                              ("post_buf_blk_", post.buf)):
            for f in _STORE_FIELDS:
                d[prefix + f] = getattr(store, f)
    return d


def _arena_from_npz(d: dict) -> SketchArena:
    """Rebuild an arena from ``_arena_to_npz`` output or any older format:

    v3  ``post_blk_*`` / ``post_buf_blk_*`` blocked stores — loaded
        verbatim (zero re-encoding work)
    v2  flat-CSR ``post_offsets``/``post_rec_ids``/... — re-encoded into
        blocks on load (one vectorized pass)
    v1  no ``post_*`` entries — postings stay lazy
    """
    arena = SketchArena(
        values=d["values"], lengths=d["lengths"], thresh=d["thresh"],
        buf=d["buf"], sizes=d["sizes"])
    if "post_blk_row_blocks" in d:
        from repro.planner.postings import BlockStore, PostingsIndex

        stores = {}
        for name, prefix in (("tail", "post_blk_"), ("buf", "post_buf_blk_")):
            stores[name] = BlockStore(
                **{f: d[prefix + f] for f in _STORE_FIELDS})
        arena.install_postings(PostingsIndex(
            keys=d["post_keys"], tail=stores["tail"], buf=stores["buf"],
            num_records=arena.num_records, tau=np.uint32(d["post_tau"])))
    elif "post_keys" in d:
        from repro.planner.postings import from_flat

        arena.install_postings(from_flat(
            d["post_keys"], d["post_offsets"], d["post_rec_ids"],
            d["post_buf_offsets"], d["post_buf_rec_ids"],
            arena.num_records, np.uint32(d["post_tau"])))
    return arena


def _validate_postings_arg(postings: str) -> str:
    """Reject a bad ``postings=`` BEFORE the (possibly device) build
    runs — a typo must not cost a full construction pass."""
    if postings not in ("lazy", "eager"):
        raise ValueError(f"postings must be 'lazy' or 'eager', "
                         f"got {postings!r}")
    return postings


def _maybe_eager_postings(sketches, postings: str) -> None:
    """``postings="eager"``: encode the block-compressed postings from
    the freshly packed columns at build time. Device-built columns (jnp
    arrays from the fused build) take the fused DEVICE encode — the
    blocked tail store is bit-packed on the accelerator and its mirrors
    adopted without a host round-trip, then the columns are pinned to
    host once for the host-side consumers. ``"lazy"`` (default) defers
    to the first planned query — the seed-era behavior, and what the
    space-accuracy benchmarks charge for."""
    if _validate_postings_arg(postings) == "eager":
        arena = SketchArena.from_pack(sketches)
        if not isinstance(arena.values, np.ndarray):
            from repro.planner.postings import build_postings_device

            post, dpost = build_postings_device(arena)
            arena.ensure_host()
            arena.install_postings(post)
            arena.adopt_device_postings(dpost)
        else:
            arena.ensure_host()
            arena.postings()


class _PlannedIndexMixin:
    """Planner routing for sketch-backed indexes (gbkmv/gkmv/kmv).

    ``query``/``batch_query``/``topk`` accept ``plan`` ∈ {"auto",
    "dense", "pruned"}: "auto" (default) asks :mod:`repro.planner` to
    pick the cheaper path per batch from posting selectivity; forced
    modes pin it. Both paths return identical results. ``topk`` routes
    through postings-driven upper-bound pruning (the running k-th score
    is the moving threshold) with exact parity against the dense
    ranking. Postings live ON the arena (:class:`SketchArena`) — built
    lazily on first planned query, shared with every other layer
    viewing the same arena, and maintained incrementally across
    ``insert``.

    With ``backend`` ∈ {"jnp", "pallas"} the pruned threshold path runs
    device-resident: candidate merge (kernels/postings_merge.py),
    gather-scoring, and packed thresholding all execute on device with
    no host-numpy transfer in between (``planner.device``).

    Subclasses provide ``_sketch_pack`` (the sketch arena),
    ``_plan_queries`` (per-query retained hashes / buffer bits / sizes
    + the scoring pack), and ``_pair_score_fn`` (ragged verify scorer).
    """

    last_plan = None            # QueryPlan of the most recent planned batch
    last_candidate_sizes: list | None = None
    last_explain: list | None = None   # explain dicts of the last explained batch
    _device_prunable = False    # engine scoring has a device twin

    def _sketch_pack(self) -> PackedSketches:
        raise NotImplementedError

    def _plan_queries(self, queries):
        raise NotImplementedError

    def _pair_score_fn(self, qp):
        raise NotImplementedError

    def _dense_batch_query(self, queries, threshold,
                           qp=None) -> list[np.ndarray]:
        """``qp``: query pack already built by _plan_queries (auto-routed
        dense batches must not pay the sketching twice)."""
        raise NotImplementedError

    # Postings are owned by the arena, not the wrapper: every layer that
    # views the same arena (api index, ShardedIndex, server) shares one
    # inverted index. The property keeps the legacy ``self._post`` spelling
    # working (tests and the rebuild-fallback insert assign through it).
    @property
    def _post(self):
        return getattr(self._sketch_pack(), "_post", None)

    @_post.setter
    def _post(self, value):
        arena = self._sketch_pack()
        if value is None:
            if isinstance(arena, SketchArena):
                arena.clear_postings()
        else:
            arena.install_postings(value)

    def _postings(self):
        return SketchArena.from_pack(self._sketch_pack()).postings()

    def query(self, q_ids, threshold: float, *, plan: str = "auto",
              explain: bool = False):
        if explain:
            ids, ex = self.batch_query([q_ids], threshold, plan=plan,
                                       explain=True)
            return ids[0], ex[0]
        return self.batch_query([q_ids], threshold, plan=plan)[0]

    def _explained(self, hits, *, threshold, t0, cands=None,
                   hash_rows=None, sizes=None, posts=None):
        """Pair results with per-query explain dicts (explain=True)."""
        from repro import obs

        ex = obs.build_explain(
            self.last_plan, engine=self.engine, backend=self.backend,
            threshold=threshold, n_queries=len(hits), hits=hits,
            cands=cands, hash_rows=hash_rows, sizes=sizes, posts=posts,
            measured_seconds=perf_counter() - t0)
        self.last_explain = ex
        return hits, ex

    def batch_query(self, queries, threshold: float, *,
                    plan: str = "auto", explain: bool = False):
        """Planned batch query. With ``explain=True`` returns
        ``(hits, explains)`` — one explain dict per query (see
        :mod:`repro.obs.explain`); the device-backend pruned path reruns
        the host candidate accounting to fill it (EXPLAIN ANALYZE
        semantics: asking costs extra, answers don't change)."""
        from repro import obs, planner

        plan = planner.normalize_plan(plan)
        queries = [np.asarray(q) for q in queries]
        if not queries:
            return ([], []) if explain else []
        t0 = perf_counter()
        if plan == "dense" or float(threshold) <= 0.0:
            self.last_plan = planner.QueryPlan(
                "dense", np.nan, np.nan, 0,
                "forced" if plan == "dense" else "threshold <= 0")
            with obs.stage("planner.dense", queries=len(queries)):
                ids = self._dense_batch_query(queries, threshold)
            if explain:
                return self._explained(ids, threshold=threshold, t0=t0)
            return ids
        with obs.stage("planner.sketch", queries=len(queries)):
            qp, hash_rows, bit_rows, sizes = self._plan_queries(queries)
        s = self._sketch_pack()
        decision = planner.choose_plan(
            self._postings(), hash_rows, bit_rows, threshold,
            s.num_records, s.capacity, plan=plan)
        self.last_plan = decision
        cands = None
        if decision.path == "dense":
            with obs.stage("planner.dense", queries=len(queries)):
                ids = self._dense_batch_query(queries, threshold, qp=qp)
        elif self._device_prunable and self.backend in ("jnp", "pallas"):
            from repro.planner import device as planner_device

            # The device path never materializes per-query candidate
            # sets on host — only the probe breakdown is known
            # (decision.per_query_hits); candidate accounting stays None.
            self.last_candidate_sizes = None
            ids = planner_device.pruned_batch_device(
                SketchArena.from_pack(s), qp, threshold,
                plan=decision, backend=self.backend)
            if explain:
                # Host accounting pass the device path skipped.
                gen = planner.merged_candidates(self._postings())
                cands = [gen(qh, qb, float(threshold), int(qs))
                         for qh, qb, qs in zip(hash_rows, bit_rows, sizes)]
        else:
            ids, cands = planner.pruned_batch(
                self._post, hash_rows, bit_rows, sizes, threshold,
                self._pair_score_fn(qp))
            self.last_candidate_sizes = [len(c.rec_ids) for c in cands]
        if explain:
            return self._explained(
                ids, threshold=threshold, t0=t0, cands=cands,
                hash_rows=hash_rows, sizes=sizes, posts=self._postings())
        return ids

    def topk(self, q_ids, k: int, *,
             plan: str = "auto") -> tuple[np.ndarray, np.ndarray]:
        """Planner-aware top-k: postings-driven upper-bound pruning with
        the running k-th score as the moving threshold — exact parity
        with the dense ranking under the deterministic (-score, id)
        order (``plan="dense"`` forces the full sweep)."""
        from repro import planner

        plan = planner.normalize_plan(plan)
        s = self._sketch_pack()
        if plan == "dense" or int(k) <= 0 or s.num_records == 0:
            return super().topk(q_ids, k)
        qp, hash_rows, bit_rows, sizes = self._plan_queries(
            [np.asarray(q_ids)])
        if plan == "auto":
            decision = planner.choose_plan(
                self._postings(), hash_rows, bit_rows, 1.0,
                s.num_records, s.capacity)
            self.last_plan = decision
            if decision.path == "dense":
                return super().topk(q_ids, k)
        else:
            # Forced pruned: record the route like batch_query does, so
            # serving drift accounting sees every planned execution.
            self.last_plan = planner.QueryPlan(
                "pruned", np.nan, np.nan, 0, "forced topk")
        if self._device_prunable and self.backend in ("jnp", "pallas"):
            from repro.planner import device as planner_device

            # Fully device-resident: fused probe→decode→score→lax.top_k,
            # one readback of the [1, k] result pair.
            ids, scores = planner_device.pruned_topk_device(
                SketchArena.from_pack(s), qp, k, backend=self.backend)[0]
            return ids, scores
        return planner.pruned_topk(
            self._postings(), hash_rows[0], bit_rows[0], int(sizes[0]), k,
            self._pair_score_fn(qp), s.num_records)


# ---------------------------------------------------------------------------
# GB-KMV (the paper's contribution) — dynamic inserts via sketchindex.dynamic
# ---------------------------------------------------------------------------


@register_engine("gbkmv")
class GBKMVEngine:
    """GB-KMV: G-KMV tail + top-r frequent-element bitmap buffer."""

    @classmethod
    def build(cls, records, budget, r="auto", seed=0, capacity=None,
              backend="jnp", tau_mode="exact", build_backend=None,
              postings="lazy", windowed=False, epoch=0, **_):
        """Vectorized construction (no per-record Python). ``backend``
        picks the *scoring* implementation; ``build_backend`` the
        construction path — None/"numpy" = host vectorized,
        "jnp"/"pallas" = the fused device hash→τ→pack computation.
        ``tau_mode`` ∈ {"exact", "histogram"} (histogram: two-level
        refine, τ within 2^8 of exact — the distributed selector).
        ``postings="eager"`` encodes the block-compressed postings from
        the packed columns before returning, so the first pruned query
        pays no inversion. ``windowed=True`` returns a
        :class:`repro.sketchindex.WindowManager` instead — a
        time-windowed index whose first epoch is ``epoch`` and whose
        ``insert`` takes an ``epoch=`` kwarg (docs/API.md §Windows)."""
        if windowed:
            return _windowed_build(
                cls.name, records, budget, backend, epoch,
                {"r": r, "seed": seed, "capacity": capacity,
                 "tau_mode": tau_mode, "build_backend": build_backend})
        _validate_postings_arg(postings)
        core = gbkmv_mod.build_gbkmv(records, budget=budget, r=r, seed=seed,
                                     capacity=capacity, tau_mode=tau_mode,
                                     build_backend=build_backend)
        idx = GBKMVApiIndex(core, budget=int(budget), backend=backend)
        _maybe_eager_postings(core.sketches, postings)
        return idx

    @staticmethod
    def wrap(core: gbkmv_mod.GBKMVIndex, budget: int | None = None,
             backend: str = "jnp") -> "GBKMVApiIndex":
        """Adopt an already-built core GBKMVIndex (legacy door)."""
        return GBKMVApiIndex(core, budget=budget, backend=backend)

    @classmethod
    def _load(cls, d: dict) -> "GBKMVApiIndex":
        core = gbkmv_mod.GBKMVIndex(
            sketches=_arena_from_npz(d), tau=np.uint32(d["tau"]),
            top_elems=d["top_elems"], seed=int(d["seed"]),
            buffer_bits=int(d["buffer_bits"]))
        budget = int(d["budget"]) if "budget" in d else -1
        return GBKMVApiIndex(core, budget=budget if budget >= 0 else None,
                             backend=str(d.get("backend", "jnp")))


class GBKMVApiIndex(_PlannedIndexMixin, _IndexBase):
    engine = "gbkmv"
    _device_prunable = True

    def __init__(self, core: gbkmv_mod.GBKMVIndex, budget: int | None,
                 backend: str = "jnp"):
        core.sketches = SketchArena.from_pack(core.sketches)
        self.core = core
        self.budget = budget
        self.backend = normalize_backend(backend)
        self._records = None            # dynamic path needs no raw records
        self._build_cfg = {}

    @property
    def num_records(self) -> int:
        return self.core.num_records

    def _scores(self, q_ids) -> np.ndarray:
        q = gbkmv_mod.sketch_query(self.core, np.asarray(q_ids))
        return gbkmv_mod.containment_scores(self.core, q, backend=self.backend)

    # -- planner plumbing --
    def _sketch_pack(self) -> PackedSketches:
        return self.core.sketches

    def _query_pack(self, queries) -> PackedSketches:
        from repro.sketchindex.distributed import batch_queries

        return batch_queries(self.core, queries)

    def _plan_queries(self, queries):
        from repro.planner.plan import gbkmv_plan_queries

        return gbkmv_plan_queries(self.core, queries)

    def _pair_score_fn(self, qp):
        from repro.kernels import gather_score

        return lambda cand_rec, cand_q: gather_score.score_pairs(
            self._sketch_pack(), qp, cand_rec, cand_q, backend=self.backend)

    def _dense_batch_query(self, queries, threshold,
                           qp=None) -> list[np.ndarray]:
        from repro.planner.prune import threshold_hits_packed

        if qp is None:
            qp = self._query_pack(queries)
        s = containment_matrix(qp, self.core.sketches, backend=self.backend,
                               as_numpy=False)     # device-resident for jnp/pallas
        return threshold_hits_packed(s, threshold)

    def batch_scores(self, queries) -> np.ndarray:
        """f32[m, Gq] — one index sweep for a whole query batch."""
        qp = self._query_pack([np.asarray(q) for q in queries])
        return containment_matrix(qp, self.core.sketches, backend=self.backend)

    def insert(self, new_records, budget: int | None = None):
        """Paper §IV-B dynamic maintenance: τ-retighten, never re-hash old
        rows (``sketchindex.dynamic``). The repacked arena adopts every
        cached postings structure incrementally (τ-truncation + append,
        global and per-shard) inside ``insert_records``."""
        from repro.sketchindex import dynamic

        budget = budget if budget is not None else self.budget
        if budget is None:
            budget = self.core.sketches.lengths.sum() + \
                self.core.num_records * self.core.sketches.buf_words
        self.core, self.stats = dynamic.insert_records(
            self.core, [np.asarray(r) for r in new_records], int(budget))
        return self

    def save(self, path: str) -> None:
        d = _arena_to_npz(self.core.sketches)
        np.savez_compressed(
            path, engine="gbkmv", tau=np.uint32(self.core.tau),
            top_elems=np.asarray(self.core.top_elems, np.int64),
            seed=np.int64(self.core.seed),
            buffer_bits=np.int64(self.core.buffer_bits),
            budget=np.int64(self.budget if self.budget is not None else -1),
            backend=self.backend, **d)

    def nbytes(self) -> int:
        return self.core.nbytes()


# ---------------------------------------------------------------------------
# G-KMV (global threshold, no buffer) and plain KMV (Theorem 1 allocation)
# ---------------------------------------------------------------------------


@register_engine("gkmv")
class GKMVEngine:
    """G-KMV: global hash threshold τ, no frequent-element buffer."""

    @classmethod
    def build(cls, records, budget, seed=0, capacity=None, backend="jnp",
              tau_mode="exact", build_backend=None, postings="lazy",
              windowed=False, epoch=0, **_):
        """Build a G-KMV index (global hash threshold τ from ``budget``).
        Same construction knobs as gbkmv minus the buffer; see
        :meth:`GBKMVEngine.build`. ``windowed=True`` returns a
        :class:`repro.sketchindex.WindowManager` over per-epoch G-KMV
        snapshots."""
        if windowed:
            return _windowed_build(
                cls.name, records, budget, backend, epoch,
                {"seed": seed, "capacity": capacity, "tau_mode": tau_mode,
                 "build_backend": build_backend})
        _validate_postings_arg(postings)
        sk = gkmv_mod.build_gkmv(records, budget=budget, seed=seed,
                                 capacity=capacity, tau_mode=tau_mode,
                                 build_backend=build_backend)
        _maybe_eager_postings(sk, postings)
        tau = int(np.asarray(sk.thresh).max()) if sk.num_records else int(PAD - 1)
        idx = GKMVApiIndex(sk, tau=tau, seed=seed, backend=backend)
        idx._records = _record_list(records)
        idx._build_cfg = {"budget": budget, "seed": seed, "capacity": capacity,
                          "backend": backend}
        return idx

    @staticmethod
    def wrap(sk: PackedSketches, seed: int = 0, backend: str = "jnp"):
        tau = int(np.asarray(sk.thresh).max()) if sk.num_records else int(PAD - 1)
        return GKMVApiIndex(sk, tau=tau, seed=seed, backend=backend)

    @classmethod
    def _load(cls, d: dict) -> "GKMVApiIndex":
        return GKMVApiIndex(_arena_from_npz(d), tau=int(d["tau"]),
                            seed=int(d["seed"]),
                            backend=str(d.get("backend", "jnp")))


class GKMVApiIndex(_PlannedIndexMixin, _IndexBase):
    engine = "gkmv"
    _device_prunable = True

    def __init__(self, sketches: PackedSketches, tau: int, seed: int,
                 backend: str = "jnp"):
        self.sketches = SketchArena.from_pack(sketches)
        self.tau = np.uint32(tau)
        self.seed = seed
        self.backend = normalize_backend(backend)
        self._records = None
        self._build_cfg = {}

    @property
    def num_records(self) -> int:
        return self.sketches.num_records

    def _scores(self, q_ids) -> np.ndarray:
        q = gkmv_mod.sketch_query(np.asarray(q_ids), self.tau, seed=self.seed,
                                  capacity=self.sketches.capacity)
        return containment_matrix(q, self.sketches, backend=self.backend)[:, 0]

    # -- planner plumbing --
    def _sketch_pack(self) -> PackedSketches:
        return self.sketches

    def _query_pack(self, queries) -> PackedSketches:
        return gkmv_mod.sketch_query_batch(
            queries, self.tau, seed=self.seed,
            capacity=self.sketches.capacity)

    def _plan_queries(self, queries):
        qp = self._query_pack(queries)
        vals, lens = np.asarray(qp.values), np.asarray(qp.lengths)
        hash_rows = [vals[g, : lens[g]] for g in range(len(queries))]
        bit_rows = [np.zeros(0, np.int64)] * len(queries)   # no buffer
        return qp, hash_rows, bit_rows, np.asarray(qp.sizes)

    def _pair_score_fn(self, qp):
        from repro.kernels import gather_score

        return lambda cand_rec, cand_q: gather_score.score_pairs(
            self.sketches, qp, cand_rec, cand_q, backend=self.backend)

    def _dense_batch_query(self, queries, threshold,
                           qp=None) -> list[np.ndarray]:
        from repro.planner.prune import threshold_hits_packed

        if qp is None:
            qp = self._query_pack(queries)
        s = containment_matrix(qp, self.sketches, backend=self.backend,
                               as_numpy=False)
        return threshold_hits_packed(s, threshold)

    def save(self, path: str) -> None:
        np.savez_compressed(path, engine="gkmv", tau=np.uint32(self.tau),
                            seed=np.int64(self.seed), backend=self.backend,
                            **_arena_to_npz(self.sketches))

    def nbytes(self) -> int:
        return self.sketches.nbytes()


@register_engine("kmv")
class KMVEngine:
    """Plain KMV, uniform k = floor(budget/m) per record (Theorem 1)."""

    @classmethod
    def build(cls, records, budget, seed=0, backend="jnp",
              build_backend=None, postings="lazy", windowed=False,
              epoch=0, **_):
        """Build a plain-KMV index (uniform k = floor(budget/m) per
        record, Theorem 1). ``windowed=True`` returns a
        :class:`repro.sketchindex.WindowManager` over per-epoch KMV
        snapshots."""
        if windowed:
            return _windowed_build(cls.name, records, budget, backend,
                                   epoch, {"seed": seed,
                                           "build_backend": build_backend})
        _validate_postings_arg(postings)
        sk = kmv_mod.build_kmv(records, budget=budget, seed=seed,
                               build_backend=build_backend)
        _maybe_eager_postings(sk, postings)
        idx = KMVApiIndex(sk, seed=seed, backend=backend)
        idx._records = _record_list(records)
        idx._build_cfg = {"budget": budget, "seed": seed, "backend": backend}
        return idx

    @staticmethod
    def wrap(sk: PackedSketches, seed: int = 0, backend: str = "jnp"):
        return KMVApiIndex(sk, seed=seed, backend=backend)

    @classmethod
    def _load(cls, d: dict) -> "KMVApiIndex":
        return KMVApiIndex(_arena_from_npz(d), seed=int(d["seed"]),
                           backend=str(d.get("backend", "jnp")))


class KMVApiIndex(_PlannedIndexMixin, _IndexBase):
    engine = "kmv"

    def __init__(self, sketches: PackedSketches, seed: int,
                 backend: str = "jnp"):
        self.sketches = SketchArena.from_pack(sketches)
        self.seed = seed
        self.backend = normalize_backend(backend)
        self._records = None
        self._build_cfg = {}

    @property
    def num_records(self) -> int:
        return self.sketches.num_records

    def _query_sketch(self, q_ids) -> np.ndarray:
        """The query's own KMV synopsis: its k smallest hashes, sorted."""
        k = self.sketches.capacity
        return np.sort(hash_u32_np(np.asarray(q_ids), seed=self.seed))[:k]

    def _scores(self, q_ids) -> np.ndarray:
        """Ĉ = D̂∩ / |Q| with the Eq. 8-10 pair estimator (k = min rule)."""
        q_ids = np.asarray(q_ids)
        h = self._query_sketch(q_ids)
        return self._scores_rows(h, len(q_ids), rows=None)

    def _scores_rows(self, q_hashes, q_len: int, rows) -> np.ndarray:
        """Pair estimator against all record rows (rows=None) or a
        gathered candidate subset — identical math either way."""
        from repro.core.estimators import kmv_pair_estimate
        import jax.numpy as jnp

        k = self.sketches.capacity
        qv = np.pad(q_hashes, (0, k - len(q_hashes)), constant_values=PAD)
        xv = np.asarray(self.sketches.values)
        xl = np.asarray(self.sketches.lengths)
        if rows is not None:
            xv, xl = xv[rows], xl[rows]
        d_hat, _, _ = kmv_pair_estimate(
            jnp.asarray(qv), jnp.int32(len(q_hashes)),
            jnp.asarray(xv), jnp.asarray(xl))
        return np.asarray(d_hat) / max(q_len, 1)

    # -- planner plumbing --
    def _sketch_pack(self) -> PackedSketches:
        return self.sketches

    def _plan_queries(self, queries):
        hash_rows = [self._query_sketch(q) for q in queries]
        bit_rows = [np.zeros(0, np.int64)] * len(queries)
        sizes = np.asarray([len(q) for q in queries], np.int64)
        return (hash_rows, sizes), hash_rows, bit_rows, sizes

    def _pair_score_fn(self, qp):
        hash_rows, sizes = qp

        def score(cand_rec, cand_q):
            out = np.zeros(len(cand_rec), np.float32)
            for g in np.unique(cand_q):
                sel = np.nonzero(cand_q == g)[0]
                out[sel] = self._scores_rows(
                    hash_rows[g], int(sizes[g]), rows=cand_rec[sel])
            return out

        return score

    def _dense_batch_query(self, queries, threshold,
                           qp=None) -> list[np.ndarray]:
        from repro.planner.prune import threshold_hits_packed

        if qp is not None:                    # query sketches already hashed
            hash_rows, sizes = qp
            cols = [self._scores_rows(h, int(n), rows=None)
                    for h, n in zip(hash_rows, sizes)]
        else:
            cols = [self._scores(q) for q in queries]
        s = np.stack(cols, axis=-1) if cols else \
            np.zeros((self.num_records, 0), np.float32)
        return threshold_hits_packed(s, threshold)

    def save(self, path: str) -> None:
        np.savez_compressed(path, engine="kmv", seed=np.int64(self.seed),
                            backend=self.backend,
                            **_arena_to_npz(self.sketches))

    def nbytes(self) -> int:
        return self.sketches.nbytes()


# ---------------------------------------------------------------------------
# LSH Ensemble baseline
# ---------------------------------------------------------------------------


@register_engine("lshe")
class LSHEEngine:
    """LSH Ensemble (Zhu et al.): size-partitioned MinHash banding.

    ``budget`` (slots, 32-bit words) maps onto the MinHash count:
    k ≈ budget/m, the same space accounting the sketch engines use.
    """

    @classmethod
    def build(cls, records, budget=None, num_hashes=None, num_partitions=32,
              seed=0, **_):
        if num_hashes is None:
            num_hashes = (max(8, int(budget) // max(len(records), 1))
                          if budget is not None else 256)
        core = lshe_mod.build_lshe(records, num_hashes=num_hashes,
                                   num_partitions=num_partitions, seed=seed)
        idx = LSHEApiIndex(core, seed=seed)
        idx._records = [np.asarray(r) for r in records]
        idx._build_cfg = {"num_hashes": num_hashes,
                          "num_partitions": num_partitions, "seed": seed}
        return idx

    @staticmethod
    def wrap(core: lshe_mod.LSHEnsemble, seed: int = 0):
        return LSHEApiIndex(core, seed=seed)

    @classmethod
    def _load(cls, d: dict) -> "LSHEApiIndex":
        core = lshe_mod.LSHEnsemble(
            signatures=d["signatures"], sizes=d["sizes"], order=d["order"],
            boundaries=d["boundaries"], upper_bounds=d["upper_bounds"],
            num_hashes=int(d["num_hashes"]))
        return LSHEApiIndex(core, seed=int(d["seed"]))


class LSHEApiIndex(_IndexBase):
    engine = "lshe"

    def __init__(self, core: lshe_mod.LSHEnsemble, seed: int = 0):
        self.core = core
        self.seed = seed
        self._records = None
        self._build_cfg = {}

    @property
    def num_records(self) -> int:
        return len(self.core.sizes)

    def query(self, q_ids, threshold: float) -> np.ndarray:
        return lshe_mod.query_lshe(self.core, np.asarray(q_ids), threshold,
                                   seed=self.seed)

    def _scores(self, q_ids) -> np.ndarray:
        """Signature-level containment t̂ (Eq. 14) — the topk ranking."""
        q_ids = np.asarray(q_ids)
        q_sig = minhash_mod.build_signatures([q_ids], self.core.num_hashes,
                                             seed=self.seed)[0]
        s_hat = minhash_mod.jaccard_estimate(q_sig, self.core.signatures)
        return minhash_mod.containment_from_jaccard(
            s_hat, self.core.sizes, len(q_ids)).astype(np.float32)

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, engine="lshe", signatures=self.core.signatures,
            sizes=self.core.sizes, order=self.core.order,
            boundaries=self.core.boundaries,
            upper_bounds=self.core.upper_bounds,
            num_hashes=np.int64(self.core.num_hashes),
            seed=np.int64(self.seed))

    def nbytes(self) -> int:
        return self.core.nbytes()


# ---------------------------------------------------------------------------
# Exact engines (ground truth / strong baselines)
# ---------------------------------------------------------------------------


class _ExactBase(_IndexBase):
    def __init__(self, core: exact_mod.InvertedIndex, records=None):
        self.core = core
        self._records = records
        self._build_cfg = {"budget": None}

    @property
    def num_records(self) -> int:
        return len(self.core.sizes)

    def _scores(self, q_ids) -> np.ndarray:
        counts = exact_mod.intersection_counts(self.core, np.asarray(q_ids))
        return counts.astype(np.float32) / max(len(q_ids), 1)

    def nbytes(self) -> int:
        return int(self.core.sizes.nbytes + sum(
            p.nbytes for p in self.core.postings.values()))


@register_engine("exact")
class ExactEngine:
    """Posting-list counting: exact |Q∩X| in one pass (FrequentSet-style)."""

    @classmethod
    def build(cls, records, budget=None, **_):
        return ExactApiIndex(exact_mod.build_inverted(records),
                             records=[np.asarray(r) for r in records])

    @staticmethod
    def wrap(core: exact_mod.InvertedIndex):
        return ExactApiIndex(core)


class ExactApiIndex(_ExactBase):
    engine = "exact"

    def query(self, q_ids, threshold: float) -> np.ndarray:
        return exact_mod.exact_search(self.core, np.asarray(q_ids), threshold)


@register_engine("prefix")
class PrefixEngine:
    """PPjoin*-adapted prefix filter + exact verification."""

    @classmethod
    def build(cls, records, budget=None, **_):
        return PrefixApiIndex(exact_mod.build_inverted(records),
                              records=[np.asarray(r) for r in records])

    @staticmethod
    def wrap(core: exact_mod.InvertedIndex):
        return PrefixApiIndex(core)


class PrefixApiIndex(_ExactBase):
    engine = "prefix"

    def query(self, q_ids, threshold: float) -> np.ndarray:
        return exact_mod.prefix_filter_search(self.core, np.asarray(q_ids),
                                              threshold)


# ---------------------------------------------------------------------------
# Legacy adoption: wrap pre-API index objects without rebuilding
# ---------------------------------------------------------------------------


def as_index(engine: str, index, seed: int = 0, backend: str = "jnp"):
    """Wrap a legacy core index object (GBKMVIndex, PackedSketches,
    LSHEnsemble, InvertedIndex — or an api index, returned as-is) so the
    old ``run_search(engine, index, ...)`` door keeps working."""
    if isinstance(index, (_IndexBase,)):
        return index
    if hasattr(index, "query") and hasattr(index, "topk"):
        return index                                  # already protocol-shaped
    if engine == "gbkmv":
        return GBKMVEngine.wrap(index, backend=backend)
    if engine == "gkmv":
        return GKMVEngine.wrap(index, seed=seed, backend=backend)
    if engine == "kmv":
        return KMVEngine.wrap(index, seed=seed, backend=backend)
    if engine == "lshe":
        return LSHEEngine.wrap(index, seed=seed)
    if engine == "exact":
        return ExactEngine.wrap(index)
    if engine == "prefix":
        return PrefixEngine.wrap(index)
    raise ValueError(f"unknown engine {engine!r}")
