"""JAX version-compatibility shims.

The codebase targets the modern JAX surface (``jax.shard_map``,
``jax.sharding.AxisType``, positional ``AbstractMesh(shape, names)``)
but must also run on the 0.4.x line this container ships, where those
live under ``jax.experimental`` or use older signatures. Everything that
touches a version-dependent API goes through this module so the rest of
the code stays on one spelling.
"""

from __future__ import annotations

import math

import jax
import numpy as np

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # 0.4.x
    _AxisType = None


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = False):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``check_rep`` maps onto ``check_vma`` (new) / ``check_rep`` (old) —
    both gate the same replication-consistency check.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_rep)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` tolerant of the ``axis_types`` kwarg's absence."""
    if devices is None:
        n = math.prod(shape)
        devices = np.array(jax.devices()[:n])
    if _AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, devices=devices,
                                 axis_types=(_AxisType.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes, devices=devices)


def abstract_mesh(shape, axes):
    """``AbstractMesh`` across the positional-signature change.

    New jax: ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x:
    ``AbstractMesh(tuple(zip(names, sizes)))``.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
