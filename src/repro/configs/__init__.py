from repro.configs.registry import ARCH_IDS, family, get_module, shapes_for  # noqa: F401
from repro.configs.shapes import FAMILY_SHAPES  # noqa: F401
