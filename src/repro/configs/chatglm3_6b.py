"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2D-RoPE (rotary on half the head dim), GQA.
[arXiv:2406.12793; hf]
"""

from repro.models.transformer import LMConfig

ARCH_ID = "chatglm3-6b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13_696,
        vocab=65_024,
        rope_mode="2d",
    )


def reduced() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=512,
        rope_mode="2d",
        chunk_q=32,
    )
