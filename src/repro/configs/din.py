"""din [recsys]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn. [arXiv:1706.06978; paper]

Item table: 10⁶ hashed rows (industrial scale; the assignment leaves the
vocab open — 10⁶ sits in its 10⁶–10⁹ band).
"""

from repro.models.recsys import RecSysConfig

ARCH_ID = "din"
FAMILY = "recsys"


def config() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID,
        kind="din",
        embed_dim=18,
        seq_len=100,
        vocab_rows=1_000_000,
        attn_mlp=(80, 40),
        mlp=(200, 80),
        cand_chunk=8_000,
    )


def reduced() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID + "-smoke",
        kind="din",
        embed_dim=8,
        seq_len=12,
        vocab_rows=500,
        attn_mlp=(16, 8),
        mlp=(24, 12),
        cand_chunk=64,
    )
