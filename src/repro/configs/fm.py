"""fm [recsys]: n_sparse=39 embed_dim=10 interaction=fm-2way — pairwise
⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk) sum-square trick. [ICDM'10 (Rendle); paper]

The 39 sparse fields (Criteo layout) hash into one 10⁶-row table.
"""

from repro.models.recsys import RecSysConfig

ARCH_ID = "fm"
FAMILY = "recsys"


def config() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID,
        kind="fm",
        embed_dim=10,
        n_fields=39,
        vocab_rows=1_000_000,
        cand_chunk=8_000,
    )


def reduced() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID + "-smoke",
        kind="fm",
        embed_dim=4,
        n_fields=8,
        vocab_rows=500,
        cand_chunk=64,
    )
