"""graphsage-reddit [gnn]: n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10. [arXiv:1706.02216; paper]

d_feat / n_classes vary per assigned shape (cora / reddit / products /
molecule) — configs/shapes.py carries them; ``config(d_feat, n_classes)``
builds the matching GNNConfig.
"""

from repro.models.gnn import GNNConfig

ARCH_ID = "graphsage-reddit"
FAMILY = "gnn"


def config(d_feat: int = 602, n_classes: int = 41) -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID,
        n_layers=2,
        d_hidden=128,
        d_feat=d_feat,
        n_classes=n_classes,
        aggregator="mean",
        sample_sizes=(25, 10),
    )


def reduced() -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_hidden=16,
        d_feat=24,
        n_classes=5,
        aggregator="mean",
        sample_sizes=(4, 3),
    )
