"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Per DESIGN.md §6.6: an all-MoE reading of the given numbers lands at
≈773B params, not 400B; we follow Llama-4's published interleaved layout
(every 2nd layer MoE with a shared expert, dense layers d_ff 16384) which
gives ≈400B total / ≈17B active — matching the name. All given
per-component numbers (48L, 5120d, 40H/8kv, 8192 expert d_ff, 128e top-1,
202048 vocab) are taken exactly.

Training memory at this scale needs bf16 Adam moments (DESIGN.md §6) —
set via OptConfig(moment_dtype="bfloat16") in launch/cells.py.
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "llama4-maverick-400b-a17b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,              # expert width
        dense_d_ff=16_384,      # interleaved dense layers
        vocab=202_048,
        rope_mode="full",
        moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, every=2,
                      shared_expert=True),
    )


def reduced() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        dense_d_ff=192,
        vocab=512,
        rope_mode="full",
        chunk_q=32,
        # capacity_factor 8: no token drops at smoke scale, so decode
        # agrees bit-for-bit with the full forward (the 1.25 production
        # factor drops differently under different grouping).
        moe=MoEConfig(num_experts=8, top_k=1, d_ff=96, every=2,
                      shared_expert=True, group_size=256,
                      capacity_factor=8.0),
    )
