"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest. [arXiv:1904.08030; unverified]

Behaviour-sequence length is unspecified by the assignment; 100 chosen to
match DIN (both model user histories).
"""

from repro.models.recsys import RecSysConfig

ARCH_ID = "mind"
FAMILY = "recsys"


def config() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID,
        kind="mind",
        embed_dim=64,
        seq_len=100,
        vocab_rows=1_000_000,
        n_interests=4,
        capsule_iters=3,
        cand_chunk=8_000,
    )


def reduced() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID + "-smoke",
        kind="mind",
        embed_dim=8,
        seq_len=12,
        vocab_rows=500,
        n_interests=2,
        capsule_iters=2,
        cand_chunk=64,
    )
