"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]

Config taken verbatim (DESIGN.md §6.7); every layer is MoE.
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "moonshot-v1-16b-a3b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163_840,
        rope_mode="full",
        moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, every=1),
    )


def reduced() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=512,
        rope_mode="full",
        chunk_q=32,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=96, every=1,
                      group_size=256, capacity_factor=8.0),
    )
