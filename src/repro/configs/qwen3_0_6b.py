"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]

Qwen3 uses head_dim=128 (decoupled from d_model/n_heads = 64).
"""

from repro.models.transformer import LMConfig

ARCH_ID = "qwen3-0.6b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab=151_936,
        head_dim=128,
        qk_norm=True,
        rope_mode="full",
    )


def reduced() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        head_dim=16,
        qk_norm=True,
        rope_mode="full",
        chunk_q=32,
    )
