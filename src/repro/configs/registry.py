"""Architecture registry: ``--arch <id>`` → config module."""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen3-0.6b",
    "stablelm-12b",
    "chatglm3-6b",
    "llama4-maverick-400b-a17b",
    "moonshot-v1-16b-a3b",
    "graphsage-reddit",
    "din",
    "fm",
    "mind",
    "wide-deep",
]

_MODULES = {
    "qwen3-0.6b": "qwen3_0_6b",
    "stablelm-12b": "stablelm_12b",
    "chatglm3-6b": "chatglm3_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "graphsage-reddit": "graphsage_reddit",
    "din": "din",
    "fm": "fm",
    "mind": "mind",
    "wide-deep": "wide_deep",
}


def get_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def family(arch_id: str) -> str:
    return get_module(arch_id).FAMILY


def shapes_for(arch_id: str) -> dict:
    from repro.configs.shapes import FAMILY_SHAPES

    return FAMILY_SHAPES[family(arch_id)]
