"""Assigned input-shape sets, one per architecture family (task spec).

Every (arch × shape) pair is one dry-run/roofline cell; the launcher's
``cells.py`` turns (family, shape dict) into concrete step functions and
ShapeDtypeStruct inputs.
"""

LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32_768,  batch=128),
    # long_500k is a DECODE shape (one token, 512k KV cache) — decode
    # attention is O(L) so it runs for all 5 LM archs with the cache
    # sequence-sharded (DESIGN.md §6.9); no sub-quadratic skip needed.
    "long_500k":   dict(kind="decode",  seq=524_288, batch=1),
}

GNN_SHAPES = {
    # Cora-scale citation graph (full-batch).
    "full_graph_sm": dict(kind="full", n_nodes=2_708, n_edges=10_556,
                          d_feat=1_433, n_classes=7),
    # Reddit (sampled-training): real fanout-sampled minibatches.
    "minibatch_lg":  dict(kind="sampled", n_nodes=232_965,
                          n_edges=114_615_892, batch_nodes=1_024,
                          fanout=(15, 10), d_feat=602, n_classes=41),
    # ogbn-products (full-batch-large).
    "ogb_products":  dict(kind="full", n_nodes=2_449_029,
                          n_edges=61_859_140, d_feat=100, n_classes=47),
    # Batched small dense graphs. d_feat/n_classes are unspecified by the
    # assignment; 64/2 chosen (typical molecular property tasks).
    "molecule":      dict(kind="molecule", n_nodes=30, n_edges=64,
                          batch=128, d_feat=64, n_classes=2),
}

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train",     batch=65_536),
    "serve_p99":      dict(kind="serve",     batch=512),
    "serve_bulk":     dict(kind="serve",     batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
}
