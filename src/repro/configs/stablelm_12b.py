"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]
"""

from repro.models.transformer import LMConfig

ARCH_ID = "stablelm-12b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=13_824,
        vocab=100_352,
        rope_mode="full",
    )


def reduced() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=160,
        vocab=512,
        rope_mode="full",
        chunk_q=32,
    )
