"""wide-deep [recsys]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat. [arXiv:1606.07792; paper]
"""

from repro.models.recsys import RecSysConfig

ARCH_ID = "wide-deep"
FAMILY = "recsys"


def config() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID,
        kind="wide_deep",
        embed_dim=32,
        n_fields=40,
        vocab_rows=1_000_000,
        mlp=(1024, 512, 256),
        cand_chunk=8_000,
    )


def reduced() -> RecSysConfig:
    return RecSysConfig(
        name=ARCH_ID + "-smoke",
        kind="wide_deep",
        embed_dim=8,
        n_fields=8,
        vocab_rows=500,
        mlp=(32, 16),
        cand_chunk=64,
    )
