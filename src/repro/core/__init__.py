# The paper's primary contribution: KMV / G-KMV / GB-KMV sketches,
# estimators, cost model, baselines (MinHash, LSH-E), exact engines,
# and the unified search front end.

from repro.core.gbkmv import GBKMVIndex, build_gbkmv, sketch_query, search  # noqa: F401
from repro.core.gkmv import build_gkmv, select_global_threshold  # noqa: F401
from repro.core.kmv import build_kmv  # noqa: F401
from repro.core.search import evaluate_engine, f_score, run_search  # noqa: F401
