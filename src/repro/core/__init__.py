# The paper's primary contribution: KMV / G-KMV / GB-KMV sketches,
# estimators, cost model, baselines (MinHash, LSH-E), exact engines.
# The unified front end lives in repro.api (engine registry); the
# re-exports below are the legacy spellings kept for compatibility.

from repro.core.gbkmv import GBKMVIndex, build_gbkmv, sketch_query, search  # noqa: F401
from repro.core.gkmv import build_gkmv, select_global_threshold  # noqa: F401
from repro.core.kmv import build_kmv  # noqa: F401
from repro.core.search import evaluate_engine, f_score, run_search  # noqa: F401
