"""Device-resident sketch arena: one packed store for every layer.

The paper's speed claim is a *layout* claim as much as an estimator
claim: containment queries win when the sketch bytes are contiguous and
the hot loop never leaves them. Before this module each layer of the
repo re-materialized its own copies of the packed sketches — the planner
built postings from a throwaway pack, ``ShardedIndex`` sliced per-shard
sub-packs, the device paths re-uploaded columns per call, and save/load
spoke a postings-less dialect. :class:`SketchArena` is the single owner:

    columns    the structure-of-arrays pack (values / lengths / thresh /
               buf / sizes) — a :class:`PackedSketches` subclass, so
               every existing reader of a pack reads an arena unchanged
    postings   the block-compressed hash + buffer-bit inverted index over
               the columns (planner/postings.py delta-bitpacked / dense
               block layout), built once, maintained incrementally
               across inserts — the single at-rest, on-device, and
               on-disk postings format
    shards     per-record-slice postings views for ``ShardedIndex``
               (column *views*, never copies), maintained incrementally
    device     cached jnp mirrors of columns and postings so the pruned
               query path runs candidate-gen → gather-score → packed
               thresholding without a host round-trip

Mutation model: sketches are immutable between inserts. A dynamic insert
builds a *new* arena (sketchindex/dynamic.py repacks rows) and calls
:meth:`adopt_postings_from` on it, which carries the old arena's postings
forward by τ-truncation + append — never a rebuild, never re-hashing old
rows — including every cached per-shard slice. Device mirrors are
re-created lazily on the next device query (one placement per mutation,
then resident).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax

from repro.core.sketches import PackedSketches


@dataclasses.dataclass
class DevicePostings:
    """jnp mirrors of the blocked postings' TAIL store (device residency).

    Only the hash-keyed tail blocks cross to the accelerator: the pruned
    device path recovers the exact buffer intersections o1 directly from
    the packed bitmaps already resident in the device pack (the same
    popcount the dense kernel runs), so the buffer posting lists — the
    bulk of the flat index's bytes — never need a mirror at all. Offsets
    are int32 on device (payload words < 2³¹ — the host index would not
    fit in memory long before that bound binds).
    """

    keys: object          # u32[U]
    row_blocks: object    # i32[U+1]  block range per key
    first: object         # i32[NB]   min record id per block
    last: object          # i32[NB]   max record id per block
    meta: object          # u32[NB]   count-1 | bitwidth<<8 | kind<<13
    off: object           # i32[NB+1] payload word offsets
    payload: object       # u32[P]    bitpacked block bodies
    num_records: int
    # Static property of the STORE (not of any batch): whether any block
    # is dense-bitmap encoded. The fused pipeline compiles its dense
    # while_loop out entirely when False, so it's part of the jit key.
    has_dense: bool = True

    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes) for a in (
            self.keys, self.row_blocks, self.first, self.last, self.meta,
            self.off, self.payload))


@dataclasses.dataclass
class SketchArena(PackedSketches):
    """A :class:`PackedSketches` that owns its derived structures.

    Construction: ``SketchArena.from_pack(pack)`` (idempotent). All
    caches live outside the dataclass fields so ``dataclasses.replace``
    and pytree flatten/unflatten reset them for free.
    """

    def __post_init__(self):
        self._post = None         # planner PostingsIndex | None
        self._shard_posts = None  # (bounds tuple[(lo, hi)], [PostingsIndex])
        self._dev_pack = None     # PackedSketches of jnp arrays
        self._dev_post = None     # DevicePostings

    # -- construction ------------------------------------------------------

    @classmethod
    def from_pack(cls, pack: PackedSketches) -> "SketchArena":
        if isinstance(pack, cls):
            return pack
        return cls(values=pack.values, lengths=pack.lengths,
                   thresh=pack.thresh, buf=pack.buf, sizes=pack.sizes)

    # -- postings ----------------------------------------------------------

    def postings(self):
        """The CSR postings over this arena's columns (built lazily,
        cached until a mutation installs or clears them)."""
        from repro.planner.postings import build_postings

        if self._post is None or self._post.num_records != self.num_records:
            self._post = build_postings(self)
            self._dev_post = None
        return self._post

    def install_postings(self, post) -> None:
        self._post = post
        self._dev_post = None

    def clear_postings(self) -> None:
        self._post = None
        self._shard_posts = None
        self._dev_post = None

    # -- per-shard postings (record-offset slices) -------------------------

    def _column_view(self, lo: int, hi: int) -> PackedSketches:
        """A row-slice view of the columns — numpy basic slicing, no copy."""
        return PackedSketches(
            values=np.asarray(self.values)[lo:hi],
            lengths=np.asarray(self.lengths)[lo:hi],
            thresh=np.asarray(self.thresh)[lo:hi],
            buf=np.asarray(self.buf)[lo:hi],
            sizes=np.asarray(self.sizes)[lo:hi])

    def shard_postings(self, num_shards: int):
        """(postings, row_offsets) over ``num_shards`` record slices.

        Built once from column views and cached; ``adopt_postings_from``
        maintains the cache across inserts (truncate + append), so the
        slice boundaries may lag the mesh's ceil-partition after inserts
        — harmless, because candidate generation unions all slices and
        reports *global* record ids regardless of where the cuts sit.
        """
        if self._shard_posts is not None:
            bounds, posts = self._shard_posts
            if bounds[-1][1] == self.num_records:
                return posts, [lo for lo, _ in bounds]
        from repro.planner.postings import build_postings

        m = self.num_records
        rows = max(-(-m // max(num_shards, 1)), 1)
        bounds, posts = [], []
        for lo in range(0, m, rows):
            hi = min(lo + rows, m)
            posts.append(build_postings(self._column_view(lo, hi)))
            bounds.append((lo, hi))
        self._shard_posts = (tuple(bounds), posts)
        return posts, [lo for lo, _ in bounds]

    # -- incremental maintenance across a dynamic insert -------------------

    def adopt_postings_from(self, old: "SketchArena", tau) -> None:
        """Carry ``old``'s cached postings onto this (post-insert) arena.

        Rows ``[0, old.num_records)`` here are the old records refiltered
        at the new global threshold ``tau`` (τ only decreases under the
        fixed budget); rows beyond are new. Maintenance is therefore
        τ-truncation of every cached postings structure plus an append of
        the new rows — the global postings and every per-shard slice
        update in place, no rebuild.
        """
        from repro.planner.postings import append_rows, truncate_postings

        if not isinstance(old, SketchArena):
            return
        m_old, m_new = old.num_records, self.num_records
        if old._post is not None:
            post = truncate_postings(old._post, np.uint32(tau))
            self._post = append_rows(post, self, m_old, m_new)
            self._dev_post = None
        if old._shard_posts is not None:
            bounds, posts = old._shard_posts
            kept = [truncate_postings(p, np.uint32(tau)) for p in posts]
            # New rows extend the LAST slice (ids local to its row offset).
            lo_last = bounds[-1][0]
            kept[-1] = append_rows(kept[-1], self, m_old, m_new,
                                   rec_offset=-lo_last)
            new_bounds = tuple(bounds[:-1]) + ((lo_last, m_new),)
            self._shard_posts = (new_bounds, kept)

    # -- host/device column residency --------------------------------------

    def ensure_host(self) -> "SketchArena":
        """Pin device-built columns to host numpy in place (one transfer).

        The fused device build leaves columns as jnp arrays; host
        pipelines that read them repeatedly (postings build, shard
        slicing, save) call this once instead of paying a transfer per
        ``np.asarray``. The jnp originals become the cached device pack,
        so device residency is kept, not dropped. No-op for host-built
        arenas.
        """
        import jax.numpy as jnp

        if not isinstance(self.values, np.ndarray):
            if self._dev_pack is None:
                self._dev_pack = PackedSketches(
                    values=jnp.asarray(self.values),
                    lengths=jnp.asarray(self.lengths),
                    thresh=jnp.asarray(self.thresh),
                    buf=jnp.asarray(self.buf),
                    sizes=jnp.asarray(self.sizes))
            self.values = np.asarray(self.values)
            self.lengths = np.asarray(self.lengths)
            self.thresh = np.asarray(self.thresh)
            self.buf = np.asarray(self.buf)
            self.sizes = np.asarray(self.sizes)
        return self

    # -- device mirrors ----------------------------------------------------

    def device_pack(self) -> PackedSketches:
        """jnp mirror of the columns — placed once, then resident.

        Columns that are already jnp arrays (the fused device build
        writes them that way) are adopted as-is: build → query shares
        one device allocation, no host round-trip."""
        import jax.numpy as jnp

        if self._dev_pack is None:
            self._dev_pack = PackedSketches(
                values=jnp.asarray(self.values),
                lengths=jnp.asarray(self.lengths),
                thresh=jnp.asarray(self.thresh),
                buf=jnp.asarray(self.buf),
                sizes=jnp.asarray(self.sizes))
        return self._dev_pack

    def device_postings(self) -> DevicePostings:
        """jnp mirror of the blocked tail store — placed once, then
        resident. Buffer postings stay host-only (o1 comes from the
        device pack's bitmaps), so the mirror is a fraction of the flat
        CSR it replaced."""
        import jax.numpy as jnp

        post = self.postings()
        if self._dev_post is None:
            t = post.tail
            self._dev_post = DevicePostings(
                keys=jnp.asarray(post.keys),
                row_blocks=jnp.asarray(t.row_blocks, jnp.int32),
                first=jnp.asarray(t.first, jnp.int32),
                last=jnp.asarray(t.last, jnp.int32),
                meta=jnp.asarray(t.meta, jnp.uint32),
                off=jnp.asarray(t.off, jnp.int32),
                payload=jnp.asarray(t.payload, jnp.uint32),
                num_records=post.num_records,
                has_dense=bool(
                    np.any((np.asarray(t.meta) >> 13) & 1)))
        return self._dev_post

    def adopt_device_postings(self, dev: DevicePostings) -> None:
        """Install device-built postings mirrors directly (the fused
        device encode produces them without a host round-trip); the host
        :class:`PostingsIndex` is installed separately by the caller."""
        self._dev_post = dev

    # -- space accounting --------------------------------------------------

    def sketch_nbytes(self) -> int:
        """The packed sketch columns alone (the paper's space budget)."""
        return super().nbytes()

    def postings_nbytes(self) -> int:
        """At-rest bytes of the blocked postings (built if absent)."""
        return self.postings().nbytes()

    def nbytes(self) -> int:
        """Honest total: columns + every derived structure currently
        materialized (global postings, per-shard slices, device mirrors
        of both the columns and the postings). The space–accuracy
        benchmarks charge the index for the bytes that make it fast,
        not only for the sketch payload."""
        total = super().nbytes()
        if self._post is not None:
            total += self._post.nbytes()
        if self._shard_posts is not None:
            _, posts = self._shard_posts
            total += sum(p.nbytes() for p in posts)
        if self._dev_pack is not None:
            total += self._dev_pack.nbytes()
        if self._dev_post is not None:
            total += self._dev_post.nbytes()
        return total


    # -- merge / union ------------------------------------------------------

    def merge(self, other: "SketchArena", tail_budget: int,
              **kw) -> "SketchArena":
        """Union this arena with ``other`` under a shared slot budget —
        see :func:`merge_arenas` (this is ``merge_arenas([self, other],
        tail_budget)``)."""
        merged, _ = merge_arenas([self, other], tail_budget, **kw)
        return merged


def flat_kept(pack: PackedSketches) -> tuple[np.ndarray, np.ndarray]:
    """The live packed entries as flat (hash uint32, row int64) streams.

    Row-major (row ascending, hash ascending within a row — rows are
    stored sorted), i.e. already in :func:`repro.core.sketches.pack_csr`
    ``presorted`` order.
    """
    vals = np.asarray(pack.values)
    lens = np.asarray(pack.lengths)
    live = np.arange(pack.capacity)[None, :] < lens[:, None]
    rows = np.repeat(np.arange(pack.num_records, dtype=np.int64),
                     lens.astype(np.int64))
    return vals[live].astype(np.uint32), rows


def merge_arenas(
    arenas,
    tail_budget: int,
    part_taus=None,
    capacity: int | None = None,
) -> tuple["SketchArena", np.uint32]:
    """Union independently built arenas into one, re-tightening τ.

    The KMV-family merge: concatenate the packed columns record-range-
    wise (part i's records become rows ``[off_i, off_i + m_i)``), select
    the new global threshold τ′ as the ``tail_budget``-th smallest hash
    of the kept union, refilter every row at ``min(row_thresh, τ′)``,
    and repack. Returns ``(merged_arena, τ′)``.

    **Bit-identity contract**: when every part was built from disjoint
    record sets with the *same* budget ``tail_budget`` (and no binding
    ``capacity`` cap), the result is bit-identical to rebuilding from
    the concatenated records. Proof sketch: the rebuild's τ is the
    budget-th smallest hash of the full union, which is ≤ every part's
    τ_i (a superset's k-th order statistic never exceeds a subset's),
    so every hash the rebuild keeps is already stored in its part and
    the budget-th smallest of the *kept* union equals the rebuild's τ.
    Parts built with smaller budgets may have dropped hashes below the
    merged τ′ — the merge is then still a valid sketch (per-row
    thresholds keep τ_pair semantics exact) but not rebuild-identical.

    ``part_taus`` optionally passes each part's global τ (used only to
    disambiguate the boundary case where the kept union has exactly
    ``tail_budget`` entries); it defaults to each part's max row
    threshold, which is exact whenever some row did not overflow.

    Block-postings are spliced, not rebuilt: part 0's cached postings
    are τ′-truncated and the remaining parts' rows appended
    (`planner.postings.truncate_postings` + `append_rows`) — block-for-
    block identical to a fresh build over the merged arena. Parts after
    the first contribute their rows through the merged columns, so
    their own cached postings are not consulted.
    """
    from repro.core.hashing import PAD
    from repro.core.sketches import pack_csr

    arenas = [SketchArena.from_pack(a) for a in arenas]
    if not arenas:
        raise ValueError("merge_arenas needs at least one arena")
    widths = {a.buf_words for a in arenas}
    if len(widths) != 1:
        raise ValueError(f"buffer widths differ across parts: {widths} — "
                         "merge requires one shared top-elements set")

    parts = [flat_kept(a) for a in arenas]
    counts_m = [a.num_records for a in arenas]
    offs = np.concatenate([[0], np.cumsum(counts_m)]).astype(np.int64)
    m = int(offs[-1])
    flat_h = np.concatenate([h for h, _ in parts]) if m else \
        np.zeros(0, np.uint32)
    flat_row = np.concatenate(
        [r + offs[i] for i, (_, r) in enumerate(parts)]) if m else \
        np.zeros(0, np.int64)
    thr_old = np.concatenate([np.asarray(a.thresh, np.uint32)
                              for a in arenas])
    sizes = np.concatenate([np.asarray(a.sizes, np.int32) for a in arenas])
    buf = np.vstack([np.asarray(a.buf, np.uint32) for a in arenas])

    if part_taus is None:
        part_taus = [np.asarray(a.thresh).max() if a.num_records else
                     np.uint32(PAD - np.uint32(1)) for a in arenas]
    pad1 = np.uint32(PAD - np.uint32(1))
    tail_budget = int(tail_budget)
    # τ′ binds strictly when the kept union exceeds the budget. At exactly
    # budget entries it binds only if some part dropped hashes (then the
    # virtual full union is larger and its budget-th smallest is the kept
    # max); with no drops anywhere the rebuild keeps everything (τ=PAD-1).
    binds = len(flat_h) > tail_budget or (
        len(flat_h) == tail_budget
        and any(np.uint32(t) < pad1 for t in part_taus))
    if binds and tail_budget > 0:
        tau = np.uint32(np.partition(flat_h, tail_budget - 1)
                        [tail_budget - 1])
    else:
        tau = pad1
    thr = np.minimum(thr_old, tau)
    keep = flat_h <= thr[flat_row]
    packed = pack_csr(flat_h[keep], flat_row[keep], m, thr, sizes,
                      bitmaps=buf, capacity=capacity, presorted=True)
    merged = SketchArena.from_pack(packed)

    # Splice part 0's cached postings forward (τ-truncate + append the
    # remaining rows) — but only if packing did not *further* truncate
    # any row via the capacity cap (then the spliced entries would not
    # match the stored columns; leave postings to rebuild lazily).
    src = arenas[0]
    if src._post is not None and np.array_equal(
            np.asarray(merged.thresh), thr):
        from repro.planner.postings import append_rows, truncate_postings

        post = truncate_postings(src._post, tau)
        m0 = counts_m[0]
        if m > m0:
            post = append_rows(post, merged, m0, m)
        merged.install_postings(post)
    return merged, tau


# An arena IS a pack — let it cross jit boundaries the same way (caches
# reset on unflatten via __post_init__, which is exactly right: a traced
# arena cannot carry host-side caches).
jax.tree_util.register_dataclass(
    SketchArena,
    data_fields=["values", "lengths", "thresh", "buf", "sizes"],
    meta_fields=[],
)
