"""GB-KMV buffer-size cost model (paper §IV-C6).

The paper derives ``Var_GBKMV = f(r, α1, α2, b)`` under power-law element
frequency (exponent α1) and record size (α2), then picks ``r`` numerically
on a grid (Abel's theorem rules out a closed-form root).

We implement the same variance functional in its *empirical* form — the
F/L statistics (f_r, f_{n²}, f_{r²}, size moments) are computed from the
actual dataset instead of the fitted power law, which is strictly more
accurate and reduces to the paper's formula when the data is exactly
power-law. A power-law-parameterized wrapper is provided for the Fig. 5
reproduction and for datasets summarized only by (α1, α2).
"""

from __future__ import annotations

import numpy as np


def pair_variance(d_cap: np.ndarray, d_cup: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Var[D̂∩] — paper Eq. 11, vectorized.

    k <= 2 leaves Eq. 11 undefined (the estimator degenerates); the error
    of a degenerate tail is bounded by missing the tail intersection
    entirely, so we charge D∩² (squared-error worst case) instead of +inf
    — without this, the §IV-C6 optimizer can never prefer a buffer large
    enough to shrink the tail below the estimator's working range.
    """
    d_cap = np.asarray(d_cap, dtype=np.float64)
    d_cup = np.asarray(d_cup, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    num = d_cap * (k * d_cup - k * k - d_cup + k + d_cap)
    den = k * (k - 2.0)
    out = np.where(den > 0, num / np.maximum(den, 1e-12), np.square(d_cap))
    return np.maximum(out, 0.0)


def _stats_for_r(freqs: np.ndarray, r: int):
    """(f_r, f_n2 - f_r2) for buffer size r over sorted-descending freqs."""
    n_total = float(freqs.sum())
    if n_total <= 0:
        return 0.0, 0.0
    fr = float(freqs[:r].sum()) / n_total
    fn2 = float((freqs.astype(np.float64) ** 2).sum()) / n_total**2
    fr2 = float((freqs[:r].astype(np.float64) ** 2).sum()) / n_total**2
    return fr, fn2 - fr2


def gbkmv_variance(
    freqs: np.ndarray,
    sizes: np.ndarray,
    budget: int,
    m: int,
    r: int,
    rng: np.random.Generator | None = None,
    n_pairs: int = 4096,
) -> float:
    """Average Var[Ĉ_GBKMV] over random (query, record) pairs at buffer r.

    Implements §IV-C6: buffer eats ``m·r/32`` slots; the tail G-KMV gets
    ``τ = (b - m·r/32) / N_tail``; per-pair moments feed Eq. 11; the
    query is a random record (third assumption in §IV-C1).
    """
    freqs = np.sort(np.asarray(freqs, dtype=np.float64))[::-1]
    sizes = np.asarray(sizes, dtype=np.float64)
    n_total = float(freqs.sum())
    words = -(-r // 32) if r else 0
    t2 = float(budget - m * words)
    if t2 <= 0:
        return np.inf
    fr, tail_fn2 = _stats_for_r(freqs, r)
    n_tail = n_total * (1.0 - fr)
    if n_tail <= 0:
        return 0.0  # everything buffered — exact answers
    tau = min(t2 / n_tail, 1.0)

    rng = rng or np.random.default_rng(0)
    j = rng.integers(0, len(sizes), size=n_pairs)
    l = rng.integers(0, len(sizes), size=n_pairs)
    xj, xl = sizes[j], sizes[l]

    d_cap = xj * xl * tail_fn2                  # expected tail intersection
    tail_j = xj * (1.0 - fr)
    tail_l = xl * (1.0 - fr)
    d_cup = np.maximum(tail_j + tail_l - d_cap, 1.0)
    k = tau * (tail_j + tail_l) - tau**2 * xj * xl * tail_fn2
    k = np.maximum(k, 0.0)

    var = pair_variance(d_cap, d_cup, k) / np.maximum(xj, 1.0) ** 2
    return float(var.mean())


def choose_buffer_size(
    freqs: np.ndarray,
    sizes: np.ndarray,
    budget: int,
    m: int,
    grid_step: int = 8,
    max_r: int | None = None,
    rng: np.random.Generator | None = None,
) -> int:
    """Numerical minimization of the §IV-C6 variance on an r-grid.

    Grid is {0, 8, 16, ...} (the paper assigns 8, 16, 24, …), bounded by
    the number of distinct elements and by the budget (buffer may consume
    at most half the budget — the G-KMV tail must keep enough resolution,
    enforcing the paper's ``V_Δ < 0`` feasibility constraint in spirit).
    """
    freqs = np.sort(np.asarray(freqs, dtype=np.float64))[::-1]
    n_distinct = len(freqs)
    cap = max_r if max_r is not None else n_distinct
    cap = min(cap, n_distinct, int(32 * (budget / 2) / max(m, 1)))
    best_r, best_v = 0, gbkmv_variance(freqs, sizes, budget, m, 0, rng=rng)
    r = grid_step
    while r <= cap:
        v = gbkmv_variance(freqs, sizes, budget, m, r, rng=rng)
        if v < best_v:
            best_r, best_v = r, v
        r += grid_step
    return best_r


# ---------------------------------------------------------------------------
# Query-path cost model (planner): dense sweep vs postings-pruned verify.
#
# Relative units — one unit ≈ scoring one (record-slot × query) pair in
# the vectorized sweep. Host-side posting merges touch scattered memory
# and the ragged verify pays gather overhead, so their per-item weights
# are calibrated above 1; each path also carries a fixed dispatch cost
# per query batch. The constants only need to rank the two paths, not
# predict wall-clock.
#
# The hand-set defaults below can be replaced by MEASURED constants:
# ``benchmarks.run --suite planner --json --calibrate`` fits them from
# the BENCH_PLANNER.json QPS trajectory (fit_query_constants) and writes
# them into the artifact's "calibration" key; ``load_calibration`` (or
# the REPRO_COST_CALIBRATION env var pointing at such a file) installs
# them, after which ``plan="auto"`` decisions use the fitted values.
# ---------------------------------------------------------------------------

import json
import os

DENSE_COST_PER_SLOT = 1.0     # one record-slot scored for one query
PRUNE_COST_PER_HIT = 6.0      # one posting entry decoded + merged on host
PRUNE_COST_PER_CAND_SLOT = 3.0  # one gather-scored candidate slot
PRUNE_FIXED_PER_QUERY = 2048.0  # postings probe + ragged dispatch
PRUNE_COST_PER_BLOCK = 12.0   # block header check + bitpack/bitmap decode
                              # setup (the compressed-postings merge pays
                              # per touched block, not only per entry)

_CAL_KEYS = ("dense_cost_per_slot", "prune_cost_per_hit",
             "prune_cost_per_cand_slot", "prune_fixed_per_query")
_calibration: dict | None = None
_env_checked = False


def set_calibration(cal: dict | None) -> None:
    """Install fitted query-path constants (None restores the defaults).

    ``prune_cost_per_block`` is optional: fits from pre-block artifacts
    fold block-decode time into the per-hit constant (hits and touched
    blocks are strongly collinear on one workload), so a missing key
    means 0.0 under calibration — never the hand-set default on top of
    an already-inclusive fitted per-hit cost.
    """
    global _calibration
    if cal is not None:
        missing = [k for k in _CAL_KEYS if k not in cal]
        if missing:
            raise ValueError(f"calibration missing keys: {missing}")
        installed = {k: float(cal[k]) for k in _CAL_KEYS}
        installed["prune_cost_per_block"] = float(
            cal.get("prune_cost_per_block", 0.0))
        cal = installed
    _calibration = cal


def load_calibration(path: str) -> dict:
    """Read calibration from a JSON file — either a bare constants dict
    or a BENCH_PLANNER.json artifact with a "calibration" key — and
    install it."""
    with open(path) as f:
        payload = json.load(f)
    cal = payload.get("calibration", payload)
    set_calibration(cal)
    return cal


def calibration() -> dict | None:
    """The installed calibration, auto-loading $REPRO_COST_CALIBRATION
    (a path) on first use."""
    global _env_checked
    if _calibration is None and not _env_checked:
        _env_checked = True
        path = os.environ.get("REPRO_COST_CALIBRATION", "")
        if path and os.path.exists(path):
            try:
                load_calibration(path)
            except (ValueError, KeyError, json.JSONDecodeError):
                pass  # malformed artifact: keep hand-set defaults
    return _calibration


def dense_sweep_cost(m: int, capacity: int, gq: int) -> float:
    """Cost of scoring the full [m, Gq] matrix (one index sweep)."""
    cal = calibration()
    a = cal["dense_cost_per_slot"] if cal else DENSE_COST_PER_SLOT
    return a * float(m) * float(max(capacity, 1)) * max(gq, 1)


def pruned_path_cost(hits: int, capacity: int, gq: int,
                     blocks: int = 0) -> float:
    """Cost of block decode + merge + ragged verify; ``hits`` = posting
    entries touched by the batch's query hashes/bits (upper-bounds the
    candidate count), ``blocks`` = compressed posting blocks those
    entries live in (each pays a header check + decode setup)."""
    cal = calibration()
    if cal:
        f, h, s, b = (cal["prune_fixed_per_query"],
                      cal["prune_cost_per_hit"],
                      cal["prune_cost_per_cand_slot"],
                      cal["prune_cost_per_block"])
    else:
        f, h, s, b = (PRUNE_FIXED_PER_QUERY, PRUNE_COST_PER_HIT,
                      PRUNE_COST_PER_CAND_SLOT, PRUNE_COST_PER_BLOCK)
    return (f * max(gq, 1) + h * float(hits) + b * float(blocks)
            + s * float(hits) * float(max(capacity, 1)))


def fit_query_constants(
    rows: list[dict], m: int, capacity: int,
) -> dict:
    """Fit the query-path constants from measured planner-bench rows.

    Rows with ``qps_dense`` anchor the dense model; rows with
    ``qps_pruned`` + ``mean_probe_hits`` feed the pruned regression
    (bench_planner adds calibration-only rows at truncated query sizes,
    because probe hits do NOT vary with threshold — without hit spread
    the fixed/per-hit split is unidentifiable). The model is per-query
    seconds

        t_dense  = a · m · capacity
        t_pruned = fixed + g · hits            (g = per-hit merge+verify)

    expressed in relative units with ``dense_cost_per_slot`` normalized
    to 1 (only the *ranking* of the two paths matters to the planner).
    ``g`` splits between per-hit and per-candidate-slot terms in the
    defaults' proportion, so the fitted model stays comparable across
    capacities near the calibration point.
    """
    t_dense = np.asarray([1.0 / r["qps_dense"] for r in rows
                          if "qps_dense" in r], np.float64)
    a = float(t_dense.mean()) / (float(m) * float(max(capacity, 1)))

    pr = [r for r in rows if "qps_pruned" in r and "mean_probe_hits" in r]
    t_pruned = np.asarray([1.0 / r["qps_pruned"] for r in pr], np.float64)
    hits = np.asarray([r["mean_probe_hits"] for r in pr], np.float64)
    if len(pr) >= 2 and np.ptp(hits) > 1e-6 * max(float(hits.max()), 1.0):
        design = np.stack([np.ones_like(hits), hits], axis=1)
        (fixed, g), *_ = np.linalg.lstsq(design, t_pruned, rcond=None)
        fixed, g = max(float(fixed), 0.0), max(float(g), 1e-12)
    else:
        # Degenerate spread (constant hits): the split is unidentifiable.
        # Keep the default fixed cost (converted to measured seconds) and
        # attribute the remaining measured time to the per-hit term.
        fixed = a * PRUNE_FIXED_PER_QUERY
        g = max(float(t_pruned.mean()) - fixed, 1e-12 * a) \
            / max(float(hits.mean()), 1.0)

    # Split g between merge and verify in the defaults' proportion.
    h0 = PRUNE_COST_PER_HIT
    s0 = PRUNE_COST_PER_CAND_SLOT * max(capacity, 1)
    w = h0 / (h0 + s0)
    return {
        "dense_cost_per_slot": 1.0,
        "prune_fixed_per_query": fixed / a,
        "prune_cost_per_hit": (g * w) / a,
        "prune_cost_per_cand_slot": (g * (1.0 - w)) / (a * max(capacity, 1)),
        "fit": {"m": int(m), "capacity": int(capacity),
                "seconds_per_unit": a},
    }


# ---------------------------------------------------------------------------
# Power-law-parameterized wrapper: f(r, α1, α2, b)   (Fig. 5 / §IV-C6)
# ---------------------------------------------------------------------------

def powerlaw_variance(
    r: int,
    alpha1: float,
    alpha2: float,
    budget: int,
    n_elems: int,
    m: int,
    size_min: float = 10.0,
    size_max: float = 5000.0,
) -> float:
    """Var_GBKMV = f(r, α1, α2, b): instantiate the implied power-law
    frequency/size profiles and evaluate the empirical functional on them."""
    ranks = np.arange(1, n_elems + 1, dtype=np.float64)
    freqs = ranks ** (-alpha1)
    freqs *= (m * (size_min + size_max) / 2.0) / freqs.sum()  # scale to N
    u = np.linspace(1e-6, 1 - 1e-6, m)
    if abs(alpha2 - 1.0) < 1e-9:
        sizes = size_min * (size_max / size_min) ** u
    else:
        a = 1.0 - alpha2
        sizes = (size_min**a + u * (size_max**a - size_min**a)) ** (1.0 / a)
    return gbkmv_variance(freqs, sizes, budget, m, r)


def fit_power_law_exponent(values: np.ndarray, x_min: float = 1.0) -> float:
    """Continuous MLE α̂ = 1 + n / Σ ln(x/x_min) (Clauset et al. 2009)."""
    x = np.asarray(values, dtype=np.float64)
    x = x[x >= x_min]
    if len(x) == 0:
        return 1.0
    return 1.0 + len(x) / max(float(np.log(x / x_min).sum()), 1e-12)
