"""GB-KMV buffer-size cost model (paper §IV-C6).

The paper derives ``Var_GBKMV = f(r, α1, α2, b)`` under power-law element
frequency (exponent α1) and record size (α2), then picks ``r`` numerically
on a grid (Abel's theorem rules out a closed-form root).

We implement the same variance functional in its *empirical* form — the
F/L statistics (f_r, f_{n²}, f_{r²}, size moments) are computed from the
actual dataset instead of the fitted power law, which is strictly more
accurate and reduces to the paper's formula when the data is exactly
power-law. A power-law-parameterized wrapper is provided for the Fig. 5
reproduction and for datasets summarized only by (α1, α2).
"""

from __future__ import annotations

import numpy as np


def pair_variance(d_cap: np.ndarray, d_cup: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Var[D̂∩] — paper Eq. 11, vectorized.

    k <= 2 leaves Eq. 11 undefined (the estimator degenerates); the error
    of a degenerate tail is bounded by missing the tail intersection
    entirely, so we charge D∩² (squared-error worst case) instead of +inf
    — without this, the §IV-C6 optimizer can never prefer a buffer large
    enough to shrink the tail below the estimator's working range.
    """
    d_cap = np.asarray(d_cap, dtype=np.float64)
    d_cup = np.asarray(d_cup, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    num = d_cap * (k * d_cup - k * k - d_cup + k + d_cap)
    den = k * (k - 2.0)
    out = np.where(den > 0, num / np.maximum(den, 1e-12), np.square(d_cap))
    return np.maximum(out, 0.0)


def _stats_for_r(freqs: np.ndarray, r: int):
    """(f_r, f_n2 - f_r2) for buffer size r over sorted-descending freqs."""
    n_total = float(freqs.sum())
    if n_total <= 0:
        return 0.0, 0.0
    fr = float(freqs[:r].sum()) / n_total
    fn2 = float((freqs.astype(np.float64) ** 2).sum()) / n_total**2
    fr2 = float((freqs[:r].astype(np.float64) ** 2).sum()) / n_total**2
    return fr, fn2 - fr2


def gbkmv_variance(
    freqs: np.ndarray,
    sizes: np.ndarray,
    budget: int,
    m: int,
    r: int,
    rng: np.random.Generator | None = None,
    n_pairs: int = 4096,
) -> float:
    """Average Var[Ĉ_GBKMV] over random (query, record) pairs at buffer r.

    Implements §IV-C6: buffer eats ``m·r/32`` slots; the tail G-KMV gets
    ``τ = (b - m·r/32) / N_tail``; per-pair moments feed Eq. 11; the
    query is a random record (third assumption in §IV-C1).
    """
    freqs = np.sort(np.asarray(freqs, dtype=np.float64))[::-1]
    sizes = np.asarray(sizes, dtype=np.float64)
    n_total = float(freqs.sum())
    words = -(-r // 32) if r else 0
    t2 = float(budget - m * words)
    if t2 <= 0:
        return np.inf
    fr, tail_fn2 = _stats_for_r(freqs, r)
    n_tail = n_total * (1.0 - fr)
    if n_tail <= 0:
        return 0.0  # everything buffered — exact answers
    tau = min(t2 / n_tail, 1.0)

    rng = rng or np.random.default_rng(0)
    j = rng.integers(0, len(sizes), size=n_pairs)
    l = rng.integers(0, len(sizes), size=n_pairs)
    xj, xl = sizes[j], sizes[l]

    d_cap = xj * xl * tail_fn2                  # expected tail intersection
    tail_j = xj * (1.0 - fr)
    tail_l = xl * (1.0 - fr)
    d_cup = np.maximum(tail_j + tail_l - d_cap, 1.0)
    k = tau * (tail_j + tail_l) - tau**2 * xj * xl * tail_fn2
    k = np.maximum(k, 0.0)

    var = pair_variance(d_cap, d_cup, k) / np.maximum(xj, 1.0) ** 2
    return float(var.mean())


def choose_buffer_size(
    freqs: np.ndarray,
    sizes: np.ndarray,
    budget: int,
    m: int,
    grid_step: int = 8,
    max_r: int | None = None,
    rng: np.random.Generator | None = None,
) -> int:
    """Numerical minimization of the §IV-C6 variance on an r-grid.

    Grid is {0, 8, 16, ...} (the paper assigns 8, 16, 24, …), bounded by
    the number of distinct elements and by the budget (buffer may consume
    at most half the budget — the G-KMV tail must keep enough resolution,
    enforcing the paper's ``V_Δ < 0`` feasibility constraint in spirit).
    """
    freqs = np.sort(np.asarray(freqs, dtype=np.float64))[::-1]
    n_distinct = len(freqs)
    cap = max_r if max_r is not None else n_distinct
    cap = min(cap, n_distinct, int(32 * (budget / 2) / max(m, 1)))
    best_r, best_v = 0, gbkmv_variance(freqs, sizes, budget, m, 0, rng=rng)
    r = grid_step
    while r <= cap:
        v = gbkmv_variance(freqs, sizes, budget, m, r, rng=rng)
        if v < best_v:
            best_r, best_v = r, v
        r += grid_step
    return best_r


# ---------------------------------------------------------------------------
# Query-path cost model (planner): dense sweep vs postings-pruned verify.
#
# Relative units — one unit ≈ scoring one (record-slot × query) pair in
# the vectorized sweep. Host-side posting merges touch scattered memory
# and the ragged verify pays gather overhead, so their per-item weights
# are calibrated above 1; each path also carries a fixed dispatch cost
# per query batch. The constants only need to rank the two paths, not
# predict wall-clock.
# ---------------------------------------------------------------------------

DENSE_COST_PER_SLOT = 1.0     # one record-slot scored for one query
PRUNE_COST_PER_HIT = 6.0      # one posting entry merged on host
PRUNE_COST_PER_CAND_SLOT = 3.0  # one gather-scored candidate slot
PRUNE_FIXED_PER_QUERY = 2048.0  # postings probe + ragged dispatch


def dense_sweep_cost(m: int, capacity: int, gq: int) -> float:
    """Cost of scoring the full [m, Gq] matrix (one index sweep)."""
    return DENSE_COST_PER_SLOT * float(m) * float(max(capacity, 1)) * max(gq, 1)


def pruned_path_cost(hits: int, capacity: int, gq: int) -> float:
    """Cost of merge + ragged verify; ``hits`` = posting entries touched
    by the batch's query hashes/bits (upper-bounds the candidate count)."""
    return (PRUNE_FIXED_PER_QUERY * max(gq, 1)
            + PRUNE_COST_PER_HIT * float(hits)
            + PRUNE_COST_PER_CAND_SLOT * float(hits) * float(max(capacity, 1)))


# ---------------------------------------------------------------------------
# Power-law-parameterized wrapper: f(r, α1, α2, b)   (Fig. 5 / §IV-C6)
# ---------------------------------------------------------------------------

def powerlaw_variance(
    r: int,
    alpha1: float,
    alpha2: float,
    budget: int,
    n_elems: int,
    m: int,
    size_min: float = 10.0,
    size_max: float = 5000.0,
) -> float:
    """Var_GBKMV = f(r, α1, α2, b): instantiate the implied power-law
    frequency/size profiles and evaluate the empirical functional on them."""
    ranks = np.arange(1, n_elems + 1, dtype=np.float64)
    freqs = ranks ** (-alpha1)
    freqs *= (m * (size_min + size_max) / 2.0) / freqs.sum()  # scale to N
    u = np.linspace(1e-6, 1 - 1e-6, m)
    if abs(alpha2 - 1.0) < 1e-9:
        sizes = size_min * (size_max / size_min) ** u
    else:
        a = 1.0 - alpha2
        sizes = (size_min**a + u * (size_max**a - size_min**a)) ** (1.0 / a)
    return gbkmv_variance(freqs, sizes, budget, m, r)


def fit_power_law_exponent(values: np.ndarray, x_min: float = 1.0) -> float:
    """Continuous MLE α̂ = 1 + n / Σ ln(x/x_min) (Clauset et al. 2009)."""
    x = np.asarray(values, dtype=np.float64)
    x = x[x >= x_min]
    if len(x) == 0:
        return 1.0
    return 1.0 + len(x) / max(float(np.log(x / x_min).sum()), 1e-12)
