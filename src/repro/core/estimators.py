"""Shared KMV estimator math (paper §II-C, §IV-A).

Used by three layers: the packed-index scoring path, the Pallas kernel's
pure-jnp oracle (kernels/ref.py delegates here), and NumPy test oracles.

All pair estimators are vectorized: one query row against ``m`` record rows.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.hashing import PAD, TWO32


def _count_le(sorted_vals, lengths, bound):
    """#values <= bound per row of a PAD-padded ascending matrix.

    ``sorted_vals`` uint32[m, C]; ``bound`` uint32[m] or scalar.
    PAD never counts because bound < PAD always (thresholds are real hashes).
    """
    b = jnp.asarray(bound, dtype=jnp.uint32)
    if b.ndim == 0:
        b = b[None]
    return jnp.sum(sorted_vals <= b[:, None], axis=-1).astype(jnp.int32)


def gkmv_pair_estimate(
    q_values, q_length, q_thresh,
    x_values, x_lengths, x_thresh,
):
    """G-KMV intersection estimator D̂∩ (Eq. 25) under pairwise thresholds.

    Args:
      q_values:  uint32[Cq]    query sketch (sorted, PAD-padded)
      q_length:  int32 scalar
      q_thresh:  uint32 scalar
      x_values:  uint32[m, C]  record sketches
      x_lengths: int32[m]
      x_thresh:  uint32[m]

    Returns (d_hat f32[m], k i32[m], k_cap i32[m]).
    """
    q_values = jnp.asarray(q_values, dtype=jnp.uint32)
    x_values = jnp.asarray(x_values, dtype=jnp.uint32)
    tau_pair = jnp.minimum(jnp.asarray(x_thresh, jnp.uint32),
                           jnp.asarray(q_thresh, jnp.uint32))  # [m]

    nq = _count_le(q_values[None, :], None, tau_pair)          # [m] query vals ≤ τ_pair
    nx = _count_le(x_values, None, tau_pair)                   # [m]

    # Membership: each record value ≤ τ_pair that also appears in the query
    # sketch. Both rows are sorted & duplicate-free, so equality-broadcast
    # against the query row counts exactly the common values.
    live = x_values <= tau_pair[:, None]                        # [m, C]
    member = jnp.any(x_values[:, :, None] == q_values[None, None, :], axis=-1)
    k_cap = jnp.sum(live & member, axis=-1).astype(jnp.int32)   # K∩ [m]

    k = nq + nx - k_cap                                         # |L_Q ∪ L_X| [m]

    # U_(k): largest hash ≤ τ_pair in either row. Rows are ascending, so it
    # is max(last-live-of-Q, last-live-of-X).
    def last_live(vals, n):
        idx = jnp.maximum(n - 1, 0)
        v = jnp.take_along_axis(vals, idx[:, None].astype(jnp.int32), axis=-1)[:, 0]
        return jnp.where(n > 0, v, jnp.uint32(0))

    uq = last_live(jnp.broadcast_to(q_values[None, :], (x_values.shape[0],) + q_values.shape), nq)
    ux = last_live(x_values, nx)
    u = jnp.maximum(uq, ux)
    u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

    valid = (k >= 2) & (k_cap >= 1)
    d_hat = jnp.where(
        valid,
        (k_cap.astype(jnp.float32) / jnp.maximum(k, 1).astype(jnp.float32))
        * ((k.astype(jnp.float32) - 1.0) / jnp.maximum(u_unit, 1e-30)),
        jnp.where(k_cap >= 1, k_cap.astype(jnp.float32), 0.0),
    )
    return d_hat, k, k_cap


def buffer_intersection(q_buf, x_buf):
    """|H_Q ∩ H_X| via AND + popcount. q_buf uint32[W], x_buf uint32[m, W]."""
    if x_buf.shape[-1] == 0:
        return jnp.zeros(x_buf.shape[0], dtype=jnp.int32)
    from jax import lax
    inter = jnp.bitwise_and(x_buf, q_buf[None, :])
    return jnp.sum(lax.population_count(inter), axis=-1).astype(jnp.int32)


def gbkmv_containment(
    q, index, *, exact_when_full: bool = False,
):
    """Full GB-KMV containment estimate Ĉ(Q→X) per record (Eq. 26/27).

    ``q`` / ``index`` are PackedSketches (q has one row). Returns f32[m].

    ``exact_when_full`` (beyond-paper, default off): when both rows kept
    every element below their threshold *and* the threshold covers the whole
    set (lengths == sizes - buffered elements isn't tracked; we use the
    conservative check k_cap == d_hat rounding), use K∩ + buffer exactly.
    """
    d_hat, k, k_cap = gkmv_pair_estimate(
        q.values[0], q.lengths[0], q.thresh[0],
        index.values, index.lengths, index.thresh,
    )
    o1 = buffer_intersection(q.buf[0], index.buf)
    qsize = jnp.maximum(q.sizes[0].astype(jnp.float32), 1.0)
    est_inter = o1.astype(jnp.float32) + d_hat
    if exact_when_full:
        # If the pair's k equals the estimated union (all elements seen),
        # the sketch intersection is exact.
        est_inter = jnp.where(k_cap == k, o1.astype(jnp.float32) + k_cap, est_inter)
    return est_inter / qsize


# ---------------------------------------------------------------------------
# Backend dispatch: one scoring door for numpy / jnp / pallas.
# ---------------------------------------------------------------------------

BACKENDS = ("numpy", "jnp", "pallas")


def normalize_backend(backend: str | None, impl: str | None = None) -> str:
    """Resolve the public ``backend=`` option (``impl=`` is the deprecated
    spelling used by older callers: "kernel" → "pallas")."""
    if backend is None:
        backend = {"kernel": "pallas", None: "jnp"}.get(impl, impl)
    if backend == "kernel":
        backend = "pallas"
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    return backend


def _popcount_np(words: np.ndarray) -> np.ndarray:
    """Per-row popcount of uint32[..., W] (host path)."""
    if words.shape[-1] == 0:
        return np.zeros(words.shape[:-1], dtype=np.int32)
    bytes_ = np.ascontiguousarray(words).view(np.uint8)
    return np.unpackbits(bytes_, axis=-1).sum(axis=-1).astype(np.int32)


def gbkmv_containment_np(q_values, q_thresh, q_buf, q_size, x) -> np.ndarray:
    """NumPy twin of :func:`gbkmv_containment` for one query row.

    Float32 arithmetic mirrors the jnp/pallas paths bit-for-bit in the
    regimes the tests exercise. ``x`` is a PackedSketches.
    """
    qv = np.asarray(q_values, dtype=np.uint32)
    xv = np.asarray(x.values, dtype=np.uint32)
    xt = np.asarray(x.thresh, dtype=np.uint32)
    tau_pair = np.minimum(xt, np.uint32(q_thresh))               # [m]

    nq = (qv[None, :] <= tau_pair[:, None]).sum(-1).astype(np.int32)
    nx = (xv <= tau_pair[:, None]).sum(-1).astype(np.int32)
    live = xv <= tau_pair[:, None]
    member = np.isin(xv, qv)
    k_cap = (live & member).sum(-1).astype(np.int32)
    k = nq + nx - k_cap

    m = xv.shape[0]
    uq = np.where(nq > 0, qv[np.maximum(nq - 1, 0)], np.uint32(0))
    ux = xv[np.arange(m), np.maximum(nx - 1, 0)]
    ux = np.where(nx > 0, ux, np.uint32(0))
    u = np.maximum(uq, ux)
    u_unit = (u.astype(np.float32) + np.float32(1.0)) / np.float32(TWO32)

    kf = k.astype(np.float32)
    cf = k_cap.astype(np.float32)
    valid = (k >= 2) & (k_cap >= 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        d_hat = np.where(
            valid,
            (cf / np.maximum(kf, np.float32(1.0)))
            * ((kf - np.float32(1.0)) / np.maximum(u_unit, np.float32(1e-30))),
            np.where(k_cap >= 1, cf, np.float32(0.0)),
        ).astype(np.float32)

    x_buf = np.asarray(x.buf)
    if x_buf.shape[-1]:
        o1 = _popcount_np(x_buf & np.asarray(q_buf, np.uint32)[None, :])
    else:
        o1 = np.zeros(m, dtype=np.int32)
    qs = np.float32(max(int(q_size), 1))
    return ((o1.astype(np.float32) + d_hat) / qs).astype(np.float32)


def _align_buf_widths(q, x):
    """Zero-pad the narrower bitmap so both packs share a buffer width."""
    import dataclasses

    wq, wx = q.buf.shape[1], x.buf.shape[1]
    if wq == wx:
        return q, x
    w = max(wq, wx)

    def widen(p):
        buf = np.zeros((p.buf.shape[0], w), dtype=np.uint32)
        if p.buf.shape[1]:
            buf[:, : p.buf.shape[1]] = np.asarray(p.buf)
        return dataclasses.replace(p, buf=buf)

    return (widen(q) if wq < w else q), (widen(x) if wx < w else x)


def containment_matrix(q, x, backend: str = "jnp", *, as_numpy: bool = True):
    """Ĉ(Q→X) scores f32[m, Gq]: every query row of ``q`` against every
    record row of ``x`` — the single scoring door all layers share.

    ``backend``: "numpy" (host, dependency-free), "jnp" (XLA), or
    "pallas" (fused TPU kernel; interpret mode off-TPU).
    ``as_numpy=False`` keeps device backends' output on device so
    consumers (e.g. batch_query's packed thresholding) can compare
    there instead of fetching the full float matrix.
    """
    backend = normalize_backend(backend)
    q, x = _align_buf_widths(q, x)
    if backend == "numpy":
        cols = [
            gbkmv_containment_np(
                np.asarray(q.values)[g], np.asarray(q.thresh)[g],
                np.asarray(q.buf)[g], np.asarray(q.sizes)[g], x)
            for g in range(q.num_records)
        ]
        return np.stack(cols, axis=-1) if cols else \
            np.zeros((x.num_records, 0), np.float32)
    if backend == "pallas":
        from repro.kernels.ops import score_index

        out = score_index(
            x.values, x.thresh, x.buf,
            q.values, q.thresh, q.buf, q.sizes)
        return np.asarray(out) if as_numpy else out

    def one_query(qv, qt, qb, qs):
        d_hat, _, _ = gkmv_pair_estimate(
            qv, None, qt, x.values, x.lengths, x.thresh)
        o1 = buffer_intersection(qb, x.buf)
        return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
            jnp.asarray(qs, jnp.float32), 1.0)

    import jax

    out = jax.vmap(one_query)(
        jnp.asarray(q.values, jnp.uint32), jnp.asarray(q.thresh, jnp.uint32),
        jnp.asarray(q.buf, jnp.uint32), jnp.asarray(q.sizes, jnp.int32))
    return np.asarray(out.T) if as_numpy else out.T


# ---------------------------------------------------------------------------
# Plain KMV baseline (Eq. 8-11): k = min(k_Q, k_X), merge k smallest.
# ---------------------------------------------------------------------------

def kmv_pair_estimate(q_values, q_length, x_values, x_lengths):
    """Plain-KMV D̂∩ (Eq. 10) of one query row vs m record rows.

    Sketches here are per-record top-k minimum hash lists (no threshold).
    """
    m, c = x_values.shape
    cq = q_values.shape[0]
    k = jnp.minimum(jnp.asarray(q_length, jnp.int32), x_lengths)  # [m]

    # Distinct union of the two rows, sorted: concat → sort → dedup-mask.
    merged = jnp.sort(
        jnp.concatenate(
            [jnp.broadcast_to(q_values[None, :], (m, cq)), x_values], axis=-1
        ).astype(jnp.uint32),
        axis=-1,
    )                                                           # [m, cq+c]
    dup = jnp.concatenate(
        [jnp.zeros((m, 1), bool), merged[:, 1:] == merged[:, :-1]], axis=-1
    )
    is_pad = merged == PAD
    distinct = (~dup) & (~is_pad)
    rank = jnp.cumsum(distinct.astype(jnp.int32), axis=-1)       # 1-based among distinct
    in_topk = distinct & (rank <= k[:, None])

    # U_(k) = max value among the k smallest distinct.
    u = jnp.max(jnp.where(in_topk, merged, 0), axis=-1)
    u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

    # K∩ among the k smallest: value present in BOTH rows (dup pair) whose
    # first occurrence is within top-k.
    next_dup = jnp.concatenate(
        [merged[:, 1:] == merged[:, :-1], jnp.zeros((m, 1), bool)], axis=-1
    )
    kcap = jnp.sum(in_topk & next_dup, axis=-1).astype(jnp.int32)

    valid = (k >= 2) & (kcap >= 1)
    d_hat = jnp.where(
        valid,
        (kcap.astype(jnp.float32) / jnp.maximum(k, 1).astype(jnp.float32))
        * ((k.astype(jnp.float32) - 1.0) / jnp.maximum(u_unit, 1e-30)),
        jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0),
    )
    return d_hat, k, kcap


# ---------------------------------------------------------------------------
# NumPy oracles (tests) — straight transliteration of the paper's formulas
# over explicit python sets.
# ---------------------------------------------------------------------------

def gkmv_pair_oracle_np(q_hashes, q_tau, x_hashes, x_tau):
    """Set-based G-KMV estimator for one pair; returns (d_hat, k, kcap)."""
    tau = min(int(q_tau), int(x_tau))
    lq = {int(v) for v in q_hashes if int(v) <= tau}
    lx = {int(v) for v in x_hashes if int(v) <= tau}
    union = lq | lx
    k = len(union)
    kcap = len(lq & lx)
    if k < 2 or kcap < 1:
        return float(kcap), k, kcap
    u = (max(union) + 1.0) / TWO32
    return (kcap / k) * ((k - 1.0) / u), k, kcap


def kmv_pair_oracle_np(q_hashes, x_hashes):
    """Set-based plain-KMV estimator (Eq. 8-10) for one pair."""
    lq = sorted(int(v) for v in np.asarray(q_hashes).tolist())
    lx = sorted(int(v) for v in np.asarray(x_hashes).tolist())
    k = min(len(lq), len(lx))
    union = sorted(set(lq) | set(lx))
    topk = union[:k]
    if k < 1:
        return 0.0, 0, 0
    common = set(lq) & set(lx)
    kcap = sum(1 for v in topk if v in common)
    if k < 2 or kcap < 1:
        return float(kcap), k, kcap
    u = (topk[-1] + 1.0) / TWO32
    return (kcap / k) * ((k - 1.0) / u), k, kcap
