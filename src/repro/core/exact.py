"""Exact containment search baselines (paper §V: PPjoin* / FrequentSet).

Two exact engines:

* :func:`InvertedIndex` — posting-list counting (the FrequentSet-style
  candidate counter [5]): gather the query elements' posting lists, count
  hits per record; exact intersection sizes in one pass.
* :func:`prefix_filter_search` — PPjoin*-adapted [40]: records sorted by a
  global (frequency-increasing) token order; a query only needs to probe
  the posting lists of its "prefix" tokens (the |q| - ⌈t*·q⌉ + 1 rarest),
  because any record sharing zero prefix tokens cannot reach the overlap
  threshold θ = ⌈t*·q⌉. Candidates are then verified exactly.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class InvertedIndex:
    postings: dict            # element id → np.ndarray of record ids
    sizes: np.ndarray         # int32[m]
    token_rank: dict          # element id → global frequency rank (rare→0)


def build_inverted(records: Sequence[np.ndarray]) -> InvertedIndex:
    post: dict[int, list[int]] = defaultdict(list)
    sizes = np.zeros(len(records), dtype=np.int32)
    for i, rec in enumerate(records):
        sizes[i] = len(rec)
        for e in np.asarray(rec):
            post[int(e)].append(i)
    postings = {e: np.asarray(v, dtype=np.int64) for e, v in post.items()}
    # Frequency-increasing token order for prefix filtering.
    rank = {e: r for r, (e, _) in enumerate(
        sorted(postings.items(), key=lambda kv: (len(kv[1]), kv[0])))}
    return InvertedIndex(postings=postings, sizes=sizes, token_rank=rank)


def intersection_counts(index: InvertedIndex, q_ids: np.ndarray) -> np.ndarray:
    """Exact |Q ∩ X| for every record (posting-list counting)."""
    counts = np.zeros(len(index.sizes), dtype=np.int64)
    for e in np.asarray(q_ids):
        p = index.postings.get(int(e))
        if p is not None:
            counts[p] += 1
    return counts


def exact_search(index: InvertedIndex, q_ids: np.ndarray, threshold: float) -> np.ndarray:
    """Ground truth: ids with |Q∩X| / |Q| >= t*."""
    q = max(len(q_ids), 1)
    theta = threshold * q
    counts = intersection_counts(index, q_ids)
    return np.nonzero(counts >= theta - 1e-9)[0]


def prefix_filter_search(
    index: InvertedIndex, q_ids: np.ndarray, threshold: float
) -> np.ndarray:
    """PPjoin*-adapted exact search: prefix-probe then verify.

    θ = ⌈t*·|Q|⌉ overlap needed ⇒ a record disjoint from the
    (|Q| - θ + 1) rarest query tokens can share at most θ-1 tokens.
    """
    q_ids = np.asarray(q_ids)
    q = len(q_ids)
    if q == 0:
        return np.zeros(0, dtype=np.int64)
    theta = int(np.ceil(threshold * q - 1e-9))
    theta = max(theta, 1)
    prefix_len = q - theta + 1
    ranked = sorted(q_ids.tolist(), key=lambda e: index.token_rank.get(int(e), -1))
    prefix = ranked[:prefix_len]

    cand = set()
    for e in prefix:
        p = index.postings.get(int(e))
        if p is not None:
            cand.update(p.tolist())
    if not cand:
        return np.zeros(0, dtype=np.int64)
    cand = np.asarray(sorted(cand), dtype=np.int64)

    # Exact verification restricted to candidates.
    counts = np.zeros(len(index.sizes), dtype=np.int64)
    for e in q_ids:
        p = index.postings.get(int(e))
        if p is not None:
            counts[p] += 1
    return cand[counts[cand] >= theta]
