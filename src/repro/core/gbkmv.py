"""GB-KMV: G-KMV + a bitmap buffer of the top-r frequent elements
(paper §IV-B, Algorithm 1-2).

Budget accounting follows Algorithm 1: with budget ``b`` measured in hash
slots (32-bit words), the buffer costs ``r/32`` words per record and the
G-KMV tail gets the remainder: ``Σ_X (r/32 + n_X) <= b``.

Construction is the paper's headline speed claim (§V-E: one hash
function, >100× faster than LSH-E) and is fully vectorized here: one CSR
ingest, element frequencies via ``np.unique`` over the flat ids, top-r by
argpartition, buffer membership by sorted search, one flat hash pass, one
τ-selection, one lexsort+scatter pack. The seed-era per-record builder
survives as :func:`build_gbkmv_oracle` — the bit-parity oracle for tests
and the build bench. ``build_backend="jnp"|"pallas"`` routes the
hash→τ→pack stage through the fused device computation
(:func:`repro.kernels.hash_threshold.fused_build_columns`).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

import numpy as np

from repro.core import cost_model
from repro.core.gkmv import select_global_threshold, select_tau_flat
from repro.core.hashing import hash_u32_np
from repro.core.sketches import (PackedSketches, RaggedBatch, make_bitmaps,
                                 make_bitmaps_oracle, pack_csr, pack_rows,
                                 top_membership)


@dataclasses.dataclass
class GBKMVIndex:
    """A GB-KMV index: packed sketches + the metadata to sketch queries."""

    sketches: PackedSketches
    tau: np.uint32            # global hash threshold of the G-KMV part
    top_elems: np.ndarray     # element ids owning buffer bits (len r)
    seed: int
    buffer_bits: int          # r

    @property
    def num_records(self) -> int:
        return self.sketches.num_records

    def nbytes(self) -> int:
        return self.sketches.nbytes()


def element_frequencies(records: Sequence[np.ndarray]) -> Counter:
    """Per-element occurrence counts as a Counter (oracle-path helper)."""
    cnt: Counter = Counter()
    for rec in records:
        cnt.update(int(e) for e in np.asarray(rec))
    return cnt


def element_frequencies_csr(batch: RaggedBatch
                            ) -> tuple[np.ndarray, np.ndarray]:
    """(unique element ids, counts) over the flat id stream — the
    vectorized twin of :func:`element_frequencies`. Dense non-negative
    universes count through one ``np.bincount`` (O(N + U), no sort);
    anything else falls back to ``np.unique``."""
    ids = batch.ids
    if len(ids) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    lo, hi = int(ids.min()), int(ids.max())
    if lo >= 0 and hi < max(4 * len(ids), 1 << 22):
        counts = np.bincount(ids, minlength=hi + 1)
        uniq = np.nonzero(counts)[0].astype(np.int64)
        return uniq, counts[uniq]
    return np.unique(ids, return_counts=True)


def choose_top_elements(freq: Counter, r: int) -> np.ndarray:
    """The r globally most frequent element ids (ties broken by id)."""
    if r <= 0:
        return np.zeros(0, dtype=np.int64)
    items = sorted(freq.items(), key=lambda kv: (-kv[1], kv[0]))[:r]
    return np.asarray([e for e, _ in items], dtype=np.int64)


def choose_top_elements_csr(uniq: np.ndarray, counts: np.ndarray,
                            r: int) -> np.ndarray:
    """Vectorized top-r by (count desc, id asc): argpartition down to the
    r candidates, then one small lexsort — bit-identical ordering to
    :func:`choose_top_elements` on the same frequency table."""
    if r <= 0 or len(uniq) == 0:
        return np.zeros(0, dtype=np.int64)
    r_eff = min(int(r), len(uniq))
    if r_eff < len(uniq):
        # np.unique returns ids ascending, so within equal counts the
        # stable partition key (-count, id) is realized by partitioning
        # on -count alone only AFTER tie-breaking — use the composite
        # sort on the (cheap) argpartition survivors plus ties at the cut.
        kth = np.partition(counts, len(counts) - r_eff)[len(counts) - r_eff]
        cand = np.nonzero(counts >= kth)[0]
    else:
        cand = np.arange(len(uniq))
    order = np.lexsort((uniq[cand], -counts[cand]))[:r_eff]
    return uniq[cand[order]].astype(np.int64)


def _auto_buffer_bits(counts: np.ndarray, sizes: np.ndarray,
                      budget: int, m: int) -> int:
    """§IV-C6 cost model on the vectorized frequency table."""
    freqs = np.sort(counts.astype(np.int64))[::-1]
    return cost_model.choose_buffer_size(freqs, np.asarray(sizes, np.int64),
                                         budget, m)


def build_gbkmv(
    records: Sequence[np.ndarray],
    budget: int,
    r: int | str = "auto",
    seed: int = 0,
    capacity: int | None = None,
    tau_mode: str = "exact",
    build_backend: str | None = None,
    top_elems: np.ndarray | None = None,
) -> GBKMVIndex:
    """Algorithm 1, vectorized: pick r (cost model), top-r elements, τ,
    pack sketches — no per-record Python anywhere on the path.

    Args:
      records:  element-id arrays (distinct ids within each record), or a
                pre-ingested :class:`RaggedBatch`
      budget:   total space in 32-bit slots across all records
      r:        buffer bits per record; "auto" runs the §IV-C6 cost model
      capacity: optional cap on the packed G-KMV row length
      tau_mode: "exact" (partition; bit-equal to the oracle) or
                "histogram" (two-level histogram refine, τ within 2^8 of
                exact — the distributed selector's semantics)
      build_backend: None/"numpy" = host vectorized; "jnp"/"pallas" = the
                fused device hash→τ→pack computation (Pallas hash kernel
                on the pallas spelling), columns land device-resident
      top_elems: pin the buffer element set instead of deriving it from
                this batch's frequencies (r defaults to its length).
                The windowed index pins the first epoch's set so every
                epoch's buffers stay merge-compatible — same philosophy
                as the dynamic-insert path, which freezes the buffer
                layout at build time.
    """
    batch = (records if isinstance(records, RaggedBatch)
             else RaggedBatch.from_records(records))
    m = batch.num_records
    sizes = batch.sizes

    if top_elems is not None:
        top = np.asarray(top_elems, dtype=np.int64)
        r = len(top) if r == "auto" else int(r)
    else:
        uniq, counts = element_frequencies_csr(batch)
        if r == "auto":
            r = _auto_buffer_bits(counts, sizes.astype(np.int64), budget, m)
        r = int(r)
        top = choose_top_elements_csr(uniq, counts, r)

    # Buffer split via sorted-search membership (no Python sets); the
    # same membership pass feeds the bitmaps.
    is_top, bit = top_membership(batch.ids, top)
    tail_mask = ~is_top

    words_per_rec = -(-r // 32) if r else 0
    tail_budget = max(budget - m * words_per_rec, m)  # ≥1 slot per record

    bitmaps = make_bitmaps(batch, top, membership=(is_top, bit))
    if build_backend in ("jnp", "pallas"):
        from repro.kernels.hash_threshold import fused_build_columns

        packed, tau = fused_build_columns(
            batch, tail_mask, tail_budget, seed=seed, capacity=capacity,
            tau_mode=tau_mode, bitmaps=bitmaps, backend=build_backend)
    else:
        h_tail = hash_u32_np(batch.ids[tail_mask], seed=seed)
        tau = select_tau_flat(h_tail, tail_budget, tau_mode=tau_mode)
        keep = h_tail <= tau
        row_tail = batch.row_index()[tail_mask]
        thr = np.full(m, tau, dtype=np.uint32)
        packed = pack_csr(h_tail[keep], row_tail[keep], m, thr, sizes,
                          bitmaps=bitmaps, capacity=capacity)
    from repro.core.arena import SketchArena

    packed = SketchArena.from_pack(packed)
    return GBKMVIndex(sketches=packed, tau=np.uint32(tau), top_elems=top,
                      seed=seed, buffer_bits=r)


def merge_gbkmv(indexes: Sequence[GBKMVIndex], budget: int,
                capacity: int | None = None) -> GBKMVIndex:
    """Union independently built GB-KMV indexes under one global budget.

    Both halves of the sketch are order-independent, so the merge needs
    no re-hashing: the bitmap buffers concatenate row-wise (same bit ↔
    same element, because the parts must share ``top_elems``), and the
    G-KMV tails union with τ re-tightened to the merged tail budget
    (:func:`repro.core.arena.merge_arenas`). When every part was built
    with this same ``budget``, the same ``top_elems``/``r``/``seed``,
    and no binding ``capacity``, the result is bit-identical to
    :func:`build_gbkmv` on the concatenated records with the buffer set
    pinned (``top_elems=``) — including under arbitrary merge grouping
    (associativity) — provided the budget covers the merged buffer
    cost, ``budget ≥ m_total·(ceil(r/32)+1)``. Below that, the ≥1-slot-
    per-record floor on the tail budget can give an intermediate merge
    a *smaller* tail budget than a part's, dropping hashes the rebuild
    keeps; the merge is then still a valid sketch (per-row thresholds
    preserve τ_pair semantics) but not rebuild-identical. Raises on
    parts whose seed, buffer size, or buffer element set disagree —
    those sketches are not mergeable.
    """
    from repro.core.arena import merge_arenas

    if not indexes:
        raise ValueError("merge_gbkmv needs at least one index")
    base = indexes[0]
    for ix in indexes[1:]:
        if ix.seed != base.seed:
            raise ValueError(f"hash seeds differ: {ix.seed} != {base.seed}")
        if ix.buffer_bits != base.buffer_bits or not np.array_equal(
                np.asarray(ix.top_elems), np.asarray(base.top_elems)):
            raise ValueError(
                "buffer element sets differ across parts — build every "
                "epoch with top_elems pinned to the first epoch's set")
    m = sum(ix.num_records for ix in indexes)
    words_per_rec = -(-base.buffer_bits // 32) if base.buffer_bits else 0
    tail_budget = max(budget - m * words_per_rec, m)
    merged, tau = merge_arenas(
        [ix.sketches for ix in indexes], tail_budget,
        part_taus=[ix.tau for ix in indexes], capacity=capacity)
    return GBKMVIndex(sketches=merged, tau=np.uint32(tau),
                      top_elems=base.top_elems, seed=base.seed,
                      buffer_bits=base.buffer_bits)


def build_gbkmv_oracle(
    records: Sequence[np.ndarray],
    budget: int,
    r: int | str = "auto",
    seed: int = 0,
    capacity: int | None = None,
) -> GBKMVIndex:
    """The seed-era per-record Algorithm 1 — test oracle for build_gbkmv."""
    m = len(records)
    freq = element_frequencies(records)

    if r == "auto":
        sizes = np.asarray([len(rec) for rec in records], dtype=np.int64)
        freqs = np.asarray(sorted(freq.values(), reverse=True), dtype=np.int64)
        r = cost_model.choose_buffer_size(freqs, sizes, budget, m)
    r = int(r)

    top = choose_top_elements(freq, r)
    top_set = set(int(e) for e in top)

    # Split records: buffered head (exact bitmap) vs hashed tail (G-KMV).
    tails = []
    for rec in records:
        rec = np.asarray(rec)
        if top_set:
            mask = np.asarray([int(e) not in top_set for e in rec], dtype=bool)
            tails.append(rec[mask])
        else:
            tails.append(rec)

    hrows = [np.sort(hash_u32_np(t, seed=seed)) if len(t) else np.zeros(0, np.uint32)
             for t in tails]

    words_per_rec = -(-r // 32) if r else 0
    tail_budget = max(budget - m * words_per_rec, m)  # ≥1 slot per record
    tau = select_global_threshold(hrows, tail_budget)

    kept = [h[h <= tau] for h in hrows]
    bitmaps = make_bitmaps_oracle(records, top)
    sizes = np.asarray([len(rec) for rec in records], dtype=np.int32)
    thr = np.full(m, tau, dtype=np.uint32)
    from repro.core.arena import SketchArena

    packed = SketchArena.from_pack(
        pack_rows(kept, thr, sizes, bitmaps=bitmaps, capacity=capacity))
    return GBKMVIndex(sketches=packed, tau=np.uint32(tau), top_elems=top,
                      seed=seed, buffer_bits=r)


def sketch_query(index: GBKMVIndex, q_ids: np.ndarray) -> PackedSketches:
    """Sketch a query with the index's τ / top-r / seed (§IV-B)."""
    return sketch_query_batch(index, [np.asarray(q_ids)])


def sketch_query_batch(index: GBKMVIndex, queries) -> PackedSketches:
    """One vectorized pack for a whole query batch (shared by api
    ``query``/``batch_query`` and the distributed ``batch_queries``)."""
    from repro.core.gkmv import sketch_query_batch as _sqb

    q = _sqb(queries, index.tau, seed=index.seed,
             capacity=index.sketches.capacity, top_elems=index.top_elems)
    # Align buffer word width with the index (make_bitmaps already matches
    # because top_elems defines the width; guard the r=0 case). A query
    # pack WIDER than the index would mean dropping live buffer bits —
    # that's an inconsistent index, not something to paper over.
    if q.buf.shape[1] != index.sketches.buf.shape[1]:
        w = index.sketches.buf.shape[1]
        if q.buf.shape[1] > w:
            raise ValueError(
                f"query buffer needs {q.buf.shape[1]} words but the index "
                f"stores {w}: top_elems is inconsistent with the packed "
                "buffer width")
        buf = np.zeros((q.num_records, w), dtype=np.uint32)
        buf[:, : q.buf.shape[1]] = q.buf
        q = dataclasses.replace(q, buf=buf)
    return q


def containment_scores(index: GBKMVIndex, q: PackedSketches, backend: str = "jnp"):
    """Ĉ(Q→X) for every record (Eq. 27): buffer popcount + G-KMV tail.

    ``backend`` ∈ {"numpy", "jnp", "pallas"} — estimators.containment_matrix.
    """
    from repro.core.estimators import containment_matrix

    return containment_matrix(q, index.sketches, backend=backend)[:, 0]


def search(
    index: GBKMVIndex,
    q_ids: np.ndarray,
    threshold: float,
    backend: str = "jnp",
) -> np.ndarray:
    """Algorithm 2: record ids with estimated containment ≥ t*."""
    q = sketch_query(index, q_ids)
    scores = containment_scores(index, q, backend=backend)
    return np.nonzero(scores >= threshold)[0]
