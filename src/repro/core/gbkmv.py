"""GB-KMV: G-KMV + a bitmap buffer of the top-r frequent elements
(paper §IV-B, Algorithm 1-2).

Budget accounting follows Algorithm 1: with budget ``b`` measured in hash
slots (32-bit words), the buffer costs ``r/32`` words per record and the
G-KMV tail gets the remainder: ``Σ_X (r/32 + n_X) <= b``.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

import numpy as np

from repro.core import cost_model
from repro.core.gkmv import select_global_threshold
from repro.core.hashing import hash_u32_np, PAD
from repro.core.sketches import PackedSketches, make_bitmaps, pack_rows


@dataclasses.dataclass
class GBKMVIndex:
    """A GB-KMV index: packed sketches + the metadata to sketch queries."""

    sketches: PackedSketches
    tau: np.uint32            # global hash threshold of the G-KMV part
    top_elems: np.ndarray     # element ids owning buffer bits (len r)
    seed: int
    buffer_bits: int          # r

    @property
    def num_records(self) -> int:
        return self.sketches.num_records

    def nbytes(self) -> int:
        return self.sketches.nbytes()


def element_frequencies(records: Sequence[np.ndarray]) -> Counter:
    cnt: Counter = Counter()
    for rec in records:
        cnt.update(int(e) for e in np.asarray(rec))
    return cnt


def choose_top_elements(freq: Counter, r: int) -> np.ndarray:
    """The r globally most frequent element ids (ties broken by id)."""
    if r <= 0:
        return np.zeros(0, dtype=np.int64)
    items = sorted(freq.items(), key=lambda kv: (-kv[1], kv[0]))[:r]
    return np.asarray([e for e, _ in items], dtype=np.int64)


def build_gbkmv(
    records: Sequence[np.ndarray],
    budget: int,
    r: int | str = "auto",
    seed: int = 0,
    capacity: int | None = None,
) -> GBKMVIndex:
    """Algorithm 1: pick r (cost model), top-r elements, τ, pack sketches.

    Args:
      records:  element-id arrays (distinct ids within each record)
      budget:   total space in 32-bit slots across all records
      r:        buffer bits per record; "auto" runs the §IV-C6 cost model
      capacity: optional cap on the packed G-KMV row length
    """
    m = len(records)
    freq = element_frequencies(records)

    if r == "auto":
        sizes = np.asarray([len(rec) for rec in records], dtype=np.int64)
        freqs = np.asarray(sorted(freq.values(), reverse=True), dtype=np.int64)
        r = cost_model.choose_buffer_size(freqs, sizes, budget, m)
    r = int(r)

    top = choose_top_elements(freq, r)
    top_set = set(int(e) for e in top)

    # Split records: buffered head (exact bitmap) vs hashed tail (G-KMV).
    tails = []
    for rec in records:
        rec = np.asarray(rec)
        if top_set:
            mask = np.asarray([int(e) not in top_set for e in rec], dtype=bool)
            tails.append(rec[mask])
        else:
            tails.append(rec)

    hrows = [np.sort(hash_u32_np(t, seed=seed)) if len(t) else np.zeros(0, np.uint32)
             for t in tails]

    words_per_rec = -(-r // 32) if r else 0
    tail_budget = max(budget - m * words_per_rec, m)  # ≥1 slot per record
    tau = select_global_threshold(hrows, tail_budget)

    kept = [h[h <= tau] for h in hrows]
    bitmaps = make_bitmaps(records, top)
    sizes = np.asarray([len(rec) for rec in records], dtype=np.int32)
    thr = np.full(m, tau, dtype=np.uint32)
    from repro.core.arena import SketchArena

    packed = SketchArena.from_pack(
        pack_rows(kept, thr, sizes, bitmaps=bitmaps, capacity=capacity))
    return GBKMVIndex(sketches=packed, tau=np.uint32(tau), top_elems=top,
                      seed=seed, buffer_bits=r)


def sketch_query(index: GBKMVIndex, q_ids: np.ndarray) -> PackedSketches:
    """Sketch a query with the index's τ / top-r / seed (§IV-B)."""
    from repro.core.gkmv import sketch_query as _sq

    q = _sq(q_ids, index.tau, seed=index.seed,
            capacity=index.sketches.capacity, top_elems=index.top_elems)
    # Align buffer word width with the index (make_bitmaps already matches
    # because top_elems defines the width; guard the r=0 case).
    if q.buf.shape[1] != index.sketches.buf.shape[1]:
        w = index.sketches.buf.shape[1]
        buf = np.zeros((1, w), dtype=np.uint32)
        buf[:, : q.buf.shape[1]] = q.buf
        q = dataclasses.replace(q, buf=buf)
    return q


def containment_scores(index: GBKMVIndex, q: PackedSketches, backend: str = "jnp"):
    """Ĉ(Q→X) for every record (Eq. 27): buffer popcount + G-KMV tail.

    ``backend`` ∈ {"numpy", "jnp", "pallas"} — estimators.containment_matrix.
    """
    from repro.core.estimators import containment_matrix

    return containment_matrix(q, index.sketches, backend=backend)[:, 0]


def search(
    index: GBKMVIndex,
    q_ids: np.ndarray,
    threshold: float,
    backend: str = "jnp",
) -> np.ndarray:
    """Algorithm 2: record ids with estimated containment ≥ t*."""
    q = sketch_query(index, q_ids)
    scores = containment_scores(index, q, backend=backend)
    return np.nonzero(scores >= threshold)[0]
