"""G-KMV: KMV with a global hash threshold (paper §IV-A(2), Theorems 2-3).

Every record keeps *all* hash values ``h(e) <= τ``. τ is set from the space
budget: the expected row length is ``τ · |X|``, so ``Σ_j τ·x_j = b`` gives
``τ = b / N`` (paper §IV-C4). We compute τ *exactly* instead: the b-th
smallest value of the multiset of all record-element hashes, which hits the
budget precisely on the given data rather than in expectation.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import hash_u32_np, PAD
from repro.core.sketches import PackedSketches, pack_rows


def select_global_threshold(
    hash_rows: Sequence[np.ndarray], budget: int
) -> np.uint32:
    """Exact τ: the budget-th smallest hash over all (record, element) pairs.

    ``hash_rows`` are per-record hash arrays (need not be sorted). When the
    budget exceeds the total number of elements, τ = PAD-1 (keep all).
    """
    total = sum(len(r) for r in hash_rows)
    if budget >= total or total == 0:
        return np.uint32(PAD - np.uint32(1))
    allh = np.concatenate([np.asarray(r, dtype=np.uint32) for r in hash_rows])
    # budget-th smallest (1-indexed) == partition at budget-1
    tau = np.partition(allh, budget - 1)[budget - 1]
    return np.uint32(tau)


def build_gkmv(
    records: Sequence[np.ndarray],
    budget: int,
    seed: int = 0,
    capacity: int | None = None,
) -> PackedSketches:
    """Build a G-KMV index: filter every record's hashes at the global τ.

    ``capacity`` optionally caps row length (rows above it fall back to a
    lower per-record effective threshold — see sketches.pack_rows).
    """
    from repro.core.arena import SketchArena

    m = len(records)
    hrows = [np.sort(hash_u32_np(np.asarray(r), seed=seed)) for r in records]
    tau = select_global_threshold(hrows, budget)
    kept = [r[r <= tau] for r in hrows]
    sizes = np.asarray([len(r) for r in records], dtype=np.int32)
    thr = np.full(m, tau, dtype=np.uint32)
    return SketchArena.from_pack(pack_rows(kept, thr, sizes, capacity=capacity))


def sketch_query(
    q_ids: np.ndarray,
    tau: np.uint32,
    seed: int = 0,
    capacity: int | None = None,
    top_elems: np.ndarray | None = None,
) -> PackedSketches:
    """Sketch one query record at threshold τ (matching an index build)."""
    from repro.core.sketches import make_bitmaps

    q_ids = np.asarray(q_ids)
    if top_elems is not None and len(top_elems):
        top_set = set(int(e) for e in top_elems)
        tail = np.asarray([e for e in q_ids if int(e) not in top_set])
        bitmaps = make_bitmaps([q_ids], top_elems)
    else:
        tail = q_ids
        bitmaps = None
    h = np.sort(hash_u32_np(tail, seed=seed)) if len(tail) else np.zeros(0, np.uint32)
    kept = h[h <= tau]
    thr = np.asarray([tau], dtype=np.uint32)
    sizes = np.asarray([len(q_ids)], dtype=np.int32)
    return pack_rows([kept], thr, sizes, bitmaps=bitmaps, capacity=capacity)
