"""G-KMV: KMV with a global hash threshold (paper §IV-A(2), Theorems 2-3).

Every record keeps *all* hash values ``h(e) <= τ``. τ is set from the space
budget: the expected row length is ``τ · |X|``, so ``Σ_j τ·x_j = b`` gives
``τ = b / N`` (paper §IV-C4). We compute τ *exactly* instead: the b-th
smallest value of the multiset of all record-element hashes, which hits the
budget precisely on the given data rather than in expectation.

Construction is fully vectorized (no per-record Python): records ingest
once into a ragged CSR batch, one hash pass covers every element, τ is a
single ``np.partition`` (or the two-level ``histogram_tau`` under
``tau_mode="histogram"`` — within 2^8 hash values of exact), and packing
is one lexsort + scatter (:func:`repro.core.sketches.pack_csr`). The
seed-era per-record builder survives as :func:`build_gkmv_oracle` — the
bit-parity oracle the tests and the build bench compare against.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import hash_u32_np, PAD
from repro.core.sketches import (PackedSketches, RaggedBatch, pack_csr,
                                 pack_rows, top_membership)

TAU_MODES = ("exact", "histogram")


def select_global_threshold(
    hash_rows: Sequence[np.ndarray], budget: int
) -> np.uint32:
    """Exact τ: the budget-th smallest hash over all (record, element) pairs.

    ``hash_rows`` are per-record hash arrays (need not be sorted). When the
    budget exceeds the total number of elements, τ = PAD-1 (keep all).
    """
    total = sum(len(r) for r in hash_rows)
    if budget >= total or total == 0:
        return np.uint32(PAD - np.uint32(1))
    allh = np.concatenate([np.asarray(r, dtype=np.uint32) for r in hash_rows])
    return select_tau_flat(allh, budget)


def select_tau_flat(hashes: np.ndarray, budget: int,
                    tau_mode: str = "exact") -> np.uint32:
    """τ over a FLAT hash stream — the vectorized pipeline's selector.

    ``tau_mode="exact"``: the budget-th smallest value (``np.partition``),
    bit-equal to :func:`select_global_threshold` on the same multiset.
    ``tau_mode="histogram"``: the two-level histogram refine shared with
    the distributed reduction (:func:`repro.sketchindex.build
    .histogram_tau`) — returns the 2^8-wide bin upper bound, i.e.
    ``(τ_exact & ~0xFF) | 0xFF`` whenever the budget binds (so
    τ_hist ≥ τ_exact and τ_hist − τ_exact ≤ 255).
    """
    if tau_mode not in TAU_MODES:
        raise ValueError(f"tau_mode must be one of {TAU_MODES}, "
                         f"got {tau_mode!r}")
    hashes = np.asarray(hashes, dtype=np.uint32)
    if budget >= len(hashes) or len(hashes) == 0:
        return np.uint32(PAD - np.uint32(1))
    if tau_mode == "histogram":
        from repro.sketchindex.build import histogram_tau

        return np.uint32(histogram_tau(hashes, budget))
    # budget-th smallest (1-indexed) == partition at budget-1
    return np.uint32(np.partition(hashes, budget - 1)[budget - 1])


def build_gkmv(
    records: Sequence[np.ndarray],
    budget: int,
    seed: int = 0,
    capacity: int | None = None,
    tau_mode: str = "exact",
    build_backend: str | None = None,
) -> PackedSketches:
    """Build a G-KMV index: filter every record's hashes at the global τ.

    One vectorized pass — CSR ingest, flat hash, one τ-selection, one
    lexsort+scatter pack. ``capacity`` optionally caps row length (rows
    above it fall back to a lower per-record effective threshold — see
    sketches.pack_csr). ``build_backend="jnp"|"pallas"`` runs the fused
    device hash→τ→pack computation instead of the host pass.
    """
    from repro.core.arena import SketchArena

    batch = (records if isinstance(records, RaggedBatch)
             else RaggedBatch.from_records(records))
    m = batch.num_records
    if build_backend in ("jnp", "pallas"):
        from repro.kernels.hash_threshold import fused_build_columns

        packed, _ = fused_build_columns(
            batch, np.ones(batch.total, bool), budget, seed=seed,
            capacity=capacity, tau_mode=tau_mode, backend=build_backend)
        return SketchArena.from_pack(packed)
    h = hash_u32_np(batch.ids, seed=seed)
    tau = select_tau_flat(h, budget, tau_mode=tau_mode)
    keep = h <= tau
    row = batch.row_index()
    thr = np.full(m, tau, dtype=np.uint32)
    return SketchArena.from_pack(pack_csr(
        h[keep], row[keep], m, thr, batch.sizes, capacity=capacity))


def merge_gkmv(parts, budget: int, capacity: int | None = None):
    """Union independently built G-KMV arenas under one global budget.

    ``parts`` are the packed arenas of indexes built over *disjoint*
    record sets; the result covers their concatenation. When every part
    was built with this same ``budget`` (and no binding ``capacity``),
    the merge is bit-identical to :func:`build_gkmv` on the
    concatenated records — the mergeability property of KMV synopses
    (paper Theorem 2: a τ-filtered union is again a τ-sketch). Returns
    the merged :class:`~repro.core.arena.SketchArena`.
    """
    from repro.core.arena import merge_arenas

    merged, _ = merge_arenas(parts, budget, capacity=capacity)
    return merged


def build_gkmv_oracle(
    records: Sequence[np.ndarray],
    budget: int,
    seed: int = 0,
    capacity: int | None = None,
) -> PackedSketches:
    """The seed-era per-record builder — test oracle for build_gkmv."""
    from repro.core.arena import SketchArena

    m = len(records)
    hrows = [np.sort(hash_u32_np(np.asarray(r), seed=seed)) for r in records]
    tau = select_global_threshold(hrows, budget)
    kept = [r[r <= tau] for r in hrows]
    sizes = np.asarray([len(r) for r in records], dtype=np.int32)
    thr = np.full(m, tau, dtype=np.uint32)
    return SketchArena.from_pack(pack_rows(kept, thr, sizes, capacity=capacity))


def sketch_query_batch(
    queries: Sequence[np.ndarray],
    tau: np.uint32,
    seed: int = 0,
    capacity: int | None = None,
    top_elems: np.ndarray | None = None,
) -> PackedSketches:
    """Sketch a whole query batch at threshold τ in one vectorized pass.

    The single shared packer behind api ``query``/``batch_query`` and the
    distributed ``batch_queries``: CSR ingest, one hash pass, sorted-search
    buffer membership (no per-element Python ``set``), one lexsort+scatter
    pack, vectorized bitmaps. Row i of the result is bit-identical to
    :func:`sketch_query` on ``queries[i]`` alone (given the same
    ``capacity``, which fixes the pack width).
    """
    from repro.core.sketches import make_bitmaps

    batch = (queries if isinstance(queries, RaggedBatch)
             else RaggedBatch.from_records(queries))
    m = batch.num_records
    h = hash_u32_np(batch.ids, seed=seed)
    tail_mask = np.ones(batch.total, bool)
    bitmaps = None
    if top_elems is not None and len(top_elems):
        is_top, _ = top_membership(batch.ids, top_elems)
        tail_mask = ~is_top
        bitmaps = make_bitmaps(batch, top_elems)
    keep = tail_mask & (h <= tau)
    row = batch.row_index()
    thr = np.full(m, tau, dtype=np.uint32)
    return pack_csr(h[keep], row[keep], m, thr, batch.sizes,
                    bitmaps=bitmaps, capacity=capacity)


def sketch_query(
    q_ids: np.ndarray,
    tau: np.uint32,
    seed: int = 0,
    capacity: int | None = None,
    top_elems: np.ndarray | None = None,
) -> PackedSketches:
    """Sketch one query record at threshold τ (matching an index build)."""
    return sketch_query_batch([np.asarray(q_ids)], tau, seed=seed,
                              capacity=capacity, top_elems=top_elems)


def sketch_query_oracle(
    q_ids: np.ndarray,
    tau: np.uint32,
    seed: int = 0,
    capacity: int | None = None,
    top_elems: np.ndarray | None = None,
) -> PackedSketches:
    """Seed-era per-element query sketcher — test oracle for sketch_query."""
    from repro.core.sketches import make_bitmaps_oracle

    q_ids = np.asarray(q_ids)
    if top_elems is not None and len(top_elems):
        top_set = set(int(e) for e in top_elems)
        tail = np.asarray([e for e in q_ids if int(e) not in top_set])
        bitmaps = make_bitmaps_oracle([q_ids], top_elems)
    else:
        tail = q_ids
        bitmaps = None
    h = np.sort(hash_u32_np(tail, seed=seed)) if len(tail) else np.zeros(0, np.uint32)
    kept = h[h <= tau]
    thr = np.asarray([tau], dtype=np.uint32)
    sizes = np.asarray([len(q_ids)], dtype=np.int32)
    return pack_rows([kept], thr, sizes, bitmaps=bitmaps, capacity=capacity)
