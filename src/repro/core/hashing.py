"""Fingerprint hashing for KMV-family sketches.

The paper assumes a collision-free hash ``h: E → [0, 1]``. We use a 32-bit
avalanche fingerprint (murmur3 finalizer, seed-mixed) over element ids and
normalize lazily: an estimator that needs ``U_(k) ∈ (0, 1]`` maps a raw
``uint32`` value ``v`` to ``(v + 1) / 2^32``. Keeping raw ``uint32`` values
on device lets sketch compare / sort / threshold ops stay in integer VPU
lanes (TPU-friendly) and halves HBM traffic vs float64.

One hash function serves the whole GB-KMV index — the paper's construction
advantage over LSH-E's 256 MinHash functions (§V-E) is preserved.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# 2^32 as float — normalization constant.
TWO32 = 4294967296.0
# Padding sentinel for fixed-capacity sketch rows (max uint32 — sorts last).
PAD = np.uint32(0xFFFFFFFF)


def _mix(h):
    """murmur3 fmix32 avalanche (works on jnp or np uint32 arrays)."""
    h = h ^ (h >> 16)
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_u32(ids, seed: int = 0):
    """Hash int element ids → uint32 fingerprints (jnp path, jit-safe)."""
    x = jnp.asarray(ids).astype(jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9) * jnp.uint32(seed + 1)
    return _mix(x)


def hash_u32_np(ids, seed: int = 0) -> np.ndarray:
    """NumPy twin of :func:`hash_u32` (host-side pipelines, oracles)."""
    with np.errstate(over="ignore"):
        x = np.asarray(ids, dtype=np.uint64) & np.uint64(0xFFFFFFFF)
        x = x.astype(np.uint32)
        x = x + np.uint32((0x9E3779B9 * (seed + 1)) & 0xFFFFFFFF)
        h = x
        h = h ^ (h >> np.uint32(16))
        h = (h.astype(np.uint64) * np.uint64(0x85EBCA6B)).astype(np.uint32)
        h = h ^ (h >> np.uint32(13))
        h = (h.astype(np.uint64) * np.uint64(0xC2B2AE35)).astype(np.uint32)
        h = h ^ (h >> np.uint32(16))
    return h


def unit(v):
    """Map raw uint32 hash values to the open unit interval (0, 1]."""
    return (jnp.asarray(v).astype(jnp.float64 if False else jnp.float32) + 1.0) / TWO32


def unit_np(v) -> np.ndarray:
    """Float64 host-side normalization — used by oracles where the extra
    mantissa matters for tight allclose checks."""
    return (np.asarray(v, dtype=np.float64) + 1.0) / TWO32


def _mix_np(h: np.ndarray) -> np.ndarray:
    """murmur3 fmix32 on a uint32 array of any shape (host).

    uint32 multiplies wrap mod 2^32 in C just like the uint64-widening
    spelling in :func:`hash_u32_np` — bit-identical, half the traffic.
    """
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def minhash_seed_offsets(num_hashes: int, seed: int = 0,
                         start: int = 0) -> np.ndarray:
    """uint32[num_hashes] pre-mix additive constants for hash functions
    ``start .. start+num_hashes``: ``0x9E3779B9 · (seed·1000003 + i + 1)``
    mod 2^32 — exactly the per-function seeding hash_u32_np applies."""
    i = np.arange(start, start + num_hashes, dtype=np.uint64)
    offs = (np.uint64(0x9E3779B9) * (np.uint64(seed) * np.uint64(1000003)
                                     + i + np.uint64(1)))
    return (offs & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def minhash_matrix_np(ids: np.ndarray, num_hashes: int, seed: int = 0,
                      start: int = 0) -> np.ndarray:
    """All hash values ``uint32[num_hashes, n]`` of one id array under
    ``num_hashes`` independent functions — one batched mix, no loop."""
    ids32 = (np.asarray(ids, dtype=np.uint64)
             & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    offs = minhash_seed_offsets(num_hashes, seed=seed, start=start)
    with np.errstate(over="ignore"):
        return _mix_np(ids32[None, :] + offs[:, None])


def minhash_signature_np(ids: np.ndarray, num_hashes: int, seed: int = 0) -> np.ndarray:
    """MinHash signature (k independent hash fns) of one element-id set.

    Baseline substrate for MinHash / LSH-E. Returns ``uint32[num_hashes]``
    from ONE ``[num_hashes, n]`` batched hash + row-min (the seed-era
    256-iteration Python loop survives as
    :func:`minhash_signature_oracle`).
    """
    ids = np.asarray(ids)
    if len(ids) == 0:
        return np.full(num_hashes, PAD, dtype=np.uint32)
    return minhash_matrix_np(ids, num_hashes, seed=seed).min(axis=1)


def minhash_signature_oracle(ids: np.ndarray, num_hashes: int,
                             seed: int = 0) -> np.ndarray:
    """Seed-era one-hash-at-a-time loop — test oracle for the batched
    signature (bit-identical output)."""
    ids = np.asarray(ids, dtype=np.uint64)
    sig = np.empty(num_hashes, dtype=np.uint32)
    for i in range(num_hashes):
        sig[i] = hash_u32_np(ids, seed=seed * 1000003 + i).min() if len(ids) else PAD
    return sig
