"""Plain KMV sketch (paper §II-C) — equal-allocation baseline.

Theorem 1: under a total budget ``b`` over ``m`` records, the optimal plain
KMV allocation is uniform ``k_i = floor(b / m)``, because pair estimation
uses ``k = min(k_Q, k_X)`` (Eq. 8). We implement exactly that.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import hash_u32_np
from repro.core.sketches import PackedSketches, pack_rows
from repro.core.hashing import PAD


def build_kmv(records: Sequence[np.ndarray], budget: int, seed: int = 0) -> PackedSketches:
    """Keep the ``floor(budget/m)`` minimum hash values of every record.

    ``budget`` counts hash slots (paper's "number of signatures").
    """
    from repro.core.arena import SketchArena

    m = len(records)
    k = max(budget // max(m, 1), 2)
    rows = []
    sizes = np.zeros(m, dtype=np.int32)
    for i, rec in enumerate(records):
        h = np.sort(hash_u32_np(np.asarray(rec), seed=seed))
        rows.append(h[:k])
        sizes[i] = len(rec)
    # Plain KMV has no threshold semantics; use PAD-1 so τ_pair never binds.
    thr = np.full(m, PAD - np.uint32(1), dtype=np.uint32)
    return SketchArena.from_pack(pack_rows(rows, thr, sizes, capacity=k))


def kmv_distinct_estimate_np(hashes: np.ndarray, k: int) -> float:
    """D̂ = (k-1)/U_(k) (paper §II-C) for a single record, NumPy."""
    h = np.sort(np.asarray(hashes))
    if len(h) < k or k < 2:
        return float(len(set(h.tolist())))
    u = (float(h[k - 1]) + 1.0) / 4294967296.0
    return (k - 1) / u
