"""Plain KMV sketch (paper §II-C) — equal-allocation baseline.

Theorem 1: under a total budget ``b`` over ``m`` records, the optimal plain
KMV allocation is uniform ``k_i = floor(b / m)``, because pair estimation
uses ``k = min(k_Q, k_X)`` (Eq. 8). We implement exactly that.

Construction is vectorized: one CSR ingest, one flat hash pass, one
lexsort, then each row keeps its k smallest by within-row position — no
per-record Python. :func:`build_kmv_oracle` keeps the seed-era loop as
the bit-parity test oracle; ``build_backend="jnp"|"pallas"`` routes the
hash/sort/pack through the fused device computation.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import hash_u32_np
from repro.core.sketches import PackedSketches, RaggedBatch, pack_csr, pack_rows
from repro.core.hashing import PAD


def build_kmv(records: Sequence[np.ndarray], budget: int, seed: int = 0,
              build_backend: str | None = None) -> PackedSketches:
    """Keep the ``floor(budget/m)`` minimum hash values of every record.

    ``budget`` counts hash slots (paper's "number of signatures").
    """
    from repro.core.arena import SketchArena

    batch = (records if isinstance(records, RaggedBatch)
             else RaggedBatch.from_records(records))
    m = batch.num_records
    k = max(budget // max(m, 1), 2)
    if build_backend in ("jnp", "pallas"):
        from repro.kernels.hash_threshold import fused_build_columns

        packed, _ = fused_build_columns(
            batch, np.ones(batch.total, bool), 0, seed=seed, row_cap=k,
            backend=build_backend)
        return SketchArena.from_pack(packed)
    h = hash_u32_np(batch.ids, seed=seed)
    row = batch.row_index()
    # Per-row k-smallest: one u64 (row | hash) key sort, keep pos < k.
    key = np.sort((row.astype(np.uint64) << np.uint64(32))
                  | h.astype(np.uint64))
    h = (key & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    row = (key >> np.uint64(32)).astype(np.int64)
    counts = np.bincount(row, minlength=m).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(len(h), dtype=np.int64) - starts[row]
    keep = pos < k
    # Plain KMV has no threshold semantics; use PAD-1 so τ_pair never binds.
    thr = np.full(m, PAD - np.uint32(1), dtype=np.uint32)
    # Truncation preserves the (row, hash) order — skip pack_csr's sort.
    return SketchArena.from_pack(pack_csr(
        h[keep], row[keep], m, thr, batch.sizes, capacity=k,
        presorted=True))


def merge_kmv(parts, budget: int) -> PackedSketches:
    """Union independently built plain-KMV arenas under one budget.

    The merged uniform allocation is ``k = max(budget // m_total, 2)``,
    which never exceeds any part's per-record k (k is non-increasing in
    the record count), so every merged row's k smallest hashes are
    already stored in its part: re-truncating each row positionally is
    bit-identical to :func:`build_kmv` on the concatenated records —
    for *any* per-part record counts, as long as the parts shared this
    ``budget``. No postings splice (the cut is positional, not a τ
    filter); postings rebuild lazily on the merged arena.
    """
    from repro.core.arena import SketchArena, flat_kept

    parts = [SketchArena.from_pack(p) for p in parts]
    if not parts:
        raise ValueError("merge_kmv needs at least one arena")
    counts_m = [p.num_records for p in parts]
    offs = np.concatenate([[0], np.cumsum(counts_m)]).astype(np.int64)
    m = int(offs[-1])
    k = max(budget // max(m, 1), 2)
    streams = [flat_kept(p) for p in parts]
    h = np.concatenate([s[0] for s in streams]) if m else np.zeros(0, np.uint32)
    row = np.concatenate([s[1] + offs[i] for i, s in enumerate(streams)]) \
        if m else np.zeros(0, np.int64)
    counts = np.bincount(row, minlength=m).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(len(h), dtype=np.int64) - starts[row]
    keep = pos < k
    sizes = np.concatenate([np.asarray(p.sizes, np.int32) for p in parts])
    thr = np.full(m, PAD - np.uint32(1), dtype=np.uint32)
    return SketchArena.from_pack(pack_csr(
        h[keep], row[keep], m, thr, sizes, capacity=k, presorted=True))


def build_kmv_oracle(records: Sequence[np.ndarray], budget: int,
                     seed: int = 0) -> PackedSketches:
    """The seed-era per-record builder — test oracle for build_kmv."""
    from repro.core.arena import SketchArena

    m = len(records)
    k = max(budget // max(m, 1), 2)
    rows = []
    sizes = np.zeros(m, dtype=np.int32)
    for i, rec in enumerate(records):
        h = np.sort(hash_u32_np(np.asarray(rec), seed=seed))
        rows.append(h[:k])
        sizes[i] = len(rec)
    thr = np.full(m, PAD - np.uint32(1), dtype=np.uint32)
    return SketchArena.from_pack(pack_rows(rows, thr, sizes, capacity=k))


def kmv_distinct_estimate_np(hashes: np.ndarray, k: int) -> float:
    """D̂ = (k-1)/U_(k) (paper §II-C) for a single record, NumPy."""
    h = np.sort(np.asarray(hashes))
    if len(h) < k or k < 2:
        return float(len(set(h.tolist())))
    u = (float(h[k - 1]) + 1.0) / 4294967296.0
    return (k - 1) / u
