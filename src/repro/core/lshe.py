"""LSH Ensemble (LSH-E) baseline — Zhu et al., VLDB'16 (paper §III-A).

Pipeline (as described in the paper):
  1. equal-depth partition of records by size (optimal under power-law
     sizes + uniform similarity, per [44]);
  2. per partition, a MinHash LSH index with banding (b bands × r rows);
  3. per query, transform t* → s* with the partition's size *upper bound*
     u (Eq. 13), then pick (b, r) minimizing estimated FP+FN at s*;
  4. union of partition candidate sets.

The (b, r) choice uses the standard S-curve: P(candidate | s) =
1 - (1 - s^r)^b; expected FP ≈ Σ_{s<s*} P, FN ≈ Σ_{s>=s*} (1 - P) under a
uniform similarity prior — the same device used by datasketch's
LSH Ensemble implementation that [44] ships.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.minhash import build_signatures


def _divisor_pairs(k: int) -> list[tuple[int, int]]:
    """All (bands, rows) with bands*rows <= k, rows >= 1."""
    out = []
    for rows in range(1, k + 1):
        bands = k // rows
        if bands >= 1:
            out.append((bands, rows))
    return out


def _choose_br(k: int, s_star: float) -> tuple[int, int]:
    """Minimize estimated FP+FN of the banding S-curve at threshold s*."""
    xs = np.linspace(0.0, 1.0, 64)
    best, best_cost = (1, k), np.inf
    for bands, rows in _divisor_pairs(k):
        p = 1.0 - (1.0 - xs**rows) ** bands
        fp = p[xs < s_star].sum()
        fn = (1.0 - p[xs >= s_star]).sum()
        cost = fp + fn
        if cost < best_cost:
            best, best_cost = (bands, rows), cost
    return best


@dataclasses.dataclass
class LSHEnsemble:
    signatures: np.ndarray          # uint32[m, k]
    sizes: np.ndarray               # int32[m]
    order: np.ndarray               # record ids sorted by size
    boundaries: np.ndarray          # partition start offsets into `order`
    upper_bounds: np.ndarray        # max record size per partition
    num_hashes: int

    def nbytes(self) -> int:
        return int(self.signatures.nbytes + self.sizes.nbytes)


def build_lshe(
    records: Sequence[np.ndarray],
    num_hashes: int = 256,
    num_partitions: int = 32,
    seed: int = 0,
) -> LSHEnsemble:
    """Build the ensemble. The signature matrix — the entire
    construction cost (§V-E) — comes from the vectorized batched
    MinHash (:func:`repro.core.minhash.build_signatures`), not the
    seed-era per-record × per-function loop."""
    sizes = np.asarray([len(r) for r in records], dtype=np.int32)
    order = np.argsort(sizes, kind="stable")
    m = len(records)
    num_partitions = max(1, min(num_partitions, m))
    # Equal-depth partitioning (optimal per [44] §4).
    bounds = np.linspace(0, m, num_partitions + 1).astype(np.int64)
    ends = bounds[1:]
    uppers = np.where(
        ends > 0, sizes[order[np.maximum(ends - 1, 0)]], 0).astype(np.int64)
    sigs = build_signatures(records, num_hashes, seed=seed)
    return LSHEnsemble(
        signatures=sigs, sizes=sizes, order=order,
        boundaries=bounds, upper_bounds=uppers, num_hashes=num_hashes,
    )


def query_lshe(
    index: LSHEnsemble, q_ids: np.ndarray, threshold: float, seed: int = 0
) -> np.ndarray:
    """Candidate record ids whose (transformed) banding matches fire."""
    from repro.core.minhash import build_signatures as _sig

    q_sig = _sig([np.asarray(q_ids)], index.num_hashes, seed=seed)[0]
    q_size = len(q_ids)
    cands: list[np.ndarray] = []
    for p in range(len(index.upper_bounds)):
        lo, hi = index.boundaries[p], index.boundaries[p + 1]
        if hi <= lo:
            continue
        u = float(index.upper_bounds[p])
        # Eq. 13: s* from t* with the partition's size upper bound.
        s_star = threshold / (u / max(q_size, 1) + 1.0 - threshold)
        s_star = min(max(s_star, 1e-3), 1.0)
        bands, rows = _choose_br(index.num_hashes, s_star)
        ids = index.order[lo:hi]
        sig = index.signatures[ids]                       # [p_m, k]
        used = bands * rows
        band_eq = (sig[:, :used] == q_sig[None, :used]).reshape(len(ids), bands, rows)
        fire = band_eq.all(axis=2).any(axis=1)
        cands.append(ids[fire])
    if not cands:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(cands))
