"""MinHash substrate (paper §II-B) — basis of the LSH-E baseline.

Signatures use k independent hash functions (k minimum values, one per
function). Jaccard is estimated as the collision fraction (Eq. 5);
containment via the size transformation (Eq. 14).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import hash_u32_np, PAD


def build_signatures(
    records: Sequence[np.ndarray], num_hashes: int, seed: int = 0
) -> np.ndarray:
    """uint32[m, k] MinHash signature matrix."""
    m = len(records)
    sig = np.full((m, num_hashes), PAD, dtype=np.uint32)
    for i, rec in enumerate(records):
        ids = np.asarray(rec, dtype=np.uint64)
        if len(ids) == 0:
            continue
        for h in range(num_hashes):
            sig[i, h] = hash_u32_np(ids, seed=seed * 1000003 + h).min()
    return sig


def jaccard_estimate(q_sig: np.ndarray, sigs: np.ndarray) -> np.ndarray:
    """ŝ (Eq. 5): collision fraction of one signature vs m signatures."""
    return (sigs == q_sig[None, :]).mean(axis=1)


def containment_from_jaccard(
    s_hat: np.ndarray, x_sizes: np.ndarray, q_size: int
) -> np.ndarray:
    """t̂ = (x/q + 1)·ŝ / (1 + ŝ) — Eq. 14 (true record sizes)."""
    alpha = x_sizes.astype(np.float64) / max(q_size, 1) + 1.0
    return alpha * s_hat / (1.0 + s_hat)
