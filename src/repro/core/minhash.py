"""MinHash substrate (paper §II-B) — basis of the LSH-E baseline.

Signatures use k independent hash functions (k minimum values, one per
function). Jaccard is estimated as the collision fraction (Eq. 5);
containment via the size transformation (Eq. 14).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hashing import (PAD, _mix_np, hash_u32_np,
                                minhash_seed_offsets)
from repro.core.sketches import RaggedBatch

# Hash functions processed per pass — bounds the [chunk, N] uint32
# work matrix to a few MB regardless of num_hashes.
_SIG_CHUNK = 32


def build_signatures(
    records: Sequence[np.ndarray], num_hashes: int, seed: int = 0
) -> np.ndarray:
    """uint32[m, k] MinHash signature matrix, fully vectorized.

    One CSR ingest, then per chunk of hash functions a single
    ``[chunk, N]`` batched mix over the flat id stream and a segment-min
    (``np.minimum.reduceat`` keyed by the row offsets) — the vectorized
    replacement for the seed-era m×k Python loop
    (:func:`build_signatures_oracle`), making the paper's §V-E
    construction-time comparison against LSH-E meaningful again.
    """
    batch = (records if isinstance(records, RaggedBatch)
             else RaggedBatch.from_records(records))
    m = batch.num_records
    sig = np.full((m, num_hashes), PAD, dtype=np.uint32)
    if batch.total == 0 or num_hashes == 0:
        return sig
    ids32 = ((batch.ids.astype(np.uint64) & np.uint64(0xFFFFFFFF))
             .astype(np.uint32))
    sizes = np.diff(batch.offsets)
    nz = sizes > 0
    # reduceat over the non-empty rows only: consecutive non-empty row
    # starts are strictly increasing and < N, and empty rows (zero
    # extent) cannot shift any segment boundary.
    starts_nz = batch.offsets[:-1][nz]
    with np.errstate(over="ignore"):
        for h0 in range(0, num_hashes, _SIG_CHUNK):
            hc = min(_SIG_CHUNK, num_hashes - h0)
            offs = minhash_seed_offsets(hc, seed=seed, start=h0)
            hm = _mix_np(ids32[None, :] + offs[:, None])      # [hc, N]
            sig[nz, h0 : h0 + hc] = np.minimum.reduceat(
                hm, starts_nz, axis=1).T
    return sig


def build_signatures_oracle(
    records: Sequence[np.ndarray], num_hashes: int, seed: int = 0
) -> np.ndarray:
    """The seed-era per-record × per-function loop — test oracle for
    :func:`build_signatures` (bit-identical output)."""
    m = len(records)
    sig = np.full((m, num_hashes), PAD, dtype=np.uint32)
    for i, rec in enumerate(records):
        ids = np.asarray(rec, dtype=np.uint64)
        if len(ids) == 0:
            continue
        for h in range(num_hashes):
            sig[i, h] = hash_u32_np(ids, seed=seed * 1000003 + h).min()
    return sig


def jaccard_estimate(q_sig: np.ndarray, sigs: np.ndarray) -> np.ndarray:
    """ŝ (Eq. 5): collision fraction of one signature vs m signatures."""
    return (sigs == q_sig[None, :]).mean(axis=1)


def containment_from_jaccard(
    s_hat: np.ndarray, x_sizes: np.ndarray, q_size: int
) -> np.ndarray:
    """t̂ = (x/q + 1)·ŝ / (1 + ŝ) — Eq. 14 (true record sizes)."""
    alpha = x_sizes.astype(np.float64) / max(q_size, 1) + 1.0
    return alpha * s_hat / (1.0 + s_hat)
