"""Legacy containment-search front end + evaluation metrics (paper §V-A).

``run_search``/``evaluate_engine`` are now thin shims over the
:mod:`repro.api` engine registry — ``repro.api.get_engine(name)`` is the
canonical door; these stay so existing callers and benchmarks keep
working unchanged. ``f_score`` implements Eq. 35.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def f_score(truth: np.ndarray, returned: np.ndarray, alpha: float = 1.0) -> float:
    """F_α (Eq. 35). truth/returned are id arrays."""
    t, a = set(np.asarray(truth).tolist()), set(np.asarray(returned).tolist())
    if not a and not t:
        return 1.0
    if not a or not t:
        return 0.0
    inter = len(t & a)
    prec = inter / len(a)
    rec = inter / len(t)
    if prec + rec == 0:
        return 0.0
    return (1 + alpha**2) * prec * rec / (alpha**2 * prec + rec)


def precision_recall(truth: np.ndarray, returned: np.ndarray) -> tuple[float, float]:
    t, a = set(np.asarray(truth).tolist()), set(np.asarray(returned).tolist())
    if not a:
        return (1.0 if not t else 0.0), (1.0 if not t else 0.0)
    inter = len(t & a)
    return inter / len(a), (inter / len(t) if t else 1.0)


def run_search(engine, index, q_ids: np.ndarray, threshold: float, seed: int = 0):
    """Any registered engine → candidate id array (registry shim).

    ``index`` may be a legacy core object (GBKMVIndex, PackedSketches,
    LSHEnsemble, InvertedIndex) or a ``repro.api`` index.
    """
    from repro import api

    return api.as_index(engine, index, seed=seed).query(np.asarray(q_ids),
                                                        threshold)


def evaluate_engine(
    engine,
    index,
    exact_index,
    queries: Sequence[np.ndarray],
    threshold: float,
    alpha: float = 1.0,
    seed: int = 0,
) -> dict:
    """Mean F_α / precision / recall of an engine over a query workload.

    One ``batch_query`` call per side — sketch engines answer the whole
    workload in a single planned sweep instead of paying per-query
    dispatch (sketching, device round-trips) ``len(queries)`` times.
    """
    from repro import api

    truth_idx = api.as_index("exact", exact_index)
    idx = api.as_index(engine, index, seed=seed)
    queries = [np.asarray(q) for q in queries]
    truths = truth_idx.batch_query(queries, threshold)
    gots = idx.batch_query(queries, threshold)
    fs, ps, rs = [], [], []
    for truth, got in zip(truths, gots):
        fs.append(f_score(truth, got, alpha=alpha))
        p, r = precision_recall(truth, got)
        ps.append(p)
        rs.append(r)
    return {
        "f": float(np.mean(fs)), "f_min": float(np.min(fs)), "f_max": float(np.max(fs)),
        "precision": float(np.mean(ps)), "recall": float(np.mean(rs)),
    }
