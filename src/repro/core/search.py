"""Unified containment-search front end + evaluation metrics (paper §V-A).

``run_search`` dispatches to any of the implemented engines so benchmarks
compare methods through one door. ``f_score`` implements Eq. 35.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import exact as exact_mod
from repro.core import gbkmv as gbkmv_mod
from repro.core import lshe as lshe_mod


def f_score(truth: np.ndarray, returned: np.ndarray, alpha: float = 1.0) -> float:
    """F_α (Eq. 35). truth/returned are id arrays."""
    t, a = set(np.asarray(truth).tolist()), set(np.asarray(returned).tolist())
    if not a and not t:
        return 1.0
    if not a or not t:
        return 0.0
    inter = len(t & a)
    prec = inter / len(a)
    rec = inter / len(t)
    if prec + rec == 0:
        return 0.0
    return (1 + alpha**2) * prec * rec / (alpha**2 * prec + rec)


def precision_recall(truth: np.ndarray, returned: np.ndarray) -> tuple[float, float]:
    t, a = set(np.asarray(truth).tolist()), set(np.asarray(returned).tolist())
    if not a:
        return (1.0 if not t else 0.0), (1.0 if not t else 0.0)
    inter = len(t & a)
    return inter / len(a), (inter / len(t) if t else 1.0)


def run_search(engine, index, q_ids: np.ndarray, threshold: float, seed: int = 0):
    """engine ∈ {gbkmv, lshe, exact, prefix} → candidate id array."""
    if engine == "gbkmv":
        return gbkmv_mod.search(index, q_ids, threshold)
    if engine == "lshe":
        return lshe_mod.query_lshe(index, q_ids, threshold, seed=seed)
    if engine == "exact":
        return exact_mod.exact_search(index, q_ids, threshold)
    if engine == "prefix":
        return exact_mod.prefix_filter_search(index, q_ids, threshold)
    raise ValueError(f"unknown engine {engine!r}")


def evaluate_engine(
    engine,
    index,
    exact_index,
    queries: Sequence[np.ndarray],
    threshold: float,
    alpha: float = 1.0,
    seed: int = 0,
) -> dict:
    """Mean F_α / precision / recall of an engine over a query workload."""
    fs, ps, rs = [], [], []
    for q in queries:
        truth = exact_mod.exact_search(exact_index, q, threshold)
        got = run_search(engine, index, q, threshold, seed=seed)
        fs.append(f_score(truth, got, alpha=alpha))
        p, r = precision_recall(truth, got)
        ps.append(p)
        rs.append(r)
    return {
        "f": float(np.mean(fs)), "f_min": float(np.min(fs)), "f_max": float(np.max(fs)),
        "precision": float(np.mean(ps)), "recall": float(np.mean(rs)),
    }
