"""Packed, fixed-capacity sketch containers (TPU-native layout).

The paper stores one variable-length hash list per record. On TPU we pack
the whole index into dense matrices (DESIGN.md §3):

    values  uint32[m, C]   sorted ascending, PAD-filled
    lengths int32[m]       number of live hash values per row
    thresh  uint32[m]      per-record *effective* threshold: the global τ,
                           or (C-th smallest hash) for rows that overflowed
                           the capacity C
    buf     uint32[m, W]   GB-KMV bitmap buffer (W = ceil(r / 32) words)
    sizes   int32[m]       true |X| (record cardinalities; known, per paper)

A pair (Q, X) is estimated under τ_pair = min(thresh_Q, thresh_X): both
rows provably contain *every* element hashing below τ_pair, so the union
of the truncated rows is a valid KMV synopsis of Q ∪ X (paper Theorem 2
applied at τ_pair). This keeps correctness under bounded capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import PAD, hash_u32_np


@dataclasses.dataclass
class RaggedBatch:
    """A record batch ingested once into CSR form (flat ids + offsets).

    The vectorized construction pipeline never walks records in Python:
    every per-record quantity becomes a segment op over ``ids`` keyed by
    ``offsets`` (frequencies = bincount, buffer split = sorted-search,
    packing = lexsort + scatter). ``ids`` is record-major: record i owns
    ``ids[offsets[i]:offsets[i+1]]``.
    """

    ids: np.ndarray       # int64[N] flat element ids, record-major
    offsets: np.ndarray   # int64[m+1] row starts (offsets[-1] == N)

    @classmethod
    def from_records(cls, records: Sequence[np.ndarray]) -> "RaggedBatch":
        try:
            # Fast path: records already 1-D arrays — one concatenate,
            # no per-record asarray round-trip.
            sizes = np.fromiter((len(r) for r in records), np.int64,
                                count=len(records))
            ids = (np.concatenate(records).astype(np.int64, copy=False)
                   if len(records) and sizes.sum() else np.zeros(0, np.int64))
            if ids.ndim != 1:
                raise ValueError
        except (ValueError, TypeError):
            arrs = [np.asarray(r, dtype=np.int64).reshape(-1)
                    for r in records]
            sizes = np.asarray([len(a) for a in arrs], dtype=np.int64)
            ids = (np.concatenate(arrs) if arrs else np.zeros(0, np.int64))
        offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        return cls(ids=ids, offsets=offsets)

    @property
    def num_records(self) -> int:
        return len(self.offsets) - 1

    @property
    def total(self) -> int:
        return int(self.offsets[-1])

    @property
    def sizes(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int32)

    def row_index(self) -> np.ndarray:
        """int64[N]: the record id owning each flat position."""
        return np.repeat(np.arange(self.num_records, dtype=np.int64),
                         np.diff(self.offsets))


@dataclasses.dataclass
class PackedSketches:
    """Device-ready GB-KMV index (or a single-query slice of one)."""

    values: np.ndarray | jnp.ndarray   # uint32[m, C]
    lengths: np.ndarray | jnp.ndarray  # int32[m]
    thresh: np.ndarray | jnp.ndarray   # uint32[m]
    buf: np.ndarray | jnp.ndarray      # uint32[m, W] (W may be 0)
    sizes: np.ndarray | jnp.ndarray    # int32[m]

    @property
    def num_records(self) -> int:
        return self.values.shape[0]

    @property
    def capacity(self) -> int:
        return self.values.shape[1]

    @property
    def buf_words(self) -> int:
        return self.buf.shape[1]

    def row(self, i: int) -> "PackedSketches":
        return PackedSketches(
            values=self.values[i : i + 1],
            lengths=self.lengths[i : i + 1],
            thresh=self.thresh[i : i + 1],
            buf=self.buf[i : i + 1],
            sizes=self.sizes[i : i + 1],
        )

    def to_device(self) -> "PackedSketches":
        return PackedSketches(
            values=jnp.asarray(self.values),
            lengths=jnp.asarray(self.lengths),
            thresh=jnp.asarray(self.thresh),
            buf=jnp.asarray(self.buf),
            sizes=jnp.asarray(self.sizes),
        )

    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes) for a in
                   (self.values, self.lengths, self.thresh, self.buf, self.sizes))


# PackedSketches crosses jit boundaries (sketchindex/distributed.py).
jax.tree_util.register_dataclass(
    PackedSketches,
    data_fields=["values", "lengths", "thresh", "buf", "sizes"],
    meta_fields=[],
)


def pack_rows(
    hash_rows: Sequence[np.ndarray],
    thresholds: np.ndarray,
    sizes: np.ndarray,
    bitmaps: np.ndarray | None = None,
    capacity: int | None = None,
    pad_to_multiple: int = 8,
) -> PackedSketches:
    """Pack per-record sorted hash arrays into a :class:`PackedSketches`.

    ``hash_rows[i]`` must already be filtered to ``h <= thresholds[i]`` and
    sorted ascending. Rows longer than ``capacity`` are truncated to their
    ``capacity`` smallest values and their effective threshold lowered to
    the largest kept value (so τ_pair semantics stay exact).
    """
    m = len(hash_rows)
    max_len = max((len(r) for r in hash_rows), default=0)
    cap = _resolve_capacity(max_len, capacity, pad_to_multiple)

    values = np.full((m, cap), PAD, dtype=np.uint32)
    lengths = np.zeros(m, dtype=np.int32)
    thr = np.asarray(thresholds, dtype=np.uint32).copy()
    for i, row in enumerate(hash_rows):
        row = np.asarray(row, dtype=np.uint32)
        if len(row) > cap:
            row = row[:cap]
            # Effective threshold drops to the largest kept value.
            thr[i] = row[-1]
        values[i, : len(row)] = row
        lengths[i] = len(row)

    if bitmaps is None:
        bitmaps = np.zeros((m, 0), dtype=np.uint32)
    return PackedSketches(
        values=values,
        lengths=lengths,
        thresh=thr,
        buf=np.asarray(bitmaps, dtype=np.uint32),
        sizes=np.asarray(sizes, dtype=np.int32),
    )


def _resolve_capacity(max_len: int, capacity: int | None,
                      pad_to_multiple: int) -> int:
    """The shared pack width rule: requested capacity (or the longest
    row), floored at 1, rounded up to ``pad_to_multiple``."""
    cap = capacity if capacity is not None else max_len
    cap = max(cap, 1)
    return -(-cap // pad_to_multiple) * pad_to_multiple


def pack_csr(
    hashes: np.ndarray,
    row: np.ndarray,
    m: int,
    thresholds: np.ndarray,
    sizes: np.ndarray,
    bitmaps: np.ndarray | None = None,
    capacity: int | None = None,
    pad_to_multiple: int = 8,
    presorted: bool = False,
) -> PackedSketches:
    """Vectorized twin of :func:`pack_rows` over a flat (hash, row) list.

    ``hashes[k]`` belongs to record ``row[k]``; neither needs any
    pre-sorting — one u64 key sort orders the whole batch (row-major,
    hashes ascending within a row) and one scatter writes the value
    matrix. Callers whose stream already has that order pass
    ``presorted=True`` to skip the sort. Bit-identical to packing the
    per-record lists through ``pack_rows``, including the
    capacity-overflow rule (rows longer than the capacity keep their
    smallest values and lower their effective threshold to the largest
    kept value).
    """
    hashes = np.asarray(hashes, dtype=np.uint32)
    row = np.asarray(row, dtype=np.int64)
    if not presorted:
        # One u64 key sort realizes (row asc, hash asc) and decomposes
        # back — same order a stable lexsort gives, at single-sort cost.
        key = np.sort((row.astype(np.uint64) << np.uint64(32))
                      | hashes.astype(np.uint64))
        hashes = (key & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        row = (key >> np.uint64(32)).astype(np.int64)

    counts = np.bincount(row, minlength=m).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])
    cap = _resolve_capacity(int(counts.max()) if m else 0, capacity,
                            pad_to_multiple)

    thr = np.asarray(thresholds, dtype=np.uint32).copy()
    over = counts > cap
    if over.any():
        # Effective threshold drops to the cap-th smallest kept value.
        thr[over] = hashes[starts[:-1][over] + cap - 1]

    pos = np.arange(len(hashes), dtype=np.int64) - starts[row]
    keep = pos < cap
    values = np.full((m, cap), PAD, dtype=np.uint32)
    values[row[keep], pos[keep]] = hashes[keep]
    lengths = np.minimum(counts, cap).astype(np.int32)

    if bitmaps is None:
        bitmaps = np.zeros((m, 0), dtype=np.uint32)
    return PackedSketches(
        values=values,
        lengths=lengths,
        thresh=thr,
        buf=np.asarray(bitmaps, dtype=np.uint32),
        sizes=np.asarray(sizes, dtype=np.int32),
    )


def top_membership(ids: np.ndarray, top_elems: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(is_top bool[N], bit j int64[N]) of flat ids vs the top-r set.

    Sorted-search (or dense-table) membership — the vectorized
    replacement for the per-element Python ``set`` test. ``bit[k]`` is
    only meaningful where ``is_top[k]``; bit j is the *frequency-order*
    position of the element in ``top_elems`` (the buffer-bit layout
    make_bitmaps uses).
    """
    ids = np.asarray(ids, dtype=np.int64)
    top = np.asarray(top_elems, dtype=np.int64)
    if len(top) == 0 or len(ids) == 0:
        return np.zeros(len(ids), bool), np.zeros(len(ids), np.int64)
    max_id = int(top.max())
    if 0 <= int(top.min()) and max_id < max(4 * len(ids), 1 << 22):
        # Dense-universe fast path: one gather per element beats a
        # log(r) binary search. Table bytes are bounded by ~8×N.
        table = np.full(max_id + 2, -1, np.int64)
        table[top] = np.arange(len(top), dtype=np.int64)
        if int(ids.min()) >= 0 and int(ids.max()) <= max_id:
            bit = table[ids]
        else:
            safe = np.where((ids >= 0) & (ids <= max_id), ids, max_id + 1)
            bit = table[safe]
        return bit >= 0, bit
    sort_idx = np.argsort(top, kind="stable")
    sorted_top = top[sort_idx]
    pos = np.searchsorted(sorted_top, ids)
    ok = pos < len(top)
    is_top = np.zeros(len(ids), bool)
    is_top[ok] = sorted_top[pos[ok]] == ids[ok]
    bit = np.zeros(len(ids), np.int64)
    bit[is_top] = sort_idx[pos[is_top]]
    return is_top, bit


def make_bitmaps(records: Sequence[np.ndarray], top_elems: np.ndarray,
                 membership: tuple[np.ndarray, np.ndarray] | None = None
                 ) -> np.ndarray:
    """Per-record bitmap over the top-r frequent elements (vectorized).

    ``top_elems[j]`` is the element id owning bit ``j``. Returns
    ``uint32[m, ceil(r/32)]`` (r rounded up to a word). Word layout: bit j
    lives in word ``j // 32`` at position ``j % 32``. Accepts either a
    record list or a :class:`RaggedBatch`; ``membership`` passes a
    precomputed :func:`top_membership` of the batch's flat ids so build
    pipelines that already split on it don't pay the pass twice.
    """
    batch = (records if isinstance(records, RaggedBatch)
             else RaggedBatch.from_records(records))
    r = len(top_elems)
    words = max(-(-r // 32), 1) if r else 0
    m = batch.num_records
    out = np.zeros((m, words), dtype=np.uint32)
    if r == 0 or batch.total == 0:
        return out
    is_top, bit = (membership if membership is not None
                   else top_membership(batch.ids, top_elems))
    rows = batch.row_index()[is_top]
    j = bit[is_top]
    # Buffered bool scatter (duplicates just re-set True), then one
    # vectorized bit-pack — far cheaper than an unbuffered bitwise_or.at.
    # Chunk rows so the [chunk, words*32] bool matrix stays small.
    shifts = (np.uint32(1) << np.arange(32, dtype=np.uint32))
    chunk = max(1, (1 << 22) // max(words * 32, 1))
    # rows comes off row_index() and is already ascending; searchsorted
    # below relies on that record-major order.
    lo_idx = 0
    for lo in range(0, m, chunk):
        hi = min(lo + chunk, m)
        hi_idx = np.searchsorted(rows, hi, side="left")
        bits = np.zeros((hi - lo, words * 32), dtype=bool)
        bits[rows[lo_idx:hi_idx] - lo, j[lo_idx:hi_idx]] = True
        out[lo:hi] = (bits.reshape(hi - lo, words, 32)
                      * shifts[None, None, :]).sum(axis=2, dtype=np.uint32)
        lo_idx = hi_idx
    return out


def make_bitmaps_oracle(records: Sequence[np.ndarray],
                        top_elems: np.ndarray) -> np.ndarray:
    """The seed-era per-element loop — the test oracle for make_bitmaps."""
    r = len(top_elems)
    words = max(-(-r // 32), 1) if r else 0
    m = len(records)
    out = np.zeros((m, words), dtype=np.uint32)
    if r == 0:
        return out
    pos = {int(e): j for j, e in enumerate(np.asarray(top_elems))}
    for i, rec in enumerate(records):
        for e in np.asarray(rec):
            j = pos.get(int(e))
            if j is not None:
                out[i, j // 32] |= np.uint32(1) << np.uint32(j % 32)
    return out


def hash_records(records: Sequence[np.ndarray], seed: int = 0) -> list[np.ndarray]:
    """Hash each record's element ids → sorted uint32 arrays (host side)."""
    return [np.sort(hash_u32_np(np.asarray(r), seed=seed)) for r in records]
