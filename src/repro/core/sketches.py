"""Packed, fixed-capacity sketch containers (TPU-native layout).

The paper stores one variable-length hash list per record. On TPU we pack
the whole index into dense matrices (DESIGN.md §3):

    values  uint32[m, C]   sorted ascending, PAD-filled
    lengths int32[m]       number of live hash values per row
    thresh  uint32[m]      per-record *effective* threshold: the global τ,
                           or (C-th smallest hash) for rows that overflowed
                           the capacity C
    buf     uint32[m, W]   GB-KMV bitmap buffer (W = ceil(r / 32) words)
    sizes   int32[m]       true |X| (record cardinalities; known, per paper)

A pair (Q, X) is estimated under τ_pair = min(thresh_Q, thresh_X): both
rows provably contain *every* element hashing below τ_pair, so the union
of the truncated rows is a valid KMV synopsis of Q ∪ X (paper Theorem 2
applied at τ_pair). This keeps correctness under bounded capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import PAD, hash_u32_np


@dataclasses.dataclass
class PackedSketches:
    """Device-ready GB-KMV index (or a single-query slice of one)."""

    values: np.ndarray | jnp.ndarray   # uint32[m, C]
    lengths: np.ndarray | jnp.ndarray  # int32[m]
    thresh: np.ndarray | jnp.ndarray   # uint32[m]
    buf: np.ndarray | jnp.ndarray      # uint32[m, W] (W may be 0)
    sizes: np.ndarray | jnp.ndarray    # int32[m]

    @property
    def num_records(self) -> int:
        return self.values.shape[0]

    @property
    def capacity(self) -> int:
        return self.values.shape[1]

    @property
    def buf_words(self) -> int:
        return self.buf.shape[1]

    def row(self, i: int) -> "PackedSketches":
        return PackedSketches(
            values=self.values[i : i + 1],
            lengths=self.lengths[i : i + 1],
            thresh=self.thresh[i : i + 1],
            buf=self.buf[i : i + 1],
            sizes=self.sizes[i : i + 1],
        )

    def to_device(self) -> "PackedSketches":
        return PackedSketches(
            values=jnp.asarray(self.values),
            lengths=jnp.asarray(self.lengths),
            thresh=jnp.asarray(self.thresh),
            buf=jnp.asarray(self.buf),
            sizes=jnp.asarray(self.sizes),
        )

    def nbytes(self) -> int:
        return sum(int(np.asarray(a).nbytes) for a in
                   (self.values, self.lengths, self.thresh, self.buf, self.sizes))


# PackedSketches crosses jit boundaries (sketchindex/distributed.py).
jax.tree_util.register_dataclass(
    PackedSketches,
    data_fields=["values", "lengths", "thresh", "buf", "sizes"],
    meta_fields=[],
)


def pack_rows(
    hash_rows: Sequence[np.ndarray],
    thresholds: np.ndarray,
    sizes: np.ndarray,
    bitmaps: np.ndarray | None = None,
    capacity: int | None = None,
    pad_to_multiple: int = 8,
) -> PackedSketches:
    """Pack per-record sorted hash arrays into a :class:`PackedSketches`.

    ``hash_rows[i]`` must already be filtered to ``h <= thresholds[i]`` and
    sorted ascending. Rows longer than ``capacity`` are truncated to their
    ``capacity`` smallest values and their effective threshold lowered to
    the largest kept value (so τ_pair semantics stay exact).
    """
    m = len(hash_rows)
    max_len = max((len(r) for r in hash_rows), default=0)
    cap = capacity if capacity is not None else max_len
    cap = max(cap, 1)
    cap = -(-cap // pad_to_multiple) * pad_to_multiple  # round up

    values = np.full((m, cap), PAD, dtype=np.uint32)
    lengths = np.zeros(m, dtype=np.int32)
    thr = np.asarray(thresholds, dtype=np.uint32).copy()
    for i, row in enumerate(hash_rows):
        row = np.asarray(row, dtype=np.uint32)
        if len(row) > cap:
            row = row[:cap]
            # Effective threshold drops to the largest kept value.
            thr[i] = row[-1]
        values[i, : len(row)] = row
        lengths[i] = len(row)

    if bitmaps is None:
        bitmaps = np.zeros((m, 0), dtype=np.uint32)
    return PackedSketches(
        values=values,
        lengths=lengths,
        thresh=thr,
        buf=np.asarray(bitmaps, dtype=np.uint32),
        sizes=np.asarray(sizes, dtype=np.int32),
    )


def make_bitmaps(records: Sequence[np.ndarray], top_elems: np.ndarray) -> np.ndarray:
    """Per-record bitmap over the top-r frequent elements.

    ``top_elems[j]`` is the element id owning bit ``j``. Returns
    ``uint32[m, ceil(r/32)]`` (r rounded up to a word). Word layout: bit j
    lives in word ``j // 32`` at position ``j % 32``.
    """
    r = len(top_elems)
    words = max(-(-r // 32), 1) if r else 0
    m = len(records)
    out = np.zeros((m, words), dtype=np.uint32)
    if r == 0:
        return out
    pos = {int(e): j for j, e in enumerate(np.asarray(top_elems))}
    for i, rec in enumerate(records):
        for e in np.asarray(rec):
            j = pos.get(int(e))
            if j is not None:
                out[i, j // 32] |= np.uint32(1) << np.uint32(j % 32)
    return out


def hash_records(records: Sequence[np.ndarray], seed: int = 0) -> list[np.ndarray]:
    """Hash each record's element ids → sorted uint32 arrays (host side)."""
    return [np.sort(hash_u32_np(np.asarray(r), seed=seed)) for r in records]
