"""Named dataset stand-ins for paper Table II (scaled for CPU CI).

Each spec carries the dataset's *published* skew statistics (α1 element
frequency, α2 record size) and a scale factor; generation is deterministic.
`scale` divides record count / universe so the whole benchmark suite runs
on one CPU core; the skew exponents — which drive every claim in the paper
— are preserved exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synth import generate_dataset


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    m: int                 # records after scaling
    n_elems: int           # element universe after scaling
    alpha_freq: float      # α1 (Table II)
    alpha_size: float      # α2 (Table II)
    size_min: int
    size_max: int
    seed: int


# Table II, scaled ~100-1000×; (α1, α2) exact.
SPECS: dict[str, DatasetSpec] = {
    "NETFLIX": DatasetSpec("NETFLIX", 4000, 17770, 1.14, 4.95, 10, 1200, 11),
    "DELIC":   DatasetSpec("DELIC",   4000, 45000, 1.14, 3.05, 10, 600, 12),
    "COD":     DatasetSpec("COD",     1000, 120000, 1.09, 1.81, 10, 8000, 13),
    "ENRON":   DatasetSpec("ENRON",   4000, 60000, 1.16, 3.10, 10, 800, 14),
    "REUTERS": DatasetSpec("REUTERS", 4000, 28000, 1.32, 6.61, 10, 500, 15),
    "WEBSPAM": DatasetSpec("WEBSPAM", 1500, 80000, 1.33, 9.34, 100, 6000, 16),
    "WDC":     DatasetSpec("WDC",     8000, 100000, 1.08, 2.40, 10, 300, 17),
}


def load(name: str, scale: float = 1.0) -> list[np.ndarray]:
    spec = SPECS[name]
    m = max(int(spec.m * scale), 50)
    n = max(int(spec.n_elems * scale), 500)
    return generate_dataset(
        m=m, n_elems=n, alpha_freq=spec.alpha_freq, alpha_size=spec.alpha_size,
        size_min=spec.size_min, size_max=min(spec.size_max, max(n // 4, 20)),
        seed=spec.seed,
    )
