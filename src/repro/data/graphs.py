"""Synthetic graph generators for the four assigned GNN shapes (scaled for
CPU tests/examples; the dry-run uses the full shape specs directly).

Power-law degree distribution (preferential-attachment-ish) matches the
skew of reddit/ogbn-products; mesh-padding helpers add mask-0 nodes and
self-loop edges so every mesh axis divides (launch/cells.py contract).
"""

from __future__ import annotations

import numpy as np


def powerlaw_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                   seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    # Degree-skewed destination choice: preferential weights ~ rank^-0.8.
    w = (np.arange(1, n_nodes + 1) ** -0.8)
    p = w / w.sum()
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    return {
        "feats": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "edges": np.stack([src, dst], axis=1).astype(np.int32),
        "labels": rng.integers(0, n_classes, size=n_nodes).astype(np.int32),
        "mask": np.ones(n_nodes, dtype=np.float32),
    }


def molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                   n_classes: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    adj = np.zeros((batch, n_nodes, n_nodes), np.float32)
    for b in range(batch):
        e = rng.integers(0, n_nodes, size=(n_edges, 2))
        adj[b, e[:, 0], e[:, 1]] = 1.0
        adj[b, e[:, 1], e[:, 0]] = 1.0
    return {
        "feats": rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32),
        "adj": adj,
        "labels": rng.integers(0, n_classes, size=batch).astype(np.int32),
    }


def pad_graph(batch: dict, n_dev: int) -> dict:
    """Pad nodes/edges to multiples of the mesh size (mask-0 / self-loops)."""
    out = dict(batch)
    nn = batch["feats"].shape[0]
    nn_pad = -(-nn // n_dev) * n_dev
    if nn_pad != nn:
        pad_n = nn_pad - nn
        out["feats"] = np.pad(batch["feats"], ((0, pad_n), (0, 0)))
        out["labels"] = np.pad(batch["labels"], (0, pad_n))
        out["mask"] = np.pad(batch["mask"], (0, pad_n))
    ne = batch["edges"].shape[0]
    ne_pad = -(-ne // n_dev) * n_dev
    if ne_pad != ne:
        # Self-loops on node 0 contribute only to node 0's aggregation,
        # which the mask already handles if node 0 is real (its degree
        # normalizer includes the loop — negligible at scale, exact in
        # tests via mask-0 sink node).
        sink = nn_pad - 1 if nn_pad != nn else 0
        loops = np.full((ne_pad - ne, 2), sink, dtype=np.int32)
        out["edges"] = np.concatenate([batch["edges"], loops], axis=0)
    return out
