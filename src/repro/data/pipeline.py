"""LM data pipeline: shingling, GB-KMV near-duplicate filtering, and a
deterministic, checkpoint-resumable batch iterator.

The paper's technique plugs in as a first-class pipeline stage: documents
become q-gram shingle sets; a GB-KMV index over the corpus answers
"is (most of) this document contained in an already-kept one?" — exact
containment dedup is O(n²·len); the sketch makes the sweep linear in
sketch size (paper §V-E's construction-speed + query-speed advantage).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import api


def shingle(tokens: np.ndarray, q: int = 3) -> np.ndarray:
    """Token q-gram shingles → distinct int64 ids (rolling polynomial)."""
    t = np.asarray(tokens, dtype=np.int64)
    if len(t) < q:
        return np.unique(t)
    base = np.int64(1_000_003)
    acc = np.zeros(len(t) - q + 1, dtype=np.int64)
    for i in range(q):
        acc = acc * base + t[i : len(t) - q + 1 + i]
    return np.unique(acc & np.int64(0x7FFF_FFFF_FFFF))


def dedup_corpus(
    docs: list[np.ndarray],
    threshold: float = 0.8,
    budget_frac: float = 0.1,
    q: int = 3,
    seed: int = 0,
) -> tuple[list[int], dict]:
    """Containment-similarity near-dup sweep (GB-KMV-powered).

    A doc is dropped when ≥``threshold`` of its shingles are contained in
    an earlier KEPT doc — the asymmetric containment direction is exactly
    what catches sub/superset duplication that Jaccard misses (paper §I).

    Returns (kept indices, stats).
    """
    shingles = [shingle(d, q=q) for d in docs]
    total = sum(len(s) for s in shingles)
    index = api.get_engine("gbkmv").build(
        shingles, max(int(total * budget_frac), 64), seed=seed)
    kept: list[int] = []
    kept_mask = np.zeros(len(docs), dtype=bool)
    dropped = 0
    for i, s in enumerate(shingles):
        if len(s) == 0:
            continue
        cands = index.query(s, threshold)
        # Containment of doc i in any EARLIER kept doc → near-dup.
        hit = any(kept_mask[c] for c in cands if c != i)
        if hit:
            dropped += 1
        else:
            kept.append(i)
            kept_mask[i] = True
    return kept, {"total": len(docs), "kept": len(kept), "dropped": dropped}


@dataclasses.dataclass
class BatchCursor:
    """Deterministic resumable pipeline position (rides in checkpoints)."""

    seed: int
    step: int = 0


def token_batches(
    docs: list[np.ndarray],
    batch: int,
    seq: int,
    cursor: BatchCursor,
):
    """Infinite deterministic [batch, seq+1] token stream.

    The permutation and packing depend only on (seed, step): restoring a
    checkpointed cursor resumes the exact stream (ft/checkpoint.py).
    """
    flat = np.concatenate([np.asarray(d, np.int64) for d in docs])
    if len(flat) < seq + 2:           # tiny corpus: wrap-pad once
        reps = (seq + 2) // max(len(flat), 1) + 1
        flat = np.tile(flat, reps)
    n_tok = len(flat)
    while True:
        rng = np.random.default_rng(cursor.seed + 7_919 * cursor.step)
        starts = rng.integers(0, n_tok - seq - 1, size=batch)
        rows = np.stack([flat[s : s + seq + 1] for s in starts])
        cursor.step += 1
        yield {"tokens": rows[:, :-1].astype(np.int32),
               "labels": rows[:, 1:].astype(np.int32)}
