"""GNN neighbor sampler (the real thing, not a stub): CSR adjacency +
layer-wise fanout sampling for the ``minibatch_lg`` regime.

Host-side numpy (samplers are IO/pipeline work, per GraphSAGE practice);
emits fixed-shape [B, f1, ...] feature tensors ready for the jitted step.
Sampling with replacement (uniform per neighbor) keeps shapes static —
isolated nodes self-loop.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # int64[N+1]
    indices: np.ndarray   # int32[E]
    num_nodes: int

    @staticmethod
    def from_edges(edges: np.ndarray, num_nodes: int) -> "CSRGraph":
        """edges i32[E, 2] (src, dst) → CSR over *incoming* neighbors."""
        dst = edges[:, 1].astype(np.int64)
        order = np.argsort(dst, kind="stable")
        sorted_src = edges[order, 0].astype(np.int32)
        counts = np.bincount(dst, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=sorted_src, num_nodes=num_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """Uniform with replacement → i32[len(nodes), fanout]."""
        lo = self.indptr[nodes]
        deg = self.indptr[nodes + 1] - lo
        safe_deg = np.maximum(deg, 1)
        draw = rng.integers(0, 1 << 62, size=(len(nodes), fanout)) % safe_deg[:, None]
        neigh = self.indices[(lo[:, None] + draw).astype(np.int64)]
        # Isolated nodes: self-loop.
        return np.where(deg[:, None] > 0, neigh, nodes[:, None]).astype(np.int32)


def sample_batch(
    graph: CSRGraph,
    feats: np.ndarray,
    labels: np.ndarray,
    batch_nodes: int,
    fanout: tuple[int, int],
    rng: np.random.Generator,
) -> dict:
    """One layer-wise sampled minibatch for the 2-layer GraphSAGE step."""
    f1, f2 = fanout
    seeds = rng.integers(0, graph.num_nodes, size=batch_nodes).astype(np.int32)
    hop1 = graph.sample_neighbors(seeds, f1, rng)              # [B, f1]
    hop2 = graph.sample_neighbors(hop1.reshape(-1), f2, rng)   # [B*f1, f2]
    return {
        "seed_feats": feats[seeds],
        "h1": feats[hop1],
        "h2": feats[hop2].reshape(batch_nodes, f1, f2, -1),
        "labels": labels[seeds].astype(np.int32),
    }
