"""Synthetic set-valued dataset generation (paper Table II / Fig. 16 / 19).

Records are element-id sets with:
  * element popularity ~ zipf(α1) over a universe of ``n_elems``
  * record size ~ truncated power-law(α2) on [size_min, size_max]
(paper §IV-C1 assumptions; Fig. 16 varies both z-values).

No network access in this environment, so the 7 real datasets of Table II
are reproduced as scaled synthetics with their *published* (α1, α2, m,
avg-length) statistics — see data/datasets.py.
"""

from __future__ import annotations

import numpy as np


def powerlaw_sizes(
    m: int, alpha: float, size_min: int, size_max: int, rng: np.random.Generator
) -> np.ndarray:
    """Record sizes ~ p(x) ∝ x^{-alpha} on [size_min, size_max] (inverse CDF)."""
    u = rng.random(m)
    if abs(alpha - 1.0) < 1e-9:
        s = size_min * (size_max / size_min) ** u
    elif alpha == 0.0:
        s = size_min + u * (size_max - size_min)
    else:
        a = 1.0 - alpha
        s = (size_min**a + u * (size_max**a - size_min**a)) ** (1.0 / a)
    return np.clip(s.astype(np.int64), size_min, size_max)


def zipf_element_sampler(n_elems: int, alpha: float, rng: np.random.Generator):
    """Sampler over element ids with zipf(alpha) popularity (alias-free:
    inverse-CDF on the normalized rank weights)."""
    ranks = np.arange(1, n_elems + 1, dtype=np.float64)
    w = ranks ** (-alpha) if alpha > 0 else np.ones(n_elems)
    cdf = np.cumsum(w / w.sum())

    def sample(k: int) -> np.ndarray:
        return np.searchsorted(cdf, rng.random(k), side="left")

    return sample


def generate_dataset(
    m: int,
    n_elems: int,
    alpha_freq: float,
    alpha_size: float,
    size_min: int = 10,
    size_max: int = 500,
    seed: int = 0,
) -> list[np.ndarray]:
    """m records of *distinct* element ids (sets), zipf-popular elements.

    Sampling with rejection-free trick: draw 2× the target size, unique,
    then top up uniformly if dedup undershot (rare for big universes).
    """
    rng = np.random.default_rng(seed)
    sizes = powerlaw_sizes(m, alpha_size, size_min, size_max, rng)
    sample = zipf_element_sampler(n_elems, alpha_freq, rng)
    records = []
    for s in sizes:
        draw = np.unique(sample(int(2.2 * s) + 4))
        if len(draw) < s:
            extra = rng.choice(n_elems, size=int(s) * 2, replace=False)
            draw = np.unique(np.concatenate([draw, extra]))
        rng.shuffle(draw)
        records.append(np.sort(draw[: int(s)]).astype(np.int64))
    return records


def make_query_workload(
    records: list[np.ndarray], n_queries: int, seed: int = 0
) -> list[np.ndarray]:
    """Queries randomly chosen from the records (paper §IV-C1 / §V-A)."""
    rng = np.random.default_rng(seed + 7919)
    idx = rng.integers(0, len(records), size=n_queries)
    return [records[i] for i in idx]
