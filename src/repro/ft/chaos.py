"""Fault-injection harness for the durability layer (stdlib only).

The WAL and snapshot code thread every dangerous IO step through a
**named fault point** (``chaos.FAULT_POINTS`` is the canonical list, and
what the kill-and-recover test matrix iterates). With no monkey
installed a fault point is one module-global ``is None`` check — the
production cost of the harness is nothing.

A test installs a :class:`ChaosMonkey` and arms points with actions:

    crash      raise :class:`SimulatedCrash` *at* the point — the
               in-process stand-in for ``kill -9`` between two
               instructions. Durable state is exactly the bytes already
               handed to the OS (the WAL writes unbuffered, so nothing
               hides in user-space buffers).
    torn       (write points only) write a prefix of the payload, then
               crash — a torn record / torn file, the on-disk state a
               real crash mid-``write(2)`` leaves behind.
    error      raise ``OSError(errno, ...)`` — disk-full (ENOSPC),
               read-only remounts (EROFS), pulled volumes (EIO). The
               serving stack must degrade, not die.
    delay      sleep at the point — slow IO (a saturating disk, NFS
               hiccups); latency accounting must survive it.

Actions arm once by default (``times=1``) so recovery code re-running
the same path does not re-crash; ``times=-1`` keeps a point hot.

    monkey = ChaosMonkey().arm("wal.append.pre_fsync", "crash")
    with chaos.installed(monkey):
        ...            # the armed append raises SimulatedCrash

``SimulatedCrash`` subclasses ``BaseException`` deliberately: the
serving stack's ``except Exception`` guards (which keep a request error
from killing a connection) must not swallow a simulated kill — it has
to unwind to the test harness like a real SIGKILL unwinds to init.
"""

from __future__ import annotations

import threading
import time

#: Every fault point the durability layer declares, in WAL-lifecycle
#: order. The kill-and-recover matrix in tests/test_durability.py
#: iterates exactly this list — adding a point here without recovery
#: coverage fails that test by construction.
FAULT_POINTS = (
    "wal.append.pre_write",      # before the record frame hits the file
    "wal.append.write",          # the frame write itself (torn target)
    "wal.append.pre_fsync",      # frame written, not yet durable
    "wal.append.post_fsync",     # durable, not yet acked/applied
    "wal.rotate.pre_open",       # segment sealed, next not yet open
    "snapshot.pre_write",        # before any snapshot byte exists
    "snapshot.pre_rename",       # tmp dir complete, not yet visible
    "snapshot.post_rename",      # snapshot live, WAL not yet truncated
    "wal.truncate.pre_unlink",   # covered segments about to drop
)


class SimulatedCrash(BaseException):
    """The process 'dies' here — everything after never happened."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at fault point {point!r}")
        self.point = point


class ChaosMonkey:
    """Armed fault plan: ``{point: (action, kwargs, remaining_times)}``."""

    def __init__(self):
        self._lock = threading.Lock()
        self._plan: dict[str, list] = {}
        self.hits: list[str] = []       # every reached-and-fired point

    def arm(self, point: str, action: str = "crash", *, times: int = 1,
            keep_bytes: int | None = None, errno_: int | None = None,
            delay_s: float = 0.0) -> "ChaosMonkey":
        """Arm ``point``. ``times=-1`` keeps it armed forever;
        ``keep_bytes`` (torn) caps how much of the payload survives;
        ``errno_`` picks the OSError; ``delay_s`` the sleep."""
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}; "
                             f"known: {FAULT_POINTS}")
        if action not in ("crash", "torn", "error", "delay"):
            raise ValueError(f"unknown chaos action {action!r}")
        self._plan[point] = [action, {"keep_bytes": keep_bytes,
                                      "errno": errno_,
                                      "delay_s": delay_s}, int(times)]
        return self

    def _take(self, point: str):
        """Consume one firing of ``point`` (None when unarmed/spent)."""
        with self._lock:
            entry = self._plan.get(point)
            if entry is None or entry[2] == 0:
                return None
            if entry[2] > 0:
                entry[2] -= 1
            self.hits.append(point)
            return entry[0], entry[1]

    # -- fault-point entry hooks (called by the durability layer) ------

    def reach(self, point: str) -> None:
        """A plain (non-write) fault point."""
        fired = self._take(point)
        if fired is None:
            return
        action, kw = fired
        if action == "delay":
            time.sleep(kw["delay_s"])
        elif action == "error":
            import errno as errno_mod
            raise OSError(kw["errno"] or errno_mod.ENOSPC,
                          f"injected IO error at {point}")
        else:                           # crash / torn degrade to crash
            raise SimulatedCrash(point)

    def write(self, fileobj, data: bytes, point: str) -> None:
        """A write-shaped fault point: 'torn' leaves a prefix of
        ``data`` on disk and crashes; every other action behaves like
        :meth:`reach` *before* the bytes land."""
        fired = self._take(point)
        if fired is not None:
            action, kw = fired
            if action == "torn":
                keep = kw["keep_bytes"]
                keep = len(data) // 2 if keep is None else int(keep)
                fileobj.write(data[:max(0, min(keep, len(data) - 1))])
                raise SimulatedCrash(point)
            if action == "delay":
                time.sleep(kw["delay_s"])
            elif action == "error":
                import errno as errno_mod
                raise OSError(kw["errno"] or errno_mod.ENOSPC,
                              f"injected IO error at {point}")
            else:
                raise SimulatedCrash(point)
        fileobj.write(data)


# -- module-global installation (one None-check on the fast path) -----------

_MONKEY: ChaosMonkey | None = None


def install(monkey: ChaosMonkey) -> ChaosMonkey:
    global _MONKEY
    _MONKEY = monkey
    return monkey


def uninstall() -> None:
    global _MONKEY
    _MONKEY = None


class installed:
    """``with chaos.installed(monkey): ...`` — scoped installation."""

    def __init__(self, monkey: ChaosMonkey):
        self.monkey = monkey

    def __enter__(self) -> ChaosMonkey:
        return install(self.monkey)

    def __exit__(self, *exc):
        uninstall()
        return False


def point(name: str) -> None:
    """Reach fault point ``name`` (no-op unless a monkey armed it)."""
    m = _MONKEY
    if m is not None:
        m.reach(name)


def chaos_write(fileobj, data: bytes, name: str) -> None:
    """Write ``data`` through fault point ``name`` (torn-write capable)."""
    m = _MONKEY
    if m is None:
        fileobj.write(data)
    else:
        m.write(fileobj, data, name)
