"""Step-granular sharded checkpointing with restore-time resharding.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.msgpack   — tree structure, shapes, dtypes, step, data state
        arrays.npz         — one entry per leaf, keyed by tree path

Save path: every leaf is host-gathered from its addressable shards
(``np.asarray`` pulls and re-assembles; on a multi-host deployment each
process would write only ``addressable_shards`` — the manifest format
already keys per leaf, so per-shard files are a pure IO change, noted in
DESIGN.md). Restore takes a *target sharding tree* and ``device_put``s
each leaf straight to its (possibly different) mesh placement — elastic
re-meshing is restore-time resharding, no separate converter.

Atomicity: write to ``<dir>.tmp`` then ``os.rename`` — a crashed save never
corrupts the newest complete checkpoint; ``latest_step`` scans completed
dirs only.
"""

from __future__ import annotations

import os
import re
import shutil

import jax
import msgpack
import numpy as np


_SEP = "/"

# npz can't serialize ml_dtypes (bfloat16 etc.); ship them as same-width
# uint views and restore via the dtype string in the manifest.
_UINT_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}
_NATIVE = set("biufc")  # numpy dtype kinds npz handles natively


def _to_savable(a: np.ndarray) -> np.ndarray:
    if a.dtype.kind in _NATIVE:
        return a
    return a.view(_UINT_VIEW[a.dtype.itemsize])


def _from_saved(a: np.ndarray, dtype_str: str) -> np.ndarray:
    import jax.numpy as jnp
    want = jnp.dtype(dtype_str)
    if a.dtype == want:
        return a
    if np.dtype(want).kind not in _NATIVE:
        return a.view(want)
    return a.astype(want)


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_elem(p) for p in path)
        out[key] = leaf
    return out


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, state: dict, extra: dict | None = None):
    """state: arbitrary pytree (params / opt_state / rng / data cursor)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: _to_savable(a) for k, a in arrays.items()})

    manifest = {
        "step": int(step),
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d{8})", d))]
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    step: int | None = None,
    target: dict | None = None,
    shardings: dict | None = None,
):
    """Load a checkpoint; reshard onto ``shardings`` when given.

    ``target`` (a pytree of like-structured arrays or ShapeDtypeStructs)
    provides the tree structure to unflatten into; without it a nested-dict
    reconstruction from the path keys is returned.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    npz = np.load(os.path.join(d, "arrays.npz"))
    arrays = {k: _from_saved(npz[k], manifest["dtypes"][k])
              for k in manifest["keys"]}

    if target is not None:
        leaves = _flatten_with_paths(target)
        missing = set(leaves) - set(arrays)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
        shard_leaves = _flatten_with_paths(shardings) if shardings else {}
        put = {}
        for k, like in leaves.items():
            a = arrays[k]
            sh = shard_leaves.get(k)
            put[k] = jax.device_put(a, sh) if sh is not None else a
        state = _unflatten_like(target, put)
    else:
        state = _nest(arrays)
    return state, manifest


def _unflatten_like(target, flat_by_key):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(target)
    keys = [_SEP.join(_path_elem(p) for p in path)
            for path, _ in paths_and_leaves[0]]
    return jax.tree_util.tree_unflatten(
        paths_and_leaves[1], [flat_by_key[k] for k in keys])


def _nest(flat: dict) -> dict:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root
