"""Elastic scaling: resume the same logical training run on a different
mesh (fewer/more hosts) without changing the math.

Invariants preserved across a re-mesh:
  * global batch size       — microbatch count is re-derived so
                              global_batch = dp_size · per_device · micros
  * optimization trajectory — params/opt-state restored bit-exact, then
                              resharded onto the new mesh (ft/checkpoint
                              does device_put with the new shardings)
  * data order              — the data cursor (seed, step) rides in the
                              checkpoint manifest

The launcher calls ``plan_remesh`` on restart after the straggler monitor
(or the scheduler) changed the node set.
"""

from __future__ import annotations

import dataclasses

from repro.ft import checkpoint as ckpt_mod
from repro.parallel.sharding import tree_shardings


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    dp_size: int                # data-parallel ways on the new mesh
    per_device_batch: int
    microbatches: int
    notes: str = ""


def plan_remesh(new_mesh, global_batch: int, per_device_batch: int) -> RemeshPlan:
    """Re-derive microbatching so the global batch survives the re-mesh."""
    axes = dict(zip(new_mesh.axis_names, new_mesh.devices.shape))
    dp = axes.get("data", 1) * axes.get("pod", 1)
    denom = dp * per_device_batch
    if global_batch % denom:
        # Shrink per-device batch until it divides (keeps global batch exact).
        while per_device_batch > 1 and global_batch % (dp * per_device_batch):
            per_device_batch //= 2
        denom = dp * per_device_batch
        if global_batch % denom:
            raise ValueError(
                f"global_batch={global_batch} unreachable on dp={dp}")
    micro = global_batch // denom
    return RemeshPlan(dp_size=dp, per_device_batch=per_device_batch,
                      microbatches=micro,
                      notes=f"dp={dp} pdb={per_device_batch} micro={micro}")


def resume(ckpt_dir: str, new_mesh, state_like, state_axes, step=None):
    """Restore + reshard a run's state onto ``new_mesh``.

    ``state_like``  — pytree of arrays/ShapeDtypeStructs (tree structure)
    ``state_axes``  — matching pytree of logical-axis tuples
    """
    shardings = tree_shardings(state_axes, new_mesh)
    state, manifest = ckpt_mod.restore_checkpoint(
        ckpt_dir, step=step, target=state_like, shardings=shardings)
    return state, manifest
