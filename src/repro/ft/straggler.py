"""Straggler detection: per-step wall-time EWMA + deviation policy.

On a real pod the per-step time is a barrier over all hosts, so one slow
host inflates every step it participates in; the monitor distinguishes a
*step spike* (one-off, e.g. checkpoint write) from a *sustained straggle*
(failing HBM / thermal throttle) by counting consecutive flags, and its
``action()`` feeds the launcher's policy: log → re-shard data away from
the slow host → evict + elastic re-mesh (ft/elastic.py).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.05            # EWMA smoothing
    sigma_thresh: float = 3.0      # flag beyond mean + k·std
    sustain_steps: int = 5         # consecutive flags → sustained
    warmup: int = 10               # steps before flagging starts

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    consecutive: int = 0

    def record(self, step_time: float) -> str:
        """Feed one step's wall time; returns "ok" | "spike" | "sustained".

        Flagged samples do NOT update the EWMA — otherwise a sustained
        straggle drags the baseline up until it stops being detected.
        """
        self.n += 1
        if self.n == 1:
            self.mean = step_time
            return "ok"

        std = max(self.var ** 0.5, 0.05 * max(self.mean, 1e-9))
        flagged = (self.n > self.warmup
                   and step_time > self.mean + self.sigma_thresh * std)
        if flagged:
            self.consecutive += 1
            return ("sustained" if self.consecutive >= self.sustain_steps
                    else "spike")

        delta = step_time - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.consecutive = 0
        return "ok"

    def action(self, status: str) -> str:
        return {
            "ok": "none",
            "spike": "log",
            "sustained": "evict-and-remesh",
        }[status]
