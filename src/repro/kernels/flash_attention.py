"""Pallas TPU kernel: fused causal flash attention (forward).

The §Roofline tables show every LM train/prefill cell is MEMORY-bound,
dominated by the [B,H,cq,S] f32 score/prob tensors the unfused jnp path
materializes to HBM per chunk per layer (e.g. qwen train_4k: 3.44 s
memory term vs 0.19 s compute). This kernel keeps the running softmax
state (m, l, o) in VMEM and never writes scores to HBM — the classic
flash-attention memory discipline, adapted to TPU:

  * grid (batch·kv_head, q_chunk); the MXU-aligned [BLK_Q, D]·[D, BLK_K]
    tiles stream K/V through VMEM with a fori_loop over k-chunks;
  * causal masking by global position; k-chunks entirely above the
    diagonal are skipped via the loop bound (≈2× fewer tiles);
  * GQA: the q block carries all G group members of one kv head, so K/V
    tiles are loaded once per group (not per q head).

Analytic effect on the roofline memory term (per layer, per device):
  jnp path writes+reads  n_chunks·[B,H,cq,S]·4 B   (scores + probs)
  kernel writes only the [B,S,H,D] output            → ~S/D× less traffic
For qwen train_4k that is 3.44 s → ≈0.6 s (bound moves toward compute).

Used on the serving path (prefill) where TPU lowering is exercised for
real; CPU dry-runs keep the jnp path (pallas_call does not lower through
the CPU SPMD pipeline). Validated against models/attention.py in
interpret mode over shape/dtype sweeps (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q, blk_k, seq, scale):
    """One (batch·kv-head, q-chunk) cell: online softmax over k-chunks.

    q_ref [1, G, BLK_Q, D]; k_ref/v_ref [1, S, D]; o_ref [1, G, BLK_Q, D].
    """
    qi = pl.program_id(1)
    _, g, _, d = q_ref.shape

    q = q_ref[0].astype(jnp.float32) * scale              # [G, BQ, D]
    q2 = q.reshape(g * blk_q, d)

    m0 = jnp.full((g * blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g * blk_q,), jnp.float32)
    o0 = jnp.zeros((g * blk_q, d), jnp.float32)

    q_pos = qi * blk_q + jnp.arange(blk_q)                # global q rows
    q_pos_g = jnp.tile(q_pos, (g,))                       # [G*BQ]

    def body(ki, carry):
        m, l, o = carry
        k = lax.dynamic_slice(k_ref[0], (ki * blk_k, 0),
                              (blk_k, d)).astype(jnp.float32)
        v = lax.dynamic_slice(v_ref[0], (ki * blk_k, 0),
                              (blk_k, d)).astype(jnp.float32)
        s = q2 @ k.T                                      # [G*BQ, BK] (MXU)
        k_pos = ki * blk_k + jnp.arange(blk_k)
        mask = k_pos[None, :] <= q_pos_g[:, None]
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[:, None] + p @ v                # [G*BQ, D] (MXU)
        return m_new, l_new, o_new

    # Causal: k-chunks beyond this q-chunk's last row never contribute.
    n_k = (qi + 1) * blk_q // blk_k
    n_k = jnp.minimum(n_k + (((qi + 1) * blk_q) % blk_k != 0), seq // blk_k)
    m, l, o = lax.fori_loop(0, n_k, body, (m0, l0, o0))

    o = o / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = o.reshape(g, blk_q, d).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False, scale: float | None = None):
    """Causal GQA flash attention.

    q [B,S,Hq,D], k/v [B,S,Hkv,D] -> [B,S,Hq,D]. S % blk_q == 0,
    S % blk_k == 0; D should be a multiple of 128 for MXU alignment
    (the ops.py wrapper pads).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    assert s % blk_q == 0 and s % blk_k == 0, (s, blk_q, blk_k)

    # [B,S,Hq,D] -> [B·Hkv, G, S, D]; K/V -> [B·Hkv, S, D]
    qg = q.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4) \
          .reshape(b * hkv, g, s, d)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)

    grid = (b * hkv, s // blk_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                          seq=s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, blk_q, d), lambda h, i: (h, 0, i, 0)),
            pl.BlockSpec((1, s, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, s, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, blk_q, d), lambda h, i: (h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, s, d), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)

    return out.reshape(b, hkv, g, s, d).transpose(0, 3, 1, 2, 4) \
              .reshape(b, s, hq, d)
