"""Pallas TPU kernel: ragged candidate gather-scoring (planner verify step).

The dense kernel (gbkmv_score.py) sweeps every record row for every
query. After postings pruning the surviving work is a *ragged* list of
(record, query) pairs — a few hits per query at selective thresholds —
so the verify step is a gather problem, not a sweep problem:

    cand_rec i32[P]   record row to score           (scalar-prefetched)
    cand_q   i32[P]   query row it belongs to       (scalar-prefetched)
    out      f32[P]   Ĉ(Q_{cand_q[p]} → X_{cand_rec[p]})

Both gathers happen *in the kernel* via scalar-prefetch BlockSpec index
maps — the sketch matrices stay in HBM and only the addressed rows are
DMA'd to VMEM, so the pruned path never materializes a gathered copy of
the index. Per grid step the kernel scores one pair with exactly the
dense kernel's math (buffer popcount + τ_pair counts + Eq. 25 tail
estimator), reduced along the row (the segment here is one sketch row).

``score_pairs`` is the public door with the repo-standard ``backend=``
switch: "pallas" (this kernel, interpret mode off-TPU), "jnp" (XLA
gather + vectorized pair math), "numpy" (host oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import PAD, TWO32

# Lane-aligned membership chunk (matches gbkmv_score.QCHUNK).
QCHUNK = 128


def _pair_kernel(
    cand_rec_ref,   # i32[P]   (scalar prefetch)
    cand_q_ref,     # i32[P]   (scalar prefetch)
    x_values_ref,   # u32[1, C]   gathered record row
    x_thresh_ref,   # u32[1, 1]
    x_buf_ref,      # u32[1, W]
    q_values_ref,   # u32[1, Cq]  gathered query row
    q_thresh_ref,   # u32[1, 1]
    q_buf_ref,      # u32[1, W]
    q_sizes_ref,    # i32[1, 1]
    out_ref,        # f32[1, 1]
):
    xv = x_values_ref[...]                    # [1, C]
    xt = x_thresh_ref[...][:, 0]              # [1]
    qv = q_values_ref[0, :]                   # [Cq]
    qt = q_thresh_ref[0, 0]
    qs = q_sizes_ref[0, 0]
    _, c = xv.shape
    cq = qv.shape[0]

    tau = jnp.minimum(xt, qt)                 # [1]
    live_x = xv <= tau[:, None]               # [1, C]
    nx = jnp.sum(live_x.astype(jnp.int32), axis=-1)
    live_q = qv[None, :] <= tau[:, None]      # [1, Cq]
    nq = jnp.sum(live_q.astype(jnp.int32), axis=-1)

    def mem_body(i, member):
        chunk = lax.dynamic_slice(qv, (i * QCHUNK,), (QCHUNK,))
        hit = jnp.any(xv[:, :, None] == chunk[None, None, :], axis=-1)
        return member | hit

    member = lax.fori_loop(
        0, cq // QCHUNK, mem_body, jnp.zeros((1, c), jnp.bool_)
    )
    kcap = jnp.sum((member & live_x).astype(jnp.int32), axis=-1)
    k = nx + nq - kcap

    ux = jnp.max(jnp.where(live_x, xv, jnp.uint32(0)), axis=-1)
    uq = jnp.max(jnp.where(live_q, qv[None, :], jnp.uint32(0)), axis=-1)
    u = jnp.maximum(ux, uq)
    u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

    kf = k.astype(jnp.float32)
    d_hat = (kcap.astype(jnp.float32) / jnp.maximum(kf, 1.0)) * (
        (kf - 1.0) / jnp.maximum(u_unit, 1e-30)
    )
    d_hat = jnp.where((k >= 2) & (kcap >= 1), d_hat,
                      jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0))

    o1 = jnp.sum(lax.population_count(x_buf_ref[...] & q_buf_ref[...]),
                 axis=-1)
    out_ref[0, 0] = ((o1.astype(jnp.float32) + d_hat) / jnp.maximum(
        qs.astype(jnp.float32), 1.0))[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_score_pallas(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    cand_rec, cand_q,
    *, interpret: bool = False,
):
    """One grid step per candidate pair; rows addressed via prefetch."""
    _, c = x_values.shape
    _, cq = q_values.shape
    w = x_buf.shape[1]
    p = cand_rec.shape[0]
    assert cq % QCHUNK == 0 and w >= 1 and w == q_buf.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, rec, q: (rec[i], 0)),
            pl.BlockSpec((1, 1), lambda i, rec, q: (rec[i], 0)),
            pl.BlockSpec((1, w), lambda i, rec, q: (rec[i], 0)),
            pl.BlockSpec((1, cq), lambda i, rec, q: (q[i], 0)),
            pl.BlockSpec((1, 1), lambda i, rec, q: (q[i], 0)),
            pl.BlockSpec((1, w), lambda i, rec, q: (q[i], 0)),
            pl.BlockSpec((1, 1), lambda i, rec, q: (q[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, rec, q: (i, 0)),
    )
    out = pl.pallas_call(
        _pair_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.float32),
        interpret=interpret,
    )(cand_rec, cand_q,
      x_values, x_thresh[:, None], x_buf,
      q_values, q_thresh[:, None], q_buf, q_sizes[:, None])
    return out[:, 0]


@jax.jit
def _gather_score_jnp(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    cand_rec, cand_q,
):
    """XLA path: gather both sides, then vectorized per-pair math.

    Same op sequence per row as estimators.gkmv_pair_estimate +
    buffer_intersection, broadcast per-pair instead of one-query-vs-all.
    """
    xv = x_values[cand_rec]                   # [P, C]
    xt = x_thresh[cand_rec]                   # [P]
    xb = x_buf[cand_rec]                      # [P, W]
    qv = q_values[cand_q]                     # [P, Cq]
    qt = q_thresh[cand_q]
    qb = q_buf[cand_q]
    qs = q_sizes[cand_q]

    tau = jnp.minimum(xt, qt)                               # [P]
    nq = jnp.sum(qv <= tau[:, None], axis=-1).astype(jnp.int32)
    nx = jnp.sum(xv <= tau[:, None], axis=-1).astype(jnp.int32)
    live = xv <= tau[:, None]
    member = jnp.any(xv[:, :, None] == qv[:, None, :], axis=-1)
    kcap = jnp.sum(live & member, axis=-1).astype(jnp.int32)
    k = nq + nx - kcap

    def last_live(vals, n):
        idx = jnp.maximum(n - 1, 0)
        v = jnp.take_along_axis(vals, idx[:, None].astype(jnp.int32),
                                axis=-1)[:, 0]
        return jnp.where(n > 0, v, jnp.uint32(0))

    u = jnp.maximum(last_live(qv, nq), last_live(xv, nx))
    u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

    valid = (k >= 2) & (kcap >= 1)
    d_hat = jnp.where(
        valid,
        (kcap.astype(jnp.float32) / jnp.maximum(k, 1).astype(jnp.float32))
        * ((k.astype(jnp.float32) - 1.0) / jnp.maximum(u_unit, 1e-30)),
        jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0),
    )
    if xb.shape[-1]:
        o1 = jnp.sum(lax.population_count(xb & qb), axis=-1).astype(jnp.int32)
    else:
        o1 = jnp.zeros(xv.shape[0], dtype=jnp.int32)
    return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
        qs.astype(jnp.float32), 1.0)


def _gather_score_np(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    cand_rec, cand_q,
):
    """Host twin of the jnp path (float32 arithmetic, estimators.py idiom)."""
    from repro.core.estimators import _popcount_np

    xv = x_values[cand_rec].astype(np.uint32)
    xt = x_thresh[cand_rec].astype(np.uint32)
    xb = x_buf[cand_rec]
    qv = q_values[cand_q].astype(np.uint32)
    qt = q_thresh[cand_q].astype(np.uint32)
    qb = q_buf[cand_q]
    qs = q_sizes[cand_q]

    tau = np.minimum(xt, qt)
    nq = (qv <= tau[:, None]).sum(-1).astype(np.int32)
    nx = (xv <= tau[:, None]).sum(-1).astype(np.int32)
    live = xv <= tau[:, None]
    member = (xv[:, :, None] == qv[:, None, :]).any(-1)
    kcap = (live & member).sum(-1).astype(np.int32)
    k = nq + nx - kcap

    p = xv.shape[0]
    uq = qv[np.arange(p), np.maximum(nq - 1, 0)]
    uq = np.where(nq > 0, uq, np.uint32(0))
    ux = xv[np.arange(p), np.maximum(nx - 1, 0)]
    ux = np.where(nx > 0, ux, np.uint32(0))
    u = np.maximum(uq, ux)
    u_unit = (u.astype(np.float32) + np.float32(1.0)) / np.float32(TWO32)

    kf = k.astype(np.float32)
    cf = kcap.astype(np.float32)
    valid = (k >= 2) & (kcap >= 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        d_hat = np.where(
            valid,
            (cf / np.maximum(kf, np.float32(1.0)))
            * ((kf - np.float32(1.0)) / np.maximum(u_unit, np.float32(1e-30))),
            np.where(kcap >= 1, cf, np.float32(0.0)),
        ).astype(np.float32)

    if xb.shape[-1]:
        o1 = _popcount_np(xb & qb)
    else:
        o1 = np.zeros(p, dtype=np.int32)
    qsf = np.maximum(qs.astype(np.float32), np.float32(1.0))
    return ((o1.astype(np.float32) + d_hat) / qsf).astype(np.float32)


def _pad_pow2(n: int, lo: int = 8) -> int:
    """Bucket P so jit caches a handful of shapes, not one per batch."""
    p = lo
    while p < n:
        p *= 2
    return p


def score_pairs(
    x, q, cand_rec, cand_q, *, backend: str = "jnp",
    interpret: bool | None = None,
) -> np.ndarray:
    """f32[P] pair scores for a ragged candidate list.

    ``x`` / ``q`` are PackedSketches (record index / query batch pack,
    buffer widths already aligned). ``cand_rec[p]`` indexes x rows,
    ``cand_q[p]`` indexes q rows. Device paths pad P to a power-of-two
    bucket (extra pairs repeat pair 0 and are sliced off) so steady-state
    serving reuses a handful of compiled shapes.
    """
    from repro.core.estimators import normalize_backend

    backend = normalize_backend(backend)
    p = len(cand_rec)
    if p == 0:
        return np.zeros(0, dtype=np.float32)
    cand_rec = np.asarray(cand_rec, dtype=np.int32)
    cand_q = np.asarray(cand_q, dtype=np.int32)

    if backend == "numpy":
        return _gather_score_np(
            np.asarray(x.values), np.asarray(x.thresh), np.asarray(x.buf),
            np.asarray(q.values), np.asarray(q.thresh), np.asarray(q.buf),
            np.asarray(q.sizes), cand_rec, cand_q)

    pp = _pad_pow2(p)
    if pp != p:
        cand_rec = np.concatenate(
            [cand_rec, np.zeros(pp - p, np.int32) + cand_rec[0]])
        cand_q = np.concatenate(
            [cand_q, np.zeros(pp - p, np.int32) + cand_q[0]])

    xv = jnp.asarray(x.values, jnp.uint32)
    xt = jnp.asarray(x.thresh, jnp.uint32)
    xb = jnp.asarray(x.buf, jnp.uint32)
    qv = jnp.asarray(q.values, jnp.uint32)
    qt = jnp.asarray(q.thresh, jnp.uint32)
    qb = jnp.asarray(q.buf, jnp.uint32)
    qs = jnp.asarray(q.sizes, jnp.int32)

    if backend == "pallas":
        from repro.kernels.ops import _on_tpu, _pad_axis

        if interpret is None:
            interpret = not _on_tpu()
        qv = _pad_axis(qv, 1, QCHUNK, PAD)
        w = max(xb.shape[1], qb.shape[1], 1)
        xb = _pad_axis(xb if xb.shape[1] else
                       jnp.zeros((xb.shape[0], 1), jnp.uint32), 1, w, 0)
        qb = _pad_axis(qb if qb.shape[1] else
                       jnp.zeros((qb.shape[0], 1), jnp.uint32), 1, w, 0)
        out = _gather_score_pallas(
            xv, xt, xb, qv, qt, qb, qs,
            jnp.asarray(cand_rec), jnp.asarray(cand_q),
            interpret=interpret)
    else:
        out = _gather_score_jnp(
            xv, xt, xb, qv, qt, qb, qs,
            jnp.asarray(cand_rec), jnp.asarray(cand_q))
    return np.asarray(out[:p])
