"""Pallas TPU kernel: fused GB-KMV containment scoring (the paper's search
hot loop, Algorithm 2 line 4).

One sweep of the record-sketch matrix scores a whole *batch* of queries
(beyond-paper: the paper scores one query per index pass; batching divides
the HBM-bound roofline term by the query-batch size Gq — see
EXPERIMENTS.md §Perf).

Per (record block, query) the kernel fuses:
  1. bitmap-buffer intersection: popcount(x_buf & q_buf)          (exact part)
  2. pairwise threshold      : τ_pair = min(x_thresh, q_thresh)
  3. live counts             : n_x, n_q = #values ≤ τ_pair
  4. sorted-set membership   : K∩ via chunked equality-broadcast —
       both rows are sorted *and duplicate-free* (the hash is a uint32
       bijection), so equality-count is the exact intersection size; no
       gather/binary-search needed (TPU VPU-friendly, DESIGN.md §3)
  5. KMV estimator           : D̂∩ = K∩/k · (k-1)/U_(k)           (Eq. 25)
  6. score                   : (popcount + D̂∩) / |Q|             (Eq. 27)

Layout: records blocked over the grid; the query pack (values, thresholds,
buffers, sizes) is small and resident in VMEM for every block.

VMEM budget (defaults BM=8, C≤2048, Gq≤16, QCHUNK=128):
  x block 8·C·4 ≤ 64 KiB; equality intermediate 8·C·128 ≤ 2 MiB bool;
  well under the ~16 MiB/core VMEM of TPU v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import TWO32

# Lane-aligned chunk of query sketch values per membership step.
QCHUNK = 128


def _score_kernel(
    x_values_ref,   # u32[BM, C]
    x_thresh_ref,   # u32[BM, 1]
    x_buf_ref,      # u32[BM, W]
    q_values_ref,   # u32[Gq, Cq]
    q_thresh_ref,   # u32[Gq, 1]
    q_buf_ref,      # u32[Gq, W]
    q_sizes_ref,    # i32[Gq, 1]
    out_ref,        # f32[BM, Gq]
):
    xv = x_values_ref[...]                    # [BM, C]
    xt = x_thresh_ref[...][:, 0]              # [BM]
    xb = x_buf_ref[...]                       # [BM, W]
    bm, c = xv.shape
    gq, cq = q_values_ref.shape

    for g in range(gq):                       # static unroll over query batch
        qv = q_values_ref[g, :]               # [Cq]
        qt = q_thresh_ref[g, 0]
        qb = q_buf_ref[g, :]
        qs = q_sizes_ref[g, 0]

        tau = jnp.minimum(xt, qt)             # [BM]
        live_x = xv <= tau[:, None]           # [BM, C]  (PAD rows excluded)
        nx = jnp.sum(live_x.astype(jnp.int32), axis=-1)
        live_q = qv[None, :] <= tau[:, None]  # [BM, Cq]
        nq = jnp.sum(live_q.astype(jnp.int32), axis=-1)

        # K∩: x values present in the query sketch, chunked over Cq so the
        # [BM, C, QCHUNK] equality intermediate stays VMEM-small.
        def mem_body(i, member):
            chunk = lax.dynamic_slice(qv, (i * QCHUNK,), (QCHUNK,))
            hit = jnp.any(xv[:, :, None] == chunk[None, None, :], axis=-1)
            return member | hit

        member = lax.fori_loop(
            0, cq // QCHUNK, mem_body, jnp.zeros((bm, c), jnp.bool_)
        )
        kcap = jnp.sum((member & live_x).astype(jnp.int32), axis=-1)
        k = nx + nq - kcap

        # U_(k): largest live hash on either side.
        ux = jnp.max(jnp.where(live_x, xv, jnp.uint32(0)), axis=-1)
        uq = jnp.max(jnp.where(live_q, qv[None, :], jnp.uint32(0)), axis=-1)
        u = jnp.maximum(ux, uq)
        u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

        kf = k.astype(jnp.float32)
        d_hat = (kcap.astype(jnp.float32) / jnp.maximum(kf, 1.0)) * (
            (kf - 1.0) / jnp.maximum(u_unit, 1e-30)
        )
        d_hat = jnp.where((k >= 2) & (kcap >= 1), d_hat,
                          jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0))

        o1 = jnp.sum(lax.population_count(xb & qb[None, :]), axis=-1)
        score = (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
            qs.astype(jnp.float32), 1.0)
        out_ref[:, g] = score


@functools.partial(
    jax.jit, static_argnames=("block_m", "interpret")
)
def gbkmv_score(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    *, block_m: int = 8, interpret: bool = False,
):
    """pallas_call wrapper. Shapes as in kernels/ref.py:gbkmv_score_ref.

    Preconditions (ops.py enforces by padding): M % block_m == 0,
    Cq % QCHUNK == 0, W >= 1.
    """
    m, c = x_values.shape
    gq, cq = q_values.shape
    w = x_buf.shape[1]
    assert m % block_m == 0 and cq % QCHUNK == 0 and w >= 1

    grid = (m // block_m,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, c), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, w), lambda i: (i, 0)),
            pl.BlockSpec((gq, cq), lambda i: (0, 0)),
            pl.BlockSpec((gq, 1), lambda i: (0, 0)),
            pl.BlockSpec((gq, w), lambda i: (0, 0)),
            pl.BlockSpec((gq, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, gq), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, gq), jnp.float32),
        interpret=interpret,
    )(x_values, x_thresh, x_buf, q_values, q_thresh, q_buf, q_sizes)
