"""Pallas TPU kernel: fused fingerprint hash + global-τ filter
(GB-KMV construction hot loop, Algorithm 1 line 6) — and the fused
device-path sketch build on top of it.

Element ids stream through in lane-aligned 2D tiles; each tile is mixed
(murmur3 fmix32) and compared against the global threshold in registers —
one HBM read (ids) and two writes (hashes, keep-mask) per element, no
intermediate materialization.

:func:`fused_build_columns` is the construction pipeline's device path:
one jitted hash→τ-select→lexsort stage (the Pallas kernel or its
``hash_u32`` jnp twin does the mixing; τ comes from ``jnp.sort`` in
exact mode or the two-level ``histogram_tau`` shared with the
distributed reduction), then one jitted scatter-pack stage that writes
the packed sketch columns. The only host crossing between the two is
the per-row count vector, which fixes the static pack width — every
per-element quantity stays on device, and the columns come back as
device-resident jnp arrays ready to live in a
:class:`repro.core.arena.SketchArena`. Bit-identical to the host
``pack_csr`` pipeline (same hashes, same τ rule, same stable sort, same
capacity-overflow thresholds).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hashing import PAD, hash_u32

LANES = 128


def _hash_kernel(seed_ref, tau_ref, ids_ref, h_ref, keep_ref):
    x = ids_ref[...].astype(jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9) * (seed_ref[0, 0].astype(jnp.uint32) + jnp.uint32(1))
    h = x ^ (x >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    h_ref[...] = h
    keep_ref[...] = (h <= tau_ref[0, 0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hash_threshold(ids2d, seed, tau, *, block_rows: int = 8, interpret: bool = False):
    """ids2d u32[R, 128] → (hashes u32[R, 128], keep i32[R, 128]).

    ops.py reshapes/pads flat id streams into the [R, LANES] view.
    """
    r, l = ids2d.shape
    assert l == LANES and r % block_rows == 0
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    tau_arr = jnp.asarray(tau, jnp.uint32).reshape(1, 1)
    grid = (r // block_rows,)
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((r, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(seed_arr, tau_arr, ids2d)


# ---------------------------------------------------------------------------
# Fused device-path sketch construction (hash → τ → sort → pack)
# ---------------------------------------------------------------------------


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _hash_flat(ids, seed, *, use_pallas: bool, interpret: bool):
    """u32[N] fingerprints of a flat id stream (Pallas kernel or jnp twin).

    The Pallas spelling pads to the [R, 128] lane view the kernel wants
    and slices back; padding lanes hash garbage that never escapes.
    """
    n = ids.shape[0]
    if not use_pallas:
        return hash_u32(ids, seed=seed)
    rows = max(-(-n // LANES), 1)
    rows = -(-rows // 8) * 8
    flat = jnp.zeros(rows * LANES, jnp.uint32).at[:n].set(
        ids.astype(jnp.uint32))
    h2d, _ = hash_threshold(flat.reshape(rows, LANES), seed,
                            jnp.uint32(PAD), interpret=interpret)
    return h2d.reshape(-1)[:n]


@functools.partial(
    jax.jit,
    static_argnames=("m", "budget", "tau_mode", "filter_tau", "use_pallas",
                     "interpret"))
def _fused_hash_sort(ids, row, seed, *, m: int, budget: int, tau_mode: str,
                     filter_tau: bool, use_pallas: bool, interpret: bool):
    """Stage 1: hash every element, select τ, stable-sort to row-major.

    Returns (hs, rs, counts, starts, tau): hashes/rows sorted by
    (row asc, hash asc) with τ-dropped elements parked on sentinel row
    ``m`` at the tail, per-row kept counts, their exclusive prefix sum,
    and the selected threshold. ``filter_tau=False`` (plain-KMV mode)
    keeps everything and pins τ at PAD-1 — positional truncation happens
    in stage 2.
    """
    n = ids.shape[0]
    h = _hash_flat(ids, seed, use_pallas=use_pallas, interpret=interpret)
    if not filter_tau or budget >= n:
        tau = jnp.uint32(PAD - np.uint32(1))
        keep = jnp.ones(n, bool)
    else:
        if tau_mode == "histogram":
            from repro.sketchindex.build import histogram_tau

            tau = histogram_tau(h, budget)
        else:
            # Exact: the budget-th smallest hash, same as np.partition.
            tau = jnp.sort(h)[budget - 1]
        keep = h <= tau
    rkey = jnp.where(keep, row.astype(jnp.int32), jnp.int32(m))
    hkey = jnp.where(keep, h, jnp.uint32(PAD))
    order = jnp.lexsort((hkey, rkey))
    rs, hs = rkey[order], hkey[order]
    counts = jnp.zeros(m + 1, jnp.int32).at[rs].add(1)[:m]
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    return hs, rs, counts, starts, tau


@functools.partial(jax.jit, static_argnames=("m", "cap", "limit", "lower_thresh"))
def _fused_pack(hs, rs, counts, starts, tau, *, m: int, cap: int, limit: int,
                lower_thresh: bool):
    """Stage 2: scatter the row-sorted hashes into packed [m, cap] columns.

    ``limit`` is the per-row kept length (== cap for τ-mode, == k for
    plain KMV where cap is k rounded up to the pad multiple).
    ``lower_thresh`` applies the capacity-overflow rule: a row with more
    kept hashes than ``cap`` drops its effective threshold to the
    largest value it packs (pack_csr's exact semantics).
    """
    n = hs.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32) - starts[rs]
    sel = (rs < m) & (pos < limit)
    tr = jnp.where(sel, rs, jnp.int32(m))        # sentinel row, sliced off
    tp = jnp.where(sel, pos, 0)
    values = jnp.full((m + 1, cap), jnp.uint32(PAD))
    values = values.at[tr, tp].set(jnp.where(sel, hs, jnp.uint32(PAD)))[:m]
    lengths = jnp.minimum(counts, limit).astype(jnp.int32)
    if lower_thresh:
        idx = jnp.clip(starts[:m] + (cap - 1), 0, n - 1)
        thresh = jnp.where(counts > cap, hs[idx],
                           jnp.broadcast_to(tau, (m,)))
    else:
        thresh = jnp.broadcast_to(tau, (m,))
    return values, lengths, thresh.astype(jnp.uint32)


def fused_build_columns(batch, tail_mask, budget: int, *, seed: int = 0,
                        capacity: int | None = None, tau_mode: str = "exact",
                        bitmaps=None, backend: str = "jnp",
                        row_cap: int | None = None,
                        interpret: bool | None = None):
    """Device-path sketch construction: (PackedSketches, τ).

    ``batch`` is a :class:`repro.core.sketches.RaggedBatch`; ``tail_mask``
    selects the hashed (non-buffered) elements. ``row_cap`` switches to
    plain-KMV semantics (keep the k smallest per row, τ never binds).
    The returned pack's columns are jnp arrays already resident on the
    default device — :class:`SketchArena` adopts them without a copy —
    and are bit-identical to the host ``pack_csr`` pipeline's output.
    """
    from repro.core.gkmv import TAU_MODES
    from repro.core.sketches import PackedSketches, _resolve_capacity, pack_csr

    if tau_mode not in TAU_MODES:
        raise ValueError(f"tau_mode must be one of {TAU_MODES}, "
                         f"got {tau_mode!r}")
    if interpret is None:
        interpret = not _on_tpu()
    tail_mask = np.asarray(tail_mask, bool)
    ids = np.asarray(batch.ids)[tail_mask]
    row = batch.row_index()[tail_mask]
    m, n = batch.num_records, len(ids)
    sizes = batch.sizes

    if m == 0 or n == 0:
        thr_fill = np.uint32(PAD - np.uint32(1))
        pack = pack_csr(np.zeros(0, np.uint32), np.zeros(0, np.int64), m,
                        np.full(m, thr_fill, np.uint32), sizes,
                        bitmaps=bitmaps,
                        capacity=row_cap if row_cap is not None else capacity)
        return pack, thr_fill

    # uint32 id view with the same wrap rule as hash_u32_np.
    ids32 = jnp.asarray((ids.astype(np.uint64) & np.uint64(0xFFFFFFFF))
                        .astype(np.uint32))
    hs, rs, counts, starts, tau = _fused_hash_sort(
        ids32, jnp.asarray(row, jnp.int32), jnp.uint32(seed), m=m,
        budget=int(budget), tau_mode=tau_mode, filter_tau=row_cap is None,
        use_pallas=(backend == "pallas"), interpret=bool(interpret))

    # The one host crossing: per-row counts fix the static pack width.
    counts_h = np.asarray(counts)
    if row_cap is not None:
        cap = _resolve_capacity(int(row_cap), None, 8)
        limit, lower = int(row_cap), False
    else:
        cap = _resolve_capacity(int(counts_h.max()) if m else 0, capacity, 8)
        limit, lower = cap, True
    values, lengths, thresh = _fused_pack(
        hs, rs, counts, starts, tau, m=m, cap=cap, limit=limit,
        lower_thresh=lower)

    if bitmaps is None:
        buf = jnp.zeros((m, 0), jnp.uint32)
    else:
        buf = jnp.asarray(np.asarray(bitmaps, np.uint32))
    pack = PackedSketches(values=values, lengths=lengths, thresh=thresh,
                          buf=buf, sizes=jnp.asarray(sizes, jnp.int32))
    return pack, np.uint32(tau)
