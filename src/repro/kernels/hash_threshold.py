"""Pallas TPU kernel: fused fingerprint hash + global-τ filter
(GB-KMV construction hot loop, Algorithm 1 line 6) — and the fused
device-path sketch build on top of it.

Element ids stream through in lane-aligned 2D tiles; each tile is mixed
(murmur3 fmix32) and compared against the global threshold in registers —
one HBM read (ids) and two writes (hashes, keep-mask) per element, no
intermediate materialization.

:func:`fused_build_columns` is the construction pipeline's device path:
one jitted hash→τ-select→lexsort stage (the Pallas kernel or its
``hash_u32`` jnp twin does the mixing; τ comes from ``jnp.sort`` in
exact mode or the two-level ``histogram_tau`` shared with the
distributed reduction), then one jitted scatter-pack stage that writes
the packed sketch columns. The only host crossing between the two is
the per-row count vector, which fixes the static pack width — every
per-element quantity stays on device, and the columns come back as
device-resident jnp arrays ready to live in a
:class:`repro.core.arena.SketchArena`. Bit-identical to the host
``pack_csr`` pipeline (same hashes, same τ rule, same stable sort, same
capacity-overflow thresholds).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hashing import PAD, hash_u32

LANES = 128


def _hash_kernel(seed_ref, tau_ref, ids_ref, h_ref, keep_ref):
    x = ids_ref[...].astype(jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9) * (seed_ref[0, 0].astype(jnp.uint32) + jnp.uint32(1))
    h = x ^ (x >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    h_ref[...] = h
    keep_ref[...] = (h <= tau_ref[0, 0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hash_threshold(ids2d, seed, tau, *, block_rows: int = 8, interpret: bool = False):
    """ids2d u32[R, 128] → (hashes u32[R, 128], keep i32[R, 128]).

    ops.py reshapes/pads flat id streams into the [R, LANES] view.
    """
    r, l = ids2d.shape
    assert l == LANES and r % block_rows == 0
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    tau_arr = jnp.asarray(tau, jnp.uint32).reshape(1, 1)
    grid = (r // block_rows,)
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((r, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(seed_arr, tau_arr, ids2d)


# ---------------------------------------------------------------------------
# Fused device-path sketch construction (hash → τ → sort → pack)
# ---------------------------------------------------------------------------


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _hash_flat(ids, seed, *, use_pallas: bool, interpret: bool):
    """u32[N] fingerprints of a flat id stream (Pallas kernel or jnp twin).

    The Pallas spelling pads to the [R, 128] lane view the kernel wants
    and slices back; padding lanes hash garbage that never escapes.
    """
    n = ids.shape[0]
    if not use_pallas:
        return hash_u32(ids, seed=seed)
    rows = max(-(-n // LANES), 1)
    rows = -(-rows // 8) * 8
    flat = jnp.zeros(rows * LANES, jnp.uint32).at[:n].set(
        ids.astype(jnp.uint32))
    h2d, _ = hash_threshold(flat.reshape(rows, LANES), seed,
                            jnp.uint32(PAD), interpret=interpret)
    return h2d.reshape(-1)[:n]


@functools.partial(
    jax.jit,
    static_argnames=("m", "budget", "tau_mode", "filter_tau", "use_pallas",
                     "interpret"))
def _fused_hash_sort(ids, row, seed, *, m: int, budget: int, tau_mode: str,
                     filter_tau: bool, use_pallas: bool, interpret: bool):
    """Stage 1: hash every element, select τ, stable-sort to row-major.

    Returns (hs, rs, counts, starts, tau): hashes/rows sorted by
    (row asc, hash asc) with τ-dropped elements parked on sentinel row
    ``m`` at the tail, per-row kept counts, their exclusive prefix sum,
    and the selected threshold. ``filter_tau=False`` (plain-KMV mode)
    keeps everything and pins τ at PAD-1 — positional truncation happens
    in stage 2.
    """
    n = ids.shape[0]
    h = _hash_flat(ids, seed, use_pallas=use_pallas, interpret=interpret)
    if not filter_tau or budget >= n:
        tau = jnp.uint32(PAD - np.uint32(1))
        keep = jnp.ones(n, bool)
    else:
        if tau_mode == "histogram":
            from repro.sketchindex.build import histogram_tau

            tau = histogram_tau(h, budget)
        else:
            # Exact: the budget-th smallest hash, same as np.partition.
            tau = jnp.sort(h)[budget - 1]
        keep = h <= tau
    rkey = jnp.where(keep, row.astype(jnp.int32), jnp.int32(m))
    hkey = jnp.where(keep, h, jnp.uint32(PAD))
    order = jnp.lexsort((hkey, rkey))
    rs, hs = rkey[order], hkey[order]
    counts = jnp.zeros(m + 1, jnp.int32).at[rs].add(1)[:m]
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    return hs, rs, counts, starts, tau


@functools.partial(jax.jit, static_argnames=("m", "cap", "limit", "lower_thresh"))
def _fused_pack(hs, rs, counts, starts, tau, *, m: int, cap: int, limit: int,
                lower_thresh: bool):
    """Stage 2: scatter the row-sorted hashes into packed [m, cap] columns.

    ``limit`` is the per-row kept length (== cap for τ-mode, == k for
    plain KMV where cap is k rounded up to the pad multiple).
    ``lower_thresh`` applies the capacity-overflow rule: a row with more
    kept hashes than ``cap`` drops its effective threshold to the
    largest value it packs (pack_csr's exact semantics).
    """
    n = hs.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32) - starts[rs]
    sel = (rs < m) & (pos < limit)
    tr = jnp.where(sel, rs, jnp.int32(m))        # sentinel row, sliced off
    tp = jnp.where(sel, pos, 0)
    values = jnp.full((m + 1, cap), jnp.uint32(PAD))
    values = values.at[tr, tp].set(jnp.where(sel, hs, jnp.uint32(PAD)))[:m]
    lengths = jnp.minimum(counts, limit).astype(jnp.int32)
    if lower_thresh:
        idx = jnp.clip(starts[:m] + (cap - 1), 0, n - 1)
        thresh = jnp.where(counts > cap, hs[idx],
                           jnp.broadcast_to(tau, (m,)))
    else:
        thresh = jnp.broadcast_to(tau, (m,))
    return values, lengths, thresh.astype(jnp.uint32)


def fused_build_columns(batch, tail_mask, budget: int, *, seed: int = 0,
                        capacity: int | None = None, tau_mode: str = "exact",
                        bitmaps=None, backend: str = "jnp",
                        row_cap: int | None = None,
                        interpret: bool | None = None):
    """Device-path sketch construction: (PackedSketches, τ).

    ``batch`` is a :class:`repro.core.sketches.RaggedBatch`; ``tail_mask``
    selects the hashed (non-buffered) elements. ``row_cap`` switches to
    plain-KMV semantics (keep the k smallest per row, τ never binds).
    The returned pack's columns are jnp arrays already resident on the
    default device — :class:`SketchArena` adopts them without a copy —
    and are bit-identical to the host ``pack_csr`` pipeline's output.
    """
    from repro.core.gkmv import TAU_MODES
    from repro.core.sketches import PackedSketches, _resolve_capacity, pack_csr

    if tau_mode not in TAU_MODES:
        raise ValueError(f"tau_mode must be one of {TAU_MODES}, "
                         f"got {tau_mode!r}")
    if interpret is None:
        interpret = not _on_tpu()
    tail_mask = np.asarray(tail_mask, bool)
    ids = np.asarray(batch.ids)[tail_mask]
    row = batch.row_index()[tail_mask]
    m, n = batch.num_records, len(ids)
    sizes = batch.sizes

    if m == 0 or n == 0:
        thr_fill = np.uint32(PAD - np.uint32(1))
        pack = pack_csr(np.zeros(0, np.uint32), np.zeros(0, np.int64), m,
                        np.full(m, thr_fill, np.uint32), sizes,
                        bitmaps=bitmaps,
                        capacity=row_cap if row_cap is not None else capacity)
        return pack, thr_fill

    # uint32 id view with the same wrap rule as hash_u32_np.
    ids32 = jnp.asarray((ids.astype(np.uint64) & np.uint64(0xFFFFFFFF))
                        .astype(np.uint32))
    hs, rs, counts, starts, tau = _fused_hash_sort(
        ids32, jnp.asarray(row, jnp.int32), jnp.uint32(seed), m=m,
        budget=int(budget), tau_mode=tau_mode, filter_tau=row_cap is None,
        use_pallas=(backend == "pallas"), interpret=bool(interpret))

    # The one host crossing: per-row counts fix the static pack width.
    counts_h = np.asarray(counts)
    if row_cap is not None:
        cap = _resolve_capacity(int(row_cap), None, 8)
        limit, lower = int(row_cap), False
    else:
        cap = _resolve_capacity(int(counts_h.max()) if m else 0, capacity, 8)
        limit, lower = cap, True
    values, lengths, thresh = _fused_pack(
        hs, rs, counts, starts, tau, m=m, cap=cap, limit=limit,
        lower_thresh=lower)

    if bitmaps is None:
        buf = jnp.zeros((m, 0), jnp.uint32)
    else:
        buf = jnp.asarray(np.asarray(bitmaps, np.uint32))
    pack = PackedSketches(values=values, lengths=lengths, thresh=thresh,
                          buf=buf, sizes=jnp.asarray(sizes, jnp.int32))
    return pack, np.uint32(tau)


# ---------------------------------------------------------------------------
# Fused device-path POSTINGS encode (packed columns → blocked tail store)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m", "cap"))
def _encode_tail_device(values, lengths, *, m: int, cap: int):
    """Block-compress the tail postings ON DEVICE from packed columns.

    The device twin of ``planner/postings.py::encode_store`` fed by
    ``_row_pairs`` + ``_csr_from_pairs`` — same (hash asc, record asc)
    sort, same 128-entry blocks, same delta-bitpack / dense-bitmap rule,
    bit for bit. Everything is scatter arithmetic over the flattened
    [m·cap] element stream; dynamic sizes (#keys U, #blocks NB, #payload
    words P) live in the returned ``sizes`` vector, and every output is
    statically sized N+1 = m·cap+1 with slot N as the scatter trash can
    (the host wrapper slices by the real sizes — device slices, no
    copy-back). Notable 32-bit spellings, since x64 is off on device:

    * bit lengths via 31 shift-compare accumulations (the host float64
      ``floor(log2)+1`` is exactly equal for deltas < 2³¹)
    * the bitpack writes each delta as (lo = d << s, hi = d >> (32-s))
      u32 halves with scatter-ADD — fields are disjoint because
      d < 2^bitwidth, so add IS or, matching the host's uint64 shift +
      or.at exactly (a zero hi lands as +0 in the next block's first
      word, which the host simply skips — same bits either way)
    """
    from jax import lax

    from repro.planner.postings import BLOCK, DENSE_MAX_WORDS

    n = m * cap
    iota = jnp.arange(n, dtype=jnp.int32)
    col = iota % cap
    rec = iota // cap
    live = col < lengths[rec]
    h = jnp.where(live, values.reshape(-1), jnp.uint32(PAD))
    r = jnp.where(live, rec, jnp.int32(m))
    # (hash asc, record asc); dead (PAD, m) lanes sort to the tail —
    # even a real PAD-valued hash sorts before them on the row key.
    order = jnp.lexsort((r, h))
    hs, rsrt = h[order], r[order]
    nnz = jnp.sum(live.astype(jnp.int32))
    valid = iota < nnz

    prev_h = jnp.concatenate([hs[:1], hs[:-1]])
    newkey = valid & ((iota == 0) | (hs != prev_h))
    key_id = jnp.cumsum(newkey.astype(jnp.int32)) - 1
    kstart = lax.cummax(jnp.where(newkey, iota, -1))
    posr = iota - kstart                      # position within key run
    bstart = valid & (posr % BLOCK == 0)
    blk_id = jnp.cumsum(bstart.astype(jnp.int32)) - 1
    posb = iota - lax.cummax(jnp.where(bstart, iota, -1))
    prev_r = jnp.concatenate([rsrt[:1], rsrt[:-1]])
    d = jnp.where(valid & (posb > 0), rsrt - prev_r, 0)

    # -- per-block headers (scatter into [n+1], slot n = trash) ---------
    tgt = jnp.where(valid, blk_id, n)
    tgtb = jnp.where(bstart, blk_id, n)
    first_b = jnp.zeros(n + 1, jnp.int32).at[tgtb].set(rsrt)
    last_b = jnp.zeros(n + 1, jnp.int32).at[tgt].max(
        jnp.where(valid, rsrt, 0))
    cnt_b = jnp.zeros(n + 1, jnp.int32).at[tgt].add(1)
    md_b = jnp.zeros(n + 1, jnp.int32).at[tgt].max(d)
    mind_b = jnp.full(n + 1, 1 << 30, jnp.int32).at[
        jnp.where(valid & (posb > 0), blk_id, n)].min(d)

    bw = jnp.zeros(n + 1, jnp.int32)
    for k in range(31):
        bw = bw + (md_b >> k > 0).astype(jnp.int32)
    w_sparse = ((cnt_b - 1) * bw + 31) // 32
    w_dense = (last_b - first_b + 1 + 31) // 32
    dense = (mind_b >= 1) & (w_dense < w_sparse) \
        & (w_dense <= DENSE_MAX_WORDS)
    words_b = jnp.where(dense, w_dense, w_sparse).at[n].set(0)
    off_b = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(words_b[:n]).astype(jnp.int32)])      # [n+1]
    meta_b = ((cnt_b - 1).astype(jnp.uint32) & jnp.uint32(0x7F)) \
        | (bw.astype(jnp.uint32) << 8) \
        | (dense.astype(jnp.uint32) << 13)

    # -- payload scatters ----------------------------------------------
    blk = jnp.clip(blk_id, 0, n)
    b_dense, b_bw = dense[blk], bw[blk]
    b_off, b_first = off_b[blk], first_b[blk]
    payload = jnp.zeros(n + 1, jnp.uint32)

    sel = valid & (posb > 0) & ~b_dense & (b_bw > 0)
    bitpos = (posb - 1) * b_bw
    wloc = b_off + (bitpos >> 5)
    sh = (bitpos & 31).astype(jnp.uint32)
    du = d.astype(jnp.uint32)
    lo = du << sh
    hi = jnp.where(sh > 0,
                   du >> ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    payload = payload.at[jnp.where(sel, wloc, n)].add(
        jnp.where(sel, lo, jnp.uint32(0)))
    payload = payload.at[jnp.where(sel, wloc + 1, n)].add(
        jnp.where(sel, hi, jnp.uint32(0)))

    dsel = valid & b_dense
    bit = rsrt - b_first
    payload = payload.at[jnp.where(dsel, b_off + (bit >> 5), n)].add(
        jnp.where(dsel,
                  jnp.uint32(1) << (bit & 31).astype(jnp.uint32),
                  jnp.uint32(0)))

    # -- keyspace -------------------------------------------------------
    keys_b = jnp.zeros(n + 1, jnp.uint32).at[
        jnp.where(newkey, key_id, n)].set(hs)
    nblk_k = jnp.zeros(n + 1, jnp.int32).at[
        jnp.where(bstart, key_id, n)].add(1)
    row_blocks_b = jnp.concatenate(
        [jnp.zeros(1, jnp.int32),
         jnp.cumsum(nblk_k[:n]).astype(jnp.int32)])
    u = jnp.sum(newkey.astype(jnp.int32))
    nb = jnp.sum(bstart.astype(jnp.int32))
    sizes = jnp.stack([u, nb, off_b[nb]])
    return (keys_b, row_blocks_b, first_b, last_b, meta_b, off_b,
            payload, sizes)


def fused_encode_postings(values, lengths, *, m: int, cap: int) -> dict:
    """Device-resident blocked tail postings from packed columns.

    Runs :func:`_encode_tail_device` and slices the statically-shaped
    outputs down to their true sizes — ONE host readback (the 3-int
    sizes vector); every returned array is a device slice, so a device
    build's postings mirrors never round-trip through host. Keys are the
    arrays of :class:`repro.core.arena.DevicePostings`.
    """
    import jax.numpy as jnp  # noqa: F811 (kept local for doc symmetry)

    out = _encode_tail_device(jnp.asarray(values, jnp.uint32),
                              jnp.asarray(lengths, jnp.int32),
                              m=m, cap=cap)
    keys_b, rb_b, first_b, last_b, meta_b, off_b, payload_b, sizes = out
    u, nb, p = (int(x) for x in np.asarray(sizes))
    return {
        "keys": keys_b[:u],
        "row_blocks": rb_b[: u + 1],
        "first": first_b[:nb],
        "last": last_b[:nb],
        "meta": meta_b[:nb],
        "off": off_b[: nb + 1],
        "payload": payload_b[:p],
    }
