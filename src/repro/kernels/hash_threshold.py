"""Pallas TPU kernel: fused fingerprint hash + global-τ filter
(GB-KMV construction hot loop, Algorithm 1 line 6).

Element ids stream through in lane-aligned 2D tiles; each tile is mixed
(murmur3 fmix32) and compared against the global threshold in registers —
one HBM read (ids) and two writes (hashes, keep-mask) per element, no
intermediate materialization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _hash_kernel(seed_ref, tau_ref, ids_ref, h_ref, keep_ref):
    x = ids_ref[...].astype(jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9) * (seed_ref[0, 0].astype(jnp.uint32) + jnp.uint32(1))
    h = x ^ (x >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    h_ref[...] = h
    keep_ref[...] = (h <= tau_ref[0, 0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hash_threshold(ids2d, seed, tau, *, block_rows: int = 8, interpret: bool = False):
    """ids2d u32[R, 128] → (hashes u32[R, 128], keep i32[R, 128]).

    ops.py reshapes/pads flat id streams into the [R, LANES] view.
    """
    r, l = ids2d.shape
    assert l == LANES and r % block_rows == 0
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    tau_arr = jnp.asarray(tau, jnp.uint32).reshape(1, 1)
    grid = (r // block_rows,)
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((r, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(seed_arr, tau_arr, ids2d)
