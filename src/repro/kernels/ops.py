"""jit'd public wrappers around the Pallas kernels.

Handles padding/alignment so callers pass natural shapes, and switches to
``interpret=True`` automatically off-TPU (this container is CPU-only; the
kernels are written for TPU and *validated* in interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import PAD
from repro.kernels import gbkmv_score as _score_mod
from repro.kernels import hash_threshold as _hash_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(a, axis, mult, fill):
    n = a.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(a, pad, constant_values=fill)


def score_index(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    *, block_m: int = 8, interpret: bool | None = None,
):
    """Containment scores f32[M, Gq] of a query batch against the index.

    Pads records to block_m, query capacity to the 128-lane membership
    chunk, and guarantees ≥1 buffer word (zero word == empty buffer).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m = x_values.shape[0]

    x_values = _pad_axis(jnp.asarray(x_values, jnp.uint32), 0, block_m, PAD)
    # Padded records: threshold 0 → nothing live → score 0.
    x_thresh = _pad_axis(jnp.asarray(x_thresh, jnp.uint32)[:, None], 0, block_m, 0)
    x_buf = jnp.asarray(x_buf, jnp.uint32)
    if x_buf.shape[1] == 0:
        x_buf = jnp.zeros((x_buf.shape[0], 1), jnp.uint32)
    x_buf = _pad_axis(x_buf, 0, block_m, 0)

    q_values = _pad_axis(jnp.asarray(q_values, jnp.uint32), 1, _score_mod.QCHUNK, PAD)
    q_thresh = jnp.asarray(q_thresh, jnp.uint32)[:, None]
    q_buf = jnp.asarray(q_buf, jnp.uint32)
    if q_buf.shape[1] == 0:
        q_buf = jnp.zeros((q_buf.shape[0], 1), jnp.uint32)
    q_sizes = jnp.asarray(q_sizes, jnp.int32)[:, None]

    # Align x capacity with nothing (C free); align buffer widths.
    w = max(x_buf.shape[1], q_buf.shape[1])
    x_buf = _pad_axis(x_buf, 1, w, 0)
    q_buf = _pad_axis(q_buf, 1, w, 0)

    out = _score_mod.gbkmv_score(
        x_values, x_thresh, x_buf, q_values, q_thresh, q_buf, q_sizes,
        block_m=block_m, interpret=interpret,
    )
    return out[:m]


def hash_and_filter(ids, seed: int, tau, *, interpret: bool | None = None):
    """(hashes u32[N], keep bool[N]) for a flat element-id stream."""
    if interpret is None:
        interpret = not _on_tpu()
    ids = jnp.asarray(ids)
    n = ids.shape[0]
    lanes = _hash_mod.LANES
    rows = max(-(-n // lanes), 1)
    rows = -(-rows // 8) * 8
    flat = jnp.zeros(rows * lanes, jnp.uint32).at[:n].set(ids.astype(jnp.uint32))
    h2d, keep2d = _hash_mod.hash_threshold(
        flat.reshape(rows, lanes), seed, tau, interpret=interpret
    )
    return h2d.reshape(-1)[:n], keep2d.reshape(-1)[:n].astype(bool)
