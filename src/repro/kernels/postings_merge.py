"""Device-resident postings merge over BLOCK-COMPRESSED postings:
candidate generation for the pruned query path without leaving the
accelerator — and without ever materializing the flat posting lists.

The host planner decodes blocks with vectorized numpy; that round-trips
every batch through host memory — exactly the transfer the arena exists
to kill. Here the same merge runs as fused device stages over the
arena's blocked tail mirror:

    probe    for every query hash, its postings row (index + existence)
             — a chunked compare against the sorted key column
             (Pallas kernel for ``backend="pallas"``, XLA searchsorted
             for ``backend="jnp"``)
    expand   matched rows' block ranges → a flat, statically-bounded
             stream of block tasks (cumsum + searchsorted ragged-expand;
             the bound is the batch's touched-block count, known on host
             *before* candidate generation from the planner's header
             probe)
    decode   each task's block body → up to 128 record ids. Sparse
             bodies unpack their bitpacked deltas and prefix-sum back to
             ids (the Pallas block-decode kernel for ``"pallas"`` — one
             task per grid step, one dynamic-slice DMA of the body, a
             one-hot word select instead of a data-dependent gather — or
             a vectorized jnp twin); the rare dense-bitmap bodies
             rank-select their set bits through a masked scatter
             (``tbd`` static bound, compiled out when the batch touches
             none)
    score    scatter-add the decoded stream into the exact K∩ count
             matrix (a posting entry for (h, X) against query Q *is* one
             shared retained hash — multiplicity is the count), take the
             exact o1 matrix straight from the resident packed bitmaps
             (the dense kernel's own popcount — which is why the buffer
             posting lists never need a device mirror at all), then
             evaluate the estimator in closed form per cell: n_x, n_q
             and U₍k₎ come from per-row searchsorted tables against
             τ_pair, every float op copied from the dense kernel —
             O(m·Gq) elementwise instead of the dense sweep's
             O(m·Gq·C·Cq) membership broadcast

The output matrix therefore equals the dense sweep's score matrix
bit for bit EVERYWHERE: inside the candidate set the counts are the
dense kernel's counts, outside it K∩ = 0 and o1 is the identical
popcount, which is exactly what the dense estimator produces. Packed
thresholding over it returns identical hits. Everything between staging
and the final mask fetch is one jitted computation: no host-numpy
transfer between candidate generation and the packed threshold output
(tests assert this with a transfer guard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import PAD, TWO32
from repro.planner.postings import BLOCK, DENSE_MAX_WORDS

# Probe kernel tiling: query hashes per grid step / key-column chunk.
QBLOCK = 256
KCHUNK = 512
# Sparse block bodies span at most ceil(127·31/32) = 124 payload words;
# one 128-word window therefore always covers a body (plus slack the
# payload is padded with), so the decode kernel's DMA has a static size.
DECODE_WINDOW = 128


def _probe_kernel(keys_ref, q_ref, pos_ref, hit_ref):
    """pos = #keys < q, hit = any(keys == q), per query hash.

    ``keys_ref`` u32[1, Up] (whole padded key column, VMEM-resident),
    ``q_ref`` u32[1, QBLOCK]. Chunked compare instead of binary search:
    contiguous loads, no data-dependent addressing — the layout TPUs
    like. Key padding is PAD, which never matches a real hash and is
    masked for the (PAD == PAD) query-padding case below.
    """
    q = q_ref[0, :]                                     # [B]

    def body(i, carry):
        pos, hit = carry
        chunk = lax.dynamic_slice(keys_ref[...], (0, i * KCHUNK),
                                  (1, KCHUNK))[0]       # [KCHUNK]
        pos = pos + jnp.sum(
            (chunk[None, :] < q[:, None]).astype(jnp.int32), axis=-1)
        hit = hit | jnp.any(chunk[None, :] == q[:, None], axis=-1)
        return pos, hit

    b = q.shape[0]
    up = keys_ref.shape[1]
    pos, hit = lax.fori_loop(
        0, up // KCHUNK, body,
        (jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.bool_)))
    hit = hit & (q != PAD)
    pos_ref[0, :] = pos
    hit_ref[0, :] = hit.astype(jnp.int32)


def _probe_pallas(keys, q_flat, *, interpret: bool):
    """(pos i32[n], hit bool[n]) for a flat query-hash vector."""
    n = q_flat.shape[0]
    npad = -(-n // QBLOCK) * QBLOCK
    q2 = jnp.pad(q_flat, (0, npad - n), constant_values=PAD)[None, :]
    u = keys.shape[0]
    upad = max(-(-u // KCHUNK) * KCHUNK, KCHUNK)
    k2 = jnp.pad(keys, (0, upad - u), constant_values=PAD)[None, :]

    pos, hit = pl.pallas_call(
        _probe_kernel,
        grid=(npad // QBLOCK,),
        in_specs=[
            pl.BlockSpec((1, upad), lambda i: (0, 0)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
        ],
        interpret=interpret,
    )(k2, q2)
    return pos[0, :n], hit[0, :n].astype(jnp.bool_)


def _probe_jnp(keys, q_flat):
    u = keys.shape[0]
    pos = jnp.searchsorted(keys, q_flat).astype(jnp.int32)
    safe = jnp.clip(pos, 0, max(u - 1, 0))
    hit = (pos < u) & (keys[safe] == q_flat) & (q_flat != PAD) \
        if u else jnp.zeros(q_flat.shape, jnp.bool_)
    return pos, hit


# ---------------------------------------------------------------------------
# block decode: sparse bodies (the common kind)
# ---------------------------------------------------------------------------


def _block_decode_kernel(first_ref, off_ref, bw_ref, cnt_ref,
                         payload_ref, out_ref):
    """Decode ONE sparse block task per grid step.

    One dynamic-slice DMA pulls the block's ``DECODE_WINDOW``-word body
    out of the payload column; deltas unpack via a one-hot word select
    (a [127, 128] masked max — VPU-shaped work, no data-dependent
    addressing) and a prefix sum turns them back into record ids. All
    arithmetic is 32-bit: the two straddled words recombine with
    shift-or instead of a 64-bit widen, because TPUs would rather not.
    Lanes past the block's count carry garbage and are masked by the
    caller (shared with the jnp twin).
    """
    first = first_ref[0, 0]
    off = off_ref[0, 0]
    bw = bw_ref[0, 0].astype(jnp.uint32)
    cnt = cnt_ref[0, 0]

    body = lax.dynamic_slice(payload_ref[...], (0, off),
                             (1, DECODE_WINDOW))            # u32[1, W]
    p = lax.broadcasted_iota(jnp.int32, (1, BLOCK - 1), 1)  # [1, 127]
    bitpos = p * bw_ref[0, 0]
    widx = bitpos >> 5
    lanes = lax.broadcasted_iota(jnp.int32, (1, DECODE_WINDOW), 1)
    sel0 = widx[0][:, None] == lanes[0][None, :]            # [127, W]
    sel1 = (widx[0] + 1)[:, None] == lanes[0][None, :]
    w0 = jnp.max(jnp.where(sel0, body[0][None, :], jnp.uint32(0)), axis=1)
    w1 = jnp.max(jnp.where(sel1, body[0][None, :], jnp.uint32(0)), axis=1)

    sh = (bitpos[0] & 31).astype(jnp.uint32)
    lo = w0 >> sh
    hi = jnp.where(sh > 0,
                   w1 << ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    mask = jnp.where(bw > 0, (jnp.uint32(1) << bw) - jnp.uint32(1),
                     jnp.uint32(0))
    v = ((lo | hi) & mask).astype(jnp.int32)
    v = jnp.where(p[0] < cnt - 1, v, 0)[None, :]
    ids = first + jnp.concatenate(
        [jnp.zeros((1, 1), jnp.int32), jnp.cumsum(v, axis=1)], axis=1)
    out_ref[0, :] = ids[0]


def _decode_sparse_pallas(first, off, bw, cnt, payload, *, interpret: bool):
    """i32[tb, BLOCK] raw sparse-decoded ids (Pallas, one task/step)."""
    tb = first.shape[0]
    out = pl.pallas_call(
        _block_decode_kernel,
        grid=(tb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, payload.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tb, BLOCK), jnp.int32),
        interpret=interpret,
    )(first[:, None], off[:, None], bw[:, None], cnt[:, None],
      payload[None, :])
    return out


def _decode_sparse_jnp(first, off, bw, cnt, payload):
    """jnp twin of the decode kernel: same 32-bit math, XLA gathers."""
    pmax = payload.shape[0] - 1
    p = jnp.arange(BLOCK - 1, dtype=jnp.int32)[None, :]     # [1, 127]
    bitpos = p * bw[:, None]                                # [tb, 127]
    w = off[:, None] + (bitpos >> 5)
    w0 = payload[jnp.clip(w, 0, pmax)]
    w1 = payload[jnp.clip(w + 1, 0, pmax)]
    sh = (bitpos & 31).astype(jnp.uint32)
    lo = w0 >> sh
    hi = jnp.where(sh > 0,
                   w1 << ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    bwu = bw.astype(jnp.uint32)[:, None]
    mask = jnp.where(bwu > 0, (jnp.uint32(1) << bwu) - jnp.uint32(1),
                     jnp.uint32(0))
    v = ((lo | hi) & mask).astype(jnp.int32)
    v = jnp.where(p < cnt[:, None] - 1, v, 0)
    zeros = jnp.zeros((first.shape[0], 1), jnp.int32)
    return first[:, None] + jnp.concatenate(
        [zeros, jnp.cumsum(v, axis=1)], axis=1)


def _dense_overlay(ids, task_first, task_off, task_wcnt, task_kind,
                   payload, order_key, *, tbd: int, m: int):
    """Overwrite the (rare) dense-bitmap tasks' lanes with rank-selected
    set-bit ids. ``tbd`` statically bounds the dense task count (host
    header probe); the kind-major order makes every dense task land in
    the first ``tbd`` slots of ``order``."""
    order = jnp.argsort(order_key)[:tbd]
    offs = task_off[order]
    wcnt = task_wcnt[order]
    pmax = payload.shape[0] - 1
    wi = offs[:, None] + jnp.arange(DENSE_MAX_WORDS, dtype=jnp.int32)[None, :]
    words = payload[jnp.clip(wi, 0, pmax)]
    words = jnp.where(
        jnp.arange(DENSE_MAX_WORDS, dtype=jnp.int32)[None, :] < wcnt[:, None],
        words, jnp.uint32(0))
    bits = ((words[:, :, None] >> jnp.arange(32, dtype=jnp.uint32))
            & jnp.uint32(1)).astype(jnp.int32).reshape(tbd, -1)
    rank = jnp.cumsum(bits, axis=1)                     # [tbd, DW*32]
    col = jnp.where((bits == 1) & (rank <= BLOCK), rank - 1, BLOCK)
    j = jnp.arange(DENSE_MAX_WORDS * 32, dtype=jnp.int32)[None, :]
    vals = task_first[order][:, None] + j
    row = jnp.arange(tbd, dtype=jnp.int32)[:, None] + jnp.zeros_like(col)
    dense_ids = jnp.full((tbd, BLOCK + 1), m, jnp.int32) \
        .at[row.reshape(-1), col.reshape(-1)].set(vals.reshape(-1))[:, :BLOCK]
    keep = (task_kind[order] == 1)[:, None]
    return ids.at[order].set(jnp.where(keep, dense_ids, ids[order]))


@functools.partial(
    jax.jit, static_argnames=("tb", "tbd", "m", "backend", "interpret"))
def pruned_score_matrix(
    keys, row_blocks, blk_first, blk_meta, blk_off, payload,
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    *, tb: int, tbd: int, m: int, backend: str = "jnp",
    interpret: bool = True,
):
    """f32[m, Gq] pruned score matrix, computed entirely on device.

    Zero K∩ outside the candidate set (= the dense estimator's value
    there) and the dense kernel's own o1 everywhere; inside the
    candidate set, exactly the dense kernel's estimator. ``tb`` is the
    static block-task bound and ``tbd`` the dense-block-task bound —
    both from the host header probe, bucketed by the caller (``tbd=0``
    compiles the dense overlay out entirely).
    """
    gq, cq = q_values.shape
    u = keys.shape[0]
    nb = blk_first.shape[0]

    # -- probe: postings row per query hash ------------------------------
    q_flat = q_values.reshape(-1)
    if backend == "pallas" and u:
        pos, hit = _probe_pallas(keys, q_flat, interpret=interpret)
    else:
        pos, hit = _probe_jnp(keys, q_flat)
    pos_c = jnp.clip(pos, 0, max(u - 1, 0))
    if u:
        seg_start = jnp.where(hit, row_blocks[pos_c], 0)
        seg_nblk = jnp.where(hit, row_blocks[pos_c + 1] - row_blocks[pos_c],
                             0)
    else:
        seg_start = jnp.zeros(q_flat.shape, jnp.int32)
        seg_nblk = jnp.zeros(q_flat.shape, jnp.int32)

    # -- expand: matched rows' block ranges → flat block-task stream -----
    cum = jnp.cumsum(seg_nblk)
    total = cum[-1] if seg_nblk.shape[0] else jnp.int32(0)
    out = jnp.arange(tb, dtype=jnp.int32)
    seg = jnp.searchsorted(cum, out, side="right").astype(jnp.int32)
    seg_c = jnp.clip(seg, 0, max(seg_nblk.shape[0] - 1, 0))
    within = out - (cum[seg_c] - seg_nblk[seg_c])
    valid = out < total
    task_blk = jnp.where(valid, seg_start[seg_c] + within, nb)  # nb=sentinel
    task_q = jnp.where(valid, seg_c // jnp.int32(max(cq, 1)), 0)

    # Sentinel block: first = m (every lane drops), count 1, no body.
    first_s = jnp.concatenate([blk_first, jnp.full((1,), m, jnp.int32)])
    meta_s = jnp.concatenate([blk_meta, jnp.zeros((1,), jnp.uint32)])
    off_s = jnp.concatenate([blk_off, blk_off[-1:]])
    pay = jnp.pad(payload, (0, DECODE_WINDOW)) if payload.shape[0] \
        else jnp.zeros(DECODE_WINDOW, jnp.uint32)

    t_first = first_s[task_blk]
    t_meta = meta_s[task_blk]
    t_off = off_s[task_blk]
    t_wcnt = off_s[jnp.minimum(task_blk + 1, nb)] - t_off
    t_cnt = (t_meta & jnp.uint32(0x7F)).astype(jnp.int32) + 1
    t_bw = ((t_meta >> jnp.uint32(8)) & jnp.uint32(0x1F)).astype(jnp.int32)
    t_kind = ((t_meta >> jnp.uint32(13)) & jnp.uint32(1)).astype(jnp.int32)

    # -- decode: block bodies → ids [tb, BLOCK] --------------------------
    if backend == "pallas":
        ids = _decode_sparse_pallas(t_first, t_off, t_bw, t_cnt, pay,
                                    interpret=interpret)
    else:
        ids = _decode_sparse_jnp(t_first, t_off, t_bw, t_cnt, pay)
    if tbd:
        # Kind-major, position-minor key: every dense task sorts into
        # the first tbd slots deterministically (no stable-sort needed).
        order_key = (1 - t_kind) * jnp.int32(tb + 1) + out
        ids = _dense_overlay(ids, t_first, t_off, t_wcnt, t_kind, pay,
                             order_key, tbd=tbd, m=m)
    lanes = jnp.arange(BLOCK, dtype=jnp.int32)[None, :]
    ids = jnp.where(lanes < t_cnt[:, None], ids, m)

    # -- exact count scatter + bitmap o1 ---------------------------------
    # One decoded entry == one shared retained hash (it is ≤ both
    # effective thresholds by construction, so it IS a live member of
    # the pair); multiplicity is exact. Sentinel/invalid lanes carry the
    # out-of-range record id m and drop.
    lin = ids * jnp.int32(gq) + task_q[:, None]
    kcap = jnp.zeros(m * gq, jnp.int32).at[lin.reshape(-1)].add(
        1, mode="drop").reshape(m, gq)
    if x_buf.shape[1]:
        o1 = jnp.sum(lax.population_count(
            x_buf[:, None, :] & q_buf[None, :, :]), axis=-1).astype(jnp.int32)
    else:
        o1 = jnp.zeros((m, gq), jnp.int32)

    # -- closed-form estimator over the count matrices -------------------
    # n_x, n_q, U₍k₎ per pair from searchsorted tables against τ_pair
    # (rows are sorted and duplicate-free, so the insertion point IS the
    # ≤-count the dense kernel computes); every float op below is copied
    # from the dense kernel so the matrix matches it bit for bit.
    tau = jnp.minimum(x_thresh[:, None], q_thresh[None, :])    # [m, Gq]
    nx = jax.vmap(
        lambda row, t: jnp.searchsorted(row, t, side="right"))(
            x_values, tau).astype(jnp.int32)                   # [m, Gq]
    nq = jax.vmap(
        lambda row, t: jnp.searchsorted(row, t, side="right"))(
            q_values, tau.T).astype(jnp.int32).T               # [m, Gq]
    lx = jnp.take_along_axis(x_values, jnp.maximum(nx - 1, 0), axis=1)
    lx = jnp.where(nx > 0, lx, jnp.uint32(0))
    lq = jnp.take_along_axis(q_values, jnp.maximum(nq.T - 1, 0), axis=1)
    lq = jnp.where(nq.T > 0, lq, jnp.uint32(0)).T
    uu = jnp.maximum(lx, lq)
    u_unit = (uu.astype(jnp.float32) + 1.0) / TWO32

    k = nx + nq - kcap
    kf = k.astype(jnp.float32)
    d_hat = (kcap.astype(jnp.float32) / jnp.maximum(kf, 1.0)) * (
        (kf - 1.0) / jnp.maximum(u_unit, 1e-30))
    d_hat = jnp.where((k >= 2) & (kcap >= 1), d_hat,
                      jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0))
    return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
        q_sizes.astype(jnp.float32), 1.0)[None, :]
