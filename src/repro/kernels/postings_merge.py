"""Device-resident postings merge: candidate generation for the pruned
query path without leaving the accelerator.

The host planner merges posting lists with searchsorted + python loops;
that round-trips every batch through host numpy — exactly the transfer
the arena exists to kill. Here the same merge runs as three fused
device stages over the arena's device mirrors:

    probe    for every query hash, its postings row (index + existence)
             — a chunked compare against the sorted key column
             (Pallas kernel for ``backend="pallas"``, XLA searchsorted
             for ``backend="jnp"``)
    expand   ragged CSR segments → a flat, statically-bounded candidate
             stream (cumsum + searchsorted ragged-expand; the bound is
             the batch's total posting hits, known on host *before*
             candidate generation from the planner's cost probe)
    score    scatter-add the stream into exact K∩ and o1 count matrices
             (a posting entry for (h, X) against query Q *is* one shared
             retained hash / one shared buffer bit — multiplicity is the
             count), then evaluate the estimator in closed form per
             cell: n_x, n_q and U₍k₎ come from per-row searchsorted
             tables against τ_pair, every float op copied from the dense
             kernel — O(m·Gq) elementwise instead of the dense sweep's
             O(m·Gq·C·Cq) membership broadcast

The output matrix therefore equals the dense sweep's score matrix
bit for bit EVERYWHERE: inside the candidate set the counts are the
dense kernel's counts, outside it K∩ = o1 = 0 which is exactly what the
dense estimator produces. Packed thresholding over it returns identical
hits. Everything between staging and the final mask fetch is one jitted
computation: no host-numpy transfer between candidate generation and
the packed threshold output (tests assert this with a transfer guard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import PAD, TWO32

# Probe kernel tiling: query hashes per grid step / key-column chunk.
QBLOCK = 256
KCHUNK = 512


def _probe_kernel(keys_ref, q_ref, pos_ref, hit_ref):
    """pos = #keys < q, hit = any(keys == q), per query hash.

    ``keys_ref`` u32[1, Up] (whole padded key column, VMEM-resident),
    ``q_ref`` u32[1, QBLOCK]. Chunked compare instead of binary search:
    contiguous loads, no data-dependent addressing — the layout TPUs
    like. Key padding is PAD, which never matches a real hash and is
    masked for the (PAD == PAD) query-padding case below.
    """
    q = q_ref[0, :]                                     # [B]
    up = keys_ref.shape[1]

    def body(i, carry):
        pos, hit = carry
        chunk = lax.dynamic_slice(keys_ref[...], (0, i * KCHUNK),
                                  (1, KCHUNK))[0]       # [KCHUNK]
        pos = pos + jnp.sum(
            (chunk[None, :] < q[:, None]).astype(jnp.int32), axis=-1)
        hit = hit | jnp.any(chunk[None, :] == q[:, None], axis=-1)
        return pos, hit

    b = q.shape[0]
    pos, hit = lax.fori_loop(
        0, up // KCHUNK, body,
        (jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.bool_)))
    hit = hit & (q != PAD)
    pos_ref[0, :] = pos
    hit_ref[0, :] = hit.astype(jnp.int32)


def _probe_pallas(keys, q_flat, *, interpret: bool):
    """(pos i32[n], hit bool[n]) for a flat query-hash vector."""
    n = q_flat.shape[0]
    npad = -(-n // QBLOCK) * QBLOCK
    q2 = jnp.pad(q_flat, (0, npad - n), constant_values=PAD)[None, :]
    u = keys.shape[0]
    upad = max(-(-u // KCHUNK) * KCHUNK, KCHUNK)
    k2 = jnp.pad(keys, (0, upad - u), constant_values=PAD)[None, :]

    pos, hit = pl.pallas_call(
        _probe_kernel,
        grid=(npad // QBLOCK,),
        in_specs=[
            pl.BlockSpec((1, upad), lambda i: (0, 0)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
        ],
        interpret=interpret,
    )(k2, q2)
    return pos[0, :n], hit[0, :n].astype(jnp.bool_)


def _probe_jnp(keys, q_flat):
    u = keys.shape[0]
    pos = jnp.searchsorted(keys, q_flat).astype(jnp.int32)
    safe = jnp.clip(pos, 0, max(u - 1, 0))
    hit = (pos < u) & (keys[safe] == q_flat) & (q_flat != PAD) \
        if u else jnp.zeros(q_flat.shape, jnp.bool_)
    return pos, hit


def _expand(starts, lens, src, src_m_sentinel, pb, s1, cq):
    """Ragged CSR segments → flat (cand_rec, cand_q, is_tail), length pb.

    ``starts``/``lens`` are flat [Gq * s1] segment descriptors into the
    concatenated posting source ``src``; slots past the true total get
    the ``src_m_sentinel`` record id (== num_records, dropped by the
    scatter's out-of-bounds mode). ``is_tail`` splits hash-posting
    entries (the first ``cq`` segments of each query) from buffer-bit
    entries.
    """
    cum = jnp.cumsum(lens)
    total = cum[-1] if lens.shape[0] else jnp.int32(0)
    out = jnp.arange(pb, dtype=jnp.int32)
    seg = jnp.searchsorted(cum, out, side="right").astype(jnp.int32)
    seg_c = jnp.clip(seg, 0, max(lens.shape[0] - 1, 0))
    within = out - (cum[seg_c] - lens[seg_c])
    src_idx = jnp.clip(starts[seg_c] + within, 0, max(src.shape[0] - 1, 0))
    valid = out < total
    cand_rec = jnp.where(valid, src[src_idx], jnp.int32(src_m_sentinel))
    cand_q = jnp.where(valid, seg_c // jnp.int32(s1), 0)
    is_tail = (seg_c % jnp.int32(s1)) < jnp.int32(cq)
    return cand_rec, cand_q, is_tail


def _bits_of(buf):
    """u32[g, W] packed bitmap → bool[g, W*32] bit matrix."""
    g, w = buf.shape
    if w == 0:
        return jnp.zeros((g, 0), jnp.bool_)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (buf[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(g, w * 32).astype(jnp.bool_)


@functools.partial(
    jax.jit, static_argnames=("pb", "m", "backend", "interpret"))
def pruned_score_matrix(
    keys, offsets, rec_ids, buf_offsets, buf_rec_ids,
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
    *, pb: int, m: int, backend: str = "jnp", interpret: bool = True,
):
    """f32[m, Gq] pruned score matrix, computed entirely on device.

    Zero outside the candidate set (= the dense estimator's value
    there); inside it, exactly the dense kernel's estimator. ``pb``
    is the static candidate bound — the batch's total posting hits from
    the host cost probe, bucketed by the caller.
    """
    gq, cq = q_values.shape
    u = keys.shape[0]
    nnz = rec_ids.shape[0]
    r = buf_offsets.shape[0] - 1

    # -- probe: postings row per query hash ------------------------------
    q_flat = q_values.reshape(-1)
    if backend == "pallas" and u:
        pos, hit = _probe_pallas(keys, q_flat, interpret=interpret)
    else:
        pos, hit = _probe_jnp(keys, q_flat)
    pos_c = jnp.clip(pos, 0, max(u - 1, 0))
    seg_start = jnp.where(hit, offsets[pos_c], 0)
    seg_len = jnp.where(hit, offsets[pos_c + 1] - offsets[pos_c], 0) \
        if u else jnp.zeros(q_flat.shape, jnp.int32)
    seg_start = seg_start.reshape(gq, cq)
    seg_len = seg_len.reshape(gq, cq)

    # -- buffer rows: one segment per set query bit ----------------------
    if r > 0:
        bits = _bits_of(q_buf)[:, :r]                       # [Gq, R]
        blen = (buf_offsets[1:] - buf_offsets[:-1])[None, :]
        bstart = buf_offsets[:-1][None, :] + jnp.int32(nnz)
        seg_start = jnp.concatenate(
            [seg_start, jnp.broadcast_to(bstart, (gq, r))], axis=1)
        seg_len = jnp.concatenate(
            [seg_len, jnp.where(bits, blen, 0).astype(jnp.int32)], axis=1)
    s1 = seg_start.shape[1]

    src = jnp.concatenate([rec_ids, buf_rec_ids]) if r > 0 else rec_ids
    if src.shape[0] == 0:
        src = jnp.zeros(1, jnp.int32)

    # -- expand + exact count scatter ------------------------------------
    cand_rec, cand_q, is_tail = _expand(
        seg_start.reshape(-1), seg_len.reshape(-1).astype(jnp.int32),
        src, m, pb, s1, cq)
    # One tail entry == one shared retained hash (it is ≤ both effective
    # thresholds by construction, so it IS a live member of the pair);
    # one buffer entry == one shared frozen bit. Multiplicity is exact.
    # Single linearized scatter-add for both count families; invalid
    # lanes carry the out-of-range record sentinel and drop.
    lin = (cand_rec * jnp.int32(2 * gq) + cand_q * 2
           + is_tail.astype(jnp.int32))
    counts = jnp.zeros(m * gq * 2, jnp.int32).at[lin].add(
        1, mode="drop").reshape(m, gq, 2)
    o1, kcap = counts[..., 0], counts[..., 1]

    # -- closed-form estimator over the count matrices -------------------
    # n_x, n_q, U₍k₎ per pair from searchsorted tables against τ_pair
    # (rows are sorted and duplicate-free, so the insertion point IS the
    # ≤-count the dense kernel computes); every float op below is copied
    # from the dense kernel so the matrix matches it bit for bit.
    tau = jnp.minimum(x_thresh[:, None], q_thresh[None, :])    # [m, Gq]
    nx = jax.vmap(
        lambda row, t: jnp.searchsorted(row, t, side="right"))(
            x_values, tau).astype(jnp.int32)                   # [m, Gq]
    nq = jax.vmap(
        lambda row, t: jnp.searchsorted(row, t, side="right"))(
            q_values, tau.T).astype(jnp.int32).T               # [m, Gq]
    lx = jnp.take_along_axis(x_values, jnp.maximum(nx - 1, 0), axis=1)
    lx = jnp.where(nx > 0, lx, jnp.uint32(0))
    lq = jnp.take_along_axis(q_values, jnp.maximum(nq.T - 1, 0), axis=1)
    lq = jnp.where(nq.T > 0, lq, jnp.uint32(0)).T
    u = jnp.maximum(lx, lq)
    u_unit = (u.astype(jnp.float32) + 1.0) / TWO32

    k = nx + nq - kcap
    kf = k.astype(jnp.float32)
    d_hat = (kcap.astype(jnp.float32) / jnp.maximum(kf, 1.0)) * (
        (kf - 1.0) / jnp.maximum(u_unit, 1e-30))
    d_hat = jnp.where((k >= 2) & (kcap >= 1), d_hat,
                      jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0))
    return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
        q_sizes.astype(jnp.float32), 1.0)[None, :]
