"""Device-resident postings merge over BLOCK-COMPRESSED postings:
candidate generation for the pruned query path without leaving the
accelerator — and without ever materializing the flat posting lists.

The host planner decodes blocks with vectorized numpy; that round-trips
every batch through host memory — exactly the transfer the arena exists
to kill. Here the same merge runs as fused device stages over the
arena's blocked tail mirror, all inside ONE jitted program per output
mode:

    probe    for every query hash, its postings row (index + existence)
             — a chunked compare against the sorted key column
             (Pallas kernel for ``backend="pallas"``, XLA searchsorted
             for ``backend="jnp"``). The block-header probe the host
             planner used to run (row_blocks ranges per matched key)
             happens HERE, as array ops on the mirrored headers — the
             host never feeds the device a per-batch task bound.
    expand   matched rows' block ranges → a flat stream of block tasks,
             consumed by a ``lax.while_loop`` in fixed ``chunk``-sized
             windows. The trip count is data-dependent (a device
             scalar); every shape inside the body is static — so ONE
             compiled program serves every batch, however many blocks
             it touches. No host-side header probe, no per-bucket
             recompiles.
    decode   each task's block body → up to 128 record ids. Sparse
             bodies unpack their bitpacked deltas and prefix-sum back to
             ids (the Pallas block-decode kernel for ``"pallas"`` — one
             task per grid step, one dynamic-slice DMA of the body, a
             one-hot word select instead of a data-dependent gather — or
             a vectorized jnp twin). The rare dense-bitmap bodies run in
             a SECOND while_loop over a dense-task-only stream (their
             rank-select materializes a [dchunk, 3968] bit matrix — far
             too hot to pay per sparse chunk): a cumulative dense-kind
             count over the mirrored block metadata locates the j-th
             dense block of each matched row by searchsorted, so a batch
             touching zero dense blocks runs zero dense iterations. The
             loop is compiled out entirely when the store holds no dense
             blocks at all (``has_dense=False``, a static property of
             the postings, not of the batch).
    score    scatter-add the decoded stream into the exact K∩ count
             matrix (a posting entry for (h, X) against query Q *is* one
             shared retained hash — multiplicity is the count), take the
             exact o1 matrix straight from the resident packed bitmaps
             (the dense kernel's own popcount — which is why the buffer
             posting lists never need a device mirror at all), then
             evaluate the estimator in closed form per cell: n_x, n_q
             and U₍k₎ come from per-row searchsorted tables against
             τ_pair, every float op copied from the dense kernel —
             O(m·Gq) elementwise instead of the dense sweep's
             O(m·Gq·C·Cq) membership broadcast

The score matrix therefore equals the dense sweep's score matrix
bit for bit EVERYWHERE: inside the candidate set the counts are the
dense kernel's counts, outside it K∩ = 0 and o1 is the identical
popcount, which is exactly what the dense estimator produces.

Three fused outputs, each ONE jit (no host transfer anywhere inside —
tests assert it with a transfer guard):

    fused_hit_words    score ≥ threshold, bit-packed along the record
                       axis into u32 words — an 8× smaller fetch than
                       the bool mask, decoded lazily on host
    fused_topk         lax.top_k over the score columns. The dense tie
                       rule (-score, id) IS lax.top_k's order (equal
                       values rank lower-index-first), and the closed
                       form scores ALL m records — so the "bound-sort +
                       chunked while_loop with a running k-th threshold"
                       the host pruned_topk needs degenerates here: the
                       bound sort would serialize work the estimator
                       already did elementwise. Exactness comes from the
                       matrix equality above, not from bound soundness.
    fused_scores       the raw f32[m, Gq] matrix (parity tests, bench)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import PAD, TWO32
from repro.planner.postings import BLOCK, DENSE_MAX_WORDS

# Probe kernel tiling: query hashes per grid step / key-column chunk.
QBLOCK = 256
KCHUNK = 512
# Sparse block bodies span at most ceil(127·31/32) = 124 payload words;
# one 128-word window therefore always covers a body (plus slack the
# payload is padded with), so the decode kernel's DMA has a static size.
DECODE_WINDOW = 128
# while_loop window sizes: sparse block tasks / dense block tasks per
# iteration. Fixed static shapes inside a data-dependent trip count —
# the whole point: one compiled program for any batch. Each window
# always decodes a full ``chunk`` of blocks (short final windows waste
# the remainder), so the window is sized near the per-batch task count
# of the serving workload, not for loop-overhead amortization.
TASK_CHUNK = 128
DENSE_TASK_CHUNK = 64


def _probe_kernel(keys_ref, q_ref, pos_ref, hit_ref):
    """pos = #keys < q, hit = any(keys == q), per query hash.

    ``keys_ref`` u32[1, Up] (whole padded key column, VMEM-resident),
    ``q_ref`` u32[1, QBLOCK]. Chunked compare instead of binary search:
    contiguous loads, no data-dependent addressing — the layout TPUs
    like. Key padding is PAD, which never matches a real hash and is
    masked for the (PAD == PAD) query-padding case below.
    """
    q = q_ref[0, :]                                     # [B]

    def body(i, carry):
        pos, hit = carry
        chunk = lax.dynamic_slice(keys_ref[...], (0, i * KCHUNK),
                                  (1, KCHUNK))[0]       # [KCHUNK]
        pos = pos + jnp.sum(
            (chunk[None, :] < q[:, None]).astype(jnp.int32), axis=-1)
        hit = hit | jnp.any(chunk[None, :] == q[:, None], axis=-1)
        return pos, hit

    b = q.shape[0]
    up = keys_ref.shape[1]
    pos, hit = lax.fori_loop(
        0, up // KCHUNK, body,
        (jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.bool_)))
    hit = hit & (q != PAD)
    pos_ref[0, :] = pos
    hit_ref[0, :] = hit.astype(jnp.int32)


def _probe_pallas(keys, q_flat, *, interpret: bool):
    """(pos i32[n], hit bool[n]) for a flat query-hash vector."""
    n = q_flat.shape[0]
    npad = -(-n // QBLOCK) * QBLOCK
    q2 = jnp.pad(q_flat, (0, npad - n), constant_values=PAD)[None, :]
    u = keys.shape[0]
    upad = max(-(-u // KCHUNK) * KCHUNK, KCHUNK)
    k2 = jnp.pad(keys, (0, upad - u), constant_values=PAD)[None, :]

    pos, hit = pl.pallas_call(
        _probe_kernel,
        grid=(npad // QBLOCK,),
        in_specs=[
            pl.BlockSpec((1, upad), lambda i: (0, 0)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
        ],
        interpret=interpret,
    )(k2, q2)
    return pos[0, :n], hit[0, :n].astype(jnp.bool_)


def _probe_jnp(keys, q_flat):
    u = keys.shape[0]
    pos = jnp.searchsorted(keys, q_flat).astype(jnp.int32)
    safe = jnp.clip(pos, 0, max(u - 1, 0))
    hit = (pos < u) & (keys[safe] == q_flat) & (q_flat != PAD) \
        if u else jnp.zeros(q_flat.shape, jnp.bool_)
    return pos, hit


# ---------------------------------------------------------------------------
# block decode: sparse bodies (the common kind)
# ---------------------------------------------------------------------------


def _block_decode_kernel(first_ref, off_ref, bw_ref, cnt_ref,
                         payload_ref, out_ref):
    """Decode ONE sparse block task per grid step.

    One dynamic-slice DMA pulls the block's ``DECODE_WINDOW``-word body
    out of the payload column; deltas unpack via a one-hot word select
    (a [127, 128] masked max — VPU-shaped work, no data-dependent
    addressing) and a prefix sum turns them back into record ids. All
    arithmetic is 32-bit: the two straddled words recombine with
    shift-or instead of a 64-bit widen, because TPUs would rather not.
    Lanes past the block's count carry garbage and are masked by the
    caller (shared with the jnp twin).
    """
    first = first_ref[0, 0]
    off = off_ref[0, 0]
    bw = bw_ref[0, 0].astype(jnp.uint32)
    cnt = cnt_ref[0, 0]

    body = lax.dynamic_slice(payload_ref[...], (0, off),
                             (1, DECODE_WINDOW))            # u32[1, W]
    p = lax.broadcasted_iota(jnp.int32, (1, BLOCK - 1), 1)  # [1, 127]
    bitpos = p * bw_ref[0, 0]
    widx = bitpos >> 5
    lanes = lax.broadcasted_iota(jnp.int32, (1, DECODE_WINDOW), 1)
    sel0 = widx[0][:, None] == lanes[0][None, :]            # [127, W]
    sel1 = (widx[0] + 1)[:, None] == lanes[0][None, :]
    w0 = jnp.max(jnp.where(sel0, body[0][None, :], jnp.uint32(0)), axis=1)
    w1 = jnp.max(jnp.where(sel1, body[0][None, :], jnp.uint32(0)), axis=1)

    sh = (bitpos[0] & 31).astype(jnp.uint32)
    lo = w0 >> sh
    hi = jnp.where(sh > 0,
                   w1 << ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    mask = jnp.where(bw > 0, (jnp.uint32(1) << bw) - jnp.uint32(1),
                     jnp.uint32(0))
    v = ((lo | hi) & mask).astype(jnp.int32)
    v = jnp.where(p[0] < cnt - 1, v, 0)[None, :]
    ids = first + jnp.concatenate(
        [jnp.zeros((1, 1), jnp.int32), jnp.cumsum(v, axis=1)], axis=1)
    out_ref[0, :] = ids[0]


def _decode_sparse_pallas(first, off, bw, cnt, payload, *, interpret: bool):
    """i32[tb, BLOCK] raw sparse-decoded ids (Pallas, one task/step)."""
    tb = first.shape[0]
    out = pl.pallas_call(
        _block_decode_kernel,
        grid=(tb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, payload.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tb, BLOCK), jnp.int32),
        interpret=interpret,
    )(first[:, None], off[:, None], bw[:, None], cnt[:, None],
      payload[None, :])
    return out


def _decode_sparse_jnp(first, off, bw, cnt, payload):
    """jnp twin of the decode kernel: same 32-bit math, XLA gathers."""
    pmax = payload.shape[0] - 1
    p = jnp.arange(BLOCK - 1, dtype=jnp.int32)[None, :]     # [1, 127]
    bitpos = p * bw[:, None]                                # [tb, 127]
    w = off[:, None] + (bitpos >> 5)
    w0 = payload[jnp.clip(w, 0, pmax)]
    w1 = payload[jnp.clip(w + 1, 0, pmax)]
    sh = (bitpos & 31).astype(jnp.uint32)
    lo = w0 >> sh
    hi = jnp.where(sh > 0,
                   w1 << ((jnp.uint32(32) - sh) & jnp.uint32(31)),
                   jnp.uint32(0))
    bwu = bw.astype(jnp.uint32)[:, None]
    mask = jnp.where(bwu > 0, (jnp.uint32(1) << bwu) - jnp.uint32(1),
                     jnp.uint32(0))
    v = ((lo | hi) & mask).astype(jnp.int32)
    v = jnp.where(p < cnt[:, None] - 1, v, 0)
    zeros = jnp.zeros((first.shape[0], 1), jnp.int32)
    return first[:, None] + jnp.concatenate(
        [zeros, jnp.cumsum(v, axis=1)], axis=1)


def _decode_dense_jnp(first, off, wcnt, payload, *, m: int):
    """i32[n, BLOCK] rank-selected set-bit ids of dense-bitmap tasks.

    Lanes past a block's population carry the sentinel ``m`` (they never
    reach the scatter); a zero-word task (the sentinel block) decodes to
    all-sentinel. Shared by the dense while_loop stream — dense blocks
    hold strictly ascending ids, so each set bit is one entry and the
    rank IS the lane."""
    n = first.shape[0]
    pmax = payload.shape[0] - 1
    win = jnp.arange(DENSE_MAX_WORDS, dtype=jnp.int32)[None, :]
    wi = off[:, None] + win
    words = payload[jnp.clip(wi, 0, pmax)]
    words = jnp.where(win < wcnt[:, None], words, jnp.uint32(0))
    bits = ((words[:, :, None] >> jnp.arange(32, dtype=jnp.uint32))
            & jnp.uint32(1)).astype(jnp.int32).reshape(n, -1)
    rank = jnp.cumsum(bits, axis=1)                     # [n, DW*32]
    col = jnp.where((bits == 1) & (rank <= BLOCK), rank - 1, BLOCK)
    j = jnp.arange(DENSE_MAX_WORDS * 32, dtype=jnp.int32)[None, :]
    vals = first[:, None] + j
    row = jnp.arange(n, dtype=jnp.int32)[:, None] + jnp.zeros_like(col)
    return jnp.full((n, BLOCK + 1), m, jnp.int32) \
        .at[row.reshape(-1), col.reshape(-1)].set(vals.reshape(-1))[:, :BLOCK]


# ---------------------------------------------------------------------------
# shared scoring tail: bitmap o1 + the closed-form estimator
# ---------------------------------------------------------------------------


def _bitmap_o1(x_buf, q_buf, m: int, gq: int):
    """i32[m, Gq] exact buffer intersections — the dense kernel's own
    popcount over the resident packed bitmaps."""
    if x_buf.shape[1]:
        return jnp.sum(lax.population_count(
            x_buf[:, None, :] & q_buf[None, :, :]), axis=-1).astype(jnp.int32)
    return jnp.zeros((m, gq), jnp.int32)


def _estimate_scores(kcap, o1, x_values, x_thresh, q_values, q_thresh,
                     q_sizes):
    """f32[m, Gq] closed-form estimator over the count matrices.

    n_x, n_q, U₍k₎ per pair from searchsorted tables against τ_pair
    (rows are sorted and duplicate-free, so the insertion point IS the
    ≤-count the dense kernel computes — the unrolled binary search is
    the fastest XLA:CPU lowering of the batch and returns the same
    integer counts as any other method); every float op below is copied
    from the dense kernel so the matrix matches it bit for bit.
    """
    tau = jnp.minimum(x_thresh[:, None], q_thresh[None, :])    # [m, Gq]
    nx = jax.vmap(
        lambda row, t: jnp.searchsorted(
            row, t, side="right", method="scan_unrolled"))(
            x_values, tau).astype(jnp.int32)                   # [m, Gq]
    nq = jax.vmap(
        lambda row, t: jnp.searchsorted(
            row, t, side="right", method="scan_unrolled"))(
            q_values, tau.T).astype(jnp.int32).T               # [m, Gq]
    lx = jnp.take_along_axis(x_values, jnp.maximum(nx - 1, 0), axis=1)
    lx = jnp.where(nx > 0, lx, jnp.uint32(0))
    lq = jnp.take_along_axis(q_values, jnp.maximum(nq.T - 1, 0), axis=1)
    lq = jnp.where(nq.T > 0, lq, jnp.uint32(0)).T
    uu = jnp.maximum(lx, lq)
    u_unit = (uu.astype(jnp.float32) + 1.0) / TWO32

    k = nx + nq - kcap
    kf = k.astype(jnp.float32)
    d_hat = (kcap.astype(jnp.float32) / jnp.maximum(kf, 1.0)) * (
        (kf - 1.0) / jnp.maximum(u_unit, 1e-30))
    d_hat = jnp.where((k >= 2) & (kcap >= 1), d_hat,
                      jnp.where(kcap >= 1, kcap.astype(jnp.float32), 0.0))
    return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
        q_sizes.astype(jnp.float32), 1.0)[None, :]


# ---------------------------------------------------------------------------
# the fused pipeline: probe → while_loop expand/decode → K∩ → estimator
# ---------------------------------------------------------------------------


def _carve_query_blob(qblob, *, gq: int, cq: int, w: int):
    """(values u32[gq, cq], thresh u32[gq], buf u32[gq, w], sizes
    i32[gq], thresholds f32[gq]) out of the single staged u32 blob.

    The staging pool ships ONE contiguous buffer per batch (one
    device_put instead of five); the slicing and the int32/float32
    bitcasts fuse into the compiled program at static offsets.
    """
    o0 = gq * cq
    o1 = o0 + gq
    o2 = o1 + gq * w
    o3 = o2 + gq
    return (qblob[:o0].reshape(gq, cq),
            qblob[o0:o1],
            qblob[o1:o2].reshape(gq, w),
            lax.bitcast_convert_type(qblob[o2:o3], jnp.int32),
            lax.bitcast_convert_type(qblob[o3:o3 + gq], jnp.float32))


def _pipeline_scores(keys, row_blocks, blk_first, blk_meta, blk_off,
                     payload, x_values, x_thresh, x_buf,
                     q_values, q_thresh, q_buf, q_sizes,
                     *, chunk: int, dchunk: int, m: int, backend: str,
                     interpret: bool, has_dense: bool):
    """f32[m, Gq] pruned score matrix — every stage device-side.

    The expand runs as a ``lax.while_loop`` over fixed ``chunk``-sized
    task windows: trip count data-dependent, shapes static, so the
    compiled program is independent of how many blocks the batch
    touches. Dense-bitmap blocks stream through a second while_loop
    (``dchunk`` tasks per step) located via a dense-kind cumsum over the
    block metadata; ``has_dense=False`` (a static property of the
    STORE, not the batch) compiles that loop out entirely.
    """
    gq, cq = q_values.shape
    u = keys.shape[0]
    nb = blk_first.shape[0]
    nflat = gq * cq

    # -- probe: postings row + block range per query hash (on device) ----
    q_flat = q_values.reshape(-1)
    if backend == "pallas" and u:
        pos, hit = _probe_pallas(keys, q_flat, interpret=interpret)
    else:
        pos, hit = _probe_jnp(keys, q_flat)
    pos_c = jnp.clip(pos, 0, max(u - 1, 0))
    if u:
        rs = jnp.where(hit, row_blocks[pos_c], 0)
        re = jnp.where(hit, row_blocks[pos_c + 1], 0)
    else:
        rs = jnp.zeros(q_flat.shape, jnp.int32)
        re = rs
    seg_nblk = re - rs

    kflat = jnp.zeros(m * gq, jnp.int32)
    o1 = _bitmap_o1(x_buf, q_buf, m, gq)
    if nflat == 0 or nb == 0:
        # K∩ ≡ 0: the score is the o1 base everywhere (d_hat = 0.0).
        return o1.astype(jnp.float32) / jnp.maximum(
            q_sizes.astype(jnp.float32), 1.0)[None, :]

    cum = jnp.cumsum(seg_nblk)
    total = cum[-1]

    # Sentinel block: first = m (every lane drops), count 1, no body.
    first_s = jnp.concatenate([blk_first, jnp.full((1,), m, jnp.int32)])
    meta_s = jnp.concatenate([blk_meta, jnp.zeros((1,), jnp.uint32)])
    off_s = jnp.concatenate([blk_off, blk_off[-1:]])
    pay = jnp.pad(payload, (0, DECODE_WINDOW)) if payload.shape[0] \
        else jnp.zeros(DECODE_WINDOW, jnp.uint32)

    nseg = max(nflat - 1, 0)
    cqd = jnp.int32(max(cq, 1))
    lanes = jnp.arange(BLOCK, dtype=jnp.int32)[None, :]

    def sparse_body(carry):
        step, acc = carry
        out = step * chunk + jnp.arange(chunk, dtype=jnp.int32)
        seg = jnp.searchsorted(cum, out, side="right").astype(jnp.int32)
        seg_c = jnp.clip(seg, 0, nseg)
        within = out - (cum[seg_c] - seg_nblk[seg_c])
        valid = out < total
        task_blk = jnp.where(valid, rs[seg_c] + within, nb)
        task_q = jnp.where(valid, seg_c // cqd, 0)
        t_first = first_s[task_blk]
        t_meta = meta_s[task_blk]
        t_off = off_s[task_blk]
        t_cnt = (t_meta & jnp.uint32(0x7F)).astype(jnp.int32) + 1
        t_bw = ((t_meta >> jnp.uint32(8))
                & jnp.uint32(0x1F)).astype(jnp.int32)
        t_kind = ((t_meta >> jnp.uint32(13)) & jnp.uint32(1)).astype(
            jnp.int32)
        if backend == "pallas":
            ids = _decode_sparse_pallas(t_first, t_off, t_bw, t_cnt, pay,
                                        interpret=interpret)
        else:
            ids = _decode_sparse_jnp(t_first, t_off, t_bw, t_cnt, pay)
        # Dense tasks are the dense loop's; sentinel/invalid lanes carry
        # the out-of-range record id m and drop at the scatter.
        ids = jnp.where((lanes < t_cnt[:, None]) & (t_kind[:, None] == 0),
                        ids, m)
        lin = ids * jnp.int32(gq) + task_q[:, None]
        acc = acc.at[lin.reshape(-1)].add(1, mode="drop")
        return step + 1, acc

    _, kflat = lax.while_loop(
        lambda c: c[0] * chunk < total, sparse_body,
        (jnp.int32(0), kflat))

    if has_dense:
        # Dense-rank coordinates: D[b] = dense blocks among [0, b), so a
        # matched row's j-th dense block is the unique b with
        # D[b] = D[row_start] + j and kind[b] = 1.
        kind_all = ((blk_meta >> jnp.uint32(13)) & jnp.uint32(1)).astype(
            jnp.int32)
        dall = jnp.concatenate(
            [jnp.zeros(1, jnp.int32), jnp.cumsum(kind_all)])   # [nb+1]
        dcnt = dall[re] - dall[rs]
        dcum = jnp.cumsum(dcnt)
        dtotal = dcum[-1]
        dbase = dall[rs]

        def dense_body(carry):
            step, acc = carry
            r = step * dchunk + jnp.arange(dchunk, dtype=jnp.int32)
            seg = jnp.searchsorted(dcum, r, side="right").astype(jnp.int32)
            seg_c = jnp.clip(seg, 0, nseg)
            j = r - (dcum[seg_c] - dcnt[seg_c])
            valid = r < dtotal
            blk = jnp.searchsorted(dall, dbase[seg_c] + j,
                                   side="right").astype(jnp.int32) - 1
            task_blk = jnp.where(valid, blk, nb)
            task_q = jnp.where(valid, seg_c // cqd, 0)
            t_first = first_s[task_blk]
            t_off = off_s[task_blk]
            t_wcnt = off_s[jnp.minimum(task_blk + 1, nb)] - t_off
            ids = _decode_dense_jnp(t_first, t_off, t_wcnt, pay, m=m)
            lin = ids * jnp.int32(gq) + task_q[:, None]
            acc = acc.at[lin.reshape(-1)].add(1, mode="drop")
            return step + 1, acc

        _, kflat = lax.while_loop(
            lambda c: c[0] * dchunk < dtotal, dense_body,
            (jnp.int32(0), kflat))

    return _estimate_scores(kflat.reshape(m, gq), o1, x_values, x_thresh,
                            q_values, q_thresh, q_sizes)


_STATIC = ("gq", "cq", "w", "chunk", "dchunk", "m", "backend",
           "interpret", "has_dense")


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("qblob",))
def fused_scores(keys, row_blocks, blk_first, blk_meta, blk_off, payload,
                 x_values, x_thresh, x_buf, qblob,
                 *, gq: int, cq: int, w: int,
                 chunk: int = TASK_CHUNK, dchunk: int = DENSE_TASK_CHUNK,
                 m: int, backend: str = "jnp", interpret: bool = True,
                 has_dense: bool = True):
    """f32[m, Gq] device score matrix (parity/bench seam)."""
    q_values, q_thresh, q_buf, q_sizes, _ = _carve_query_blob(
        qblob, gq=gq, cq=cq, w=w)
    return _pipeline_scores(
        keys, row_blocks, blk_first, blk_meta, blk_off, payload,
        x_values, x_thresh, x_buf, q_values, q_thresh, q_buf, q_sizes,
        chunk=chunk, dchunk=dchunk, m=m, backend=backend,
        interpret=interpret, has_dense=has_dense)


@functools.partial(jax.jit, static_argnames=_STATIC,
                   donate_argnames=("qblob",))
def fused_hit_words(keys, row_blocks, blk_first, blk_meta, blk_off, payload,
                    x_values, x_thresh, x_buf, qblob,
                    *, gq: int, cq: int, w: int,
                    chunk: int = TASK_CHUNK,
                    dchunk: int = DENSE_TASK_CHUNK, m: int,
                    backend: str = "jnp", interpret: bool = True,
                    has_dense: bool = True):
    """u32[ceil(m/32), Gq] packed hit words: bit ``i & 31`` of word
    ``i >> 5`` is (score[i, g] >= thresholds[g]). The float32-exact
    per-query thresholds ride the staged blob. The packed result is
    what crosses to host — an 8× smaller fetch than the bool mask, and
    the caller decodes it lazily."""
    q_values, q_thresh, q_buf, q_sizes, thresholds = _carve_query_blob(
        qblob, gq=gq, cq=cq, w=w)
    s = _pipeline_scores(
        keys, row_blocks, blk_first, blk_meta, blk_off, payload,
        x_values, x_thresh, x_buf, q_values, q_thresh, q_buf, q_sizes,
        chunk=chunk, dchunk=dchunk, m=m, backend=backend,
        interpret=interpret, has_dense=has_dense)
    mask = s >= thresholds[None, :]
    mw = max(-(-m // 32), 1)
    mp = jnp.pad(mask, ((0, mw * 32 - m), (0, 0)))
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(mp.reshape(mw, 32, gq).astype(jnp.uint32)
                   * weights[None, :, None], axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=_STATIC + ("k",),
                   donate_argnames=("qblob",))
def fused_topk(keys, row_blocks, blk_first, blk_meta, blk_off, payload,
               x_values, x_thresh, x_buf, qblob,
               *, k: int, gq: int, cq: int, w: int,
               chunk: int = TASK_CHUNK,
               dchunk: int = DENSE_TASK_CHUNK, m: int,
               backend: str = "jnp", interpret: bool = True,
               has_dense: bool = True):
    """(scores f32[Gq, k], ids i32[Gq, k]) device top-k over the fused
    score matrix. ``lax.top_k`` ranks equal values lower-index-first —
    exactly the dense (-score, id) tie rule — and the matrix is the
    dense matrix bit for bit, so the ranking matches the host paths
    entry for entry."""
    q_values, q_thresh, q_buf, q_sizes, _ = _carve_query_blob(
        qblob, gq=gq, cq=cq, w=w)
    s = _pipeline_scores(
        keys, row_blocks, blk_first, blk_meta, blk_off, payload,
        x_values, x_thresh, x_buf, q_values, q_thresh, q_buf, q_sizes,
        chunk=chunk, dchunk=dchunk, m=m, backend=backend,
        interpret=interpret, has_dense=has_dense)
    return lax.top_k(s.T, k)
