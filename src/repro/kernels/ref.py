"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` layer).

These are the semantics of record; kernel sweeps assert allclose against
them. They delegate to the same estimator math the core library uses
(repro.core.estimators), so kernel == core == paper formulas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators import buffer_intersection, gkmv_pair_estimate
from repro.core.hashing import TWO32


def gbkmv_score_ref(
    x_values, x_thresh, x_buf,
    q_values, q_thresh, q_buf, q_sizes,
):
    """Containment scores f32[M, Gq] for every (record, query) pair.

    Shapes: x_values u32[M, C], x_thresh u32[M], x_buf u32[M, W],
            q_values u32[Gq, Cq], q_thresh u32[Gq], q_buf u32[Gq, W],
            q_sizes i32[Gq].
    """
    def one_query(qv, qt, qb, qs):
        d_hat, _, _ = gkmv_pair_estimate(qv, None, qt, x_values, None, x_thresh)
        o1 = buffer_intersection(qb, x_buf)
        return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
            qs.astype(jnp.float32), 1.0)

    scores = jax.vmap(one_query)(q_values, q_thresh, q_buf, q_sizes)  # [Gq, M]
    return scores.T


def hash_threshold_ref(ids, seed, tau):
    """(hashes u32[N], kept bool[N]): murmur-mix then global-τ filter."""
    x = jnp.asarray(ids).astype(jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9) * jnp.uint32(seed + 1)
    h = x ^ (x >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h, h <= jnp.uint32(tau)
