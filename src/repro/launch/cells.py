"""Cell builders: (arch × input-shape × mesh) → lowerable step function.

A *cell* bundles everything ``jax.jit(...).lower(...)`` needs:
    fn             — the step callable (train_step / prefill / decode /
                     serve forward / retrieval scoring)
    args           — pytree of ShapeDtypeStructs (no allocation)
    in_shardings   — matching NamedSharding pytree
    out_shardings  — pinned for train (params/opt stay put), else None
    donate         — arg indices donated (train: params + opt state)

All sharding decisions route through parallel/sharding.py logical-axis
rules; per-cell overrides (the §Perf hillclimb knob) come in via ``rules``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import FAMILY_SHAPES
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.parallel.sharding import (
    DEFAULT_RULES,
    named_sharding_for,
    tree_shardings_for,
)
from repro.train import optim, steps


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any          # None → XLA's choice
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)
    rules: Any = None           # trace-time logical-axis override

    def lower(self):
        from repro.parallel.sharding import rules_scope

        jit = jax.jit(self.fn,
                      in_shardings=self.in_shardings,
                      out_shardings=self.out_shardings,
                      donate_argnums=self.donate_argnums)
        # The rules must be live while TRACING so in-model constrain()
        # calls resolve against the variant mapping, not the defaults.
        with rules_scope(self.rules):
            return jit.lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _axes_shardings(abstract_tree, axes_tree, mesh, rules):
    return tree_shardings_for(abstract_tree, axes_tree, mesh, rules)


def _pad_to(n: int, mult: int) -> int:
    """Round a data count up so every mesh axis divides (the data pipeline
    pads with masked/no-op entries; dry-run cells record the true count in
    meta)."""
    return -(-n // mult) * mult


# Per-arch training knobs (microbatches keep per-device transients sane;
# bf16 moments + bf16 grad accumulation keep the 400B MoE inside
# 16 GB/chip — DESIGN.md §6). MoE archs run micro=4: the FSDP expert-
# weight re-gather scales with the microbatch count (§Perf cells B/F;
# micro=2 would shave another ~15 % but busts the HBM budget).
LM_TRAIN_MICRO = {
    "llama4-maverick-400b-a17b": 4,
    "moonshot-v1-16b-a3b": 4,
}
LM_MOMENT_DTYPE = {
    "llama4-maverick-400b-a17b": "bfloat16",
}
LM_ACCUM_DTYPE = {
    "llama4-maverick-400b-a17b": "bfloat16",
}
DEFAULT_LM_MICRO = 8


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_abstract_params(cfg):
    return jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg))


def _lm_cache_abstract(cfg, batch: int, seq: int):
    """KV cache SDS tree matching transformer.prefill's stacking."""
    nd, nm, interleaved = cfg.layer_plan()
    dt = jnp.dtype(cfg.dtype)
    kv = lambda n: (_sds((n, batch, seq, cfg.n_kv_heads, cfg.hd), dt),
                    _sds((n, batch, seq, cfg.n_kv_heads, cfg.hd), dt))
    if interleaved:
        n_pairs = cfg.n_layers // cfg.moe.every
        return {"dense": kv(n_pairs), "moe": kv(n_pairs)}
    out = {}
    if nd:
        out["dense"] = kv(nd)
    if nm:
        out["moe"] = kv(nm)
    return out


def _lm_cache_axes(cfg):
    ax = tfm.cache_axes(cfg)
    tree = _lm_cache_abstract(cfg, 1, 1)
    return jax.tree.map(lambda _: ax, tree)


def _lm_cell(arch, shape_id, spec, mesh, rules, overrides=None) -> Cell:
    overrides = overrides or {}
    mod = registry.get_module(arch)
    cfg = mod.config()
    if "cfg_replace" in overrides:
        cfg = dataclasses.replace(cfg, **overrides["cfg_replace"])
    params = _lm_abstract_params(cfg)
    p_axes = tfm.param_axes(cfg)
    p_sh = _axes_shardings(params, p_axes, mesh, rules)
    b, s = spec["batch"], spec["seq"]

    if spec["kind"] == "train":
        ocfg = optim.OptConfig(
            moment_dtype=LM_MOMENT_DTYPE.get(arch, "float32"))
        opt = jax.eval_shape(lambda: optim.init(params, ocfg))
        o_sh = _axes_shardings(opt, optim.opt_state_axes(p_axes), mesh, rules)
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        b_sh = _axes_shardings(
            batch, {"tokens": ("batch", None), "labels": ("batch", None)},
            mesh, rules)
        micro = overrides.get("microbatches",
                              LM_TRAIN_MICRO.get(arch, DEFAULT_LM_MICRO))
        fn = steps.make_train_step(
            functools.partial(_lm_loss, cfg=cfg), ocfg, microbatches=micro,
            accum_dtype=LM_ACCUM_DTYPE.get(arch, "float32"))
        return Cell(arch, shape_id, fn, (params, opt, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    donate_argnums=(0, 1),
                    meta={"microbatches": micro, "global_batch": b, "seq": s})

    if spec["kind"] == "prefill":
        tokens = _sds((b, s), jnp.int32)
        t_sh = named_sharding_for((b, s), ("batch", None), mesh, rules)
        fn = functools.partial(_lm_prefill, cfg=cfg)
        return Cell(arch, shape_id, fn, (params, tokens), (p_sh, t_sh), None,
                    meta={"global_batch": b, "seq": s})

    assert spec["kind"] == "decode"
    caches = _lm_cache_abstract(cfg, b, s)
    c_sh = _axes_shardings(caches, _lm_cache_axes(cfg), mesh, rules)
    token = _sds((b, 1), jnp.int32)
    lengths = _sds((b,), jnp.int32)
    tok_sh = named_sharding_for((b, 1), ("batch", None), mesh, rules)
    len_sh = named_sharding_for((b,), ("batch",), mesh, rules)
    fn = functools.partial(_lm_decode, cfg=cfg)
    # Caches are donated (in-place update) and must come back unmoved.
    return Cell(arch, shape_id, fn, (params, caches, token, lengths),
                (p_sh, c_sh, tok_sh, len_sh), (None, c_sh, None),
                donate_argnums=(1,),
                meta={"global_batch": b, "kv_seq": s})


def _lm_loss(params, batch, cfg):
    return tfm.loss_fn(params, batch, cfg)


def _lm_prefill(params, tokens, cfg):
    return tfm.prefill(params, tokens, cfg)


def _lm_decode(params, caches, token, lengths, cfg):
    logits, new_caches, new_len = tfm.decode_step(
        params, caches, token, lengths, cfg)
    return logits, new_caches, new_len


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _gnn_cell(arch, shape_id, spec, mesh, rules, overrides=None) -> Cell:
    mod = registry.get_module(arch)
    cfg = mod.config(d_feat=spec["d_feat"], n_classes=spec["n_classes"])
    params = jax.eval_shape(lambda: gnn_mod.init(jax.random.PRNGKey(0), cfg))
    p_axes = gnn_mod.param_axes(cfg)
    p_sh = _axes_shardings(params, p_axes, mesh, rules)
    ocfg = optim.OptConfig()
    opt = jax.eval_shape(lambda: optim.init(params, ocfg))
    o_sh = _axes_shardings(opt, optim.opt_state_axes(p_axes), mesh, rules)
    n_dev = mesh.devices.size

    if spec["kind"] == "full":
        # Node/edge counts padded to the mesh size; the pipeline pads with
        # masked self-loop edges / mask-0 nodes (data/graphs.py).
        nn = _pad_to(spec["n_nodes"], n_dev)
        ne = _pad_to(spec["n_edges"], n_dev)
        batch = {"feats": _sds((nn, spec["d_feat"]), jnp.float32),
                 "edges": _sds((ne, 2), jnp.int32),
                 "labels": _sds((nn,), jnp.int32),
                 "mask": _sds((nn,), jnp.float32)}
        b_axes = {"feats": ("nodes", None), "edges": ("edges", None),
                  "labels": ("nodes",), "mask": ("nodes",)}
        loss = gnn_mod.loss_full
    elif spec["kind"] == "sampled":
        bn = spec["batch_nodes"]
        f1, f2 = spec["fanout"]
        d = spec["d_feat"]
        batch = {"seed_feats": _sds((bn, d), jnp.float32),
                 "h1": _sds((bn, f1, d), jnp.float32),
                 "h2": _sds((bn, f1, f2, d), jnp.float32),
                 "labels": _sds((bn,), jnp.int32)}
        b_axes = {"seed_feats": ("batch", None), "h1": ("batch", None, None),
                  "h2": ("batch", None, None, None), "labels": ("batch",)}
        loss = gnn_mod.loss_sampled
    else:  # molecule
        bsz, n = spec["batch"], spec["n_nodes"]
        batch = {"feats": _sds((bsz, n, spec["d_feat"]), jnp.float32),
                 "adj": _sds((bsz, n, n), jnp.float32),
                 "labels": _sds((bsz,), jnp.int32)}
        b_axes = {"feats": ("batch", None, None), "adj": ("batch", None, None),
                  "labels": ("batch",)}
        loss = gnn_mod.loss_molecule

    b_sh = _axes_shardings(batch, b_axes, mesh, rules)
    fn = steps.make_train_step(functools.partial(loss, cfg=cfg), ocfg)
    return Cell(arch, shape_id, fn, (params, opt, batch),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                donate_argnums=(0, 1), meta=dict(spec))


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_batch_spec(cfg, b: int):
    if cfg.kind in ("fm", "wide_deep"):
        batch = {"ids": _sds((b, cfg.n_fields), jnp.int32),
                 "labels": _sds((b,), jnp.float32)}
        axes = {"ids": ("batch", None), "labels": ("batch",)}
    else:
        batch = {"hist_ids": _sds((b, cfg.seq_len), jnp.int32),
                 "hist_mask": _sds((b, cfg.seq_len), jnp.bool_),
                 "target_ids": _sds((b,), jnp.int32),
                 "labels": _sds((b,), jnp.float32)}
        axes = {"hist_ids": ("batch", None), "hist_mask": ("batch", None),
                "target_ids": ("batch",), "labels": ("batch",)}
    return batch, axes


def _recsys_cell(arch, shape_id, spec, mesh, rules, overrides=None) -> Cell:
    mod = registry.get_module(arch)
    cfg = mod.config()
    params = jax.eval_shape(lambda: recsys_mod.init(jax.random.PRNGKey(0), cfg))
    p_axes = recsys_mod.param_axes(cfg)
    p_sh = _axes_shardings(params, p_axes, mesh, rules)

    if spec["kind"] == "train":
        ocfg = optim.OptConfig()
        opt = jax.eval_shape(lambda: optim.init(params, ocfg))
        o_sh = _axes_shardings(opt, optim.opt_state_axes(p_axes), mesh, rules)
        batch, b_axes = _recsys_batch_spec(cfg, spec["batch"])
        b_sh = _axes_shardings(batch, b_axes, mesh, rules)
        fn = steps.make_train_step(
            functools.partial(recsys_mod.loss_fn, cfg=cfg), ocfg)
        return Cell(arch, shape_id, fn, (params, opt, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    donate_argnums=(0, 1), meta=dict(spec))

    if spec["kind"] == "serve":
        batch, b_axes = _recsys_batch_spec(cfg, spec["batch"])
        batch.pop("labels")
        b_axes.pop("labels")
        b_sh = _axes_shardings(batch, b_axes, mesh, rules)
        fn = functools.partial(_recsys_forward, cfg=cfg)
        return Cell(arch, shape_id, fn, (params, batch), (p_sh, b_sh), None,
                    meta=dict(spec))

    assert spec["kind"] == "retrieval"
    user, u_axes = _recsys_batch_spec(cfg, spec["batch"])
    user.pop("labels")
    u_axes.pop("labels")
    if cfg.kind in ("fm", "wide_deep"):
        # The candidate occupies the item field: user context is F-1 wide.
        user["ids"] = _sds((spec["batch"], cfg.n_fields - 1), jnp.int32)
    u_sh = _axes_shardings(user, u_axes, mesh, rules)
    # Candidates shard over the whole mesh (like sketch-index records);
    # count padded to the mesh size (serving pads with a sentinel id).
    nc = _pad_to(spec["n_candidates"], mesh.devices.size)
    cand = _sds((nc,), jnp.int32)
    c_sh = named_sharding_for((nc,), ("records",), mesh, rules)
    fn = functools.partial(_recsys_retrieval, cfg=cfg)
    return Cell(arch, shape_id, fn, (params, user, cand),
                (p_sh, u_sh, c_sh), None,
                meta={**spec, "n_candidates_padded": nc})


def _recsys_forward(params, batch, cfg):
    return recsys_mod.forward(params, batch, cfg)


def _recsys_retrieval(params, user, cand, cfg):
    return recsys_mod.retrieval_scores(params, user, cand, cfg, chunked=False)


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

_FAMILY_BUILDERS = {
    "lm": _lm_cell,
    "gnn": _gnn_cell,
    "recsys": _recsys_cell,
}


def build_cell(arch: str, shape_id: str, mesh: Mesh, rules=None,
               overrides=None) -> Cell:
    """``rules`` overrides the logical-axis → mesh-axis mapping (the §Perf
    hillclimb knob); ``overrides`` carries per-cell knobs (microbatches,
    cfg_replace)."""
    fam = registry.family(arch)
    spec = FAMILY_SHAPES[fam][shape_id]
    rules = rules or DEFAULT_RULES
    cell = _FAMILY_BUILDERS[fam](arch, shape_id, spec, mesh, rules,
                                 overrides=overrides)
    cell.rules = rules
    return cell


def all_cells():
    """The 40 assigned (arch × shape) pairs."""
    out = []
    for arch in registry.ARCH_IDS:
        for shape_id in FAMILY_SHAPES[registry.family(arch)]:
            out.append((arch, shape_id))
    return out
