"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective analyses.

MUST be run as a script or module entry; the two lines below must execute
before ANY jax import (jax locks the device count at first init).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

# TPU v5e roofline constants (DESIGN.md §8).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device result bytes of every collective op in optimized HLO.

    Counts ``<op>`` and ``<op>-start`` (async) lines, never ``-done``.
    For all-reduce result==operand bytes; for all-gather the result is the
    gathered (larger) buffer — a conservative upper bound on wire bytes.
    """
    out = {k: 0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
                      r"([\w-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_KINDS and not op.endswith("-done"):
            out[base] += _shape_bytes(m.group(1))
            out["count"] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


def run_cell(arch: str, shape_id: str, multi_pod: bool, out_dir: str,
             rules=None, overrides=None, tag: str = "") -> dict:
    import jax
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
           "chips": int(n_chips), "ok": False, "tag": tag}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_id, mesh, rules=rules,
                          overrides=overrides)
        with mesh:
            lowered = cell.lower()
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis()
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}

        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text)
        rec["collectives"] = coll

        rec["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll["total"] / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom
        rec["meta"] = {k: v for k, v in cell.meta.items()
                       if isinstance(v, (int, float, str, bool, tuple, list))}
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_id}__{mesh_name}" + (f"__{tag}" if tag else "")
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if rec["ok"]:
        # Persist optimized HLO for the roofline multiplicity parser
        # (benchmarks/roofline.py re-weights while-loop bodies).
        import gzip
        with gzip.open(os.path.join(out_dir, stem + ".hlo.gz"), "wt") as f:
            f.write(hlo_text)
    return rec


def _spawn(arch, shape_id, multi_pod, out_dir, timeout=1800):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape_id, "--out", out_dir]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        return r.returncode, (r.stdout + r.stderr)[-800:]
    except subprocess.TimeoutExpired:
        return -1, "TIMEOUT"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every cell in subprocesses")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    if args.all:
        sys.path.insert(0, "src")
        from repro.launch.cells import all_cells
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = 0
        for arch, shape_id in all_cells():
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape_id}__{mesh_name}.json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"SKIP (done) {arch} {shape_id} {mesh_name}")
                            continue
                t0 = time.time()
                code, tail = _spawn(arch, shape_id, mp, args.out)
                ok = False
                if os.path.exists(path):
                    with open(path) as f:
                        ok = json.load(f).get("ok", False)
                status = "OK" if ok else f"FAIL(rc={code})"
                print(f"{status:10s} {arch:28s} {shape_id:15s} {mesh_name} "
                      f"{time.time()-t0:7.1f}s")
                if not ok:
                    failures += 1
                    print("  tail:", tail.replace("\n", " | ")[-400:])
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    print(json.dumps(rec, indent=1))
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
