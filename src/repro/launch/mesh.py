"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state).

Target: TPU v5e pods. Single-pod = 16×16 = 256 chips (data, model);
multi-pod = 2×16×16 = 512 chips (pod, data, model) — the "pod" axis
crosses the slow DCI links and carries only the DP gradient reduction
(optionally int8-compressed, parallel/compression.py).
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Mesh over the first prod(shape) visible devices."""
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — the dry-run "
            "launcher must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import (launch/dryrun.py does)")
    return compat.make_mesh(shape, axes, devices=np.array(devs[:n]))


def host_mesh(model: int = 1) -> Mesh:
    """1×model CPU mesh for tests/examples on the single real device."""
    return make_mesh((1, model), ("data", "model"))
