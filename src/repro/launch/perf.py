"""§Perf hillclimb runner: named sharding/knob variants for the three
chosen cells (+ the paper's own sketch-serving cell), each re-lowered and
re-analysed per the hypothesis → change → measure → validate loop.

MUST run as a fresh process (512-device flag below, before any jax import).

    PYTHONPATH=src python -m repro.launch.perf --cell qwen-train --variant dp256
    PYTHONPATH=src python -m repro.launch.perf --all
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import subprocess
import sys
import time

from repro.parallel.sharding import DEFAULT_RULES

OUT = "reports/perf"


def _rules(**kw):
    r = dict(DEFAULT_RULES)
    r.update(kw)
    return r


# ---------------------------------------------------------------------------
# variant registry — each entry: (arch, shape, rules, overrides)
# Hypotheses are recorded in EXPERIMENTS.md §Perf; this file is the
# executable record of the changes.
# ---------------------------------------------------------------------------

VARIANTS = {
    # ---- cell A: qwen3-0.6b × train_4k (worst LM roofline fraction) ----
    # H-A1: at d_model=1024, TP=16 all-reduces dwarf compute; converting
    # the model axis to extra data parallelism (batch over all axes,
    # vocab-TP kept for the unembed/xent) removes per-layer collectives.
    "qwen-train:baseline": ("qwen3-0.6b", "train_4k", None, None),
    "qwen-train:dp256": (
        "qwen3-0.6b", "train_4k",
        _rules(batch=(("pod", "data", "model"),), heads=(), kv_heads=(),
               ff=()),
        None),
    # H-A2: with 1 sequence/device there is nothing left to microbatch;
    # micro=1 cuts the FSDP weight re-gather ×8 → ×1.
    "qwen-train:dp256-micro1": (
        "qwen3-0.6b", "train_4k",
        _rules(batch=(("pod", "data", "model"),), heads=(), kv_heads=(),
               ff=()),
        {"microbatches": 1}),
    # H-A3 (A2 refuted by measurement: vocab-TP over "model" fights
    # batch-over-"model" on the logits → 19s of resharding gathers):
    # un-shard the vocab too; the replicated unembed is only 311 MB and
    # the xent becomes fully local.
    "qwen-train:dp256-micro1-novocab": (
        "qwen3-0.6b", "train_4k",
        _rules(batch=(("pod", "data", "model"),), heads=(), kv_heads=(),
               ff=(), vocab=()),
        {"microbatches": 1}),
    # Control: isolate the micro effect under the baseline TP sharding.
    "qwen-train:micro1": ("qwen3-0.6b", "train_4k", None,
                          {"microbatches": 1}),
    # H-A4 (A3 refuted: the 19s gather is the ACTIVATIONS — with batch on
    # ("data","model") and weights FSDP'd on "data", SPMD gathers x
    # instead of the weight slice): a 0.6B model doesn't need FSDP at
    # all on 16 GB chips — replicate weights+moments (≈7 GB), keep pure
    # DP-256; the only collective left is the gradient all-reduce.
    "qwen-train:pure-dp256": (
        "qwen3-0.6b", "train_4k",
        _rules(batch=(("pod", "data", "model"),), heads=(), kv_heads=(),
               ff=(), vocab=(), embed=(), expert_embed=()),
        {"microbatches": 1}),

    # ---- cell B: llama4 × train_4k (most collective-bound) ----
    # H-B1: collective term ∝ microbatches (FSDP expert-weight re-gather
    # per microbatch × {fwd, remat, bwd}); micro 8→4 halves it, carry
    # memory doubles (still fits with bf16 moments).
    "llama4-train:baseline": ("llama4-maverick-400b-a17b", "train_4k",
                              None, None),
    "llama4-train:micro4": ("llama4-maverick-400b-a17b", "train_4k", None,
                            {"microbatches": 4}),
    # H-B2: micro 8→2 → gather tax ÷4.
    "llama4-train:micro2": ("llama4-maverick-400b-a17b", "train_4k", None,
                            {"microbatches": 2}),
    # H-B3: move the expert FSDP shard from d_model to d_ff — weights
    # stay resident per-(expert-shard, ff-slice); whichever side XLA then
    # gathers (tokens ≈1.3 GB/layer vs weights ≈5.6 GB/layer) should cut
    # the gather term ~4×.
    "llama4-train:expert-ff-shard": (
        "llama4-maverick-400b-a17b", "train_4k",
        _rules(expert_embed=(), expert_ff=("data",)),
        {"microbatches": 4}),
    # H-B4: remat policy "dots" — saving GEMM outputs removes the
    # backward recompute pass, i.e. one of the three weight-gather
    # passes (-33% gather traffic) at the cost of activation memory.
    "llama4-train:micro4-dots": (
        "llama4-maverick-400b-a17b", "train_4k", None,
        {"microbatches": 4, "cfg_replace": {"remat_policy": "dots"}}),

    # ---- cell F (extra): moonshot × train_4k (collective-bound MoE,
    # same FSDP-gather pattern as llama4 — apply the validated recipe) --
    "moonshot-train:baseline": ("moonshot-v1-16b-a3b", "train_4k",
                                None, None),
    "moonshot-train:micro4": ("moonshot-v1-16b-a3b", "train_4k", None,
                              {"microbatches": 4}),
    "moonshot-train:micro2": ("moonshot-v1-16b-a3b", "train_4k", None,
                              {"microbatches": 2}),

    # ---- cell D (extra): qwen3 × long_500k (long-context decode) ----
    # H-D1: with batch=1 the data axis is idle; sharding the KV sequence
    # over BOTH axes (524288 % 256 == 0) cuts the per-device cache read
    # 16× → memory term ~16× down.
    "qwen-long:baseline": ("qwen3-0.6b", "long_500k", None, None),
    "qwen-long:seq-2d": (
        "qwen3-0.6b", "long_500k",
        _rules(kv_seq=(("data", "model"),)), None),

    # ---- cell E (extra): graphsage × ogb_products (collective-bound
    # full-graph: edge-sharded scatter into node-sharded features) ----
    # H-E1: shard the hidden feature dim over the (idle) model axis —
    # every halo gather/scatter payload splits 16× (hidden 128 % 16 == 0;
    # the input d_feat=100 dim stays unsharded via divisibility fallback).
    "gnn-prod:baseline": ("graphsage-reddit", "ogb_products", None, None),
    "gnn-prod:hidden-model": (
        "graphsage-reddit", "ogb_products",
        _rules(gnn_hidden=("model",)), None),
    # H-E2: align edge shards with node shards (drop the model axis from
    # edges) so scatter destinations are more local.
    "gnn-prod:edges-data": (
        "graphsage-reddit", "ogb_products",
        _rules(edges=(("pod", "data"),)), None),

    # ---- cell C: fm × retrieval_cand (paper-representative: candidate-
    # set scoring ≈ containment retrieval; collective-bound) ----
    # H-C1: the FM table is only 40 MB — vocab-sharding it buys nothing
    # and costs an all-gather per lookup; replicating it zeroes the
    # collective term (table placement policy: shard only when > HBM/8).
    "fm-retr:baseline": ("fm", "retrieval_cand", None, None),
    "fm-retr:replicated-table": (
        "fm", "retrieval_cand", _rules(table_vocab=()), None),
    # Same placement policy applied to the other collective-bound recsys
    # serving cell (wide-deep table = 128 MB, still replicable).
    "wd-bulk:baseline": ("wide-deep", "serve_bulk", None, None),
    "wd-bulk:replicated-table": (
        "wide-deep", "serve_bulk", _rules(table_vocab=()), None),
}


# ---------------------------------------------------------------------------
# The paper's own serving cell: GB-KMV batched scoring on the production
# mesh. m=1M records × capacity 64 (≈10% budget of a 640-element-average
# corpus), query batch Gq swept — the §Perf query-batching knob: one sweep
# of the sketch matrix amortized over Gq queries.
# ---------------------------------------------------------------------------

SKETCH_GQ = (1, 16, 128)


def run_sketch_cell(gq: int):
    import json as _json
    import time as _time

    import jax
    import jax.numpy as jnp

    from repro.launch.dryrun import (collective_bytes, ICI_BW, HBM_BW,
                                     PEAK_FLOPS)
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import named_sharding_for
    from repro.sketchindex.distributed import _scores_jnp

    mesh = make_production_mesh()
    m, cap, w, cq = 1_048_576, 64, 8, 64
    args = {
        "values": jax.ShapeDtypeStruct((m, cap), jnp.uint32),
        "lengths": jax.ShapeDtypeStruct((m,), jnp.int32),
        "thresh": jax.ShapeDtypeStruct((m,), jnp.uint32),
        "buf": jax.ShapeDtypeStruct((m, w), jnp.uint32),
        "q_values": jax.ShapeDtypeStruct((gq, cq), jnp.uint32),
        "q_thresh": jax.ShapeDtypeStruct((gq,), jnp.uint32),
        "q_buf": jax.ShapeDtypeStruct((gq, w), jnp.uint32),
        "q_sizes": jax.ShapeDtypeStruct((gq,), jnp.int32),
    }
    rows = lambda s: named_sharding_for(s, ("records",) + (None,) * (len(s) - 1),
                                        mesh)
    rep = lambda s: named_sharding_for(s, (None,) * len(s), mesh)
    shardings = {k: (rows(v.shape) if k in ("values", "lengths", "thresh",
                                            "buf") else rep(v.shape))
                 for k, v in args.items()}

    def fn(values, lengths, thresh, buf, q_values, q_thresh, q_buf, q_sizes):
        return _scores_jnp(values, lengths, thresh, buf,
                           q_values, q_thresh, q_buf, q_sizes)

    rec = {"arch": "gbkmv-index", "shape": f"serve_gq{gq}",
           "mesh": "pod16x16", "chips": int(mesh.devices.size), "ok": False,
           "tag": f"sketch_gq{gq}"}
    t0 = _time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=tuple(
            shardings[k] for k in args)).lower(*args.values())
        compiled = lowered.compile()
    rec["compile_s"] = round(_time.time() - t0, 2)
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    rec["memory"] = {"argument_bytes": int(ma.argument_size_in_bytes),
                     "temp_bytes": int(ma.temp_size_in_bytes),
                     "peak_bytes_est": int(ma.argument_size_in_bytes
                                           + ma.output_size_in_bytes
                                           + ma.temp_size_in_bytes)}
    rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                   "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    rec["collectives"] = coll
    rec["roofline"] = {
        "compute_s": rec["cost"]["flops"] / PEAK_FLOPS,
        "memory_s": rec["cost"]["bytes_accessed"] / HBM_BW,
        "memory_s_per_query": rec["cost"]["bytes_accessed"] / HBM_BW / gq,
        "collective_s": coll["total"] / ICI_BW,
    }
    rec["ok"] = True
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"gbkmv-index__serve_gq{gq}.json"), "w") as f:
        _json.dump(rec, f, indent=1)
    return rec


def run_variant(name: str):
    from repro.launch.dryrun import run_cell

    if name.startswith("sketch-serve:gq"):
        return run_sketch_cell(int(name.split("gq")[1]))
    arch, shape, rules, overrides = VARIANTS[name]
    tag = name.replace(":", "_")
    return run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                    rules=rules, overrides=overrides, tag=tag)


def analyze(name: str) -> dict | None:
    """Roofline terms of a finished variant (weighted HLO parse)."""
    sys.path.insert(0, ".")
    from benchmarks.hlo_parse import analyze_hlo_file

    arch, shape, _, _ = VARIANTS[name]
    tag = name.replace(":", "_")
    stem = os.path.join(OUT, f"{arch}__{shape}__pod16x16__{tag}")
    if not os.path.exists(stem + ".json"):
        return None
    with open(stem + ".json") as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return {"variant": name, "ok": False, "error": rec.get("error")}
    w = analyze_hlo_file(stem + ".hlo.gz")
    return {
        "variant": name, "ok": True,
        "compute_s": w["flops_weighted"] / 197e12,
        "memory_s": w["bytes_weighted"] / 819e9,
        "collective_s": w["collectives_weighted"]["total"] / 50e9,
        "peak_gb": rec["memory"]["peak_bytes_est"] / 1e9,
        "compile_s": rec.get("compile_s"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()

    if args.report:
        print(f"{'variant':34s} {'compute':>9s} {'memory':>9s} "
              f"{'collective':>11s} {'bound':>10s} {'peak':>7s}")
        for name in VARIANTS:
            r = analyze(name)
            if r is None:
                continue
            if not r["ok"]:
                print(f"{name:34s} ERROR {r['error'][:60]}")
                continue
            terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                     "collective": r["collective_s"]}
            dom = max(terms, key=terms.get)
            print(f"{name:34s} {r['compute_s']:9.3f} {r['memory_s']:9.3f} "
                  f"{r['collective_s']:11.3f} {dom:>10s} {r['peak_gb']:6.1f}G")
        for gq in SKETCH_GQ:
            path = os.path.join(OUT, f"gbkmv-index__serve_gq{gq}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            rl = rec["roofline"]
            print(f"{'sketch-serve:gq%d' % gq:34s} {rl['compute_s']:9.5f} "
                  f"{rl['memory_s']:9.5f} {rl['collective_s']:11.5f} "
                  f"{'memory':>10s}  per-query mem "
                  f"{rl['memory_s_per_query']:.5f}s")
        return

    if args.all:
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        names = list(VARIANTS) + [f"sketch-serve:gq{g}" for g in SKETCH_GQ]
        for name in names:
            t0 = time.time()
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.perf", "--variant", name],
                capture_output=True, text=True, env=env, timeout=1800)
            ok = "OK" if r.returncode == 0 else "FAIL"
            print(f"{ok:5s} {name:34s} {time.time()-t0:7.1f}s", flush=True)
            if r.returncode:
                print(r.stdout[-400:], r.stderr[-400:])
        return

    rec = run_variant(args.variant)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1))
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
