"""Serving driver: batched containment-similarity search over a GB-KMV
index (the paper's serving path) OR LM prefill+decode, by family.

``python -m repro.launch.serve --mode sketch --dataset NETFLIX``
``python -m repro.launch.serve --mode lm --arch qwen3-0.6b --reduced``

DEPRECATED for ``--mode sketch``: the sketch path is now a thin shim
over the service layer (``repro.service.launch`` — HTTP endpoints,
admission control, metrics). Use

    PYTHONPATH=src python -m repro.service.launch [--port ... --rounds N]

directly; this entry point forwards the shared flags and will be
removed once downstream scripts migrate.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as tfm


def serve_sketch(args):
    """Shim → ``repro.service.launch`` smoke mode (real HTTP stack)."""
    from repro.service import launch as service_launch

    print("[serve] DEPRECATED: --mode sketch now delegates to "
          "repro.service.launch (HTTP service layer); invoke it directly "
          "for the full flag surface.")
    argv = ["--dataset", args.dataset, "--scale", str(args.scale),
            "--mesh", args.mesh, "--backend", args.backend,
            "--batch", str(args.batch), "--rounds", str(max(args.rounds, 1)),
            "--topk", str(args.topk),
            "--max-inflight", str(args.max_inflight),
            "--port", str(args.port)]
    if args.rate_limit is not None:
        argv += ["--rate-limit", str(args.rate_limit)]
    service_launch.main(argv)


def serve_lm(args):
    mod = registry.get_module(args.arch)
    cfg = mod.reduced() if args.reduced else mod.config()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = args.batch, args.seq
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg))
    logits, caches = prefill(params, toks)
    caches = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, args.decode_steps)] + [(0, 0)] * 2),
        caches)
    decode = jax.jit(lambda p, c, t, ln: tfm.decode_step(p, c, t, ln, cfg))
    lengths = jnp.full((b,), s, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, caches, lengths = decode(params, caches, tok, lengths)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve-lm] {cfg.name}: prefill[{b}x{s}] + {args.decode_steps} decode "
          f"steps → {b * args.decode_steps / dt:.1f} tok/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sketch", "lm"), default="sketch")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--dataset", default="NETFLIX")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--backend", default="jnp",
                    choices=("numpy", "jnp", "pallas"))
    # Service-layer passthrough flags (sketch mode shim).
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--max-inflight", type=int, default=256)
    ap.add_argument("--rate-limit", type=float, default=None)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "sketch":
        serve_sketch(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
