"""Serving driver: batched containment-similarity search over a GB-KMV
index (the paper's serving path) OR LM prefill+decode, by family.

``python -m repro.launch.serve --mode sketch --dataset NETFLIX``
``python -m repro.launch.serve --mode lm --arch qwen3-0.6b --reduced``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import registry
from repro.data import datasets, synth
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.sketchindex import ShardedIndex


def serve_sketch(args):
    mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")),
                     ("data", "model"))
    recs = datasets.load(args.dataset, scale=args.scale)
    total = sum(len(r) for r in recs)
    index = api.get_engine("gbkmv").build(recs, int(total * 0.1), seed=0,
                                          backend=args.backend)
    sharded = ShardedIndex(index, mesh, backend=args.backend)
    queries = synth.make_query_workload(recs, args.batch * args.rounds)
    print(f"[serve] {args.dataset}: m={len(recs)} index={index.nbytes()/1e6:.1f}MB "
          f"buffer_bits={index.core.buffer_bits}")

    lat = []
    for r in range(args.rounds):
        qs = queries[r * args.batch:(r + 1) * args.batch]
        t0 = time.time()
        results = sharded.serve_batch(qs, 0.5, args.topk)
        lat.append(time.time() - t0)
        if r == 0:
            print(f"[serve] round0 top1 scores: "
                  f"{[round(float(x['topk_scores'][0]), 3) for x in results[:4]]}")
    lat = np.asarray(lat) * 1e3
    print(f"[serve] batched {args.batch} queries/round: "
          f"p50={np.percentile(lat, 50):.1f}ms p99={np.percentile(lat, 99):.1f}ms "
          f"({args.batch / (np.mean(lat) / 1e3):.0f} q/s)")


def serve_lm(args):
    mod = registry.get_module(args.arch)
    cfg = mod.reduced() if args.reduced else mod.config()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = args.batch, args.seq
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg))
    logits, caches = prefill(params, toks)
    caches = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, args.decode_steps)] + [(0, 0)] * 2),
        caches)
    decode = jax.jit(lambda p, c, t, ln: tfm.decode_step(p, c, t, ln, cfg))
    lengths = jnp.full((b,), s, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, caches, lengths = decode(params, caches, tok, lengths)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve-lm] {cfg.name}: prefill[{b}x{s}] + {args.decode_steps} decode "
          f"steps → {b * args.decode_steps / dt:.1f} tok/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sketch", "lm"), default="sketch")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--dataset", default="NETFLIX")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--backend", default="jnp",
                    choices=("numpy", "jnp", "pallas"))
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "sketch":
        serve_sketch(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
