"""Index-construction driver: build a GB-KMV index over a (synthetic
Table II) dataset, demonstrate the distributed τ reduction, and persist
the packed sketches + metadata for the serving path.

``python -m repro.launch.sketch_build --dataset ENRON --budget-frac 0.1``
"""

from __future__ import annotations

import argparse
import os
import time

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.hashing import hash_u32_np
from repro.data import datasets
from repro.launch.mesh import make_mesh
from repro.sketchindex.build import distributed_tau, histogram_tau


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="NETFLIX",
                    choices=sorted(datasets.SPECS))
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--budget-frac", type=float, default=0.1)
    ap.add_argument("--buffer", default="auto")
    ap.add_argument("--out", default="reports/indexes")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--build-backend", default="numpy",
                    choices=("numpy", "jnp", "pallas"),
                    help="construction path: host vectorized (numpy) or "
                         "the fused device hash→τ→pack computation")
    ap.add_argument("--tau-mode", default="exact",
                    choices=("exact", "histogram"),
                    help="τ selector: exact partition, or the two-level "
                         "histogram refine (within 2^8 of exact — the "
                         "distributed reduction's semantics)")
    ap.add_argument("--eager-postings", action="store_true",
                    help="encode the block-compressed postings from the "
                         "packed columns at build time (build → query "
                         "with no first-query inversion)")
    args = ap.parse_args()

    recs = datasets.load(args.dataset, scale=args.scale)
    total = sum(len(r) for r in recs)
    budget = max(int(total * args.budget_frac), 64)

    # Distributed τ (histogram psum) vs the exact host quantile.
    mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")),
                     ("data", "model"))
    allh = np.concatenate([hash_u32_np(r) for r in recs])
    pad = -(-len(allh) // mesh.devices.size) * mesh.devices.size
    allh_p = np.pad(allh, (0, pad - len(allh)),
                    constant_values=np.uint32(0xFFFFFFFF))
    t0 = time.time()
    tau_d = int(distributed_tau(jnp.asarray(allh_p), budget, mesh, ("data",)))
    t_dist = time.time() - t0
    tau_h = int(histogram_tau(jnp.asarray(allh), budget))
    assert tau_d == tau_h, "distributed τ must match the single-device hist"
    print(f"[tau] budget={budget} τ_hist=0x{tau_d:08x} ({t_dist*1e3:.1f}ms, "
          f"2 psums of 16KB — node-count independent)")

    r = args.buffer if args.buffer == "auto" else int(args.buffer)
    build_backend = None if args.build_backend == "numpy" else args.build_backend
    t0 = time.time()
    index = api.get_engine("gbkmv").build(
        recs, budget, r=r, build_backend=build_backend,
        tau_mode=args.tau_mode,
        postings="eager" if args.eager_postings else "lazy")
    build_s = time.time() - t0
    s = index.core.sketches
    print(f"[build] m={len(recs)} elements={total} → sketch "
          f"{index.nbytes()/1e6:.2f}MB (cap={s.capacity}, buffer r="
          f"{index.core.buffer_bits}) in {build_s:.2f}s "
          f"({len(recs)/max(build_s, 1e-9):,.0f} rec/s, "
          f"{total/max(build_s, 1e-9):,.0f} elem/s; "
          f"path={args.build_backend}, tau={args.tau_mode})")

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.dataset}.npz")
    index.save(path)                      # api npz round-trip (load_index)
    print(f"[build] saved → {path}")


if __name__ == "__main__":
    main()
