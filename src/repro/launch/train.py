"""Production training driver: ``python -m repro.launch.train --arch <id>``.

Wires every substrate together: config registry → model init (sharded) →
AdamW → microbatched train step (pjit) → checkpoint/restart → straggler
monitor → optional int8-compressed DP gradient sync (shard_map mode).

On this CPU container run it with ``--reduced`` (smoke-scale configs);
on a pod the same flags drive the full configs. Elastic restart: rerun
with a different --mesh after a checkpoint exists — restore reshards.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import synth
from repro.data.pipeline import BatchCursor, dedup_corpus, token_batches
from repro.ft import checkpoint as ckpt_mod
from repro.ft.elastic import plan_remesh
from repro.ft.straggler import StragglerMonitor
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.parallel.sharding import tree_shardings_for
from repro.train import optim, steps


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return make_mesh(dims, axes)


def _train_non_lm(args, fam: str):
    """GNN / recsys training loops (synthetic data, same substrate:
    AdamW + jit step + straggler monitor + checkpointing)."""
    import functools

    from repro.ft.straggler import StragglerMonitor

    mod = registry.get_module(args.arch)
    rng = np.random.default_rng(args.seed)
    if fam == "gnn":
        from repro.data.graphs import powerlaw_graph
        from repro.models import gnn as gnn_mod

        cfg = mod.reduced() if args.reduced else mod.config()
        g = powerlaw_graph(n_nodes=300, n_edges=1500, d_feat=cfg.d_feat,
                           n_classes=cfg.n_classes, seed=args.seed)
        batch = {k: jnp.asarray(v) for k, v in g.items()}
        params = gnn_mod.init(jax.random.PRNGKey(args.seed), cfg)
        loss = functools.partial(gnn_mod.loss_full, cfg=cfg)
        batch_fn = lambda step: batch                   # full-batch
    else:
        from repro.models import recsys as recsys_mod

        cfg = mod.reduced() if args.reduced else mod.config()
        params = recsys_mod.init(jax.random.PRNGKey(args.seed), cfg)
        loss = functools.partial(recsys_mod.loss_fn, cfg=cfg)

        def batch_fn(step):
            r = np.random.default_rng(args.seed * 7919 + step)
            b = args.batch
            if cfg.kind in ("fm", "wide_deep"):
                return {"ids": jnp.asarray(
                            r.integers(0, cfg.vocab_rows, (b, cfg.n_fields)),
                            jnp.int32),
                        "labels": jnp.asarray(r.integers(0, 2, b), jnp.float32)}
            return {"hist_ids": jnp.asarray(
                        r.integers(0, cfg.vocab_rows, (b, cfg.seq_len)),
                        jnp.int32),
                    "hist_mask": jnp.asarray(r.integers(0, 2, (b, cfg.seq_len)),
                                             bool),
                    "target_ids": jnp.asarray(
                        r.integers(0, cfg.vocab_rows, (b,)), jnp.int32),
                    "labels": jnp.asarray(r.integers(0, 2, b), jnp.float32)}

    ocfg = optim.OptConfig(lr=args.lr, warmup_steps=args.steps // 10 + 1,
                           total_steps=args.steps)
    opt_state = optim.init(params, ocfg)
    step_fn = jax.jit(steps.make_train_step(loss, ocfg), donate_argnums=(0, 1))
    mon = StragglerMonitor()
    for step in range(args.steps):
        t0 = time.time()
        params, opt_state, met = step_fn(params, opt_state, batch_fn(step))
        mon.record(time.time() - t0)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(met['loss']):.4f} "
                  f"({(time.time()-t0)*1e3:.0f} ms)")
    if args.ckpt_dir:
        ckpt_mod.save_checkpoint(args.ckpt_dir, args.steps,
                                 {"params": params, "opt": opt_state})
    print(f"[train:{fam}] done; final loss {float(met['loss']):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--mesh", default="1x1", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dedup", action="store_true",
                    help="GB-KMV near-dup filter on the corpus first")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fam = registry.family(args.arch)
    if fam != "lm":
        return _train_non_lm(args, fam)

    mod = registry.get_module(args.arch)
    cfg = mod.reduced() if args.reduced else mod.config()
    mesh = parse_mesh(args.mesh)
    print(f"[train] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    plan = plan_remesh(mesh, args.batch * args.micro,
                       per_device_batch=max(args.batch // max(
                           mesh.shape.get("data", 1) * mesh.shape.get("pod", 1), 1), 1))
    print(f"[train] remesh plan: {plan.notes}")

    # --- data: synthetic corpus (+ optional GB-KMV dedup stage) ---
    recs = synth.generate_dataset(m=200, n_elems=max(cfg.vocab - 1, 500),
                                  alpha_freq=1.1, alpha_size=2.0,
                                  size_min=32, size_max=256, seed=args.seed)
    docs = [np.asarray(r) % cfg.vocab for r in recs]
    if args.dedup:
        kept, stats = dedup_corpus(docs, threshold=0.8)
        print(f"[data] GB-KMV dedup: {stats}")
        docs = [docs[i] for i in kept]
    cursor = BatchCursor(seed=args.seed)
    stream = token_batches(docs, args.batch, args.seq, cursor)

    # --- state (sharded) ---
    ocfg = optim.OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                           total_steps=args.steps)
    p_axes = tfm.param_axes(cfg)
    abstract = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(args.seed), cfg))
    p_sh = tree_shardings_for(abstract, p_axes, mesh)
    with mesh:
        params = jax.jit(lambda: tfm.init(jax.random.PRNGKey(args.seed), cfg),
                         out_shardings=p_sh)()
        opt_state = optim.init(params, ocfg)

    start_step = 0
    if args.resume and args.ckpt_dir and ckpt_mod.latest_step(args.ckpt_dir) is not None:
        state, manifest = ckpt_mod.restore_checkpoint(
            args.ckpt_dir, target={"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = manifest["step"]
        cursor.step = manifest["extra"].get("cursor_step", start_step)
        print(f"[ckpt] resumed at step {start_step} (resharded onto this mesh)")

    step_fn = jax.jit(
        steps.make_train_step(
            functools.partial(lambda p, b, c: tfm.loss_fn(p, b, c), c=cfg),
            ocfg, microbatches=args.micro),
        donate_argnums=(0, 1))

    mon = StragglerMonitor()
    with mesh:
        for step in range(start_step, args.steps):
            batch = next(stream)
            t0 = time.time()
            params, opt_state, met = step_fn(
                params, opt_state,
                {k: jnp.asarray(v) for k, v in batch.items()})
            met = {k: float(v) for k, v in met.items()}
            dt = time.time() - t0
            status = mon.record(dt)
            if status != "ok":
                print(f"[straggler] step {step}: {status} "
                      f"({dt:.2f}s vs mean {mon.mean:.2f}s) → {mon.action(status)}")
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {met['loss']:.4f} "
                      f"gnorm {met['grad_norm']:.2f} {dt*1e3:.0f}ms")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt_mod.save_checkpoint(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"cursor_step": cursor.step, "seed": args.seed})
    if args.ckpt_dir:
        ckpt_mod.save_checkpoint(
            args.ckpt_dir, args.steps, {"params": params, "opt": opt_state},
            extra={"cursor_step": cursor.step, "seed": args.seed})
        print(f"[ckpt] final checkpoint at step {args.steps}")
    print("[train] done; final loss", met["loss"])


if __name__ == "__main__":
    main()
