"""GQA attention: chunked-causal (train/prefill), cached decode.

Memory discipline for long context (DESIGN.md §4):
  * train/prefill: ``lax.scan`` over query chunks with online softmax
    (flash-attention algorithm in pure JAX) — peak score buffer is
    [B, H, chunk_q, S] instead of [B, H, S, S];
  * decode: one query token against a KV cache whose *sequence* dim may be
    mesh-sharded ("kv_seq" logical axis) — the softmax reductions over the
    sharded S lower to tiny all-reduces, giving sequence-parallel decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import _current_mesh, constrain

NEG_INF = -2.0e38


def _flat_heads(hq: int) -> bool:
    """Score-layout choice (EXPERIMENTS.md §Perf cell B):

    * flat [B,Hq,T,S] when Hq divides the model axis — heads shard
      cleanly and the Hq↔(Hkv,G) reshape sits OUTSIDE the sharded region;
    * grouped [B,Hkv,G,T,S] otherwise — XLA pads+gathers a reshaped
      non-divisible head dim (measured 12.4 TB/device/step on llama4).
    """
    mesh = _current_mesh()
    msize = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    return hq % msize == 0


def _gqa_scores(q, k):
    """q [B,T,Hq,D], k [B,S,Hkv,D] -> GROUPED scores [B,Hkv,G,T,S] (f32).

    Scores stay in the grouped layout end-to-end (softmax is over the
    last axis either way). Reshaping Hkv·G ↔ (Hkv, G) between sharded ops
    blocks SPMD propagation — XLA falls back to a full all-gather of the
    [B,H,T,S] tensor per attention chunk (measured: 12.4 TB/device/step
    on llama4 train_4k — EXPERIMENTS.md §Perf cell B).
    """
    b, t, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, d)
    return jnp.einsum("bthgd,bshd->bhgts", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p [B,Hkv,G,T,S] (f32), v [B,S,Hkv,D] -> [B,T,Hq,D]."""
    b, hkv, g, t, s = p.shape
    o = jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v)
    return o.reshape(b, t, hkv * g, v.shape[3])


def causal_attention(q, k, v, *, chunk_q: int = 512, scale: float | None = None):
    """Causal self-attention, online-softmax over query chunks.

    q [B,S,Hq,D], k/v [B,S,Hkv,D] -> [B,S,Hq,D].
    """
    b, s, hq, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    if s <= chunk_q:
        scores = _gqa_scores(q * scale, k)       # [B,Hkv,G,S,S]
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(p, v)

    assert s % chunk_q == 0, (s, chunk_q)
    n_chunks = s // chunk_q
    q_chunks = (q * scale).reshape(b, n_chunks, chunk_q, hq, d)
    kpos = jnp.arange(s)

    flat = _flat_heads(hq)

    def body(_, qc_i):
        qc, i = qc_i                                        # [B,cq,Hq,D]
        scores = _gqa_scores(qc, k)                         # [B,Hkv,G,cq,S]
        # Keep SPMD from replicating the scores transient inside the
        # remat-recomputed backward: flat layout shards heads→model when
        # Hq divides; grouped layout avoids the pad+gather otherwise.
        if flat:
            b_, hkv_, g_, t_, s_ = scores.shape
            scores = scores.reshape(b_, hkv_ * g_, t_, s_)
            scores = constrain(scores, ("batch", "heads", None, None))
            scores = scores.reshape(b_, hkv_, g_, t_, s_)
        else:
            scores = constrain(scores,
                               ("batch", "kv_heads", None, None, None))
        qpos = i * chunk_q + jnp.arange(chunk_q)
        mask = kpos[None, :] <= qpos[:, None]               # [cq, S]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return None, _gqa_out(p, v)                         # [B,cq,Hq,D]

    # Remat per chunk: backward recomputes each chunk's [cq, S] scores
    # instead of stacking them across the chunk scan (flash-attention
    # memory discipline; the [B,Hq,cq,S] probs never persist).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = lax.scan(body, None,
                       (jnp.moveaxis(q_chunks, 1, 0), jnp.arange(n_chunks)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, d)


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """One-token decode vs a (possibly sequence-sharded) KV cache.

    q [B,1,Hq,D]; k/v_cache [B,S,Hkv,D]; lengths i32[B] = live cache fill
    (the new token is already written at index lengths-1).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    scores = _gqa_scores(q * scale, k_cache)               # [B,Hkv,G,1,S]
    spos = jnp.arange(k_cache.shape[1])
    mask = spos[None, :] < lengths[:, None]                # [B,S]
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p, v_cache)                            # [B,1,Hq,D]
