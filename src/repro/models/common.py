"""Shared model substrate: norms, rotary embeddings, initializers, losses.

Functional style throughout: params are nested dicts of jnp arrays; every
model module exposes ``init(rng, cfg) -> params``, a matching
``param_axes(cfg)`` tree of *logical* sharding axes (parallel/sharding.py),
and pure ``apply`` functions. Layer stacks are scan-ready ([L, ...] leading
dim) so compile size is O(1) in depth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32
                                                ).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
#   mode "full":    rotate the whole head dim (llama / qwen style)
#   mode "2d":      rotate only the first half of the head dim (chatglm's
#                   2D-RoPE: half carries rotary position, half is NoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, rope_dim: int, base: float = 10000.0):
    exponent = jnp.arange(0, rope_dim, 2, dtype=jnp.float32) / rope_dim
    return 1.0 / (base ** exponent)                      # [rope_dim/2]


def apply_rope(x, positions, mode: str = "full", base: float = 10000.0):
    """x [..., T, H, D]; positions [..., T] int32."""
    d = x.shape[-1]
    rope_dim = d if mode == "full" else d // 2
    inv = rope_frequencies(d, rope_dim, base)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., T, rope_dim/2]
    sin = jnp.sin(ang)[..., :, None, :]                      # [..., T, 1, rd/2]
    cos = jnp.cos(ang)[..., :, None, :]

    rot, rest = x[..., :rope_dim], x[..., rope_dim:]
    r1, r2 = jnp.split(rot, 2, axis=-1)
    rotated = jnp.concatenate(
        [r1 * cos - r2 * sin, r2 * cos + r1 * sin], axis=-1)
    out = jnp.concatenate([rotated, rest], axis=-1) if rest.shape[-1] else rotated
    return out.astype(x.dtype)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """Token-mean xent; logits [..., V] (vocab may be mesh-sharded — the
    reductions below lower to cheap all-reduces of [...]-shaped partials)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
