"""Sparse-embedding substrate for the recsys family.

JAX has no native EmbeddingBag and no CSR/CSC sparse (BCOO only), so the
lookup path is built from first principles (kernel taxonomy §RecSys):

  * ``embedding_lookup``   — plain row gather (``jnp.take``); the table's
    vocab dim carries the "table_vocab" logical axis → row-sharded over
    "model" at scale (XLA SPMD partitions the gather: local masked lookup
    + all-reduce of the partial rows).
  * ``embedding_bag``      — multi-hot / variable-length bags:
    ``jnp.take`` + ``jax.ops.segment_sum`` over a flat (indices, segments)
    stream — this IS the EmbeddingBag op, not a stub.

Hashing multi-field categorical ids into one physical table keeps one big
10⁶–10⁹-row tensor per model (realistic industrial layout) instead of 40
small ones; field offsets de-alias the key spaces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup(table, ids):
    """table [V, D] (vocab row-sharded); ids i32[...] -> [..., D]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table, indices, segments, num_segments: int, combiner: str = "sum"):
    """EmbeddingBag from first principles: gather + segment-reduce.

    Args:
      table:        [V, D]
      indices:      i32[Nnz]   flat row ids across all bags
      segments:     i32[Nnz]   bag id of each index (ascending not required)
      num_segments: number of bags (static)
      combiner:     "sum" | "mean" | "max"

    Returns [num_segments, D].
    """
    rows = jnp.take(table, indices, axis=0)                   # [Nnz, D]
    if combiner == "max":
        return jax.ops.segment_max(rows, segments, num_segments)
    out = jax.ops.segment_sum(rows, segments, num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segments, jnp.float32), segments, num_segments)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def field_offsets(field_vocabs):
    """Cumulative offsets hashing per-field ids into one shared table."""
    import numpy as np
    offs = np.zeros(len(field_vocabs), dtype=np.int64)
    offs[1:] = np.cumsum(field_vocabs)[:-1]
    return offs


def fielded_lookup(table, ids, offsets):
    """ids i32[B, F] per-field ids; offsets i32[F] -> [B, F, D]."""
    return embedding_lookup(table, ids + offsets[None, :].astype(ids.dtype))
