"""GraphSAGE (Hamilton et al., arXiv:1706.02216) — mean aggregator.

Three execution regimes (kernel taxonomy §GNN — SpMM regime):
  * full-graph:   message passing via ``jax.ops.segment_sum`` over an
                  edge index (src→dst scatter). JAX has no CSR SpMM; the
                  segment-sum formulation IS the SpMM here.
  * minibatch:    layer-wise sampled neighborhoods (fanout f1-f2) — dense
                  gathers + mean over the fanout axis (the real neighbor
                  sampler lives in data/sampler.py).
  * molecule:     batched small dense graphs — normalized adjacency matmul.

Distribution: nodes row-sharded over ("pod","data"); edges sharded over all
axes with destination-sorted partitions; the per-layer feature gather is
the halo-exchange-degenerate all-gather (DESIGN.md §4) — deliberately the
collective-bound roofline cell.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    aggregator: str = "mean"
    sample_sizes: tuple = (25, 10)
    dtype: str = "float32"


def init(key, cfg: GNNConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    params = {}
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        params[f"w_self_{i}"] = common.truncated_normal(
            k1, (dims[i], dims[i + 1]), dims[i] ** -0.5, jnp.dtype(cfg.dtype))
        params[f"w_neigh_{i}"] = common.truncated_normal(
            k2, (dims[i], dims[i + 1]), dims[i] ** -0.5, jnp.dtype(cfg.dtype))
    return params


def param_axes(cfg: GNNConfig):
    return {k: (None, "ff") if k.endswith("0") or True else (None, None)
            for k in [f"w_{s}_{i}" for s in ("self", "neigh")
                      for i in range(cfg.n_layers)]}


def _layer(h_self, h_neigh, w_self, w_neigh, last: bool):
    out = h_self @ w_self + h_neigh @ w_neigh
    return out if last else jax.nn.relu(out)


def forward_full(params, feats, edges, cfg: GNNConfig):
    """feats [N, F]; edges i32[E, 2] (src, dst) -> logits [N, classes].

    Activations carry ("nodes", "gnn_hidden") — by default the hidden dim
    is unsharded; flipping "gnn_hidden"→model (§Perf cell E) splits every
    halo gather/scatter payload across the model axis.
    """
    n = feats.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    deg = jnp.maximum(
        jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst, n), 1.0)
    h = feats
    for i in range(cfg.n_layers):
        h = constrain(h, ("nodes", "gnn_hidden"))
        msgs = jnp.take(h, src, axis=0)                     # gather (halo)
        msgs = constrain(msgs, ("edges", "gnn_hidden"))
        agg = jax.ops.segment_sum(msgs, dst, n) / deg[:, None]
        h = _layer(h, agg, params[f"w_self_{i}"], params[f"w_neigh_{i}"],
                   last=(i == cfg.n_layers - 1))
    return h


def forward_sampled(params, seed_feats, hop_feats, cfg: GNNConfig):
    """Layer-wise sampled forward (2-layer case).

    seed_feats [B, F]; hop_feats = (h1 [B, f1, F], h2 [B, f1, f2, F]).
    Aggregation proceeds bottom-up: hop2 → hop1 → seeds.
    """
    h1, h2 = hop_feats
    # layer 0 applied at depth-1 nodes (needs their hop-2 neighborhoods)
    agg2 = h2.mean(axis=2)                                  # [B, f1, F]
    d1 = _layer(h1, agg2, params["w_self_0"], params["w_neigh_0"], last=False)
    # and at the seeds (their hop-1 neighborhoods)
    agg1 = h1.mean(axis=1)                                  # [B, F]
    d0 = _layer(seed_feats, agg1, params["w_self_0"], params["w_neigh_0"],
                last=False)
    # layer 1 at the seeds, aggregating the depth-1 activations
    agg = d1.mean(axis=1)                                   # [B, d_hidden]
    return _layer(d0, agg, params["w_self_1"], params["w_neigh_1"], last=True)


def forward_molecule(params, feats, adj, cfg: GNNConfig):
    """Batched small graphs. feats [B, n, F]; adj f32[B, n, n] (0/1)."""
    deg = jnp.maximum(adj.sum(-1, keepdims=True), 1.0)
    h = feats
    for i in range(cfg.n_layers):
        agg = (adj @ h) / deg
        h = _layer(h, agg, params[f"w_self_{i}"], params[f"w_neigh_{i}"],
                   last=(i == cfg.n_layers - 1))
    return h.mean(axis=1)                                   # graph readout


def _masked_xent(logits, labels, mask):
    """Per-node xent with a validity mask (mesh-padding support)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per = (lse - ll) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_full(params, batch, cfg: GNNConfig):
    """Full-graph xent. Optional batch["mask"] f32[N] marks real nodes
    (padding to the mesh size adds mask-0 nodes / self-loop edges)."""
    logits = forward_full(params, batch["feats"], batch["edges"], cfg)
    mask = batch.get("mask")
    if mask is None:
        return common.softmax_cross_entropy(logits, batch["labels"]), {}
    return _masked_xent(logits, batch["labels"], mask), {}


def loss_sampled(params, batch, cfg: GNNConfig):
    logits = forward_sampled(params, batch["seed_feats"],
                             (batch["h1"], batch["h2"]), cfg)
    return common.softmax_cross_entropy(logits, batch["labels"]), {}


def loss_molecule(params, batch, cfg: GNNConfig):
    logits = forward_molecule(params, batch["feats"], batch["adj"], cfg)
    return common.softmax_cross_entropy(logits, batch["labels"]), {}
