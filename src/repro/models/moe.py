"""Mixture-of-Experts block: top-k routing, capacity, gather-based dispatch
(GShard semantics, sparse-dispatch implementation).

Instead of the classic [tokens, E, C] one-hot dispatch einsum (which is the
memory hog at scale), dispatch/combine are expressed as gathers/scatters:

  sources[e, c]  — which token fills expert e's c-th slot (scatter of ids)
  expert_in      — x gathered at sources                 [G, E, C, D]
  expert FFN     — dense batched GEMMs over the E dim (experts mesh-sharded
                   over "model"; XLA inserts the all-to-all)
  combine        — h gathered back per (token, k) slot, weighted by gates

Tokens over capacity are dropped (gate 0), per GShard. Router runs in f32;
an auxiliary load-balance loss (Switch-style) is returned to the caller.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden
    every: int = 1                  # MoE every N-th layer (2 = interleaved)
    shared_expert: bool = False     # llama4-style always-on shared FFN
    capacity_factor: float = 1.25
    group_size: int = 4096          # tokens per routing group


def moe_params_shape(cfg, d_model: int):
    e, f = cfg.num_experts, cfg.d_ff
    return {
        "router": (d_model, e),
        "w_gate": (e, d_model, f),
        "w_up": (e, d_model, f),
        "w_down": (e, f, d_model),
    }


def moe_param_axes():
    return {
        "router": ("stack", "embed", None),
        "w_gate": ("stack", "experts", "expert_embed", None),
        "w_up": ("stack", "experts", "expert_embed", None),
        "w_down": ("stack", "experts", None, "expert_embed"),
    }


def moe_block(x, p, cfg: MoEConfig):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    gs = min(cfg.group_size, t)
    while t % gs:
        gs //= 2
    g = t // gs
    xg = tokens.reshape(g, gs, d)

    e, k = cfg.num_experts, cfg.top_k
    cap = max(int(gs * k * cfg.capacity_factor / e), 4)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [g, gs, E]
    gates, eidx = lax.top_k(probs, k)                          # [g, gs, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): E * Σ_e fraction_e · mean-prob_e.
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.float32)        # [g, gs, K, E]
    frac = onehot.sum(2).mean(1)                               # [g, E]
    aux = (e * (frac * probs.mean(1)).sum(-1)).mean()

    # Position of each (token, k) within its expert (first-come priority).
    flat_oh = onehot.reshape(g, gs * k, e)
    pos = jnp.cumsum(flat_oh, axis=1) - 1.0                    # [g, gs*K, E]
    pos = (pos * flat_oh).sum(-1).astype(jnp.int32)            # [g, gs*K]
    eflat = eidx.reshape(g, gs * k)
    keep = pos < cap
    slot = eflat * cap + pos                                   # [g, gs*K]
    slot = jnp.where(keep, slot, e * cap)                      # overflow bin

    # sources[e*cap + c] = token index (scatter; overflow bin dropped).
    tok_ids = jnp.broadcast_to(jnp.arange(gs)[:, None], (gs, k)).reshape(gs * k)
    sources = jnp.zeros((g, e * cap + 1), jnp.int32)
    sources = jax.vmap(lambda srcs, sl: srcs.at[sl].set(tok_ids))(sources, slot)
    filled = jnp.zeros((g, e * cap + 1), bool)
    filled = jax.vmap(lambda f, sl: f.at[sl].set(True))(filled, slot)

    expert_in = jnp.take_along_axis(
        xg, sources[:, : e * cap, None], axis=1)                # [g, E*cap, D]
    expert_in = jnp.where(filled[:, : e * cap, None], expert_in, 0.0)
    expert_in = expert_in.reshape(g, e, cap, d)
    # groups→data (batch-major), experts→model; XLA inserts the all-to-all.
    expert_in = constrain(expert_in, ("batch", "experts", None, None))

    h = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = jax.nn.silu(h) * u
    h = constrain(h, ("batch", "experts", None, None))
    h = jnp.einsum("gecf,efd->gecd", h, p["w_down"])            # [g,E,cap,D]

    # Combine: gather each (token, k)'s expert output, weight by gate.
    hflat = h.reshape(g, e * cap, d)
    gathered = jnp.take_along_axis(
        hflat, jnp.minimum(slot, e * cap - 1)[:, :, None], axis=1)
    w = (gates.reshape(g, gs * k) * keep.astype(gates.dtype))[:, :, None]
    contrib = (gathered * w.astype(gathered.dtype)).reshape(g, gs, k, d)
    y = contrib.sum(2).reshape(b, s, d).astype(x.dtype)
    return y, aux
