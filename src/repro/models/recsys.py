"""RecSys family: FM, Wide&Deep, DIN, MIND (kernel taxonomy §RecSys).

Shared anatomy: one huge hashed embedding table (vocab row-sharded over
"model" via the "table_vocab" logical axis) → feature interaction
(FM 2-way / concat / target-attention / multi-interest capsules) → small
MLP head. The lookup is the hot path and runs through
models/embedding.py's take+segment_sum EmbeddingBag substrate.

``retrieval_scores`` scores one user against ``n_cand`` candidates as
chunked batched compute (lax.scan over candidate chunks, each chunk fully
vectorized) — never a per-candidate python loop.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common
from repro.models.embedding import embedding_lookup, fielded_lookup


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                       # "fm" | "wide_deep" | "din" | "mind"
    embed_dim: int
    n_fields: int = 0               # sparse fields (fm / wide_deep)
    seq_len: int = 0                # behaviour history (din / mind)
    vocab_rows: int = 1_000_000     # physical table rows (hashed)
    mlp: Sequence[int] = ()         # deep-head hidden dims
    attn_mlp: Sequence[int] = ()    # din target-attention hidden dims
    n_interests: int = 0            # mind
    capsule_iters: int = 0          # mind
    dtype: str = "float32"
    cand_chunk: int = 8192          # retrieval scoring chunk


def _mlp_init(key, dims, dtype):
    ws = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        ws[f"w{i}"] = common.truncated_normal(k1, (a, b), a ** -0.5, dtype)
        ws[f"b{i}"] = jnp.zeros((b,), dtype)
    return ws


def _mlp_apply(ws, x, n_layers: int, final_act: bool = False):
    for i in range(n_layers):
        x = x @ ws[f"w{i}"] + ws[f"b{i}"]
        if i < n_layers - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _mlp_axes(dims):
    axes = {}
    for i in range(len(dims) - 1):
        axes[f"w{i}"] = (None, None)
        axes[f"b{i}"] = (None,)
    return axes


def init(key, cfg: RecSysConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.embed_dim
    p = {"table": common.truncated_normal(
        jax.random.fold_in(key, 0), (cfg.vocab_rows, d), 0.01, dtype)}

    if cfg.kind == "fm":
        p["linear"] = jnp.zeros((cfg.vocab_rows,), dtype)
        p["bias"] = jnp.zeros((), dtype)
    elif cfg.kind == "wide_deep":
        p["wide"] = jnp.zeros((cfg.vocab_rows,), dtype)
        dims = [cfg.n_fields * d, *cfg.mlp, 1]
        p["deep"] = _mlp_init(jax.random.fold_in(key, 1), dims, dtype)
    elif cfg.kind == "din":
        att_dims = [4 * d, *cfg.attn_mlp, 1]
        p["attn"] = _mlp_init(jax.random.fold_in(key, 1), att_dims, dtype)
        head_dims = [2 * d, *cfg.mlp, 1]
        p["head"] = _mlp_init(jax.random.fold_in(key, 2), head_dims, dtype)
    elif cfg.kind == "mind":
        p["route_s"] = common.truncated_normal(
            jax.random.fold_in(key, 1), (d, d), d ** -0.5, dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def param_axes(cfg: RecSysConfig):
    axes = {"table": ("table_vocab", None)}
    if cfg.kind == "fm":
        axes["linear"] = ("table_vocab",)
        axes["bias"] = ()
    elif cfg.kind == "wide_deep":
        axes["wide"] = ("table_vocab",)
        axes["deep"] = _mlp_axes([cfg.n_fields * cfg.embed_dim, *cfg.mlp, 1])
    elif cfg.kind == "din":
        axes["attn"] = _mlp_axes([4 * cfg.embed_dim, *cfg.attn_mlp, 1])
        axes["head"] = _mlp_axes([2 * cfg.embed_dim, *cfg.mlp, 1])
    elif cfg.kind == "mind":
        axes["route_s"] = (None, None)
    return axes


# ---------------------------------------------------------------------------
# interactions
# ---------------------------------------------------------------------------

def _fm_logit(p, ids):
    """FM 2-way via the O(nk) sum-square trick: ½(‖Σv‖² − Σ‖v‖²)."""
    v = embedding_lookup(p["table"], ids)                    # [B, F, D]
    s = v.sum(axis=1)                                        # [B, D]
    pair = 0.5 * (jnp.square(s) - jnp.square(v).sum(axis=1)).sum(axis=-1)
    lin = jnp.take(p["linear"], ids, axis=0).sum(axis=1)
    return p["bias"] + lin + pair


def _wide_deep_logit(p, ids, cfg):
    v = embedding_lookup(p["table"], ids)                    # [B, F, D]
    deep = _mlp_apply(p["deep"], v.reshape(v.shape[0], -1),
                      len(cfg.mlp) + 1)[:, 0]
    wide = jnp.take(p["wide"], ids, axis=0).sum(axis=1)
    return wide + deep


def _din_attend(p, hist_e, mask, target_e, cfg):
    """Target attention: weights from MLP([h, t, h−t, h·t]) (DIN eq. 3)."""
    t = jnp.broadcast_to(target_e[:, None, :], hist_e.shape)
    feats = jnp.concatenate([hist_e, t, hist_e - t, hist_e * t], axis=-1)
    w = _mlp_apply(p["attn"], feats, len(cfg.attn_mlp) + 1)[..., 0]  # [B, L]
    w = jnp.where(mask, w, 0.0)           # DIN keeps raw weights (no softmax)
    return (w[..., None] * hist_e).sum(axis=1)               # [B, D]


def _din_logit(p, hist_ids, hist_mask, target_ids, cfg):
    hist_e = embedding_lookup(p["table"], hist_ids)          # [B, L, D]
    hist_e = hist_e * hist_mask[..., None].astype(hist_e.dtype)
    target_e = embedding_lookup(p["table"], target_ids)      # [B, D]
    pooled = _din_attend(p, hist_e, hist_mask, target_e, cfg)
    x = jnp.concatenate([pooled, target_e], axis=-1)
    return _mlp_apply(p["head"], x, len(cfg.mlp) + 1)[:, 0]


def _squash(v, axis=-1, eps=1e-9):
    n2 = jnp.square(v).sum(axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + eps)


def _mind_interests(p, hist_ids, hist_mask, cfg):
    """B2I dynamic routing → K interest capsules [B, K, D]."""
    e = embedding_lookup(p["table"], hist_ids)               # [B, L, D]
    mask = hist_mask.astype(e.dtype)[..., None]
    e = e * mask
    u = e @ p["route_s"]                                     # shared bilinear map
    b, l, d = u.shape
    k = cfg.n_interests
    # Fixed (non-trainable) random logit init, per the MIND paper.
    logits0 = jax.random.normal(jax.random.PRNGKey(17), (1, l, k), u.dtype)
    logits = jnp.broadcast_to(logits0, (b, l, k))

    def routing_iter(logits, _):
        w = jax.nn.softmax(logits, axis=-1) * mask           # [B, L, K]
        z = jnp.einsum("blk,bld->bkd", w, u)
        v = _squash(z)                                       # [B, K, D]
        logits_new = logits + jnp.einsum("bld,bkd->blk", u, v)
        return logits_new, v

    logits, vs = lax.scan(routing_iter, logits, None, length=cfg.capsule_iters)
    return vs[-1]                                            # last iteration's capsules


def _mind_logit(p, hist_ids, hist_mask, target_ids, cfg):
    interests = _mind_interests(p, hist_ids, hist_mask, cfg)  # [B, K, D]
    t = embedding_lookup(p["table"], target_ids)              # [B, D]
    # Label-aware attention (pow 2) at train; hard-max at serving.
    att = jnp.einsum("bkd,bd->bk", interests, t)
    w = jax.nn.softmax(jnp.square(att), axis=-1)
    user = jnp.einsum("bk,bkd->bd", w, interests)
    return jnp.einsum("bd,bd->b", user, t)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: RecSysConfig):
    """batch → logits f32[B]. Field layouts per kind (see configs/)."""
    if cfg.kind == "fm":
        return _fm_logit(params, batch["ids"])
    if cfg.kind == "wide_deep":
        return _wide_deep_logit(params, batch["ids"], cfg)
    if cfg.kind == "din":
        return _din_logit(params, batch["hist_ids"], batch["hist_mask"],
                          batch["target_ids"], cfg)
    if cfg.kind == "mind":
        return _mind_logit(params, batch["hist_ids"], batch["hist_mask"],
                           batch["target_ids"], cfg)
    raise ValueError(cfg.kind)


def loss_fn(params, batch, cfg: RecSysConfig):
    """Binary cross-entropy with logits; labels f32[B] ∈ {0, 1}."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"bce": loss}


def retrieval_scores(params, user_batch, cand_ids, cfg: RecSysConfig,
                     *, chunked: bool = True):
    """Score ONE user context against n_cand candidates → f32[n_cand].

    Vectorized per chunk; scan over chunks keeps the peak intermediate at
    [chunk, ...] instead of [n_cand, ...] (e.g. DIN's [n_cand, L, 4D]).
    ``chunked=False`` scores all candidates in one vectorized pass — the
    mesh-sharded serving path (candidates sharded over every axis), where
    the per-device slice IS the chunk.
    """
    n = cand_ids.shape[0]
    if chunked:
        chunk = min(cfg.cand_chunk, n)
        assert n % chunk == 0, (n, chunk)
        chunks = cand_ids.reshape(n // chunk, chunk)

    if cfg.kind == "mind":
        interests = _mind_interests(
            params, user_batch["hist_ids"], user_batch["hist_mask"], cfg)[0]

        def body(_, ids):
            c = embedding_lookup(params["table"], ids)        # [chunk, D]
            s = jnp.max(c @ interests.T, axis=-1)             # hard-max over K
            return None, s
    elif cfg.kind == "din":
        hist_e = embedding_lookup(params["table"], user_batch["hist_ids"])
        hist_m = user_batch["hist_mask"]
        hist_e = hist_e * hist_m[..., None].astype(hist_e.dtype)

        def body(_, ids):
            c = embedding_lookup(params["table"], ids)        # [chunk, D]
            he = jnp.broadcast_to(hist_e, (ids.shape[0],) + hist_e.shape[1:])
            hm = jnp.broadcast_to(hist_m, (ids.shape[0],) + hist_m.shape[1:])
            pooled = _din_attend(params, he, hm, c, cfg)
            x = jnp.concatenate([pooled, c], axis=-1)
            return None, _mlp_apply(params["head"], x, len(cfg.mlp) + 1)[:, 0]
    elif cfg.kind == "fm":
        # User context = first F-1 fields; candidate fills the item field.
        # The user part of the FM score is candidate-independent: s_u = Σ v_f.
        u_ids = user_batch["ids"][0, : cfg.n_fields - 1]
        v_u = embedding_lookup(params["table"], u_ids)        # [F-1, D]
        s_u = v_u.sum(axis=0)

        def body(_, ids):
            c = embedding_lookup(params["table"], ids)
            lin = jnp.take(params["linear"], ids, axis=0)
            return None, c @ s_u + lin + params["bias"]
    elif cfg.kind == "wide_deep":
        u_ids = user_batch["ids"][0, : cfg.n_fields - 1]      # [F-1]

        def body(_, ids):
            full = jnp.concatenate(
                [jnp.broadcast_to(u_ids[None], (ids.shape[0], u_ids.shape[0])),
                 ids[:, None]], axis=1)
            return None, _wide_deep_logit(params, full, cfg)
    else:
        raise ValueError(cfg.kind)

    if not chunked:
        return body(None, cand_ids)[1]
    _, scores = lax.scan(body, None, chunks)
    return scores.reshape(n)
