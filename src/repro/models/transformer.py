"""Decoder-only transformer LM family (dense + MoE), scan-over-layers.

Covers the five assigned LM architectures:
  qwen3-0.6b      — GQA + qk-norm, RoPE full
  stablelm-12b    — GQA, RoPE full
  chatglm3-6b     — GQA (kv=2), 2D-RoPE (rotary on half the head dim)
  llama4-maverick — interleaved MoE (every 2nd layer) + shared expert, top-1
  moonshot-v1-16b — all-MoE, 64 experts top-6

Params are nested dicts with [L, ...]-stacked layer weights; ``param_axes``
mirrors the tree with logical sharding axes (parallel/sharding.py):
TP over "model" (heads / ff / vocab / experts), FSDP over "data" (params'
d_model dim), DP over ("pod","data") for activations.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common
from repro.models.attention import causal_attention, decode_attention
from repro.models.moe import MoEConfig, moe_block, moe_param_axes
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_mode: str = "full"            # "full" | "2d"
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    dense_d_ff: Optional[int] = None   # dense-layer FFN width when interleaved
    dtype: str = "bfloat16"
    chunk_q: int = 512
    remat: bool = True
    remat_policy: str = "nothing"      # "nothing" | "dots" (§Perf B4)
    aux_loss_coef: float = 0.01

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_plan(self):
        """(n_dense_blocks, n_moe_blocks, interleaved?)"""
        if self.moe is None:
            return self.n_layers, 0, False
        if self.moe.every == 1:
            return 0, self.n_layers, False
        assert self.n_layers % self.moe.every == 0
        n_pairs = self.n_layers // self.moe.every
        return n_pairs * (self.moe.every - 1), n_pairs, True


# ---------------------------------------------------------------------------
# init / param_axes
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: LMConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    shapes = {
        "ln1": (d,), "ln2": (d,),
        "wq": (d, hq, hd), "wk": (d, hkv, hd), "wv": (d, hkv, hd),
        "wo": (hq, hd, d),
    }
    if cfg.qk_norm:
        shapes["qn"] = (hd,)
        shapes["kn"] = (hd,)
    return shapes


def _attn_axes(cfg: LMConfig):
    axes = {
        "ln1": ("stack", None), "ln2": ("stack", None),
        "wq": ("stack", "embed", "heads", None),
        "wk": ("stack", "embed", "kv_heads", None),
        "wv": ("stack", "embed", "kv_heads", None),
        "wo": ("stack", "heads", None, "embed"),
    }
    if cfg.qk_norm:
        axes["qn"] = ("stack", None)
        axes["kn"] = ("stack", None)
    return axes


def _mlp_shapes(d: int, f: int):
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


_MLP_AXES = {
    "w_gate": ("stack", "embed", "ff"),
    "w_up": ("stack", "embed", "ff"),
    "w_down": ("stack", "ff", "embed"),
}


def _stack_init(key, shapes: dict, n: int, dtype, scale: float):
    out = {}
    for i, (name, shp) in enumerate(sorted(shapes.items())):
        if len(shp) == 1:                      # norm scales start at 0 (rms 1+s)
            out[name] = jnp.zeros((n,) + shp, dtype)
        else:
            fan_in = shp[0] if len(shp) == 2 else shp[0] * (shp[1] if name == "wo" else 1)
            k = jax.random.fold_in(key, i)
            out[name] = common.truncated_normal(
                k, (n,) + shp, scale / (fan_in ** 0.5), dtype)
    return out


def init(key, cfg: LMConfig):
    dtype = jnp.dtype(cfg.dtype)
    nd, nm, _ = cfg.layer_plan()
    d = cfg.d_model
    params = {
        "embed": common.truncated_normal(jax.random.fold_in(key, 0),
                                         (cfg.vocab, d), 0.02, dtype),
        "unembed": common.truncated_normal(jax.random.fold_in(key, 1),
                                           (d, cfg.vocab), d ** -0.5, dtype),
        "final_ln": jnp.zeros((d,), dtype),
    }
    if nd:
        dense_ff = cfg.dense_d_ff or cfg.d_ff
        shapes = {**_attn_shapes(cfg), **_mlp_shapes(d, dense_ff)}
        params["dense"] = _stack_init(jax.random.fold_in(key, 2), shapes, nd, dtype, 1.0)
    if nm:
        m = cfg.moe
        shapes = {
            **_attn_shapes(cfg),
            "router": (d, m.num_experts),
            "e_gate": (m.num_experts, d, m.d_ff),
            "e_up": (m.num_experts, d, m.d_ff),
            "e_down": (m.num_experts, m.d_ff, d),
        }
        if m.shared_expert:
            shapes.update({f"s_{k}": v for k, v in _mlp_shapes(d, m.d_ff).items()})
        params["moe"] = _stack_init(jax.random.fold_in(key, 3), shapes, nm, dtype, 1.0)
    return params


def param_axes(cfg: LMConfig):
    nd, nm, _ = cfg.layer_plan()
    axes = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_ln": (None,),
    }
    if nd:
        axes["dense"] = {**_attn_axes(cfg), **_MLP_AXES}
    if nm:
        moe_axes = {
            **_attn_axes(cfg),
            "router": ("stack", "embed", None),
            "e_gate": ("stack", "experts", "expert_embed", "expert_ff"),
            "e_up": ("stack", "experts", "expert_embed", "expert_ff"),
            "e_down": ("stack", "experts", "expert_ff", "expert_embed"),
        }
        if cfg.moe.shared_expert:
            moe_axes.update({f"s_{k}": v for k, v in _MLP_AXES.items()})
        axes["moe"] = moe_axes
    return axes


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _project_qkv(x, p, cfg: LMConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = common.rms_norm(q, p["qn"])
        k = common.rms_norm(k, p["kn"])
    q = common.apply_rope(q, positions, mode=cfg.rope_mode)
    k = common.apply_rope(k, positions, mode=cfg.rope_mode)
    return q, k, v


def _attn_block(x, p, cfg: LMConfig, positions):
    # Re-pin activation sharding at every block boundary so SPMD keeps
    # batch→data / heads→model through the remat-recompute graphs.
    x = constrain(x, ("batch", None, None))
    h = common.rms_norm(x, p["ln1"])
    q, k, v = _project_qkv(h, p, cfg, positions)
    q = constrain(q, ("batch", None, "heads", None))
    o = causal_attention(q, k, v, chunk_q=cfg.chunk_q)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def _mlp(h, p, prefix=""):
    g = jnp.einsum("bsd,df->bsf", h, p[prefix + "w_gate"])
    u = jnp.einsum("bsd,df->bsf", h, p[prefix + "w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p[prefix + "w_down"])


def _dense_layer(x, p, cfg: LMConfig, positions):
    x, kv = _attn_block(x, p, cfg, positions)
    x = x + _mlp(common.rms_norm(x, p["ln2"]), p)
    return x, kv, jnp.float32(0.0)


def _moe_layer(x, p, cfg: LMConfig, positions):
    x, kv = _attn_block(x, p, cfg, positions)
    h = common.rms_norm(x, p["ln2"])
    moe_p = {"router": p["router"], "w_gate": p["e_gate"],
             "w_up": p["e_up"], "w_down": p["e_down"]}
    y, aux = moe_block(h, moe_p, cfg.moe)
    if cfg.moe.shared_expert:
        y = y + _mlp(h, p, prefix="s_")
    return x + y, kv, aux


def _remat_policy(cfg: LMConfig):
    """"nothing": recompute everything (min memory, re-gathers FSDP
    weights in backward); "dots": save matmul outputs (no recompute of
    GEMMs → no second weight gather, more activation memory) — §Perf B4."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _scan_blocks(x, params, cfg: LMConfig, positions, collect_kv: bool):
    """Run all layers via lax.scan (interleaving dense/MoE when configured)."""
    nd, nm, interleaved = cfg.layer_plan()
    aux_total = jnp.float32(0.0)
    kvs = {}

    def run(kind, x, stacked, aux_total):
        layer_fn = _dense_layer if kind == "dense" else _moe_layer

        def body(carry, lp):
            xc, aux = carry
            xn, kv, a = layer_fn(xc, lp, cfg, positions)
            y = kv if collect_kv else None
            return (xn, aux + a), y

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        (x, aux_total), ys = lax.scan(body, (x, aux_total), stacked)
        return x, aux_total, ys

    if interleaved:
        # dense / moe alternate: scan over pairs with both param stacks.
        def pair_body(carry, lp):
            xc, aux = carry
            xc, kv_d, _ = _dense_layer(xc, lp["d"], cfg, positions)
            xc, kv_m, a = _moe_layer(xc, lp["m"], cfg, positions)
            ys = (kv_d, kv_m) if collect_kv else None
            return (xc, aux + a), ys

        if cfg.remat:
            pair_body = jax.checkpoint(pair_body, policy=_remat_policy(cfg))
        (x, aux_total), ys = lax.scan(
            pair_body, (x, aux_total), {"d": params["dense"], "m": params["moe"]})
        if collect_kv:
            kvs = {"dense": ys[0], "moe": ys[1]}
    else:
        if nd:
            x, aux_total, ys = run("dense", x, params["dense"], aux_total)
            if collect_kv:
                kvs["dense"] = ys
        if nm:
            x, aux_total, ys = run("moe", x, params["moe"], aux_total)
            if collect_kv:
                kvs["moe"] = ys
    return x, aux_total, kvs


def forward(params, tokens, cfg: LMConfig, *, positions=None, collect_kv=False):
    """tokens i32[B,S] -> (logits f32→dtype [B,S,V], aux, kv caches)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)
    x, aux, kvs = _scan_blocks(x, params, cfg, positions, collect_kv)
    x = common.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return logits, aux, kvs


def loss_fn(params, batch, cfg: LMConfig):
    """Next-token xent + MoE aux loss. batch = {tokens, labels} i32[B,S]."""
    logits, aux, _ = forward(params, batch["tokens"], cfg)
    loss = common.softmax_cross_entropy(logits, batch["labels"])
    return loss + cfg.aux_loss_coef * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: LMConfig):
    """Full-sequence forward returning last-token logits + stacked KV caches.

    Cache trees: {"dense": (k, v), "moe": (k, v)} with k/v [L*, B, S, Hkv, hd].
    """
    logits, _, kvs = forward(params, tokens, cfg, collect_kv=True)
    return logits[:, -1], kvs


def decode_step(params, caches, token, lengths, cfg: LMConfig):
    """One-token decode. token i32[B,1]; lengths i32[B] = cache fill.

    Returns (logits [B, V], updated caches, lengths+1). The caches' seq dim
    carries the "kv_seq" logical axis → sequence-parallel decode.
    """
    b = token.shape[0]
    positions = lengths[:, None]
    x = jnp.take(params["embed"], token, axis=0)            # [B,1,D]
    nd, nm, interleaved = cfg.layer_plan()

    def one_layer(x, lp, cache_kv, kind):
        p = lp
        h = common.rms_norm(x, p["ln1"])
        q, k, v = _project_qkv(h, p, cfg, positions)
        kc = cache_kv[0].at[jnp.arange(b), lengths].set(k[:, 0])
        vc = cache_kv[1].at[jnp.arange(b), lengths].set(v[:, 0])
        o = decode_attention(q, kc, vc, lengths + 1)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        h2 = common.rms_norm(x, p["ln2"])
        if kind == "dense":
            x = x + _mlp(h2, p)
        else:
            moe_p = {"router": p["router"], "w_gate": p["e_gate"],
                     "w_up": p["e_up"], "w_down": p["e_down"]}
            y, _ = moe_block(h2, moe_p, cfg.moe)
            if cfg.moe.shared_expert:
                y = y + _mlp(h2, p, prefix="s_")
            x = x + y
        return x, (kc, vc)

    new_caches = {}
    if interleaved:
        def body(x, lp_cache):
            lp, (cd, cm) = lp_cache
            x, cd2 = one_layer(x, lp["d"], cd, "dense")
            x, cm2 = one_layer(x, lp["m"], cm, "moe")
            return x, (cd2, cm2)
        x, ys = lax.scan(body, x, ({"d": params["dense"], "m": params["moe"]},
                                   (caches["dense"], caches["moe"])))
        new_caches = {"dense": ys[0], "moe": ys[1]}
    else:
        kind = "dense" if nd else "moe"
        stacked = params[kind]

        def body(x, lp_cache):
            lp, c = lp_cache
            x, c2 = one_layer(x, lp, c, kind)
            return x, c2
        x, ys = lax.scan(body, x, (stacked, caches[kind]))
        new_caches[kind] = ys

    x = common.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
    return logits, new_caches, lengths + 1


def cache_axes(cfg: LMConfig):
    """Logical axes of one KV cache tensor [L, B, S, Hkv, hd]."""
    return ("stack", "batch", "kv_seq", "kv_heads", None)
