"""Observability for the serving → planner → kernel stack.

Three pieces, all stdlib+numpy only:

* :mod:`repro.obs.trace` — nestable-span request tracing with a bounded
  ring buffer and Chrome trace-event export, plus the thread-local
  observation context (``attach`` / ``stage``) instrumented library code
  records into without signature changes.
* :mod:`repro.obs.explain` — per-query plan explain built from
  ``QueryPlan`` / ``CandidateSet`` internals.
* :mod:`repro.obs.profile` — per-stage latency histograms
  (:class:`StageProfiler`), cost-model drift accounting
  (:class:`CostDrift`), and the gated ``jax.profiler`` wrapper.

Off-by-default-cheap: with no context attached, ``stage()`` is a shared
no-op; the serving bench gates end-to-end tracing overhead at ≤5% QPS.
"""

from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Span,
    Trace,
    Tracer,
    attach,
    chrome_events,
    current_profiler,
    current_trace,
    stage,
)
from repro.obs.explain import build_explain, cost_fields  # noqa: F401
from repro.obs.profile import (  # noqa: F401
    CostDrift,
    StageProfiler,
    device_profile,
)


def device_pipeline_stats() -> dict:
    """Snapshot of the fused device-pipeline counters — jit compile-cache
    calls/compiles/cache_hits, staging-pool reuse/alloc, and the live
    signature/buffer gauges. Imported lazily so ``repro.obs`` stays
    importable (and the /metrics scrape path stays cheap) without pulling
    the planner's jax stack in."""
    from repro.planner import device as planner_device

    return planner_device.pipeline_stats()


__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Trace", "Span",
    "attach", "stage", "current_trace", "current_profiler", "chrome_events",
    "build_explain", "cost_fields",
    "StageProfiler", "CostDrift", "device_profile",
    "device_pipeline_stats",
]
