"""Per-query plan explain: the planner's decision surfaced as data.

PR 4 built the machinery — :class:`repro.planner.QueryPlan` (probe
tallies, cost estimates, block counts) and
:class:`repro.planner.prune.CandidateSet` (candidates generated, bound
prunes, blocks decoded vs header-skipped) — but never exposed it per
query. ``build_explain`` turns those internals plus a wall-clock
measurement into one JSON-able dict per query, the payload behind
``batch_query(..., explain=True)`` and the service's ``explain=true``.

Schema (pruned path):

    plan, reason, engine, backend, threshold
    cost:        est_dense / est_pruned (units), predicted_units,
                 seconds_per_unit (calibration, if installed),
                 predicted_seconds, measured_seconds (batch wall time),
                 drift (predicted/measured, None uncalibrated)
    probe_hits:  posting entries this query's probe touched
    candidates / pruned:       CandidateSet.rec_ids size / bound prunes
    blocks / skipped_blocks:   blocks decoded vs header-skipped
    tau:         postings retained-hash threshold (unit interval)
    ub_max / ub_mean:          containment upper bounds over candidates
    hits:        final result size
    batch:       batch-level decision totals (hits/blocks/tail splits)

The dense path reports ONLY plan/reason/engine/backend/threshold/cost/
hits — no planner fields, because no probe or candidate generation ran.
The block accounting is the host filter's view (the header-bound skip of
prune.candidates_for); the device path executes every probed tail block
without that skip, so explain on a device backend reruns the host
accounting — EXPLAIN ANALYZE semantics: asking costs extra, answers
don't change.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["cost_fields", "build_explain"]

_TWO32 = float(2**32)


def _f(v) -> float | None:
    """NaN/inf-free float for JSON (None when not finite)."""
    v = float(v)
    return v if math.isfinite(v) else None


def _seconds_per_unit() -> float | None:
    from repro.core import cost_model

    cal = cost_model.calibration()
    if cal:
        spu = cal.get("fit", {}).get("seconds_per_unit")
        if spu:
            return float(spu)
    return None


def cost_fields(decision, measured_seconds: float | None = None) -> dict:
    """Predicted-vs-measured cost block from a QueryPlan decision."""
    est_dense = _f(decision.est_dense)
    est_pruned = _f(decision.est_pruned)
    predicted = est_pruned if decision.path == "pruned" else est_dense
    spu = _seconds_per_unit()
    predicted_s = (predicted * spu
                   if predicted is not None and spu is not None else None)
    drift = None
    if predicted_s is not None and measured_seconds:
        drift = predicted_s / measured_seconds
    return {
        "est_dense": est_dense,
        "est_pruned": est_pruned,
        "predicted_units": predicted,
        "seconds_per_unit": spu,
        "predicted_seconds": predicted_s,
        "measured_seconds": _f(measured_seconds)
        if measured_seconds is not None else None,
        "drift": _f(drift) if drift is not None else None,
    }


def _tau_of(posts) -> float | None:
    """Postings retained-hash threshold as a unit-interval float (max
    over shards: the loosest τ bounds what any shard retains)."""
    if posts is None:
        return None
    if not isinstance(posts, (list, tuple)):
        posts = [posts]
    taus = [float(p.tau) for p in posts if p is not None]
    return max(taus) / _TWO32 if taus else None


def _ub_stats(cand, hash_row, q_size: int) -> tuple[float | None, float | None]:
    """(max, mean) containment upper bound over a query's candidates —
    the exact bound the filter thresholds on."""
    n = len(cand.rec_ids)
    if n == 0:
        return None, None
    from repro.planner import prune

    bound = prune.tail_bound(np.sort(np.asarray(hash_row, np.uint32)))
    ub = (cand.o1.astype(np.float64)
          + bound[np.minimum(cand.counts, len(bound) - 1)]) \
        / max(int(q_size), 1) * prune._BOUND_SLACK
    return float(ub.max()), float(ub.mean())


def build_explain(
    decision,
    *,
    engine: str = "",
    backend: str = "",
    threshold: float | None = None,
    n_queries: int = 1,
    hits=None,
    cands=None,
    hash_rows=None,
    sizes=None,
    posts=None,
    measured_seconds: float | None = None,
) -> list[dict]:
    """One explain dict per query in the batch.

    ``decision`` is the batch's QueryPlan. For the pruned path pass
    ``cands`` (per-query CandidateSets), ``hash_rows``/``sizes`` (for
    upper-bound stats), and ``posts`` (for τ); the dense path needs none
    of them and emits no planner fields.
    """
    cost = cost_fields(decision, measured_seconds)
    base = {
        "plan": decision.path,
        "reason": decision.reason,
        "engine": engine,
        "backend": backend,
        "threshold": _f(threshold) if threshold is not None else None,
        "cost": cost,
    }
    out = []
    for g in range(n_queries):
        e = dict(base)
        e["cost"] = dict(cost)
        if hits is not None:
            e["hits"] = int(len(hits[g]))
        if decision.path != "pruned":
            out.append(e)
            continue
        if decision.per_query_hits is not None:
            e["probe_hits"] = int(decision.per_query_hits[g])
        e["batch"] = {
            "probe_hits": int(decision.hits),
            "blocks": int(decision.blocks),
            "tail_blocks": int(decision.tail_blocks),
            "tail_dense_blocks": int(decision.tail_dense_blocks),
        }
        e["tau"] = _tau_of(posts)
        if cands is not None:
            c = cands[g]
            e["candidates"] = int(len(c.rec_ids))
            e["pruned"] = int(c.pruned)
            e["blocks"] = int(c.blocks)
            e["skipped_blocks"] = int(c.skipped_blocks)
            e["merge_hits"] = int(c.hits)
            if hash_rows is not None and sizes is not None:
                ub_max, ub_mean = _ub_stats(c, hash_rows[g], int(sizes[g]))
                e["ub_max"] = ub_max
                e["ub_mean"] = ub_mean
        out.append(e)
    return out
