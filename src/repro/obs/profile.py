"""Stage-level profiling: per-stage latency histograms, cost-model drift
accounting, and an optional ``jax.profiler`` session wrapper.

Device timing caveat (why stages are *host-side spans at sync
boundaries*): the pruned query path fuses decode+score inside one jitted
computation, so the only honest host-visible seams are data staging
(host→device transfer), kernel execution (closed by
``block_until_ready`` via ``stage(...).sync(x)``), and result fetch
(device→host). Stage names are dotted paths — ``planner.probe``,
``device.kernel``, ``serve.score`` — and land in fixed log-bucket
:class:`repro.serving.Histogram`\\ s, exported through
``Metrics.register_histogram_provider`` as
``service_stage_latency_seconds{stage=...}``.
"""

from __future__ import annotations

import contextlib
import math
import os
import threading

from repro.serving.histogram import Histogram

__all__ = ["StageProfiler", "CostDrift", "device_profile"]


class StageProfiler:
    """Latency histogram per named stage, created on first observation.

    Thread-safe; designed to be attached alongside a trace via
    ``obs.attach(trace, profiler)`` so ``obs.stage(...)`` blocks feed it
    without plumbing. ``histograms()`` is the live view a Metrics
    histogram-family provider samples at render time.
    """

    def __init__(self, bounds=None):
        self._bounds = bounds
        self._stages: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def observe(self, name: str, seconds: float) -> None:
        h = self._stages.get(name)
        if h is None:
            with self._lock:
                h = self._stages.setdefault(
                    name, Histogram(self._bounds) if self._bounds is not None
                    else Histogram())
        h.observe(seconds)

    def histogram(self, name: str) -> Histogram | None:
        return self._stages.get(name)

    def histograms(self) -> dict[str, Histogram]:
        """{prometheus labels string: Histogram} for a metrics provider."""
        with self._lock:
            return {f'stage="{k}"': h for k, h in self._stages.items()}

    def stages(self) -> dict[str, Histogram]:
        with self._lock:
            return dict(self._stages)

    def snapshot(self) -> dict[str, dict]:
        """Summary per stage: count / mean / p50 / p99 (seconds)."""
        out = {}
        for name, h in self.stages().items():
            out[name] = {
                "count": h.count,
                "mean_s": h.mean,
                "p50_s": h.quantile(0.5),
                "p99_s": h.quantile(0.99),
            }
        return out


class CostDrift:
    """Predicted-vs-actual cost ratio across serve flushes.

    The planner's cost model speaks abstract units; calibration
    (``fit_query_constants``) stores ``seconds_per_unit`` so predicted
    units convert to predicted seconds. Without an installed
    calibration the converter self-fits from the accumulated
    (units, seconds) totals — the gauge then measures *consistency* of
    the model's ranking rather than absolute accuracy, which is exactly
    what plan decisions depend on.

    ``drift`` is last-flush predicted_seconds / measured_seconds:
    1.0 = perfectly calibrated, >1 = model over-estimates cost.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.total_units = 0.0
        self.total_seconds = 0.0
        self.flushes = 0
        self.last_ratio = float("nan")

    @staticmethod
    def _calibrated_seconds_per_unit() -> float | None:
        try:
            from repro.core import cost_model

            cal = cost_model.calibration()
            if cal:
                spu = cal.get("fit", {}).get("seconds_per_unit")
                if spu:
                    return float(spu)
        except Exception:
            pass
        return None

    def seconds_per_unit(self) -> float | None:
        spu = self._calibrated_seconds_per_unit()
        if spu is not None:
            return spu
        with self._lock:
            if self.total_units > 0 and self.total_seconds > 0:
                return self.total_seconds / self.total_units
        return None

    def record(self, predicted_units: float, measured_seconds: float) -> float:
        """Fold in one flush; returns the flush's drift ratio (NaN until
        a converter exists or for non-finite inputs)."""
        if (not math.isfinite(predicted_units) or predicted_units <= 0
                or not math.isfinite(measured_seconds)
                or measured_seconds <= 0):
            return float("nan")
        spu = self._calibrated_seconds_per_unit()
        with self._lock:
            if spu is None and self.total_units > 0:
                spu = self.total_seconds / self.total_units
            self.total_units += predicted_units
            self.total_seconds += measured_seconds
            self.flushes += 1
            if spu is None:
                return float("nan")
            self.last_ratio = (predicted_units * spu) / measured_seconds
            return self.last_ratio

    @property
    def drift(self) -> float:
        """Gauge value: last flush's predicted/actual ratio (0.0 until
        the first measurable flush — Prometheus gauges can't be NaN)."""
        r = self.last_ratio
        return r if math.isfinite(r) else 0.0


@contextlib.contextmanager
def device_profile(logdir: str | None = None):
    """Optional ``jax.profiler`` trace session around a block.

    Gated: does nothing unless ``logdir`` is given or the
    ``REPRO_JAX_PROFILE`` env var names a directory. The resulting
    TensorBoard/Perfetto trace carries real device timelines; this
    wrapper exists so benches/serving can opt in with one flag without
    importing jax on the default path.
    """
    if logdir is None:
        logdir = os.environ.get("REPRO_JAX_PROFILE", "")
    if not logdir:
        yield None
        return
    import jax

    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()
