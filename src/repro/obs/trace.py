"""Request tracing: nestable spans, a bounded ring of recent traces, and
Chrome trace-event export — zero dependencies beyond the stdlib.

The primitives:

* :class:`Span` — one named interval with attributes and a parent.
* :class:`Trace` — one request's (or flush's) span tree. Spans nest via
  a per-thread stack, so a trace that crosses threads (admitted on an
  HTTP handler thread, executed on the flush worker) still parents
  correctly on each side. ``add_span`` records an interval with explicit
  start/end times (queue wait is known only in hindsight).
* :class:`Tracer` — clock + bounded ring buffer (``deque(maxlen=...)``)
  of recently *ended* traces, exported as Chrome trace-event JSON
  (:meth:`Tracer.chrome_trace`) loadable in ``chrome://tracing`` or
  Perfetto.

Instrumentation points DO NOT thread tracer handles through every
signature. Instead the executing layer (the flush worker, a benchmark
harness) *attaches* an observation context — ``with attach(trace,
profiler): ...`` — and deep layers call ``with stage("planner.probe"):``
which records into whatever is attached. When nothing is attached,
``stage()`` returns a shared no-op context manager: the disabled cost is
one thread-local attribute read and a truthiness check, measured ≤5%
end-to-end by the serving bench's tracing gate.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Callable

__all__ = [
    "Span", "Trace", "Tracer", "NULL_TRACER",
    "attach", "current_trace", "current_profiler", "stage",
    "chrome_events",
]


def _jsonable(v):
    """Attrs must survive ``json.dumps``: keep native scalars, stringify
    the rest (numpy ints, arrays, dataclasses)."""
    if isinstance(v, bool) or v is None or isinstance(v, (str, int, float)):
        return v
    try:
        import numpy as np

        if isinstance(v, np.integer):
            return int(v)
        if isinstance(v, np.floating):
            return float(v)
    except Exception:  # pragma: no cover - numpy always present here
        pass
    return str(v)


class Span:
    """One named interval. ``end`` is None while the span is open."""

    __slots__ = ("name", "start", "end", "attrs", "parent")

    def __init__(self, name: str, start: float, parent: "Span | None" = None,
                 attrs: dict | None = None):
        self.name = name
        self.start = start
        self.end: float | None = None
        self.parent = parent
        self.attrs = {k: _jsonable(v) for k, v in (attrs or {}).items()}

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0

    def set(self, **attrs) -> "Span":
        for k, v in attrs.items():
            self.attrs[k] = _jsonable(v)
        return self


class _SpanCtx:
    """Context manager closing one span (returned by ``Trace.span``)."""

    __slots__ = ("_trace", "span")

    def __init__(self, trace: "Trace", span: Span):
        self._trace = trace
        self.span = span

    def set(self, **attrs):
        self.span.set(**attrs)
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.span.attrs["error"] = exc_type.__name__
        self._trace._close(self.span)
        return False


_trace_ids = itertools.count(1)


class Trace:
    """One span tree. Thread-safe: spans may be added from any thread;
    nesting follows each thread's own open-span stack (cross-thread
    spans parent on the root)."""

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None = None):
        self.tracer = tracer
        self.trace_id = next(_trace_ids)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.root = Span(name, tracer.clock(), attrs=attrs)
        self.spans: list[Span] = [self.root]
        self.ended = False

    # -- span plumbing -----------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, **attrs) -> _SpanCtx:
        """Open a nested span (context manager). Parent = the calling
        thread's innermost open span, else the root."""
        stack = self._stack()
        parent = stack[-1] if stack else self.root
        s = Span(name, self.tracer.clock(), parent=parent, attrs=attrs)
        with self._lock:
            self.spans.append(s)
        stack.append(s)
        return _SpanCtx(self, s)

    def _close(self, span: Span):
        span.end = self.tracer.clock()
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()

    def add_span(self, name: str, start: float, end: float, **attrs) -> Span:
        """Record an already-elapsed interval (e.g. queue wait, measured
        between two events the span API never bracketed)."""
        s = Span(name, start, parent=self.root, attrs=attrs)
        s.end = end
        with self._lock:
            self.spans.append(s)
        return s

    def set(self, **attrs) -> "Trace":
        self.root.set(**attrs)
        return self

    def end(self, **attrs) -> "Trace":
        """Close the root and push the finished trace into the tracer's
        ring buffer. Idempotent."""
        if self.ended:
            return self
        self.ended = True
        self.root.set(**attrs)
        self.root.end = self.tracer.clock()
        self.tracer._record(self)
        return self

    @property
    def duration(self) -> float:
        return self.root.duration


class Tracer:
    """Bounded ring of recent traces + the clock every span reads.

    ``capacity`` bounds memory: the ring keeps the most recent
    ``capacity`` *ended* traces (old ones fall off the left). The clock
    is injectable for deterministic tests.
    """

    enabled = True

    def __init__(self, capacity: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        self.capacity = int(capacity)
        self.clock = clock
        self.traces_started = 0
        self.traces_ended = 0
        self._ring: deque[Trace] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()

    def begin(self, name: str, **attrs) -> Trace:
        self.traces_started += 1
        return Trace(self, name, attrs=attrs)

    def _record(self, trace: Trace) -> None:
        with self._lock:
            self.traces_ended += 1
            self._ring.append(trace)

    def recent(self, n: int | None = None) -> list[Trace]:
        with self._lock:
            out = list(self._ring)
        return out if n is None else out[-int(n):]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def chrome_trace(self, n: int | None = None) -> dict:
        """Chrome trace-event JSON ({"traceEvents": [...]}) of the ring's
        recent traces — load in ``chrome://tracing`` or ui.perfetto.dev.
        Each trace renders on its own thread row (tid = trace id)."""
        events = []
        for t in self.recent(n):
            events.extend(chrome_events(t))
        return {"traceEvents": events, "displayTimeUnit": "ms"}


class _NullSpanCtx:
    """Shared no-op for the disabled path — also the ``stage()`` no-op."""

    __slots__ = ()

    def set(self, **attrs):
        return self

    def sync(self, x):
        return x

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullSpanCtx()


class _NullTrace:
    trace_id = 0
    ended = True
    duration = 0.0
    spans: list = []

    def span(self, name, **attrs):
        return _NULL_CTX

    def add_span(self, name, start, end, **attrs):
        return None

    def set(self, **attrs):
        return self

    def end(self, **attrs):
        return self


class NullTracer(Tracer):
    """Tracing disabled: ``begin`` hands back a shared inert trace and
    nothing is ever retained. All methods are allocation-free."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)
        self._null = _NullTrace()

    def begin(self, name: str, **attrs) -> Trace:
        return self._null  # type: ignore[return-value]

    def recent(self, n: int | None = None) -> list[Trace]:
        return []

    def chrome_trace(self, n: int | None = None) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()


def chrome_events(trace: Trace) -> list[dict]:
    """One trace → Chrome "X" (complete) events, µs timestamps."""
    events = []
    for s in trace.spans:
        end = s.end if s.end is not None else s.start
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": round(s.start * 1e6, 3),
            "dur": round((end - s.start) * 1e6, 3),
            "pid": 0,
            "tid": trace.trace_id,
            "args": dict(s.attrs),
        })
    return events


# ---------------------------------------------------------------------------
# Observation context: the executing layer attaches (trace, profiler);
# deep layers record stages without threading handles through signatures.
# ---------------------------------------------------------------------------

_ctx = threading.local()


def _stack_of_ctx() -> list:
    st = getattr(_ctx, "stack", None)
    if st is None:
        st = _ctx.stack = []
    return st


class attach:
    """``with attach(trace, profiler): ...`` — activate an observation
    context on this thread. Either handle may be None; attaching
    (None, None) is a no-op context."""

    __slots__ = ("trace", "profiler", "_pushed")

    def __init__(self, trace: Trace | None = None, profiler=None):
        self.trace = trace
        self.profiler = profiler
        self._pushed = False

    def __enter__(self):
        if self.trace is not None or self.profiler is not None:
            _stack_of_ctx().append((self.trace, self.profiler))
            self._pushed = True
        return self

    def __exit__(self, *exc):
        if self._pushed:
            _stack_of_ctx().pop()
        return False


def current_trace() -> Trace | None:
    st = getattr(_ctx, "stack", None)
    return st[-1][0] if st else None


def current_profiler():
    st = getattr(_ctx, "stack", None)
    return st[-1][1] if st else None


class _Stage:
    """Times one stage into the attached trace span AND the attached
    profiler histogram. ``sync(x)`` blocks on a device value so the
    stage's wall time covers its device work (identity off-context)."""

    __slots__ = ("name", "attrs", "_trace", "_prof", "_span", "_t0")

    def __init__(self, trace, prof, name, attrs):
        self.name = name
        self.attrs = attrs
        self._trace = trace
        self._prof = prof
        self._span = None

    def set(self, **attrs):
        if self._span is not None:
            self._span.set(**attrs)
        return self

    def sync(self, x):
        try:
            import jax

            jax.block_until_ready(x)
        except Exception:
            pass
        return x

    def __enter__(self):
        if self._trace is not None:
            self._span = self._trace.span(self.name, **self.attrs).span
        self._t0 = (self._trace.tracer.clock() if self._trace is not None
                    else time.perf_counter())
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._trace is not None:
            now = self._trace.tracer.clock()
            if exc_type is not None and self._span is not None:
                self._span.attrs["error"] = exc_type.__name__
            self._trace._close(self._span) if self._span is not None else None
        else:
            now = time.perf_counter()
        if self._prof is not None:
            self._prof.observe(self.name, max(now - self._t0, 0.0))
        return False


def stage(name: str, **attrs):
    """Record one named stage into the active observation context.

    The hot-path contract: with nothing attached this returns a SHARED
    no-op context manager — no allocation, no clock read — so
    instrumented library code costs one thread-local read when
    observability is off.
    """
    st = getattr(_ctx, "stack", None)
    if not st:
        return _NULL_CTX
    trace, prof = st[-1]
    return _Stage(trace, prof, name, attrs)
