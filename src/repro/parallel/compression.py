"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 1000+ nodes the data-parallel gradient reduction is the dominant
inter-pod collective. XLA exposes no sub-word all-reduce, so quantization
only saves wire bytes if the collective itself carries int8. We therefore
implement the reduction as **quantize → all_gather(int8) → local sum**:

    per-device sent/received bytes:  n·S·1   (int8 all-gather)
    vs f32 ring all-reduce:          ≈ 2·S·4

a ≥4× win for axis sizes n ≤ 8 — exactly the regime of the "pod" axis
(2–8 pods), which crosses the slow DCI links. Within a pod the fast ICI
all-reduce stays uncompressed f32 (XLA-inserted).

Error feedback (Seide'14 / Karimireddy'19) keeps convergence: whatever
rounding drops this step is added back next step.

Scheme (per leaf):
    e      — persistent error-feedback buffer (f32, same shape)
    x      = grad + e
    scale  = pmax(max|x|) / 127   (shared symmetric scale → summable ints)
    q      = round(x / scale) ∈ int8
    e'     = x − q·scale
    synced = Σ_pods q · scale / n (dequantized after the int8 all-gather)

Used inside ``shard_map`` over the pod axis (launch/train.py --compress-dp);
the plain pjit path leaves all reductions to XLA uncompressed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum_mean(grads, err_state, axis_name):
    """Mean-all-reduce a gradient tree in int8 with error feedback.

    Must run inside shard_map with ``axis_name`` bound. Returns
    (mean-reduced f32 grads, new error-feedback state).
    """
    n = lax.psum(1, axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = lax.pmax(jnp.max(jnp.abs(x)), axis_name)   # shared scale
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        e_new = x - q.astype(jnp.float32) * scale
        gathered = lax.all_gather(q, axis_name)            # int8 on the wire
        summed = gathered.astype(jnp.int32).sum(axis=0).astype(jnp.float32)
        return summed * scale / n, e_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
