"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Tensors are annotated with *logical* axis names; ``logical_to_spec`` maps
them onto whatever mesh is in scope ((data, model) single-pod or
(pod, data, model) multi-pod), dropping axes the mesh doesn't have.

Parallelism styles expressed through the rules:
  DP   — "batch" over (pod, data)
  TP   — "heads"/"ff"/"vocab" over model (Megatron)
  FSDP — "embed" (params' d_model dim) over data (ZeRO-3: XLA all-gathers
         one scan step's layer slice on demand)
  EP   — "experts" over model
  SP   — "kv_seq" over model (decode KV cache); "act_seq" optionally over
         model for very long sequences
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Trace-time rules override: launch/perf.py variants re-map logical axes
# INSIDE model code (constrain calls), not just at the jit boundary.
_RULES_OVERRIDE: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_rules_override", default=None)


@contextlib.contextmanager
def rules_scope(rules):
    """Make ``rules`` the default for constrain/named_sharding while
    tracing (a no-op when rules is None)."""
    tok = _RULES_OVERRIDE.set(rules)
    try:
        yield
    finally:
        _RULES_OVERRIDE.reset(tok)


def active_rules(explicit=None):
    return explicit or _RULES_OVERRIDE.get() or DEFAULT_RULES

# logical axis -> preferred mesh axes (first match present in mesh wins;
# tuple entries that are themselves tuples shard over several mesh axes).
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"),),
    "records": (("pod", "data", "model"),),   # sketch index rows
    "embed": (("pod", "data"),),               # FSDP dim of params (the
                                               # pod axis joins at 512
                                               # chips → state halves)
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_embed": (("pod", "data"),),
    "expert_ff": (),                           # §Perf B3 flips this to data
    "kv_seq": ("model",),
    "act_seq": (),
    "nodes": (("pod", "data"),),
    "edges": (("pod", "data", "model"),),
    "gnn_hidden": (),                          # §Perf cell E flips to model
    "table_vocab": ("model",),
    "stack": (),                               # scan-stacked layer dim
    None: (),
}


def _resolve(axis_name, mesh_axes, rules):
    for cand in rules.get(axis_name, ()):
        if isinstance(cand, tuple):
            picked = tuple(a for a in cand if a in mesh_axes)
            if picked:
                return picked if len(picked) > 1 else picked[0]
        elif cand in mesh_axes:
            return cand
    return None


def logical_to_spec(logical_axes, mesh: Mesh, rules=None) -> P:
    """("batch", None, "ff") -> PartitionSpec for this mesh."""
    rules = active_rules(rules)
    mesh_axes = set(mesh.axis_names)
    used: set = set()
    out = []
    for ax in logical_axes:
        r = _resolve(ax, mesh_axes, rules)
        # A mesh axis may shard only one tensor dim.
        flat = r if isinstance(r, tuple) else (r,) if r else ()
        if any(a in used for a in flat):
            r = None
        else:
            used.update(flat)
        out.append(r)
    return P(*out)


def named_sharding(logical_axes, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules))


def spec_for_shape(shape, logical_axes, mesh: Mesh, rules=None) -> P:
    """Shape-aware spec: drops mesh axes a dim's size cannot divide.

    pjit argument shardings must divide exactly; e.g. kv_heads=8 cannot
    shard over model=16 → that dim falls back (rightmost mesh axis dropped
    first, so ("pod","data","model") degrades toward the DP axes).
    """
    base = logical_to_spec(logical_axes, mesh, rules)
    out = []
    for dim, entry in zip(shape, tuple(base)):
        if entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes.pop()            # drop the innermost (rightmost) axis
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def named_sharding_for(shape, logical_axes, mesh: Mesh, rules=None):
    return NamedSharding(mesh, spec_for_shape(shape, logical_axes, mesh, rules))


def tree_shardings_for(abstract_tree, logical_tree, mesh: Mesh, rules=None):
    """Shape-aware twin of tree_shardings: needs the abstract arg tree."""
    return jax.tree.map(
        lambda sds, ax: named_sharding_for(sds.shape, ax, mesh, rules),
        abstract_tree, logical_tree)


def tree_shardings(logical_tree, mesh: Mesh, rules=None):
    """Map a pytree of logical-axis tuples -> pytree of NamedShardings."""
    return jax.tree.map(
        lambda ax: named_sharding(ax, mesh, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )


def constrain(x, logical_axes, mesh: Mesh | None = None, rules=None):
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical_axes, mesh, rules))


def _current_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
