"""Candidate-pruning query planner: filter-and-verify over inverted
postings so selective queries stop sweeping the whole index.

    postings.py  block-compressed hash/buffer-bit postings (128-entry
                 delta-bitpacked or dense-bitmap blocks), incremental
                 under insert
    prune.py     threshold-aware candidate generation with per-block
                 header skipping + packed hits
    plan.py      per-batch dense-vs-pruned cost decision + executor
                 (+ pruned_topk: upper-bound-pruned top-k)
    device.py    device-resident pruned execution over a SketchArena
                 (block decode → gather-score → packed thresholding
                 with no host round-trip; imported lazily — jax-heavy)

The ragged verify kernel lives with the other Pallas kernels in
:mod:`repro.kernels.gather_score`, the device block-decode/merge in
:mod:`repro.kernels.postings_merge`. ``repro.api`` threads ``plan=``
("auto" | "dense" | "pruned") through every sketch engine's
``query``/``batch_query``/``topk``.
"""

from repro.planner.plan import (
    PLAN_MODES,
    QueryPlan,
    choose_plan,
    merged_candidates,
    normalize_plan,
    probe_block_stats,
    pruned_batch,
    pruned_topk,
    topk_select,
)
from repro.planner.postings import (
    BLOCK,
    BlockStore,
    PostingsIndex,
    append_rows,
    build_postings,
    decode_blocks,
    decode_store,
    encode_store,
    from_flat,
    postings_equal,
    truncate_postings,
    update_postings,
)
from repro.planner.prune import (
    CandidateSet,
    candidates_for,
    f32_threshold,
    threshold_hits_packed,
)

__all__ = [
    "PLAN_MODES",
    "QueryPlan",
    "choose_plan",
    "merged_candidates",
    "normalize_plan",
    "probe_block_stats",
    "pruned_batch",
    "pruned_topk",
    "topk_select",
    "BLOCK",
    "BlockStore",
    "PostingsIndex",
    "append_rows",
    "build_postings",
    "decode_blocks",
    "decode_store",
    "encode_store",
    "from_flat",
    "postings_equal",
    "truncate_postings",
    "update_postings",
    "CandidateSet",
    "candidates_for",
    "f32_threshold",
    "threshold_hits_packed",
]
