"""Candidate-pruning query planner: filter-and-verify over inverted
postings so selective queries stop sweeping the whole index.

    postings.py  CSR hash/buffer-bit postings, incremental under insert
    prune.py     threshold-aware candidate generation + packed hits
    plan.py      per-batch dense-vs-pruned cost decision + executor

The ragged verify kernel lives with the other Pallas kernels in
:mod:`repro.kernels.gather_score`. ``repro.api`` threads ``plan=``
("auto" | "dense" | "pruned") through every sketch engine's
``query``/``batch_query``.
"""

from repro.planner.plan import (
    PLAN_MODES,
    QueryPlan,
    choose_plan,
    normalize_plan,
    pruned_batch,
)
from repro.planner.postings import (
    PostingsIndex,
    build_postings,
    postings_equal,
    update_postings,
)
from repro.planner.prune import (
    CandidateSet,
    candidates_for,
    f32_threshold,
    threshold_hits_packed,
)

__all__ = [
    "PLAN_MODES",
    "QueryPlan",
    "choose_plan",
    "normalize_plan",
    "pruned_batch",
    "PostingsIndex",
    "build_postings",
    "postings_equal",
    "update_postings",
    "CandidateSet",
    "candidates_for",
    "f32_threshold",
    "threshold_hits_packed",
]
