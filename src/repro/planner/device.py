"""Device-resident pruned execution over a :class:`SketchArena`.

The contract the arena makes possible: with ``backend`` ∈ {"jnp",
"pallas"}, ``plan="pruned"`` runs candidate generation (block-task
expand + on-device block decode) → gather-scoring → packed thresholding
as ONE device computation over the arena's resident mirrors. The only
host work is *before* candidate generation (query sketching, the header
probe that fixes the static block-task bounds, staging the query pack)
and *after* the packed threshold output (the final bool-mask fetch that
every path, dense included, pays once).

The mirrors are the BLOCKED postings: compressed blocks upload, decode
on device (kernels/postings_merge.py), and never materialize a flat
posting list anywhere — the compression that shrinks the at-rest index
also shrinks what the arena ships to the accelerator. Buffer posting
lists don't ship at all: the device path recovers o1 from the packed
bitmaps already resident in the device pack.

``stage_query_inputs`` / ``pruned_scores`` are split exactly at those
seams so tests can wrap the middle in ``jax.transfer_guard("disallow")``
and prove the residency claim rather than assert it in prose.
"""

from __future__ import annotations

import numpy as np

from repro.core.arena import SketchArena
from repro.obs.trace import stage
from repro.planner import prune


def _bucket(n: int, lo: int = 64) -> int:
    """Power-of-two bucket so steady-state serving reuses a handful of
    compiled shapes instead of one per batch."""
    p = lo
    while p < n:
        p *= 2
    return p


def stage_query_inputs(arena: SketchArena, qp, thresholds=None):
    """Place one batch's device inputs (host → device happens HERE).

    Returns (device_postings, device_pack, device query columns, device
    float32-exact thresholds — or None when ``thresholds`` is None). The
    arena mirrors are cached — only the query pack actually moves per
    batch; the index columns and blocked postings move once per
    mutation.
    """
    import jax.numpy as jnp

    dpost = arena.device_postings()
    dpack = arena.device_pack()
    w = int(np.asarray(arena.buf).shape[1])
    q_buf = np.asarray(qp.buf)
    if q_buf.shape[1] != w:           # align bitmap widths (r=0 engines)
        qb = np.zeros((q_buf.shape[0], w), np.uint32)
        qb[:, : min(w, q_buf.shape[1])] = q_buf[:, : min(w, q_buf.shape[1])]
        q_buf = qb
    dq = (
        jnp.asarray(np.asarray(qp.values), jnp.uint32),
        jnp.asarray(np.asarray(qp.thresh), jnp.uint32),
        jnp.asarray(q_buf, jnp.uint32),
        jnp.asarray(np.asarray(qp.sizes), jnp.int32),
    )
    dthr = None
    if thresholds is not None:
        thr32 = np.broadcast_to(
            prune.f32_threshold(thresholds), (qp.num_records,))
        dthr = jnp.asarray(np.ascontiguousarray(thr32), jnp.float32)
    return dpost, dpack, dq, dthr


def pruned_scores(dpost, dpack, dq, *, tb: int, tbd: int, m: int,
                  backend: str):
    """f32[m, Gq] device score matrix — no host transfer inside.

    Block-task expand, block decode (kernels/postings_merge.py probe +
    decode kernel), the K∩ scatter, the bitmap o1 popcount, and the
    closed-form estimator are one jitted call over already-resident
    inputs. ``tb``/``tbd`` are the static (bucketed) block-task bounds
    from the host header probe.
    """
    from repro.kernels import postings_merge
    from repro.kernels.ops import _on_tpu

    qv, qt, qb, qs = dq
    return postings_merge.pruned_score_matrix(
        dpost.keys, dpost.row_blocks, dpost.first, dpost.meta,
        dpost.off, dpost.payload,
        dpack.values, dpack.thresh, dpack.buf,
        qv, qt, qb, qs,
        tb=tb, tbd=tbd, m=m, backend=backend, interpret=not _on_tpu())


def pruned_hit_mask(dpost, dpack, dq, dthr, *, tb: int, tbd: int, m: int,
                    backend: str):
    """bool[m, Gq] device hit mask — candidate-gen → block decode →
    score → packed thresholding with no host transfer anywhere in
    between (the staged ``dthr`` already encodes the float32-exact
    cut)."""
    s = pruned_scores(dpost, dpack, dq, tb=tb, tbd=tbd, m=m,
                      backend=backend)
    return s >= dthr[None, :]


def task_bounds(plan) -> tuple[int, int]:
    """(tb, tbd) static decode bounds from a :class:`QueryPlan`'s header
    probe — bucketed so steady-state serving reuses compiled shapes;
    ``tbd`` stays 0 when the batch touches no dense blocks (the overlay
    compiles out)."""
    tb = _bucket(max(int(plan.tail_blocks), 1))
    tbd = _bucket(int(plan.tail_dense_blocks), lo=8) \
        if int(plan.tail_dense_blocks) else 0
    return tb, tbd


def pruned_batch_device(
    arena: SketchArena, qp, threshold, *, plan, backend: str,
) -> list[np.ndarray]:
    """Device-resident filter-and-verify for one query batch.

    ``plan`` is the batch's :class:`QueryPlan`: its host-side header
    probe (``hits``, ``tail_blocks``, ``tail_dense_blocks``) fixes every
    static shape before any device work starts. Returns per-query hit
    ids, bit-identical to the dense sweep (same estimator math, same
    packed float32-exact thresholding).
    """
    gq = qp.num_records
    m = arena.num_records
    if plan.hits <= 0 or m == 0:
        return [np.zeros(0, np.int64) for _ in range(gq)]

    # Stage spans sit exactly at the transfer seams: "device.stage" is
    # host→device placement, "device.kernel" the fused decode+score+
    # threshold jit (closed by sync — stage() is a shared no-op when no
    # observation context is attached, so the extra block_until_ready
    # only happens when observing), "device.fetch" the one mask readback.
    with stage("device.stage", queries=gq):
        dpost, dpack, dq, dthr = stage_query_inputs(arena, qp, threshold)
    tb, tbd = task_bounds(plan)
    with stage("device.kernel", tb=tb, tbd=tbd, backend=backend) as span:
        mask = span.sync(pruned_hit_mask(dpost, dpack, dq, dthr, tb=tb,
                                         tbd=tbd, m=m, backend=backend))
    with stage("device.fetch"):
        host_mask = np.asarray(mask)
    return prune.mask_to_hits(host_mask)
