"""Device-resident pruned execution over a :class:`SketchArena`.

The contract the arena makes possible: with ``backend`` ∈ {"jnp",
"pallas"}, ``plan="pruned"`` runs the WHOLE query chain — postings
probe, block-task expand, on-device block decode, the K∩ scatter, the
closed-form estimator, and the output head (packed thresholding or
top-k) — as ONE device computation over the arena's resident mirrors
(kernels/postings_merge.py). The only host work is *before* it (query
sketching, staging the query pack — one batched ``device_put``) and
*after* it (reading back the bit-packed hit words or the [Gq, k] top-k
pair — the packed result, never an m×Gq matrix).

Two things keep steady-state serving on ONE compiled program:

    shape bucketing   the only per-batch shapes are the query count Gq
                      (bucketed to powers of two, padded with inert
                      PAD-hash queries that provably score 0) and the
                      top-k ``k`` (same bucketing). Sketch capacity and
                      bitmap width are index constants; block/task
                      counts are DATA, consumed by while_loops, not
                      shapes.
    staging pool      per-(bucket, capacity, width) pinned host buffers
                      — ONE flat u32 blob per shape, filled in place
                      through dtype views and shipped in a single
                      ``device_put`` (the jit carves it at static
                      offsets); the device blob is donated to the jit
                      so XLA can alias it into outputs.

``PIPELINE_STATS`` counts calls vs. newly-seen compile signatures (the
jit cache key mirrored host-side) and staging-pool reuse, surfaced
through ``repro.obs``/``/metrics``; every new signature logs a slow-path
line so a bucketing regression shows up in production logs, not just as
mysteriously slow batches.

``stage_query_inputs`` / ``fused_mask_words`` / ``fused_topk_scores``
split exactly at the transfer seams so tests can wrap the middle in
``jax.transfer_guard("disallow")`` and prove the residency claim rather
than assert it in prose.
"""

from __future__ import annotations

import logging
import warnings
from typing import NamedTuple

import numpy as np

from repro.core.arena import SketchArena
from repro.core.hashing import PAD
from repro.obs.trace import stage
from repro.planner import prune

_LOG = logging.getLogger("repro.planner.device")


class _quiet(warnings.catch_warnings):
    """Silence the per-compile 'donated buffers were not usable' warning
    — CPU can't donate, and the fused jits donate their query buffers so
    real accelerators can alias them into outputs."""

    def __enter__(self):
        super().__enter__()
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")


#: Device-pipeline counters (process-global, monotonically increasing —
#: the serving layer exports them through /metrics). ``compiles`` counts
#: newly-seen jit signatures; ``calls - compiles`` is the cache-hit
#: count. ``staging_reuse``/``staging_alloc`` track the host staging
#: pool: in steady state reuse grows and alloc does not.
PIPELINE_STATS = {
    "calls": 0,
    "compiles": 0,
    "staging_reuse": 0,
    "staging_alloc": 0,
}
_SIGNATURES: set = set()
_STAGING: dict = {}


def pipeline_stats() -> dict:
    """Snapshot of the device-pipeline counters (plus the derived
    cache-hit count and live signature/pool sizes)."""
    s = dict(PIPELINE_STATS)
    s["cache_hits"] = s["calls"] - s["compiles"]
    s["signatures"] = len(_SIGNATURES)
    s["staging_buffers"] = len(_STAGING)
    return s


def reset_pipeline_stats() -> None:
    for key in PIPELINE_STATS:
        PIPELINE_STATS[key] = 0
    _SIGNATURES.clear()
    _STAGING.clear()


def _note_call(sig) -> None:
    PIPELINE_STATS["calls"] += 1
    if sig not in _SIGNATURES:
        _SIGNATURES.add(sig)
        PIPELINE_STATS["compiles"] += 1
        _LOG.info(
            "device-pipeline compile (slow path): %r — %d signatures live; "
            "steady-state serving should stop seeing these once the "
            "Gq/k buckets are warm", sig, len(_SIGNATURES))


def _bucket(n: int, lo: int = 64) -> int:
    """Power-of-two bucket so steady-state serving reuses a handful of
    compiled shapes instead of one per batch."""
    p = lo
    while p < n:
        p *= 2
    return p


class StagedQuery(NamedTuple):
    """One staged batch: the device-resident query blob plus the static
    dims the fused jits need to carve it (bucketed query count, sketch
    capacity, bitmap words)."""

    blob: object          # u32[gq * (cq + w + 3)] on device
    gq: int
    cq: int
    w: int


def _staging(gq_b: int, cq: int, w: int) -> dict:
    """The (bucketed-batch, capacity, bitmap-width) host staging
    buffer — ONE flat u32 array per shape (so the batch ships in a
    single ``device_put``), filled in place through dtype views laid
    out [values | thresh | buf | sizes | thr]."""
    key = (gq_b, cq, w)
    bufs = _STAGING.get(key)
    if bufs is None:
        PIPELINE_STATS["staging_alloc"] += 1
        o0 = gq_b * cq
        o1 = o0 + gq_b
        o2 = o1 + gq_b * w
        o3 = o2 + gq_b
        flat = np.empty(o3 + gq_b, np.uint32)
        bufs = {
            "flat": flat,
            "values": flat[:o0].reshape(gq_b, cq),
            "thresh": flat[o0:o1],
            "buf": flat[o1:o2].reshape(gq_b, w),
            "sizes": flat[o2:o3].view(np.int32),
            "thr": flat[o3:].view(np.float32),
        }
        _STAGING[key] = bufs
    else:
        PIPELINE_STATS["staging_reuse"] += 1
    return bufs


def stage_query_inputs(arena: SketchArena, qp, thresholds=None):
    """Place one batch's device inputs (host → device happens HERE).

    Returns (device_postings, device_pack, :class:`StagedQuery`). The
    arena mirrors are cached — only the query blob actually moves per
    batch, ONE flat ``device_put`` out of the pooled staging buffer;
    the fused jit carves it at static offsets. When ``thresholds`` is
    None the blob's threshold lane stays +inf (the top-k/scores heads
    ignore it).

    The query count is padded to its power-of-two bucket with inert
    queries: all-PAD hash rows (PAD never probes — real keys are < PAD —
    and never counts under any τ_pair ≤ x_thresh < PAD), zero bitmaps,
    zero sizes, +inf thresholds. Padded columns score exactly 0, pass no
    threshold, and are sliced off at fetch; callers slice by
    ``qp.num_records``.
    """
    import jax

    dpost = arena.device_postings()
    dpack = arena.device_pack()
    gq = qp.num_records
    gq_b = _bucket(max(gq, 1), lo=8)
    w = int(np.asarray(arena.buf).shape[1])
    qv = np.asarray(qp.values)
    cq = int(qv.shape[1])
    host = _staging(gq_b, cq, w)

    host["values"][:gq] = qv
    host["values"][gq:] = np.uint32(PAD)
    host["thresh"][:gq] = np.asarray(qp.thresh)
    host["thresh"][gq:] = 0
    q_buf = np.asarray(qp.buf)
    wq = min(w, int(q_buf.shape[1]))
    host["buf"][:] = 0                # align bitmap widths (r=0 engines)
    host["buf"][:gq, :wq] = q_buf[:, :wq]
    host["sizes"][:gq] = np.asarray(qp.sizes)
    host["sizes"][gq:] = 0
    host["thr"][:] = np.inf
    if thresholds is not None:
        host["thr"][:gq] = np.broadcast_to(
            prune.f32_threshold(thresholds), (gq,))

    blob = jax.device_put(host["flat"])
    return dpost, dpack, StagedQuery(blob, gq_b, cq, w)


def _sig(kind: str, dpost, sq: StagedQuery, *, m: int, backend: str,
         extra=()):
    return (kind, m, sq.gq, sq.cq, sq.w, int(dpost.keys.shape[0]),
            int(dpost.first.shape[0]), int(dpost.payload.shape[0]),
            bool(dpost.has_dense), backend) + tuple(extra)


def pruned_scores(dpost, dpack, sq: StagedQuery, *, m: int, backend: str):
    """f32[m, Gq] device score matrix — no host transfer inside.

    Probe, block-task expand (device while_loop — no host header probe
    feeds this), block decode, the K∩ scatter, the bitmap o1 popcount,
    and the closed-form estimator are one jitted call over
    already-resident inputs (kernels/postings_merge.fused_scores).
    """
    from repro.kernels import postings_merge
    from repro.kernels.ops import _on_tpu

    _note_call(_sig("scores", dpost, sq, m=m, backend=backend))
    with _quiet():
        return postings_merge.fused_scores(
            dpost.keys, dpost.row_blocks, dpost.first, dpost.meta,
            dpost.off, dpost.payload,
            dpack.values, dpack.thresh, dpack.buf, sq.blob,
            gq=sq.gq, cq=sq.cq, w=sq.w,
            m=m, backend=backend, interpret=not _on_tpu(),
            has_dense=dpost.has_dense)


def fused_mask_words(dpost, dpack, sq: StagedQuery, *, m: int,
                     backend: str):
    """u32[ceil(m/32), Gq] packed device hit words — probe → decode →
    score → float32-exact packed thresholding with no host transfer
    anywhere in between (the staged blob already encodes the cut).
    """
    from repro.kernels import postings_merge
    from repro.kernels.ops import _on_tpu

    _note_call(_sig("mask", dpost, sq, m=m, backend=backend))
    with _quiet():
        return postings_merge.fused_hit_words(
            dpost.keys, dpost.row_blocks, dpost.first, dpost.meta,
            dpost.off, dpost.payload,
            dpack.values, dpack.thresh, dpack.buf, sq.blob,
            gq=sq.gq, cq=sq.cq, w=sq.w,
            m=m, backend=backend, interpret=not _on_tpu(),
            has_dense=dpost.has_dense)


def fused_topk_scores(dpost, dpack, sq: StagedQuery, *, k: int, m: int,
                      backend: str):
    """(scores f32[Gq, k], ids i32[Gq, k]) device top-k over the fused
    score matrix — same pipeline, ``lax.top_k`` head (which ranks equal
    scores lowest-id-first, the dense (-score, id) tie rule)."""
    from repro.kernels import postings_merge
    from repro.kernels.ops import _on_tpu

    _note_call(_sig("topk", dpost, sq, m=m, backend=backend, extra=(k,)))
    with _quiet():
        return postings_merge.fused_topk(
            dpost.keys, dpost.row_blocks, dpost.first, dpost.meta,
            dpost.off, dpost.payload,
            dpack.values, dpack.thresh, dpack.buf, sq.blob,
            k=k, gq=sq.gq, cq=sq.cq, w=sq.w,
            m=m, backend=backend, interpret=not _on_tpu(),
            has_dense=dpost.has_dense)


def unpack_hit_words(words, m: int) -> np.ndarray:
    """bool[m, Gq] from the fetched u32[ceil(m/32), Gq] hit words —
    bit ``i & 31`` of word ``i >> 5``. The lazy host-side half of the
    packed fetch (8× less transfer than the bool mask, 32× less than
    the float scores)."""
    words = np.asarray(words)
    shifts = np.arange(32, dtype=np.uint32)[None, :, None]
    bits = (words[:, None, :] >> shifts) & np.uint32(1)
    return bits.astype(bool).reshape(-1, words.shape[1])[:m]


def pruned_batch_device(
    arena: SketchArena, qp, thresholds, *, plan=None, backend: str,
) -> list[np.ndarray]:
    """Device-resident filter-and-verify for one query batch.

    ``thresholds`` is a scalar or per-query vector (all > 0 — the
    planner forces t ≤ 0 dense before routing here). Returns per-query
    hit ids, bit-identical to the dense sweep (same estimator math, same
    packed float32-exact thresholding). ``plan`` (a
    :class:`QueryPlan`, optional) only short-circuits the zero-hit case
    — no shape in the device program depends on it.
    """
    gq = qp.num_records
    m = arena.num_records
    if m == 0 or (plan is not None and plan.hits <= 0):
        return [np.zeros(0, np.int64) for _ in range(gq)]

    # Stage spans sit exactly at the transfer seams: "device.stage" is
    # host→device placement (one batched device_put out of the pooled
    # staging buffers), "device.kernel" the fused probe+decode+score+
    # pack jit (closed by sync — stage() is a shared no-op when no
    # observation context is attached, so the extra block_until_ready
    # only happens when observing), "device.fetch" the packed-word
    # readback + lazy bit decode.
    with stage("device.stage", queries=gq):
        dpost, dpack, sq = stage_query_inputs(arena, qp, thresholds)
    with stage("device.kernel", backend=backend) as span:
        words = span.sync(fused_mask_words(dpost, dpack, sq,
                                           m=m, backend=backend))
    with stage("device.fetch"):
        mask = unpack_hit_words(words, m)[:, :gq]
    return prune.mask_to_hits(mask)


def pruned_topk_device(
    arena: SketchArena, qp, k: int, *, backend: str,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Device-resident top-k for one query batch.

    Returns ``[(ids int64[k'], scores float32[k'])]`` per query with
    ``k' = min(k, num_records)`` — the host ``pruned_topk`` contract:
    (score desc, id asc) order, zero-score records filling any shortfall
    in ascending-id order (``lax.top_k`` over the full score matrix
    produces exactly that, because non-candidates score exactly 0 and
    equal scores rank lowest-id-first). ``k`` is bucketed on device and
    sliced on fetch, so steady state reuses one compiled program.
    """
    gq = qp.num_records
    m = arena.num_records
    k_eff = min(int(k), m)
    if k_eff <= 0:
        return [(np.zeros(0, np.int64), np.zeros(0, np.float32))
                for _ in range(gq)]
    with stage("device.stage", queries=gq):
        dpost, dpack, sq = stage_query_inputs(arena, qp, None)
    k_call = min(_bucket(k_eff, lo=8), m)
    with stage("device.kernel", backend=backend, k=k_call) as span:
        vals, ids = fused_topk_scores(dpost, dpack, sq, k=k_call, m=m,
                                      backend=backend)
        span.sync(vals)
    with stage("device.fetch"):
        vals_h = np.asarray(vals)
        ids_h = np.asarray(ids)
    return [(ids_h[g, :k_eff].astype(np.int64),
             vals_h[g, :k_eff].astype(np.float32)) for g in range(gq)]
