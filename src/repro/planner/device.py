"""Device-resident pruned execution over a :class:`SketchArena`.

The contract the arena makes possible: with ``backend`` ∈ {"jnp",
"pallas"}, ``plan="pruned"`` runs candidate generation → gather-scoring
→ packed thresholding as ONE device computation over the arena's
resident mirrors. The only host work is *before* candidate generation
(query sketching, the cost probe that fixes the static candidate bound,
staging the query pack) and *after* the packed threshold output (the
final bool-mask fetch that every path, dense included, pays once).

``stage_query_inputs`` / ``pruned_scores`` are split exactly at those
seams so tests can wrap the middle in ``jax.transfer_guard("disallow")``
and prove the residency claim rather than assert it in prose.
"""

from __future__ import annotations

import numpy as np

from repro.core.arena import SketchArena
from repro.planner import prune


def _bucket(n: int, lo: int = 64) -> int:
    """Power-of-two bucket so steady-state serving reuses a handful of
    compiled shapes instead of one per batch."""
    p = lo
    while p < n:
        p *= 2
    return p


def stage_query_inputs(arena: SketchArena, qp, thresholds=None):
    """Place one batch's device inputs (host → device happens HERE).

    Returns (device_postings, device_pack, device query columns, device
    float32-exact thresholds — or None when ``thresholds`` is None). The
    arena mirrors are cached — only the query pack actually moves per
    batch; the index columns and postings move once per mutation.
    """
    import jax.numpy as jnp

    dpost = arena.device_postings()
    dpack = arena.device_pack()
    w = int(np.asarray(arena.buf).shape[1])
    q_buf = np.asarray(qp.buf)
    if q_buf.shape[1] != w:           # align bitmap widths (r=0 engines)
        qb = np.zeros((q_buf.shape[0], w), np.uint32)
        qb[:, : min(w, q_buf.shape[1])] = q_buf[:, : min(w, q_buf.shape[1])]
        q_buf = qb
    dq = (
        jnp.asarray(np.asarray(qp.values), jnp.uint32),
        jnp.asarray(np.asarray(qp.thresh), jnp.uint32),
        jnp.asarray(q_buf, jnp.uint32),
        jnp.asarray(np.asarray(qp.sizes), jnp.int32),
    )
    dthr = None
    if thresholds is not None:
        thr32 = np.broadcast_to(
            prune.f32_threshold(thresholds), (qp.num_records,))
        dthr = jnp.asarray(np.ascontiguousarray(thr32), jnp.float32)
    return dpost, dpack, dq, dthr


def pruned_scores(dpost, dpack, dq, *, pb: int, m: int, backend: str):
    """f32[m, Gq] device score matrix — no host transfer inside.

    Candidate merge (kernels/postings_merge.py probe + ragged expand),
    gather-scoring, and the scatter into the dense matrix are one jitted
    call over already-resident inputs.
    """
    from repro.kernels import postings_merge
    from repro.kernels.ops import _on_tpu

    qv, qt, qb, qs = dq
    return postings_merge.pruned_score_matrix(
        dpost.keys, dpost.offsets, dpost.rec_ids,
        dpost.buf_offsets, dpost.buf_rec_ids,
        dpack.values, dpack.thresh, dpack.buf,
        qv, qt, qb, qs,
        pb=pb, m=m, backend=backend, interpret=not _on_tpu())


def pruned_hit_mask(dpost, dpack, dq, dthr, *, pb: int, m: int,
                    backend: str):
    """bool[m, Gq] device hit mask — candidate-gen → score → packed
    thresholding with no host transfer anywhere in between (the staged
    ``dthr`` already encodes the float32-exact cut)."""
    s = pruned_scores(dpost, dpack, dq, pb=pb, m=m, backend=backend)
    return s >= dthr[None, :]


def pruned_batch_device(
    arena: SketchArena, qp, threshold, *, hits: int, backend: str,
) -> list[np.ndarray]:
    """Device-resident filter-and-verify for one query batch.

    ``hits`` is the batch's total posting entries from the planner's
    host-side cost probe (``QueryPlan.hits``) — it upper-bounds the
    candidate stream, so the static shape is known before any device
    work starts. Returns per-query hit ids, bit-identical to the dense
    sweep (same estimator math, same packed float32-exact thresholding).
    """
    gq = qp.num_records
    m = arena.num_records
    if hits <= 0 or m == 0:
        return [np.zeros(0, np.int64) for _ in range(gq)]

    dpost, dpack, dq, dthr = stage_query_inputs(arena, qp, threshold)
    mask = pruned_hit_mask(dpost, dpack, dq, dthr, pb=_bucket(int(hits)),
                           m=m, backend=backend)
    return prune.mask_to_hits(np.asarray(mask))
