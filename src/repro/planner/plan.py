"""Per-batch query planning: dense sweep vs postings-pruned verify.

``choose_plan`` probes the postings (searchsorted only — no merge) for
the batch's query hashes, feeds the touched-entry count into the
core/cost_model.py query-path costs, and picks the cheaper path.
``plan="dense"``/``"pruned"`` force a path; ``"auto"`` is the default
everywhere. Two hard guards keep forced/auto pruning sound:

* thresholds ≤ 0 always run dense — every record trivially clears t, so
  a filter built on "shares at least one hash/bit" would drop records
  the dense sweep returns;
* ``topk`` always runs dense — it needs the full ranking, not a
  threshold cut (the cost model never routes it through the planner).

``pruned_batch`` is the shared execution skeleton: generate candidates
per query, score the ragged union in ONE backend call (the engines pass
a closure over kernels/gather_score.py or their estimator), and cut at
the float32-exact threshold so results match the dense sweep bit for
bit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import cost_model
from repro.planner import prune
from repro.planner.postings import PostingsIndex

PLAN_MODES = ("auto", "dense", "pruned")


@dataclasses.dataclass
class QueryPlan:
    """One batch's routing decision (attached to indexes as .last_plan)."""

    path: str              # "dense" | "pruned"
    est_dense: float       # cost-model units
    est_pruned: float
    hits: int              # posting entries the batch's hashes/bits touch
    reason: str


def normalize_plan(plan: str | None) -> str:
    plan = "auto" if plan is None else plan
    if plan not in PLAN_MODES:
        raise ValueError(f"plan must be one of {PLAN_MODES}, got {plan!r}")
    return plan


def gbkmv_plan_queries(core, queries):
    """Sketch a query batch and unpack the planner's per-query inputs.

    Shared by the host GB-KMV index and ShardedIndex (one definition, so
    the two planners can't drift). Returns (query pack, retained-hash
    rows, buffer-bit rows, query sizes).
    """
    from repro.sketchindex.distributed import batch_queries

    qp = batch_queries(core, queries)
    vals, lens = np.asarray(qp.values), np.asarray(qp.lengths)
    bufs = np.asarray(qp.buf)
    hash_rows = [vals[g, : lens[g]] for g in range(len(queries))]
    bit_rows = [prune.query_bits(bufs[g]) for g in range(len(queries))]
    return qp, hash_rows, bit_rows, np.asarray(qp.sizes)


def probe_hits(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
) -> int:
    """Posting entries a merge would touch — searchsorted, no merge.

    ``posts`` may be a list (one per shard); hits sum over the mesh.
    """
    if isinstance(posts, PostingsIndex):
        posts = [posts]
    hits = 0
    for post in posts:
        bl = np.diff(post.buf_offsets)
        for qh, qb in zip(q_hash_rows, q_bit_rows):
            hits += int(post.posting_lengths(qh).sum())
            qb = np.asarray(qb, dtype=np.int64)
            hits += int(bl[qb[qb < len(bl)]].sum())
    return hits


def choose_plan(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
    threshold: float,
    m: int,
    capacity: int,
    plan: str = "auto",
) -> QueryPlan:
    gq = len(q_hash_rows)
    plan = normalize_plan(plan)
    if float(threshold) <= 0.0:
        # Every record passes t ≤ 0; postings can't see zero-overlap pairs.
        return QueryPlan("dense", 0.0, np.inf, 0,
                         "threshold <= 0: pruning unsound, forced dense")
    hits = probe_hits(posts, q_hash_rows, q_bit_rows)
    est_dense = cost_model.dense_sweep_cost(m, capacity, gq)
    est_pruned = cost_model.pruned_path_cost(hits, capacity, gq)
    if plan == "dense":
        return QueryPlan("dense", est_dense, est_pruned, hits, "forced")
    if plan == "pruned":
        return QueryPlan("pruned", est_dense, est_pruned, hits, "forced")
    path = "pruned" if est_pruned < est_dense else "dense"
    return QueryPlan(path, est_dense, est_pruned, hits,
                     f"auto: dense≈{est_dense:.3g} vs pruned≈{est_pruned:.3g}")


def merged_candidates(
    posts: PostingsIndex | Sequence[PostingsIndex],
    row_offsets: Sequence[int] | None = None,
) -> Callable[..., prune.CandidateSet]:
    """Candidate generator over one postings index or a sharded list.

    ``row_offsets[s]`` maps shard-local record ids to global ids; shard
    ranges partition the records, so the cross-mesh union is a
    concatenation that stays sorted.
    """
    if isinstance(posts, PostingsIndex):
        posts = [posts]
    if row_offsets is None:
        row_offsets = [0] * len(posts)

    def gen(qh, qb, t, qs) -> prune.CandidateSet:
        parts = [prune.candidates_for(p, qh, qb, t, qs) for p in posts]
        return prune.CandidateSet(
            rec_ids=np.concatenate(
                [c.rec_ids + off for c, off in zip(parts, row_offsets)]),
            counts=np.concatenate([c.counts for c in parts]),
            o1=np.concatenate([c.o1 for c in parts]),
            hits=sum(c.hits for c in parts),
            pruned=sum(c.pruned for c in parts),
        )

    return gen


def pruned_batch(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
    q_sizes: Sequence[int],
    thresholds,
    score_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    row_offsets: Sequence[int] | None = None,
) -> tuple[list[np.ndarray], list[prune.CandidateSet]]:
    """Filter-and-verify for one query batch.

    ``score_fn(cand_rec i32[P], cand_q i32[P]) -> f32[P]`` scores the
    flattened ragged candidate list with the engine's own estimator (one
    backend dispatch for the whole batch). Returns (per-query hit ids,
    per-query candidate sets) — ids are bit-identical to the dense
    sweep's ``np.nonzero(scores >= t)`` for each query.
    """
    gq = len(q_hash_rows)
    thr = np.broadcast_to(np.asarray(thresholds, np.float64), (gq,))
    gen = merged_candidates(posts, row_offsets)
    cands = [
        gen(qh, qb, float(t), int(qs))
        for qh, qb, t, qs in zip(q_hash_rows, q_bit_rows, thr, q_sizes)
    ]
    lens = [len(c.rec_ids) for c in cands]
    if sum(lens) == 0:
        return [np.zeros(0, dtype=np.int64) for _ in range(gq)], cands

    cand_rec = np.concatenate(
        [c.rec_ids for c in cands]).astype(np.int32)
    cand_q = np.repeat(np.arange(gq, dtype=np.int32), lens)
    scores = np.asarray(score_fn(cand_rec, cand_q), dtype=np.float32)

    out = []
    pos = 0
    thr32 = prune.f32_threshold(thr)
    for g, c in enumerate(cands):
        s = scores[pos : pos + lens[g]]
        pos += lens[g]
        out.append(c.rec_ids[s >= thr32[g]].astype(np.int64))
    return out, cands
