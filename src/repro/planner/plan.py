"""Per-batch query planning: dense sweep vs postings-pruned verify.

``choose_plan`` probes the postings (searchsorted only — no merge) for
the batch's query hashes, feeds the touched-entry count into the
core/cost_model.py query-path costs, and picks the cheaper path.
``plan="dense"``/``"pruned"`` force a path; ``"auto"`` is the default
everywhere. One hard guard keeps forced/auto pruning sound: thresholds
≤ 0 always run dense — every record trivially clears t, so a filter
built on "shares at least one hash/bit" would drop records the dense
sweep returns.

``pruned_batch`` is the shared execution skeleton: generate candidates
per query, score the ragged union in ONE backend call (the engines pass
a closure over kernels/gather_score.py or their estimator), and cut at
the float32-exact threshold so results match the dense sweep bit for
bit.

``pruned_topk`` extends the same machinery to top-k: candidates come
from the postings with their containment upper bounds, get scored in
bound-descending chunks, and scoring stops once the running k-th score
exceeds every remaining bound — the moving-threshold analogue of the
fixed-threshold cut. Non-candidates score exactly 0 under the
estimator, so the result is *identical* to the dense ranking under the
deterministic (score desc, record id asc) order.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import cost_model
from repro.obs.trace import stage
from repro.planner import prune
from repro.planner.postings import PostingsIndex

PLAN_MODES = ("auto", "dense", "pruned")


@dataclasses.dataclass
class QueryPlan:
    """One batch's routing decision (attached to indexes as .last_plan)."""

    path: str              # "dense" | "pruned"
    est_dense: float       # cost-model units
    est_pruned: float
    hits: int              # posting entries the batch's hashes/bits touch
    reason: str
    per_query_hits: np.ndarray | None = None   # int64[Gq] probe breakdown
    blocks: int = 0               # posting blocks touched (tail + buffer)
    tail_blocks: int = 0          # tail blocks touched (device expand bound)
    tail_dense_blocks: int = 0    # of which dense-bitmap blocks


def normalize_plan(plan: str | None) -> str:
    plan = "auto" if plan is None else plan
    if plan not in PLAN_MODES:
        raise ValueError(f"plan must be one of {PLAN_MODES}, got {plan!r}")
    return plan


def unpack_query_rows(qp):
    """Per-query planner inputs from an already-sketched query pack:
    (retained-hash rows, buffer-bit rows, query sizes)."""
    vals, lens = np.asarray(qp.values), np.asarray(qp.lengths)
    bufs = np.asarray(qp.buf)
    hash_rows = [vals[g, : lens[g]] for g in range(qp.num_records)]
    bit_rows = [prune.query_bits(bufs[g]) for g in range(qp.num_records)]
    return hash_rows, bit_rows, np.asarray(qp.sizes)


def gbkmv_plan_queries(core, queries):
    """Sketch a query batch and unpack the planner's per-query inputs.

    Shared by the host GB-KMV index and ShardedIndex (one definition, so
    the two planners can't drift). Returns (query pack, retained-hash
    rows, buffer-bit rows, query sizes).
    """
    from repro.sketchindex.distributed import batch_queries

    qp = batch_queries(core, queries)
    return (qp,) + unpack_query_rows(qp)


def _probe(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
) -> tuple[np.ndarray, int, int, int]:
    """ONE key-probe pass over the batch: (per-query posting entries,
    tail_blocks, tail_dense_blocks, buf_blocks).

    Header arithmetic only (cached row lengths, row_blocks diffs, a
    dense-kind cumsum) — nothing decodes, the buffer store included.
    This host probe feeds the COST MODEL and explain/bookkeeping only;
    the device path runs its own probe on the mirrored headers inside
    the fused jit (kernels/postings_merge.py), so no shape there depends
    on these numbers. ``posts`` may be a list (one per shard);
    everything sums over the mesh.
    """
    if isinstance(posts, PostingsIndex):
        posts = [posts]
    gq = len(q_hash_rows)
    per = np.zeros(gq, dtype=np.int64)
    tb = td = bb = 0
    with stage("planner.probe", queries=gq, shards=len(posts)) as span:
        # ONE flattened searchsorted per shard for the whole batch (the
        # per-query segment sums come back via np.add.at — int64-exact,
        # unlike a float-weighted bincount).
        if gq:
            allh = np.concatenate(
                [np.asarray(q, np.uint32).ravel() for q in q_hash_rows])
            hidx = np.repeat(np.arange(gq, dtype=np.int64),
                             [len(np.asarray(q).ravel())
                              for q in q_hash_rows])
            allb = np.concatenate(
                [np.asarray(q, np.int64).ravel() for q in q_bit_rows])
            bidx = np.repeat(np.arange(gq, dtype=np.int64),
                             [len(np.asarray(q).ravel())
                              for q in q_bit_rows])
        else:
            allh = np.zeros(0, np.uint32)
            hidx = allb = bidx = np.zeros(0, np.int64)
        for post in posts:
            keys = post.keys
            row_lens = post.tail_row_lengths()
            buf_lens = post.buf_row_lengths()
            rbt = post.tail.row_blocks.astype(np.int64)
            dcum = np.concatenate(
                [[0], np.cumsum((post.tail.meta >> np.uint32(13))
                                & np.uint32(1))]).astype(np.int64)
            rbb = post.buf.row_blocks.astype(np.int64)
            pos = np.searchsorted(keys, allh)
            ok = pos < len(keys)
            hit = np.zeros(len(allh), dtype=bool)
            hit[ok] = keys[pos[ok]] == allh[ok]
            r = pos[hit]
            np.add.at(per, hidx[hit], row_lens[r].astype(np.int64))
            tb += int((rbt[r + 1] - rbt[r]).sum())
            td += int((dcum[rbt[r + 1]] - dcum[rbt[r]]).sum())
            live = allb < post.buf.num_rows
            qb = allb[live]
            np.add.at(per, bidx[live], buf_lens[qb].astype(np.int64))
            bb += int((rbb[qb + 1] - rbb[qb]).sum())
        span.set(hits=int(per.sum()), tail_blocks=tb, buf_blocks=bb)
    return per, tb, td, bb


def probe_hits_per_query(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
) -> np.ndarray:
    """int64[Gq] posting entries a merge would touch per query —
    searchsorted + header arithmetic, no merge, no decode."""
    return _probe(posts, q_hash_rows, q_bit_rows)[0]


def probe_hits(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
) -> int:
    """Total posting entries a merge would touch for the batch."""
    return int(probe_hits_per_query(posts, q_hash_rows, q_bit_rows).sum())


def probe_block_stats(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
) -> tuple[int, int, int]:
    """(tail_blocks, tail_dense_blocks, buf_blocks) the batch touches."""
    return _probe(posts, q_hash_rows, q_bit_rows)[1:]


def choose_plan(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
    threshold: float,
    m: int,
    capacity: int,
    plan: str = "auto",
) -> QueryPlan:
    gq = len(q_hash_rows)
    plan = normalize_plan(plan)
    if float(threshold) <= 0.0:
        # Every record passes t ≤ 0; postings can't see zero-overlap pairs.
        return QueryPlan("dense", 0.0, np.inf, 0,
                         "threshold <= 0: pruning unsound, forced dense")
    per, tb, td, bb = _probe(posts, q_hash_rows, q_bit_rows)
    hits = int(per.sum())
    est_dense = cost_model.dense_sweep_cost(m, capacity, gq)
    est_pruned = cost_model.pruned_path_cost(hits, capacity, gq,
                                             blocks=tb + bb)
    blk = dict(blocks=tb + bb, tail_blocks=tb, tail_dense_blocks=td)
    if plan == "dense":
        return QueryPlan("dense", est_dense, est_pruned, hits, "forced",
                         per, **blk)
    if plan == "pruned":
        return QueryPlan("pruned", est_dense, est_pruned, hits, "forced",
                         per, **blk)
    path = "pruned" if est_pruned < est_dense else "dense"
    return QueryPlan(path, est_dense, est_pruned, hits,
                     f"auto: dense≈{est_dense:.3g} vs pruned≈{est_pruned:.3g}",
                     per, **blk)


def merged_candidates(
    posts: PostingsIndex | Sequence[PostingsIndex],
    row_offsets: Sequence[int] | None = None,
) -> Callable[..., prune.CandidateSet]:
    """Candidate generator over one postings index or a sharded list.

    ``row_offsets[s]`` maps shard-local record ids to global ids; shard
    ranges partition the records, so the cross-mesh union is a
    concatenation that stays sorted.
    """
    if isinstance(posts, PostingsIndex):
        posts = [posts]
    if row_offsets is None:
        row_offsets = [0] * len(posts)

    def gen(qh, qb, t, qs) -> prune.CandidateSet:
        parts = [prune.candidates_for(p, qh, qb, t, qs) for p in posts]
        return prune.CandidateSet(
            rec_ids=np.concatenate(
                [c.rec_ids + off for c, off in zip(parts, row_offsets)]),
            counts=np.concatenate([c.counts for c in parts]),
            o1=np.concatenate([c.o1 for c in parts]),
            hits=sum(c.hits for c in parts),
            pruned=sum(c.pruned for c in parts),
            blocks=sum(c.blocks for c in parts),
            skipped_blocks=sum(c.skipped_blocks for c in parts),
        )

    return gen


def pruned_batch(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hash_rows: Sequence[np.ndarray],
    q_bit_rows: Sequence[np.ndarray],
    q_sizes: Sequence[int],
    thresholds,
    score_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    row_offsets: Sequence[int] | None = None,
) -> tuple[list[np.ndarray], list[prune.CandidateSet]]:
    """Filter-and-verify for one query batch.

    ``score_fn(cand_rec i32[P], cand_q i32[P]) -> f32[P]`` scores the
    flattened ragged candidate list with the engine's own estimator (one
    backend dispatch for the whole batch). Returns (per-query hit ids,
    per-query candidate sets) — ids are bit-identical to the dense
    sweep's ``np.nonzero(scores >= t)`` for each query.
    """
    gq = len(q_hash_rows)
    thr = np.broadcast_to(np.asarray(thresholds, np.float64), (gq,))
    gen = merged_candidates(posts, row_offsets)
    with stage("planner.candidates", queries=gq) as span:
        cands = [
            gen(qh, qb, float(t), int(qs))
            for qh, qb, t, qs in zip(q_hash_rows, q_bit_rows, thr, q_sizes)
        ]
        lens = [len(c.rec_ids) for c in cands]
        span.set(candidates=int(sum(lens)),
                 blocks=sum(c.blocks for c in cands),
                 skipped_blocks=sum(c.skipped_blocks for c in cands))
    if sum(lens) == 0:
        return [np.zeros(0, dtype=np.int64) for _ in range(gq)], cands

    cand_rec = np.concatenate(
        [c.rec_ids for c in cands]).astype(np.int32)
    cand_q = np.repeat(np.arange(gq, dtype=np.int32), lens)
    with stage("planner.score", candidates=len(cand_rec)):
        # np.asarray forces any device result to host — the span closes
        # only after the scores actually exist.
        scores = np.asarray(score_fn(cand_rec, cand_q), dtype=np.float32)

    out = []
    pos = 0
    thr32 = prune.f32_threshold(thr)
    for g, c in enumerate(cands):
        s = scores[pos : pos + lens[g]]
        pos += lens[g]
        out.append(c.rec_ids[s >= thr32[g]].astype(np.int64))
    return out, cands


def topk_select(rec_ids, scores, k: int,
                num_records: int) -> tuple[np.ndarray, np.ndarray]:
    """The top-k output head shared by every host route.

    One implementation of the ranking contract the device route's
    ``lax.top_k`` produces over a full score matrix: score descending,
    ties by ascending record id, and records absent from ``rec_ids`` (or
    scoring exactly 0 — the same tie pool, since absent records score 0
    under every estimator) filling any shortfall in ascending-id order.
    The dense sweep (:meth:`repro.api._IndexBase.topk`) and the host
    :func:`pruned_topk` both route here, so host and device rankings
    can only drift apart in one place.
    """
    k = min(int(k), int(num_records))
    if k <= 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float32)
    ids = np.asarray(rec_ids, np.int64)
    s = np.asarray(scores, np.float32)
    pos_mask = s > 0
    ids, s = ids[pos_mask], s[pos_mask]
    order = np.lexsort((ids, -s))           # score desc, id asc
    ids, s = ids[order][:k], s[order][:k]
    if len(ids) < k:
        # Zero-score records, ascending id — the dense tail among ties
        # at 0.
        fill = np.setdiff1d(np.arange(num_records, dtype=np.int64),
                            ids)[: k - len(ids)]
        ids = np.concatenate([ids, fill])
        s = np.concatenate([s, np.zeros(len(fill), np.float32)])
    return ids.astype(np.int64), s.astype(np.float32)


def pruned_topk(
    posts: PostingsIndex | Sequence[PostingsIndex],
    q_hashes: np.ndarray,
    q_bits: np.ndarray,
    q_size: int,
    k: int,
    score_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    num_records: int,
    row_offsets: Sequence[int] | None = None,
    chunk: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k via postings-driven upper-bound pruning.

    Candidates are generated at threshold 0 (i.e. every record sharing a
    retained hash or buffer bit), each carrying the same containment
    upper bound the threshold filter uses. They are scored in
    bound-descending chunks; once k scores are in hand and every
    remaining bound (slack-inflated, so float32 rounding of the dense
    scores cannot sneak past it) sits strictly below the running k-th
    score, the rest can neither enter nor tie into the top-k and scoring
    stops. Records outside the candidate set score exactly 0 under the
    estimator and fill any shortfall in ascending-id order — matching
    the dense ranking's deterministic (score desc, id asc) tie rule
    entry for entry.
    """
    k = min(int(k), int(num_records))
    empty = (np.zeros(0, np.int64), np.zeros(0, np.float32))
    if k <= 0:
        return empty
    gen = merged_candidates(posts, row_offsets)
    cand = gen(np.asarray(q_hashes, np.uint32), np.asarray(q_bits, np.int64),
               0.0, int(q_size))
    n = len(cand.rec_ids)

    scored_ids: list[np.ndarray] = []
    scored_s: list[np.ndarray] = []
    if n:
        bound = prune.tail_bound(np.sort(np.asarray(q_hashes, np.uint32)))
        ub = (cand.o1.astype(np.float64)
              + bound[np.minimum(cand.counts, len(bound) - 1)]) \
            / max(int(q_size), 1) * prune._BOUND_SLACK
        order = np.argsort(-ub, kind="stable")
        chunk = int(chunk) if chunk else max(4 * k, 64)
        kth = -np.inf
        done = 0
        pos = 0
        while pos < n:
            sel = order[pos : pos + chunk]
            if done >= k and ub[sel[0]] < kth:
                break               # bounds descend: nothing left can enter
            s = np.asarray(score_fn(cand.rec_ids[sel].astype(np.int32),
                                    np.zeros(len(sel), np.int32)),
                           dtype=np.float32)
            scored_ids.append(cand.rec_ids[sel])
            scored_s.append(s)
            done += len(sel)
            pos += len(sel)
            if done >= k:
                alls = np.concatenate(scored_s)
                kth = float(np.partition(alls, len(alls) - k)[len(alls) - k])

    ids = np.concatenate(scored_ids) if scored_ids else np.zeros(0, np.int64)
    s = np.concatenate(scored_s) if scored_s else np.zeros(0, np.float32)
    # Zero-scored candidates (possible for plain KMV: a shared value can
    # fall outside the top-k union) belong to the same tie pool as
    # non-candidates; whenever scoring stopped early the running k-th
    # score was positive, so dropped/unscored rows cannot matter. The
    # shared head applies the verified (score desc, id asc, zero-fill)
    # contract.
    return topk_select(ids, s, k, num_records)
