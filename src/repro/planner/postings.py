"""Block-compressed inverted index over retained sketch hashes + buffer
bits — the arena's single at-rest, on-device, and on-disk postings format.

The filter half of the planner's filter-and-verify pipeline: a record X
can share tail mass with Q only through hash values *both* sketches
retained, and buffer mass only through frozen top-r bits both have set —
so postings over exactly those two keyspaces enumerate every record with
a non-zero estimated intersection (prune.py turns the match counts into
a sound containment upper bound).

The flat CSR layout of PR 2/3 stored one int32 per posting entry plus
int64 row pointers — ~2× the sketch bytes at planner-bench scale. The
b-bit minwise observation (Li & König) applies here unchanged: posting
entries are *sorted record ids*, so consecutive deltas need ~log2(gap)
bits, not 32. Layout (all host numpy, per :class:`BlockStore`):

    row_blocks int32[nrows+1]  CSR over BLOCKS: row r owns blocks
                               row_blocks[r] : row_blocks[r+1]
    first      int32[NB]       min record id in the block (= its 1st id)
    last       int32[NB]       max record id in the block (= its last id)
    meta       uint32[NB]      (count-1) | bitwidth << 8 | kind << 13
    off        int64[NB+1]     payload word offsets per block
    payload    uint32[P]       bitpacked block bodies

Each block covers up to ``BLOCK`` (128) consecutive entries of one row.
Two roaring-style body kinds, chosen per block by encoded size:

    sparse (kind 0)   count-1 deltas ``id[i] - id[i-1]``, bitpacked at
                      the block's max-delta bitwidth (0 bits when the
                      block holds one entry or only duplicate ids)
    dense  (kind 1)   a bitmap of ``last - first + 1`` bits; chosen only
                      when strictly smaller than sparse AND the ids are
                      strictly ascending (a bitmap cannot represent the
                      duplicate ids a 32-bit hash collision inside one
                      record produces)

A :class:`PostingsIndex` is ``keys`` (distinct retained hash values,
ascending) + a tail store (one row per key) + a buffer store (one row
per frozen buffer bit). ``offsets``/``rec_ids``/``buf_offsets``/
``buf_rec_ids`` survive as lazily-decoded *views* so structural tests
and host debugging read the classic CSR; the blocked arrays are what is
stored, mirrored to device, and serialized.

Incremental maintenance under ``insert`` (sketchindex/dynamic.py): the
fixed budget only ever *lowers* τ, and after an insert every stored row
holds exactly its old hashes ≤ τ' — so maintenance is

    deletion:  drop every posting row with key > τ'. Keys are sorted by
               hash value and blocks are laid out in key order, so this
               is a prefix truncation of keys, blocks, AND payload —
               O(1) + slices.
    append:    new record ids exceed every stored id, so only rows that
               actually receive entries change; their blocks re-encode
               (full 128-entry blocks are byte-identical to a fresh
               rebuild's, because blocks are independent and the
               segmentation boundaries are deterministic) and splice
               back between untouched block runs with one vectorized
               gather. The frozen top-r buffer never deletes.

No raw-data access and no re-hashing of old rows, mirroring the dynamic
index's own τ-retightening contract; incremental update == fresh
rebuild, structurally, block for block (tests assert it).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sketches import PackedSketches

BLOCK = 128          # max entries per block
_BW_SHIFT = 8        # meta bit layout: count-1 [0:7], bitwidth [8:13],
_KIND_SHIFT = 13     # kind [13]
_CNT_MASK = np.uint32(0x7F)
_BW_MASK = np.uint32(0x1F)
# Dense bodies never exceed this many words: sparse needs at most
# ceil(127·31/32) = 124 words, and dense is only chosen when strictly
# smaller — so a static 124-word window always covers a dense body
# (the device decode relies on this bound).
DENSE_MAX_WORDS = 124


@dataclasses.dataclass
class BlockStore:
    """One keyspace's block-compressed posting lists."""

    row_blocks: np.ndarray   # int32[nrows+1]
    first: np.ndarray        # int32[NB]
    last: np.ndarray         # int32[NB]
    meta: np.ndarray         # uint32[NB]
    off: np.ndarray          # int64[NB+1]
    payload: np.ndarray      # uint32[P]

    @property
    def num_rows(self) -> int:
        return len(self.row_blocks) - 1

    @property
    def num_blocks(self) -> int:
        return len(self.first)

    def counts(self) -> np.ndarray:
        """int64[NB] entries per block (from the packed meta)."""
        return ((self.meta & _CNT_MASK) + 1).astype(np.int64)

    def row_lengths(self) -> np.ndarray:
        """int64[nrows] entries per row (header arithmetic, no decode)."""
        ccum = np.concatenate([[0], np.cumsum(self.counts())])
        rb = self.row_blocks.astype(np.int64)
        return ccum[rb[1:]] - ccum[rb[:-1]]

    @property
    def nnz(self) -> int:
        return int(self.counts().sum())

    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in (
            self.row_blocks, self.first, self.last, self.meta,
            self.off, self.payload))


def _ragged_take(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenate ``[starts[i], starts[i]+lens[i])`` ranges (int64)."""
    lens = np.asarray(lens, np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    cum = np.cumsum(lens)
    out = np.arange(total, dtype=np.int64)
    seg = np.searchsorted(cum, out, side="right")
    return np.asarray(starts, np.int64)[seg] + out - (cum[seg] - lens[seg])


def _bitlen(x: np.ndarray) -> np.ndarray:
    """int32 bit lengths (0 for 0). Exact for values < 2**53."""
    x = np.asarray(x, np.int64)
    out = np.zeros(x.shape, np.int32)
    nz = x > 0
    if nz.any():
        out[nz] = (np.floor(np.log2(x[nz].astype(np.float64)))
                   .astype(np.int32) + 1)
    return out


def encode_store(offsets: np.ndarray, rec_ids: np.ndarray) -> BlockStore:
    """Encode a flat CSR (row pointers + sorted-per-row ids) into blocks."""
    offsets = np.asarray(offsets, np.int64)
    rec = np.asarray(rec_ids, np.int64)
    nrows = len(offsets) - 1
    lens = np.diff(offsets)
    nblk_row = -(-lens // BLOCK)
    row_blocks = np.concatenate([[0], np.cumsum(nblk_row)]).astype(np.int32)
    nb = int(row_blocks[-1])
    if nb == 0:
        return BlockStore(
            row_blocks=row_blocks,
            first=np.zeros(0, np.int32), last=np.zeros(0, np.int32),
            meta=np.zeros(0, np.uint32), off=np.zeros(1, np.int64),
            payload=np.zeros(0, np.uint32))

    rowid = np.repeat(np.arange(nrows), nblk_row)
    within = np.arange(nb, dtype=np.int64) - row_blocks[rowid]
    bstart = offsets[rowid] + within * BLOCK
    bend = np.minimum(bstart + BLOCK, offsets[rowid + 1])
    cnt = (bend - bstart).astype(np.int64)
    first = rec[bstart].astype(np.int32)
    last = rec[bend - 1].astype(np.int32)

    # Deltas, zeroed at block starts (blocks tile rec positions exactly,
    # so reduceat segments over ``bstart`` are the blocks).
    d = np.zeros(len(rec), np.int64)
    d[1:] = rec[1:] - rec[:-1]
    d[bstart] = 0
    md = np.maximum.reduceat(d, bstart)
    d_lo = d.copy()
    d_lo[bstart] = np.int64(2) ** 62
    mind = np.minimum.reduceat(d_lo, bstart)    # 2^62 for 1-entry blocks

    bw = _bitlen(md)
    span = last.astype(np.int64) - first + 1
    w_sparse = ((cnt - 1) * bw + 31) // 32
    w_dense = (span + 31) // 32
    dense = (mind >= 1) & (w_dense < w_sparse) & (w_dense <= DENSE_MAX_WORDS)
    words = np.where(dense, w_dense, w_sparse)
    off = np.concatenate([[0], np.cumsum(words)]).astype(np.int64)
    payload = np.zeros(int(off[-1]), np.uint32)

    blkof = np.repeat(np.arange(nb, dtype=np.int64), cnt)
    pos_in_blk = np.arange(len(rec), dtype=np.int64) - bstart[blkof]

    # -- sparse bodies: bitpack the count-1 deltas at the block's width.
    sel = (pos_in_blk > 0) & ~dense[blkof] & (bw[blkof] > 0)
    if sel.any():
        b = blkof[sel]
        bitpos = (pos_in_blk[sel] - 1) * bw[b]
        word = off[b] + (bitpos >> 5)
        shift = (bitpos & 31).astype(np.uint64)
        val = d[sel].astype(np.uint64) << shift
        np.bitwise_or.at(payload, word, (val & 0xFFFFFFFF).astype(np.uint32))
        hi = (val >> np.uint64(32)).astype(np.uint32)
        spill = hi != 0
        np.bitwise_or.at(payload, word[spill] + 1, hi[spill])

    # -- dense bodies: one bit per id over the block's span.
    seld = dense[blkof]
    if seld.any():
        b = blkof[seld]
        bit = rec[seld] - first[b]
        np.bitwise_or.at(payload, off[b] + (bit >> 5),
                         (np.uint32(1) << (bit & 31).astype(np.uint32)))

    meta = ((cnt - 1).astype(np.uint32)
            | (bw.astype(np.uint32) << np.uint32(_BW_SHIFT))
            | (dense.astype(np.uint32) << np.uint32(_KIND_SHIFT)))
    return BlockStore(row_blocks=row_blocks, first=first, last=last,
                      meta=meta, off=off, payload=payload)


def decode_blocks(store: BlockStore, blks: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """(ids int32[total], counts int64[len(blks)]) for a block subset.

    ``blks`` may be any selection, REPEATS INCLUDED — a duplicated
    query hash merges its posting list once per occurrence, so the
    candidate-generation caller relies on repeated block ids decoding
    once each per occurrence (everything here is a pure gather, never
    an in-place write keyed by block id). Decoded entries come back
    grouped in ``blks`` order, ascending within each block.
    """
    blks = np.asarray(blks, np.int64)
    if len(blks) == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.int64)
    meta = store.meta[blks]
    cnt = ((meta & _CNT_MASK) + 1).astype(np.int64)
    bw = ((meta >> np.uint32(_BW_SHIFT)) & _BW_MASK).astype(np.int64)
    dense = (meta >> np.uint32(_KIND_SHIFT)) & np.uint32(1)
    first = store.first[blks].astype(np.int64)
    off = store.off[blks]
    pay = store.payload

    total = int(cnt.sum())
    estart = np.concatenate([[0], np.cumsum(cnt)])
    eblk = np.repeat(np.arange(len(blks), dtype=np.int64), cnt)
    erank = np.arange(total, dtype=np.int64) - estart[eblk]

    # -- sparse: unpack deltas, per-block prefix-sum back to ids.
    dall = np.zeros(total, np.int64)
    read = (dense[eblk] == 0) & (erank >= 1) & (bw[eblk] > 0)
    if read.any():
        b = eblk[read]
        bitpos = (erank[read] - 1) * bw[b]
        w = off[b] + (bitpos >> 5)
        w0 = pay[w].astype(np.uint64)
        w1 = pay[np.minimum(w + 1, max(len(pay) - 1, 0))].astype(np.uint64)
        shift = (bitpos & 31).astype(np.uint64)
        mask = (np.uint64(1) << bw[b].astype(np.uint64)) - np.uint64(1)
        dall[read] = ((((w1 << np.uint64(32)) | w0) >> shift) & mask
                      ).astype(np.int64)
    cs = np.cumsum(dall)
    base = cs[estart[:-1]] - dall[estart[:-1]]
    ids = first[eblk] + (cs - base[eblk])

    # -- dense: unpack bitmaps, set-bit positions are the ids.
    db = np.nonzero(dense)[0]
    if len(db):
        wcnt = (store.off[blks[db] + 1] - off[db]).astype(np.int64)
        widx = _ragged_take(off[db], wcnt)
        bits = ((pay[widx][:, None] >> np.arange(32, dtype=np.uint32))
                & np.uint32(1)).astype(bool)
        wrow, bpos = np.nonzero(bits)        # word order == block order
        wblk = np.repeat(np.arange(len(db)), wcnt)[wrow]
        wbase = np.concatenate([[0], np.cumsum(wcnt)])[:-1]
        dense_ids = (first[db[wblk]]
                     + (widx[wrow] - off[db[wblk]]) * 32 + bpos)
        tgt = _ragged_take(estart[db], cnt[db])
        ids[tgt] = dense_ids
        del wbase
    return ids.astype(np.int32), cnt


def decode_store(store: BlockStore) -> tuple[np.ndarray, np.ndarray]:
    """Full decode → classic flat CSR (offsets int64[nrows+1], ids)."""
    ids, _ = decode_blocks(store, np.arange(store.num_blocks))
    ccum = np.concatenate([[0], np.cumsum(store.counts())])
    return ccum[store.row_blocks.astype(np.int64)].astype(np.int64), ids


def _merge_stores(a: BlockStore, b: BlockStore, use_b: np.ndarray,
                  row: np.ndarray) -> BlockStore:
    """New store whose row i is row ``row[i]`` of ``b`` if ``use_b[i]``
    else of ``a`` — pure block-level gathers, nothing re-encodes."""
    use_b = np.asarray(use_b, bool)
    row = np.asarray(row, np.int64)
    nb_a = a.num_blocks
    first = np.concatenate([a.first, b.first])
    last = np.concatenate([a.last, b.last])
    meta = np.concatenate([a.meta, b.meta])
    words = np.concatenate([np.diff(a.off), np.diff(b.off)])
    pstart = np.concatenate([a.off[:-1], b.off[:-1] + len(a.payload)])
    pay = np.concatenate([a.payload, b.payload])

    rb_a = a.row_blocks.astype(np.int64)
    rb_b = b.row_blocks.astype(np.int64)
    start = np.where(use_b, rb_b[np.minimum(row, b.num_rows)] + nb_a,
                     rb_a[np.minimum(row, a.num_rows)])
    nbl = np.where(use_b,
                   rb_b[np.minimum(row + 1, b.num_rows)]
                   - rb_b[np.minimum(row, b.num_rows)],
                   rb_a[np.minimum(row + 1, a.num_rows)]
                   - rb_a[np.minimum(row, a.num_rows)])
    src = _ragged_take(start, nbl)
    row_blocks = np.concatenate([[0], np.cumsum(nbl)]).astype(np.int32)
    w2 = words[src]
    off2 = np.concatenate([[0], np.cumsum(w2)]).astype(np.int64)
    return BlockStore(
        row_blocks=row_blocks, first=first[src], last=last[src],
        meta=meta[src], off=off2,
        payload=pay[_ragged_take(pstart[src], w2)].astype(np.uint32))


def _append_store(store: BlockStore, new_offsets: np.ndarray,
                  new_ids: np.ndarray, rows: np.ndarray,
                  num_rows: int) -> BlockStore:
    """Append ``new_ids`` (CSR rows over ``rows``, every id exceeding all
    stored ids) to a fixed-row-count store. Only the receiving rows
    decode + re-encode; everything else splices through untouched."""
    new_lens = np.diff(np.asarray(new_offsets, np.int64))
    touched = new_lens > 0
    rows = np.asarray(rows, np.int64)[touched]
    new_lens = new_lens[touched]
    if len(rows) == 0:
        return store
    starts = np.asarray(new_offsets, np.int64)[:-1][touched]
    new_ids = np.asarray(new_ids, np.int32)

    rb = store.row_blocks.astype(np.int64)
    old_blks = _ragged_take(rb[rows], rb[rows + 1] - rb[rows])
    old_ids, _ = decode_blocks(store, old_blks)
    old_lens = store.row_lengths()[rows]

    comb_lens = old_lens + new_lens
    comb_off = np.concatenate([[0], np.cumsum(comb_lens)]).astype(np.int64)
    comb = np.empty(int(comb_off[-1]), np.int32)
    comb[_ragged_take(comb_off[:-1], old_lens)] = old_ids
    comb[_ragged_take(comb_off[:-1] + old_lens, new_lens)] = \
        new_ids[_ragged_take(starts, new_lens)]
    enc = encode_store(comb_off, comb)

    use_b = np.zeros(num_rows, bool)
    use_b[rows] = True
    src_row = np.arange(num_rows, dtype=np.int64)
    src_row[rows] = np.arange(len(rows))
    return _merge_stores(store, enc, use_b, src_row)


@dataclasses.dataclass
class PostingsIndex:
    """Block-compressed inverted postings over one engine's sketches."""

    keys: np.ndarray          # uint32[U] distinct retained hashes, asc
    tail: BlockStore          # one row per key
    buf: BlockStore           # one row per frozen buffer bit
    num_records: int
    tau: np.uint32            # max retained key at build/update time

    def __post_init__(self):
        self._decoded_tail = None   # (offsets, rec_ids) cache
        self._decoded_buf = None
        self._row_lens = None       # tail row_lengths cache (probe path)
        self._buf_row_lens = None   # buffer row_lengths cache (probe path)

    @property
    def nnz(self) -> int:
        return self.tail.nnz

    def nbytes(self) -> int:
        """At-rest bytes: keys + both block stores (decoded-view caches
        are debug/test scaffolding and intentionally excluded)."""
        return int(self.keys.nbytes) + self.tail.nbytes() + self.buf.nbytes()

    # -- decoded CSR views (lazy, cached per immutable instance) ----------

    @property
    def offsets(self) -> np.ndarray:
        if self._decoded_tail is None:
            self._decoded_tail = decode_store(self.tail)
        return self._decoded_tail[0]

    @property
    def rec_ids(self) -> np.ndarray:
        if self._decoded_tail is None:
            self._decoded_tail = decode_store(self.tail)
        return self._decoded_tail[1]

    @property
    def buf_offsets(self) -> np.ndarray:
        if self._decoded_buf is None:
            self._decoded_buf = decode_store(self.buf)
        return self._decoded_buf[0]

    @property
    def buf_rec_ids(self) -> np.ndarray:
        if self._decoded_buf is None:
            self._decoded_buf = decode_store(self.buf)
        return self._decoded_buf[1]

    def posting_lengths(self, hashes: np.ndarray) -> np.ndarray:
        """int64[n] — posting-list length per query hash (0 when absent).

        One searchsorted probe over keys + header arithmetic; used by
        the plan cost model to estimate merge work *without* decoding.
        """
        h = np.asarray(hashes, dtype=np.uint32)
        pos = np.searchsorted(self.keys, h)
        ok = pos < len(self.keys)
        hit = np.zeros(len(h), dtype=bool)
        hit[ok] = self.keys[pos[ok]] == h[ok]
        out = np.zeros(len(h), dtype=np.int64)
        out[hit] = self.tail_row_lengths()[pos[hit]]
        return out

    def tail_row_lengths(self) -> np.ndarray:
        """int64[U] entries per key — header arithmetic, cached."""
        if self._row_lens is None:
            self._row_lens = self.tail.row_lengths()
        return self._row_lens

    def buf_row_lengths(self) -> np.ndarray:
        """int64[R] entries per buffer bit — header arithmetic, cached
        (the probe path must never decode the buffer store)."""
        if self._buf_row_lens is None:
            self._buf_row_lens = self.buf.row_lengths()
        return self._buf_row_lens


def _bit_matrix(buf: np.ndarray) -> np.ndarray:
    """bool[m, W*32] — bit j of word j//32 at position j%32 (sketches.py)."""
    buf = np.asarray(buf, dtype=np.uint32)
    m, w = buf.shape
    if w == 0:
        return np.zeros((m, 0), dtype=bool)
    shifts = np.arange(32, dtype=np.uint32)
    bits = (buf[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(m, w * 32).astype(bool)


def _row_pairs(s: PackedSketches, rows: slice) -> tuple[np.ndarray, np.ndarray]:
    """Flat (hash, record) pairs over ``rows`` of the packed values."""
    vals = np.asarray(s.values)[rows]
    lens = np.asarray(s.lengths)[rows]
    n, c = vals.shape
    live = np.arange(c)[None, :] < lens[:, None]
    h = vals[live]
    start = rows.start or 0
    rec = np.broadcast_to(np.arange(start, start + n, dtype=np.int32)[:, None],
                          (n, c))[live]
    return h.astype(np.uint32), rec


def _csr_from_pairs(h: np.ndarray, rec: np.ndarray):
    """Sort pairs by (hash, record) and group into (keys, offsets, rec_ids)."""
    order = np.lexsort((rec, h))
    h, rec = h[order], rec[order]
    keys, starts = np.unique(h, return_index=True)
    offsets = np.concatenate([starts, [len(h)]]).astype(np.int64)
    return keys, offsets, rec.astype(np.int32)


def _buf_csr(buf: np.ndarray, row_offset: int = 0):
    """(offsets int64[R+1], rec_ids int32) from a bitmap block."""
    bits = _bit_matrix(buf)
    m, r = bits.shape
    if r == 0:
        return np.zeros(1, dtype=np.int64), np.zeros(0, dtype=np.int32)
    bit_idx, recs = np.nonzero(bits.T)       # sorted by bit, then record
    counts = np.bincount(bit_idx, minlength=r)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return offsets, (recs + row_offset).astype(np.int32)


def from_flat(keys, offsets, rec_ids, buf_offsets, buf_rec_ids,
              num_records: int, tau) -> PostingsIndex:
    """Encode a classic flat CSR (the PR 2/3 layout, still what v2 save
    files carry) into the blocked format."""
    return PostingsIndex(
        keys=np.asarray(keys, np.uint32),
        tail=encode_store(offsets, rec_ids),
        buf=encode_store(buf_offsets, buf_rec_ids),
        num_records=int(num_records), tau=np.uint32(tau))


def build_postings(sketches: PackedSketches) -> PostingsIndex:
    """Build hash + buffer postings from a packed index in one pass."""
    m = sketches.num_records
    h, rec = _row_pairs(sketches, slice(0, m))
    keys, offsets, rec_ids = _csr_from_pairs(h, rec)
    buf_offsets, buf_rec_ids = _buf_csr(np.asarray(sketches.buf))
    tau = keys[-1] if len(keys) else np.uint32(0)
    return from_flat(keys, offsets, rec_ids, buf_offsets, buf_rec_ids,
                     m, tau)


def build_postings_device(sketches: PackedSketches):
    """Device-fused postings build: ``(PostingsIndex, DevicePostings)``.

    For a device-built arena (columns already jnp arrays) the blocked
    TAIL store is encoded on device by
    :func:`repro.kernels.hash_threshold.fused_encode_postings` — build →
    postings → query all share one device residency, closing the seam
    where a device build re-encoded postings on host. The host
    :class:`PostingsIndex` copy is still materialized here, once, for
    the host consumers (cost-model probe, explain, save, shard slicing)
    — that transfer is per build, not per batch, and the device mirrors
    are adopted directly, not re-uploaded. Buffer postings are
    host-encoded as always: they never ship to the device (o1 comes from
    the resident packed bitmaps). Bit-identical to
    :func:`build_postings`.
    """
    import jax.numpy as jnp

    from repro.core.arena import DevicePostings
    from repro.kernels.hash_threshold import fused_encode_postings

    m = sketches.num_records
    cap = int(sketches.values.shape[1])
    dev = fused_encode_postings(sketches.values, sketches.lengths,
                                m=m, cap=cap)
    keys_h = np.asarray(dev["keys"])
    meta_h = np.asarray(dev["meta"], np.uint32)
    tail = BlockStore(
        row_blocks=np.asarray(dev["row_blocks"], np.int32),
        first=np.asarray(dev["first"], np.int32),
        last=np.asarray(dev["last"], np.int32),
        meta=meta_h,
        off=np.asarray(dev["off"]).astype(np.int64),
        payload=np.asarray(dev["payload"], np.uint32))
    buf_offsets, buf_rec_ids = _buf_csr(np.asarray(sketches.buf))
    tau = keys_h[-1] if len(keys_h) else np.uint32(0)
    post = PostingsIndex(
        keys=keys_h, tail=tail,
        buf=encode_store(buf_offsets, buf_rec_ids),
        num_records=m, tau=np.uint32(tau))
    dpost = DevicePostings(
        keys=dev["keys"],
        row_blocks=jnp.asarray(dev["row_blocks"], jnp.int32),
        first=jnp.asarray(dev["first"], jnp.int32),
        last=jnp.asarray(dev["last"], jnp.int32),
        meta=jnp.asarray(dev["meta"], jnp.uint32),
        off=jnp.asarray(dev["off"], jnp.int32),
        payload=dev["payload"],
        num_records=m,
        has_dense=bool(np.any((meta_h >> np.uint32(13)) & np.uint32(1))))
    return post, dpost


def truncate_postings(post: PostingsIndex, tau: np.uint32) -> PostingsIndex:
    """τ-retighten = prefix truncation of the hash-sorted keyspace.

    Deletion-only half of the incremental maintenance contract: every key
    above the new (lower) τ disappears; surviving rows are untouched
    because refiltering a row at τ' keeps exactly its hashes ≤ τ'.
    Blocks are laid out in key order, so keys, headers, and payload all
    truncate by prefix slices. The frozen buffer postings never delete.
    """
    cut = int(np.searchsorted(post.keys, np.uint32(tau), side="right"))
    t = post.tail
    nbk = int(t.row_blocks[cut])
    tail = BlockStore(
        row_blocks=t.row_blocks[: cut + 1], first=t.first[:nbk],
        last=t.last[:nbk], meta=t.meta[:nbk], off=t.off[: nbk + 1],
        payload=t.payload[: int(t.off[nbk])])
    return PostingsIndex(keys=post.keys[:cut], tail=tail, buf=post.buf,
                         num_records=post.num_records, tau=np.uint32(tau))


def append_rows(
    post: PostingsIndex,
    sketches: PackedSketches,
    lo: int,
    hi: int,
    rec_offset: int = 0,
) -> PostingsIndex:
    """Append rows ``[lo, hi)`` of ``sketches`` to an existing postings
    index (the append half of incremental maintenance).

    ``rec_offset`` shifts the appended record ids — shard-local postings
    pass ``-shard_lo`` so ids stay local to the shard's row slice. The
    appended ids must exceed every id already present (insert-at-the-end
    monotonicity), which holds for both the global postings and the
    per-shard slices because new records always pack after old ones.
    Only the rows that receive entries re-encode; the result is block-
    for-block identical to a fresh rebuild because blocks never span
    rows and the 128-entry segmentation is deterministic.
    """
    keys, tail = post.keys, post.tail

    # -- tail: merge the new rows' (hash, record) pairs, key by key.
    h_new, rec_new = _row_pairs(sketches, slice(lo, hi))
    rec_new = (rec_new.astype(np.int64) + rec_offset).astype(np.int32)
    if len(h_new):
        nk, noff, nrec = _csr_from_pairs(h_new, rec_new)
        merged = np.union1d(keys, nk).astype(np.uint32)
        posn = np.searchsorted(nk, merged)
        is_new = np.zeros(len(merged), bool)
        okn = posn < len(nk)
        is_new[okn] = nk[posn[okn]] == merged[okn]

        # CSR of the new pairs over ALL merged rows (zero-length where
        # the key got nothing), so _append_store sees one row space.
        lens_m = np.zeros(len(merged), np.int64)
        lens_m[is_new] = np.diff(noff)
        off_m = np.concatenate([[0], np.cumsum(lens_m)]).astype(np.int64)

        # Rows new to the key set enter the store as empty rows first
        # (pure row_blocks splice), then receive their entries.
        in_old = np.zeros(len(merged), bool)
        poso = np.searchsorted(keys, merged)
        oko = poso < len(keys)
        in_old[oko] = keys[poso[oko]] == merged[oko]
        empty = BlockStore(
            row_blocks=np.zeros(2, np.int32),
            first=np.zeros(0, np.int32), last=np.zeros(0, np.int32),
            meta=np.zeros(0, np.uint32), off=np.zeros(1, np.int64),
            payload=np.zeros(0, np.uint32))
        widened = _merge_stores(tail, empty, ~in_old,
                                np.where(in_old, poso, 0))
        tail = _append_store(widened, off_m, nrec,
                             np.arange(len(merged)), len(merged))
        keys = merged

    # -- buffer: frozen top-r set, new rows append at each bit's row.
    buf = post.buf
    w = np.asarray(sketches.buf).shape[1]
    if w:
        new_off, new_recs = _buf_csr(np.asarray(sketches.buf)[lo:hi],
                                     row_offset=lo + rec_offset)
        buf = _append_store(buf, new_off, new_recs,
                            np.arange(buf.num_rows), buf.num_rows)

    return PostingsIndex(keys=keys, tail=tail, buf=buf,
                         num_records=post.num_records + (hi - lo),
                         tau=post.tau)


def update_postings(
    post: PostingsIndex, sketches: PackedSketches, tau: np.uint32
) -> PostingsIndex:
    """Maintain postings across one ``insert`` (deletion + append only).

    ``sketches`` is the repacked index AFTER the insert: rows
    ``[0, post.num_records)`` are the old records refiltered at the new
    global threshold ``tau`` (τ only decreases), rows beyond are new.
    """
    return append_rows(truncate_postings(post, tau), sketches,
                       post.num_records, sketches.num_records)


def _stores_equal(a: BlockStore, b: BlockStore) -> bool:
    return (np.array_equal(a.row_blocks, b.row_blocks)
            and np.array_equal(a.first, b.first)
            and np.array_equal(a.last, b.last)
            and np.array_equal(a.meta, b.meta)
            and np.array_equal(a.off, b.off)
            and np.array_equal(a.payload, b.payload))


def postings_equal(a: PostingsIndex, b: PostingsIndex) -> bool:
    """Structural equality (tests: incremental update == fresh rebuild) —
    compared on the blocked arrays, so segmentation and per-block
    encoding choices must match exactly, not just the decoded ids."""
    return (a.num_records == b.num_records
            and np.array_equal(a.keys, b.keys)
            and _stores_equal(a.tail, b.tail)
            and _stores_equal(a.buf, b.buf))
