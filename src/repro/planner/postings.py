"""CSR-packed inverted index over retained sketch hashes + buffer bits.

The filter half of the planner's filter-and-verify pipeline: a record X
can share tail mass with Q only through hash values *both* sketches
retained, and buffer mass only through frozen top-r bits both have set —
so postings over exactly those two keyspaces enumerate every record with
a non-zero estimated intersection (prune.py turns the match counts into
a sound containment upper bound).

Layout (all host numpy, built once from a :class:`PackedSketches`):

    keys       uint32[U]    distinct retained hash values, ascending
    offsets    int64[U+1]   CSR row pointers into rec_ids
    rec_ids    int32[nnz]   record ids per key, ascending within a key
    buf_offsets int64[R+1]  one row per frozen buffer bit (R = W·32)
    buf_rec_ids int32[bnnz] record ids with that bit set, ascending

Incremental maintenance under ``insert`` (sketchindex/dynamic.py): the
fixed budget only ever *lowers* τ, and after an insert every stored row
holds exactly its old hashes ≤ τ' — so maintenance is

    deletion:  drop every posting with key > τ'. Keys are sorted by hash
               value, so this is a prefix truncation, O(1) + one slice.
    append:    merge the new rows' (hash, record) pairs into the CSR
               (one np.insert pass — new record ids exceed all old ids,
               so within-key ascending order is preserved for free); the
               frozen top-r buffer never deletes, new rows append at
               each bit's segment end.

No raw-data access and no re-hashing of old rows, mirroring the dynamic
index's own τ-retightening contract.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sketches import PackedSketches


@dataclasses.dataclass
class PostingsIndex:
    """Inverted postings over one engine's packed sketches."""

    keys: np.ndarray          # uint32[U]
    offsets: np.ndarray       # int64[U+1]
    rec_ids: np.ndarray       # int32[nnz]
    buf_offsets: np.ndarray   # int64[R+1]
    buf_rec_ids: np.ndarray   # int32[bnnz]
    num_records: int
    tau: np.uint32            # max retained key at build/update time

    @property
    def nnz(self) -> int:
        return len(self.rec_ids)

    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in (
            self.keys, self.offsets, self.rec_ids,
            self.buf_offsets, self.buf_rec_ids))

    def posting_lengths(self, hashes: np.ndarray) -> np.ndarray:
        """int64[n] — posting-list length per query hash (0 when absent).

        One searchsorted probe; used by the plan cost model to estimate
        merge work *without* materializing the merge.
        """
        h = np.asarray(hashes, dtype=np.uint32)
        pos = np.searchsorted(self.keys, h)
        ok = pos < len(self.keys)
        hit = np.zeros(len(h), dtype=bool)
        hit[ok] = self.keys[pos[ok]] == h[ok]
        out = np.zeros(len(h), dtype=np.int64)
        p = pos[hit]
        out[hit] = self.offsets[p + 1] - self.offsets[p]
        return out


def _bit_matrix(buf: np.ndarray) -> np.ndarray:
    """bool[m, W*32] — bit j of word j//32 at position j%32 (sketches.py)."""
    buf = np.asarray(buf, dtype=np.uint32)
    m, w = buf.shape
    if w == 0:
        return np.zeros((m, 0), dtype=bool)
    shifts = np.arange(32, dtype=np.uint32)
    bits = (buf[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(m, w * 32).astype(bool)


def _row_pairs(s: PackedSketches, rows: slice) -> tuple[np.ndarray, np.ndarray]:
    """Flat (hash, record) pairs over ``rows`` of the packed values."""
    vals = np.asarray(s.values)[rows]
    lens = np.asarray(s.lengths)[rows]
    n, c = vals.shape
    live = np.arange(c)[None, :] < lens[:, None]
    h = vals[live]
    start = rows.start or 0
    rec = np.broadcast_to(np.arange(start, start + n, dtype=np.int32)[:, None],
                          (n, c))[live]
    return h.astype(np.uint32), rec


def _csr_from_pairs(h: np.ndarray, rec: np.ndarray):
    """Sort pairs by (hash, record) and group into (keys, offsets, rec_ids)."""
    order = np.lexsort((rec, h))
    h, rec = h[order], rec[order]
    keys, starts = np.unique(h, return_index=True)
    offsets = np.concatenate([starts, [len(h)]]).astype(np.int64)
    return keys, offsets, rec.astype(np.int32)


def _buf_csr(buf: np.ndarray, row_offset: int = 0):
    """(offsets int64[R+1], rec_ids int32) from a bitmap block."""
    bits = _bit_matrix(buf)
    m, r = bits.shape
    if r == 0:
        return np.zeros(1, dtype=np.int64), np.zeros(0, dtype=np.int32)
    bit_idx, recs = np.nonzero(bits.T)       # sorted by bit, then record
    counts = np.bincount(bit_idx, minlength=r)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return offsets, (recs + row_offset).astype(np.int32)


def build_postings(sketches: PackedSketches) -> PostingsIndex:
    """Build hash + buffer postings from a packed index in one pass."""
    m = sketches.num_records
    h, rec = _row_pairs(sketches, slice(0, m))
    keys, offsets, rec_ids = _csr_from_pairs(h, rec)
    buf_offsets, buf_rec_ids = _buf_csr(np.asarray(sketches.buf))
    tau = keys[-1] if len(keys) else np.uint32(0)
    return PostingsIndex(
        keys=keys, offsets=offsets, rec_ids=rec_ids,
        buf_offsets=buf_offsets, buf_rec_ids=buf_rec_ids,
        num_records=m, tau=np.uint32(tau))


def truncate_postings(post: PostingsIndex, tau: np.uint32) -> PostingsIndex:
    """τ-retighten = prefix truncation of the hash-sorted keyspace.

    Deletion-only half of the incremental maintenance contract: every key
    above the new (lower) τ disappears; surviving posting lists are
    untouched because refiltering a row at τ' keeps exactly its hashes
    ≤ τ'. The frozen buffer postings never delete.
    """
    cut = int(np.searchsorted(post.keys, np.uint32(tau), side="right"))
    offsets = post.offsets[: cut + 1]
    return PostingsIndex(
        keys=post.keys[:cut], offsets=offsets,
        rec_ids=post.rec_ids[: offsets[-1]],
        buf_offsets=post.buf_offsets, buf_rec_ids=post.buf_rec_ids,
        num_records=post.num_records, tau=np.uint32(tau))


def append_rows(
    post: PostingsIndex,
    sketches: PackedSketches,
    lo: int,
    hi: int,
    rec_offset: int = 0,
) -> PostingsIndex:
    """Append rows ``[lo, hi)`` of ``sketches`` to an existing postings
    index (the append half of incremental maintenance).

    ``rec_offset`` shifts the appended record ids — shard-local postings
    pass ``-shard_lo`` so ids stay local to the shard's row slice. The
    appended ids must exceed every id already present (insert-at-the-end
    monotonicity), which holds for both the global postings and the
    per-shard slices because new records always pack after old ones.
    """
    keys, offsets, rec_ids = post.keys, post.offsets, post.rec_ids

    # -- tail: merge the new rows' (hash, record) pairs into the CSR.
    h_new, rec_new = _row_pairs(sketches, slice(lo, hi))
    rec_new = (rec_new.astype(np.int64) + rec_offset).astype(np.int32)
    if len(h_new):
        order = np.lexsort((rec_new, h_new))
        h_new, rec_new = h_new[order], rec_new[order]
        flat_h = np.repeat(keys, np.diff(offsets))
        # side="right": new pairs land after equal old keys; new record
        # ids all exceed old ids, so within-key order stays ascending.
        at = np.searchsorted(flat_h, h_new, side="right")
        flat_h = np.insert(flat_h, at, h_new)
        rec_ids = np.insert(rec_ids, at, rec_new)
        keys, starts = np.unique(flat_h, return_index=True)
        offsets = np.concatenate([starts, [len(flat_h)]]).astype(np.int64)

    # -- buffer: frozen top-r set, new rows append at each segment end.
    buf_offsets, buf_rec_ids = post.buf_offsets, post.buf_rec_ids
    w = np.asarray(sketches.buf).shape[1]
    if w:
        new_off, new_recs = _buf_csr(np.asarray(sketches.buf)[lo:hi],
                                     row_offset=lo + rec_offset)
        counts = np.diff(new_off)
        at = np.repeat(buf_offsets[1:], counts)
        buf_rec_ids = np.insert(buf_rec_ids, at, new_recs)
        buf_offsets = buf_offsets + np.concatenate(
            [[0], np.cumsum(counts)]).astype(np.int64)

    return PostingsIndex(
        keys=keys, offsets=offsets, rec_ids=rec_ids.astype(np.int32),
        buf_offsets=buf_offsets, buf_rec_ids=buf_rec_ids,
        num_records=post.num_records + (hi - lo), tau=post.tau)


def update_postings(
    post: PostingsIndex, sketches: PackedSketches, tau: np.uint32
) -> PostingsIndex:
    """Maintain postings across one ``insert`` (deletion + append only).

    ``sketches`` is the repacked index AFTER the insert: rows
    ``[0, post.num_records)`` are the old records refiltered at the new
    global threshold ``tau`` (τ only decreases), rows beyond are new.
    """
    return append_rows(truncate_postings(post, tau), sketches,
                       post.num_records, sketches.num_records)


def postings_equal(a: PostingsIndex, b: PostingsIndex) -> bool:
    """Structural equality (tests: incremental update == fresh rebuild)."""
    return (a.num_records == b.num_records
            and np.array_equal(a.keys, b.keys)
            and np.array_equal(a.offsets, b.offsets)
            and np.array_equal(a.rec_ids, b.rec_ids)
            and np.array_equal(a.buf_offsets, b.buf_offsets)
            and np.array_equal(a.buf_rec_ids, b.buf_rec_ids))
