"""Threshold-aware candidate generation (the planner's filter step).

The paper's set-intersection advantage, made operational: a record X can
have estimated containment Ĉ(Q→X) = (o1 + D̂∩)/|Q| ≥ t only if the pair
shares buffer bits (o1 > 0) or retained tail hashes (K∩ > 0) — both
enumerable from the postings. For each candidate the merge yields

    c  = |retained(Q) ∩ retained(X)|   (== K∩ for G-KMV/GB-KMV: a shared
         value is ≤ both effective thresholds, hence ≤ τ_pair; for plain
         KMV it upper-bounds the in-top-k K∩)
    o1 = popcount(buf_Q & buf_X)       (exact, frozen top-r counts —
                                        Eq. 14's exact head folded in)

and the tail estimator is bounded *from the query's own sketch*: the c
shared values are c distinct retained query hashes, so the pair's
U_(k) ≥ h_Q[c-1] (the c-th smallest retained query hash), and with
(k-1)/k < 1,

    D̂∩  =  K∩/k · (k-1)/U_(k)  <  max_{1≤j≤c} j / unit(h_Q[j-1])

(prefix max because plain KMV only guarantees K∩ ≤ c). Records whose
bound (o1 + bound_tail(c))/|Q| falls below t are pruned — provably below
threshold under the exact same estimator the dense sweep applies, so the
verify step returns bit-identical candidate sets.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hashing import TWO32
from repro.planner.postings import PostingsIndex

# Headroom multiplier on the (float64) containment bound: the dense
# estimator computes in float32, whose rounding can land a handful of
# ulps ABOVE the exact value (≲ 10·2⁻²³ relative across the op chain) —
# e.g. o1=1, |Q|=3 scores fl32(1/3) > 1/3. The slack keeps the bound
# above every float32 score the dense sweep could produce, including
# buffer-dominated ones, so the filter never drops a dense hit.
_BOUND_SLACK = 1.0 + 1e-5


@dataclasses.dataclass
class CandidateSet:
    """One query's pruned candidates (sorted ascending by record id)."""

    rec_ids: np.ndarray    # int64[n]
    counts: np.ndarray     # int32[n]  shared retained-hash counts c
    o1: np.ndarray         # int32[n]  exact buffer intersections
    hits: int              # posting entries merged (cost accounting)
    pruned: int            # candidates dropped by the containment bound


def query_bits(buf_row: np.ndarray) -> np.ndarray:
    """Set bit positions of a query's packed top-r bitmap row."""
    buf_row = np.asarray(buf_row, dtype=np.uint32)
    if buf_row.size == 0:
        return np.zeros(0, dtype=np.int64)
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((buf_row[:, None] >> shifts[None, :]) & np.uint32(1)).reshape(-1)
    return np.nonzero(bits)[0].astype(np.int64)


def _gather_segments(offsets, rec_ids, rows):
    """Concatenate CSR segments for ``rows`` (posting ids, with repeats)."""
    if len(rows) == 0:
        return np.zeros(0, dtype=np.int32)
    starts = offsets[rows]
    ends = offsets[rows + 1]
    total = int((ends - starts).sum())
    if total == 0:
        return np.zeros(0, dtype=np.int32)
    out = np.empty(total, dtype=np.int32)
    pos = 0
    for s, e in zip(starts, ends):
        n = int(e - s)
        out[pos : pos + n] = rec_ids[s:e]
        pos += n
    return out


def tail_bound(q_hashes: np.ndarray) -> np.ndarray:
    """float64[nq+1]: bound_tail(c) = max_{1≤j≤c} j / unit(h_Q[j-1]).

    ``q_hashes`` are the query's retained hashes, sorted ascending.
    Entry 0 is 0 (no shared tail ⇒ D̂∩ = 0 exactly).
    """
    h = np.asarray(q_hashes, dtype=np.uint64)
    n = len(h)
    out = np.zeros(n + 1, dtype=np.float64)
    if n:
        j = np.arange(1, n + 1, dtype=np.float64)
        unit = (h.astype(np.float64) + 1.0) / TWO32
        out[1:] = np.maximum.accumulate(j / unit)
    return out


def candidates_for(
    post: PostingsIndex,
    q_hashes: np.ndarray,
    q_bits: np.ndarray,
    threshold: float,
    q_size: int,
) -> CandidateSet:
    """Merge Q's hashes/bits against the postings, prune by the bound.

    Returns every record whose containment *bound* clears ``threshold``
    — a superset of the dense hits by construction (output-sensitive:
    cost scales with posting hits, never with the index size).
    """
    q_hashes = np.asarray(q_hashes, dtype=np.uint32)

    # -- tail merge: which postings rows exist for the query's hashes.
    pos = np.searchsorted(post.keys, q_hashes)
    ok = pos < len(post.keys)
    hit = np.zeros(len(q_hashes), dtype=bool)
    hit[ok] = post.keys[pos[ok]] == q_hashes[ok]
    tail_ids = _gather_segments(post.offsets, post.rec_ids, pos[hit])

    # -- buffer merge: exact o1 from the frozen top-r postings.
    q_bits = np.asarray(q_bits, dtype=np.int64)
    q_bits = q_bits[q_bits < len(post.buf_offsets) - 1]
    buf_ids = _gather_segments(post.buf_offsets, post.buf_rec_ids, q_bits)

    hits = len(tail_ids) + len(buf_ids)
    if hits == 0:
        empty = np.zeros(0, dtype=np.int64)
        return CandidateSet(empty, empty.astype(np.int32),
                            empty.astype(np.int32), 0, 0)

    rec_c, counts_c = np.unique(tail_ids, return_counts=True)
    rec_b, counts_b = np.unique(buf_ids, return_counts=True)
    rec = np.union1d(rec_c, rec_b).astype(np.int64)
    c = np.zeros(len(rec), dtype=np.int32)
    o1 = np.zeros(len(rec), dtype=np.int32)
    c[np.searchsorted(rec, rec_c)] = counts_c
    o1[np.searchsorted(rec, rec_b)] = counts_b

    # -- containment bound: (o1 + bound_tail(c)) / |Q| ≥ t or prune.
    # _BOUND_SLACK inflates the WHOLE score bound (buffer term included)
    # to dominate the dense path's float32 rounding.
    bound = tail_bound(np.sort(q_hashes))
    ub = (o1.astype(np.float64) + bound[np.minimum(c, len(bound) - 1)]) \
        / max(int(q_size), 1)
    keep = ub * _BOUND_SLACK >= float(threshold) - 1e-12
    pruned = int(len(rec) - keep.sum())
    return CandidateSet(rec[keep], c[keep], o1[keep], hits, pruned)


def f32_threshold(t) -> np.ndarray:
    """Smallest float32 ≥ t (scalar or vector).

    A float32 score s satisfies ``s >= t`` under float64 comparison (the
    legacy host path: numpy upcasts a python-float threshold) iff
    ``s >= f32_threshold(t)`` under pure-float32 comparison — so device
    side comparisons stay bit-compatible with ``np.nonzero(s >= t)``.
    """
    t64 = np.asarray(t, dtype=np.float64)
    f = t64.astype(np.float32)
    return np.where(f.astype(np.float64) < t64,
                    np.nextafter(f, np.float32(np.inf)), f)


def mask_to_hits(mask: np.ndarray) -> list[np.ndarray]:
    """bool[m, Gq] hit mask → per-query sorted id arrays, one vectorized
    nonzero pass for the whole batch (no per-column python loop)."""
    mask = np.asarray(mask)
    if mask.ndim != 2:
        raise ValueError(f"expected [m, Gq] mask, got {mask.shape}")
    q_idx, rec_idx = np.nonzero(mask.T)
    del q_idx  # row-major over queries; splits recover the grouping
    counts = mask.sum(axis=0)
    return np.split(rec_idx.astype(np.int64), np.cumsum(counts)[:-1])


def threshold_hits_packed(scores, thresholds) -> list[np.ndarray]:
    """Per-query hit ids from a score matrix, comparison at the source.

    ``scores`` is f32[m, Gq] — numpy OR a device (jnp) array. The ≥
    comparison runs where the scores live (device-side for jnp: only the
    bool mask crosses to host, 4× less transfer than the float matrix),
    then one vectorized nonzero pass packs all queries' indices.
    ``thresholds`` is scalar or per-query.
    """
    thr = f32_threshold(thresholds)
    if isinstance(scores, np.ndarray):
        mask = scores >= (thr if thr.ndim == 0 else thr[None, :])
    else:
        import jax.numpy as jnp

        mask = scores >= (jnp.float32(thr) if thr.ndim == 0
                          else jnp.asarray(thr, jnp.float32)[None, :])
    return mask_to_hits(np.asarray(mask))
