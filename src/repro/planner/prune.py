"""Threshold-aware candidate generation (the planner's filter step).

The paper's set-intersection advantage, made operational: a record X can
have estimated containment Ĉ(Q→X) = (o1 + D̂∩)/|Q| ≥ t only if the pair
shares buffer bits (o1 > 0) or retained tail hashes (K∩ > 0) — both
enumerable from the postings. For each candidate the merge yields

    c  = |retained(Q) ∩ retained(X)|   (== K∩ for G-KMV/GB-KMV: a shared
         value is ≤ both effective thresholds, hence ≤ τ_pair; for plain
         KMV it upper-bounds the in-top-k K∩)
    o1 = popcount(buf_Q & buf_X)       (exact, frozen top-r counts —
                                        Eq. 14's exact head folded in)

and the tail estimator is bounded *from the query's own sketch*: the c
shared values are c distinct retained query hashes, so the pair's
U_(k) ≥ h_Q[c-1] (the c-th smallest retained query hash), and with
(k-1)/k < 1,

    D̂∩  =  K∩/k · (k-1)/U_(k)  <  max_{1≤j≤c} j / unit(h_Q[j-1])

(prefix max because plain KMV only guarantees K∩ ≤ c). Records whose
bound (o1 + bound_tail(c))/|Q| falls below t are pruned — provably below
threshold under the exact same estimator the dense sweep applies, so the
verify step returns bit-identical candidate sets.

Block skipping (the compressed-postings payoff): the same bound is
evaluated PER BLOCK HEADER before any block decodes. A block's header
carries its record-id range [first, last]; counting how many of the
query's matched tail lists (→ c_max) and buffer-bit lists (→ o1_max)
overlap that range bounds every resident record's true counts from
above, because a record can only contribute to c/o1 through lists whose
id range covers it. Blocks whose (o1_max + bound_tail(c_max))/|Q| falls
below t never decode. Soundness of the two-phase filter: any record
touching a skipped block has its FULL-count bound below t (c_max/o1_max
bound the full counts, not the decoded subset), so it is provably below
threshold even if it also surfaces through kept blocks with partial
counts — the verify step re-scores candidates from the sketches, never
from the merge counts, so partial counts cannot flip a true hit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hashing import TWO32
from repro.planner.postings import PostingsIndex, _ragged_take, decode_blocks

# Headroom multiplier on the (float64) containment bound: the dense
# estimator computes in float32, whose rounding can land a handful of
# ulps ABOVE the exact value (≲ 10·2⁻²³ relative across the op chain) —
# e.g. o1=1, |Q|=3 scores fl32(1/3) > 1/3. The slack keeps the bound
# above every float32 score the dense sweep could produce, including
# buffer-dominated ones, so the filter never drops a dense hit.
_BOUND_SLACK = 1.0 + 1e-5


@dataclasses.dataclass
class CandidateSet:
    """One query's pruned candidates (sorted ascending by record id)."""

    rec_ids: np.ndarray    # int64[n]
    counts: np.ndarray     # int32[n]  shared retained-hash counts c
    o1: np.ndarray         # int32[n]  exact buffer intersections
    hits: int              # posting entries decoded (cost accounting)
    pruned: int            # candidates dropped by the containment bound
    blocks: int = 0        # posting blocks the merge touched
    skipped_blocks: int = 0  # blocks the header bound skipped pre-decode


def query_bits(buf_row: np.ndarray) -> np.ndarray:
    """Set bit positions of a query's packed top-r bitmap row."""
    buf_row = np.asarray(buf_row, dtype=np.uint32)
    if buf_row.size == 0:
        return np.zeros(0, dtype=np.int64)
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((buf_row[:, None] >> shifts[None, :]) & np.uint32(1)).reshape(-1)
    return np.nonzero(bits)[0].astype(np.int64)


def _row_block_list(store, rows) -> np.ndarray:
    """Flat block ids of ``rows`` (repeats kept — a duplicated query hash
    merges its posting list once per occurrence, exactly like the flat
    CSR gather did)."""
    rb = store.row_blocks.astype(np.int64)
    rows = np.asarray(rows, np.int64)
    return _ragged_take(rb[rows], rb[rows + 1] - rb[rows])


def tail_bound(q_hashes: np.ndarray) -> np.ndarray:
    """float64[nq+1]: bound_tail(c) = max_{1≤j≤c} j / unit(h_Q[j-1]).

    ``q_hashes`` are the query's retained hashes, sorted ascending.
    Entry 0 is 0 (no shared tail ⇒ D̂∩ = 0 exactly).
    """
    h = np.asarray(q_hashes, dtype=np.uint64)
    n = len(h)
    out = np.zeros(n + 1, dtype=np.float64)
    if n:
        j = np.arange(1, n + 1, dtype=np.float64)
        unit = (h.astype(np.float64) + 1.0) / TWO32
        out[1:] = np.maximum.accumulate(j / unit)
    return out


def candidates_for(
    post: PostingsIndex,
    q_hashes: np.ndarray,
    q_bits: np.ndarray,
    threshold: float,
    q_size: int,
) -> CandidateSet:
    """Merge Q's hashes/bits against the blocked postings, prune by the
    bound — skipping whole blocks whose header bound already sits below
    ``threshold`` (they never decode).

    Returns every record whose containment *bound* clears ``threshold``
    — a superset of the dense hits by construction (output-sensitive:
    cost scales with decoded posting hits, never with the index size).
    """
    q_hashes = np.asarray(q_hashes, dtype=np.uint32)

    # -- tail merge: which postings rows exist for the query's hashes.
    pos = np.searchsorted(post.keys, q_hashes)
    ok = pos < len(post.keys)
    hit = np.zeros(len(q_hashes), dtype=bool)
    hit[ok] = post.keys[pos[ok]] == q_hashes[ok]
    rows_t = pos[hit]
    blks_t = _row_block_list(post.tail, rows_t)

    # -- buffer merge: blocks of the frozen top-r postings rows.
    q_bits = np.asarray(q_bits, dtype=np.int64)
    q_bits = q_bits[q_bits < post.buf.num_rows]
    blks_b = _row_block_list(post.buf, q_bits)

    n_blocks = len(blks_t) + len(blks_b)
    skipped = 0
    bound = tail_bound(np.sort(q_hashes))    # shared: block skip + final cut
    if float(threshold) > 0.0 and n_blocks:
        rbt = post.tail.row_blocks.astype(np.int64)
        # Matched-list id ranges (tail rows are never empty; buffer rows
        # can be — a bit no record carries owns zero blocks).
        slo_t = np.sort(post.tail.first[rbt[rows_t]]) \
            if len(rows_t) else np.zeros(0, np.int32)
        shi_t = np.sort(post.tail.last[rbt[rows_t + 1] - 1]) \
            if len(rows_t) else np.zeros(0, np.int32)
        rbb = post.buf.row_blocks.astype(np.int64)
        qb_live = q_bits[rbb[q_bits + 1] > rbb[q_bits]]
        slo_b = np.sort(post.buf.first[rbb[qb_live]])
        shi_b = np.sort(post.buf.last[rbb[qb_live + 1] - 1])
        qs = max(int(q_size), 1)

        def _keep(first, last):
            c_max = (np.searchsorted(slo_t, last, side="right")
                     - np.searchsorted(shi_t, first, side="left"))
            o1_max = (np.searchsorted(slo_b, last, side="right")
                      - np.searchsorted(shi_b, first, side="left"))
            ub = (o1_max.astype(np.float64)
                  + bound[np.minimum(c_max, len(bound) - 1)]) / qs
            return ub * _BOUND_SLACK >= float(threshold) - 1e-12

        keep_t = _keep(post.tail.first[blks_t], post.tail.last[blks_t])
        keep_b = _keep(post.buf.first[blks_b], post.buf.last[blks_b])
        skipped = int((~keep_t).sum()) + int((~keep_b).sum())
        blks_t, blks_b = blks_t[keep_t], blks_b[keep_b]

    tail_ids, _ = decode_blocks(post.tail, blks_t)
    buf_ids, _ = decode_blocks(post.buf, blks_b)

    hits = len(tail_ids) + len(buf_ids)
    if hits == 0:
        empty = np.zeros(0, dtype=np.int64)
        return CandidateSet(empty, empty.astype(np.int32),
                            empty.astype(np.int32), 0, 0,
                            blocks=n_blocks - skipped,
                            skipped_blocks=skipped)

    rec_c, counts_c = np.unique(tail_ids, return_counts=True)
    rec_b, counts_b = np.unique(buf_ids, return_counts=True)
    rec = np.union1d(rec_c, rec_b).astype(np.int64)
    c = np.zeros(len(rec), dtype=np.int32)
    o1 = np.zeros(len(rec), dtype=np.int32)
    c[np.searchsorted(rec, rec_c)] = counts_c
    o1[np.searchsorted(rec, rec_b)] = counts_b

    # -- containment bound: (o1 + bound_tail(c)) / |Q| ≥ t or prune.
    # _BOUND_SLACK inflates the WHOLE score bound (buffer term included)
    # to dominate the dense path's float32 rounding.
    ub = (o1.astype(np.float64) + bound[np.minimum(c, len(bound) - 1)]) \
        / max(int(q_size), 1)
    keep = ub * _BOUND_SLACK >= float(threshold) - 1e-12
    pruned = int(len(rec) - keep.sum())
    return CandidateSet(rec[keep], c[keep], o1[keep], hits, pruned,
                        blocks=n_blocks - skipped, skipped_blocks=skipped)


def f32_threshold(t) -> np.ndarray:
    """Smallest float32 ≥ t (scalar or vector).

    A float32 score s satisfies ``s >= t`` under float64 comparison (the
    legacy host path: numpy upcasts a python-float threshold) iff
    ``s >= f32_threshold(t)`` under pure-float32 comparison — so device
    side comparisons stay bit-compatible with ``np.nonzero(s >= t)``.
    """
    t64 = np.asarray(t, dtype=np.float64)
    f = t64.astype(np.float32)
    return np.where(f.astype(np.float64) < t64,
                    np.nextafter(f, np.float32(np.inf)), f)


def mask_to_hits(mask: np.ndarray) -> list[np.ndarray]:
    """bool[m, Gq] hit mask → per-query sorted id arrays, one vectorized
    nonzero pass for the whole batch (no per-column python loop)."""
    mask = np.asarray(mask)
    if mask.ndim != 2:
        raise ValueError(f"expected [m, Gq] mask, got {mask.shape}")
    q_idx, rec_idx = np.nonzero(mask.T)
    del q_idx  # row-major over queries; splits recover the grouping
    counts = mask.sum(axis=0)
    return np.split(rec_idx.astype(np.int64), np.cumsum(counts)[:-1])


def threshold_hits_packed(scores, thresholds) -> list[np.ndarray]:
    """Per-query hit ids from a score matrix, comparison at the source.

    ``scores`` is f32[m, Gq] — numpy OR a device (jnp) array. The ≥
    comparison runs where the scores live (device-side for jnp: only the
    bool mask crosses to host, 4× less transfer than the float matrix),
    then one vectorized nonzero pass packs all queries' indices.
    ``thresholds`` is scalar or per-query.
    """
    thr = f32_threshold(thresholds)
    if isinstance(scores, np.ndarray):
        mask = scores >= (thr if thr.ndim == 0 else thr[None, :])
    else:
        import jax.numpy as jnp

        mask = scores >= (jnp.float32(thr) if thr.ndim == 0
                          else jnp.asarray(thr, jnp.float32)[None, :])
    return mask_to_hits(np.asarray(mask))
