"""Production serving layer: HTTP service + admission control + metrics
in front of the sharded GB-KMV index.

    index   = api.get_engine("gbkmv").build(records, budget)
    sharded = ShardedIndex(index, mesh)
    server  = AsyncSketchServer(sharded, max_inflight=256)
    app     = ServiceApp(server, auth_token="s3cret", rate_limit=500)
    with ServiceHandle(app, port=8080):
        ...                      # /ingest /query /topk /healthz /metrics

Durable serving mounts a data dir (``--data-dir`` on the CLI): ingest
then write-ahead-logs before applying, snapshots are atomic, and a
restart recovers snapshot + WAL tail — see docs/SERVING.md §Durability.

See docs/SERVING.md for the endpoint and metrics reference,
docs/OBSERVABILITY.md for tracing/explain/profiling, and
``python -m repro.service.launch --help`` for the CLI entry point.
"""

from repro.service.app import (  # noqa: F401
    ServiceApp, ServiceHandle, make_http_server)
from repro.service.client import ServiceClient, ServiceError  # noqa: F401
from repro.service.metrics import Metrics, parse_prometheus  # noqa: F401
from repro.service.middleware import (  # noqa: F401
    AuthToken, TenantBuckets, TokenBucket, tenant_id)
from repro.service.server import (  # noqa: F401
    AsyncSketchServer, Overloaded, Pending)
from repro.service.wal import (  # noqa: F401
    Durability, IdempotencyCache, ReadOnly, WalCorruption, WriteAheadLog)
