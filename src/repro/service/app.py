"""HTTP front for the async sketch server — stdlib only.

Endpoints (JSON in/out unless noted):

    POST /query    {"q": [ids], "threshold": 0.5, "deadline_ms"?: int,
                    "explain"?: bool}
                   → {"rid", "hits": [...], "expired": bool, "explain"?}
    POST /topk     {"q": [ids], "k": 10, "deadline_ms"?: int}
                   → {"rid", "ids": [...], "scores": [...]}
    POST /ingest   NDJSON stream (one JSON id-array per line) or
                   {"records": [[...], ...]} → {"ingested", "chunks"}
                   Windowed indexes accept a target epoch via the
                   ``?epoch=N`` query param or an ``"epoch"`` JSON key.
                   An ``Idempotency-Key`` header (or ``"idempotency_key"``
                   JSON key) makes retries safe: chunks already applied
                   inside the dedupe window are skipped and the response
                   gains ``"deduped_chunks"``.
    POST /admin/retire  {"before": N} → {"retired", "epochs"} — drop
                   window epochs < N (windowed indexes only; auth-gated,
                   exempt from rate limits like /debug/*)
    POST /admin/snapshot  → {"wal_seq", "path", ...} — atomic snapshot
                   through the mutation lane, then WAL truncation
                   (needs --data-dir; auth-gated, outside rate limits)
    POST /debug/explain  same body as /query with explain forced on
    GET  /debug/traces   → Chrome trace-event JSON of recent requests
                           (load in chrome://tracing or ui.perfetto.dev)
    GET  /debug/slow     → the slow-query log (threshold-configurable)
    GET  /healthz  → {"status": "ok", "records", "inflight",
                      "writable"} — liveness, always 200         (open)
    GET  /readyz   → readiness: 200 while writable, 503 once the
                     server degrades to read-only serving        (open)
    GET  /metrics  → Prometheus text format                      (open)

Durability degradation: when the data dir fails a write (disk full,
read-only remount) the flush worker flips the server into sticky
read-only — mutations (`/ingest`, `/admin/retire`, `/admin/snapshot`)
answer **503**, queries keep answering 200 from the in-memory index,
and `/readyz` goes 503 so a load balancer drains writes.

Middleware runs before admission: bearer-token auth (401), a global
token-bucket rate limit, and a per-tenant (per-auth-token) bucket —
both 429 + Retry-After, tenant rejections counted in
``service_ratelimited_total{tenant}``. A full admission queue also
answers 429 with a Retry-After derived from measured flush latency — the
load-shed half of graceful degradation. ``/debug/*`` endpoints sit
behind auth but outside the rate limits (introspection must work while
the service sheds).

The `/ingest` endpoint **streams**: NDJSON lines are parsed incrementally
and handed to the flush loop in chunks of ``ingest_chunk`` records, so a
record batch far larger than one flush never materializes on host —
at most one chunk of parsed records is alive at a time (the carried-over
streaming-RaggedBatch item: each chunk becomes one CSR ingest downstream).
"""

from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs

import numpy as np

from repro.service.metrics import Metrics
from repro.service.middleware import (AuthToken, TenantBuckets, TokenBucket,
                                      tenant_id)
from repro.service.server import AsyncSketchServer, Overloaded, ReadOnly


class Response:
    def __init__(self, status: int, body, content_type: str = "application/json",
                 headers: dict | None = None):
        self.status = status
        self.headers = dict(headers or {})
        self.content_type = content_type
        if isinstance(body, (dict, list)):
            body = json.dumps(body).encode()
        elif isinstance(body, str):
            body = body.encode()
        self.body = body


def _json_error(status: int, message: str, **headers) -> Response:
    return Response(status, {"error": message}, headers=headers)


# Sanity cap on one chunk-size/trailer line (incl. chunk extensions).
_MAX_LINE = 8192


def _read_line(rfile, what: str) -> bytes:
    line = rfile.readline(_MAX_LINE)
    if line and not line.endswith(b"\n"):
        raise ValueError(f"{what} line too long (> {_MAX_LINE} bytes)")
    return line


def _iter_body(rfile, headers, max_chunk: int = 1 << 16):
    """Yield raw body bytes without materializing the request:
    Content-Length bodies stream in ``max_chunk`` pieces, and
    ``Transfer-Encoding: chunked`` is decoded incrementally (chunk
    extensions stripped, trailer headers consumed)."""
    if headers.get("Transfer-Encoding", "").lower() == "chunked":
        while True:
            size_line = _read_line(rfile, "chunk size")
            if not size_line:
                return                                 # peer closed
            try:
                size = int(size_line.split(b";")[0].strip() or b"0", 16)
            except ValueError:
                raise ValueError(
                    f"bad chunk size {size_line[:32]!r}") from None
            if size == 0:
                while True:                            # trailer section
                    line = _read_line(rfile, "trailer")
                    if line in (b"", b"\r\n", b"\n"):
                        return
            remaining = size
            while remaining:
                piece = rfile.read(min(remaining, max_chunk))
                if not piece:
                    return
                remaining -= len(piece)
                yield piece
            rfile.readline(2)                          # chunk-data CRLF
        return
    remaining = int(headers.get("Content-Length", 0) or 0)
    while remaining > 0:
        piece = rfile.read(min(remaining, max_chunk))
        if not piece:
            return
        remaining -= len(piece)
        yield piece


class _Body:
    """One-shot iterator over the request body that tracks consumption.

    The handler may answer before reading the body (401/404/405/429);
    on a keep-alive connection the unread bytes would then be parsed as
    the *next* request line, corrupting the stream — so :meth:`handle`
    always drains the remainder before responding. A body that can't be
    decoded (malformed chunking) marks itself ``broken`` and the
    response carries ``Connection: close`` instead."""

    def __init__(self, rfile, headers):
        self._iter = self._decode(rfile, headers)
        self.broken = False

    def _decode(self, rfile, headers):
        try:
            yield from _iter_body(rfile, headers)
        except ValueError:
            self.broken = True
            raise

    def __iter__(self):
        return self._iter

    def drain(self) -> bool:
        """Consume whatever the handler left unread; False means the
        stream is undecodable and the connection must be closed."""
        if self.broken:
            return False
        try:
            for _ in self._iter:
                pass
        except (ValueError, OSError):
            self.broken = True
            return False
        return True


def _iter_lines(chunks):
    buf = b""
    for piece in chunks:
        buf += piece
        while True:
            nl = buf.find(b"\n")
            if nl < 0:
                break
            yield buf[:nl]
            buf = buf[nl + 1:]
    if buf.strip():
        yield buf


class ServiceApp:
    """Routing + middleware + metrics over an :class:`AsyncSketchServer`."""

    def __init__(self, server: AsyncSketchServer, *,
                 auth_token: str | None = None,
                 rate_limit: float | None = None, burst: int | None = None,
                 tenant_rate_limit: float | None = None,
                 tenant_burst: int | None = None,
                 ingest_chunk: int = 256, result_timeout: float = 60.0,
                 clock=time.monotonic):
        self.server = server
        self.auth = AuthToken(auth_token)
        self.bucket = TokenBucket(rate_limit, burst, clock=clock)
        self.tenant_buckets = TenantBuckets(tenant_rate_limit, tenant_burst,
                                            clock=clock)
        self.ingest_chunk = int(ingest_chunk)
        self.result_timeout = float(result_timeout)
        self.clock = clock
        self.metrics = Metrics()
        self._wire_metrics()

    def _wire_metrics(self):
        m, srv = self.metrics, self.server
        stats = srv.stats
        m.register_histogram(
            "service_queue_wait_seconds", stats.queue_wait_hist,
            help="Per-request wait from admission to flush")
        m.register_histogram(
            "service_flush_latency_seconds", stats.flush_latency_hist,
            help="Device execution latency per flush")
        m.register_histogram(
            "service_ingest_latency_seconds", stats.ingest_latency_hist,
            help="Host insert latency per ingest request")
        for reason, fn in (("full", lambda: stats.flushes_full),
                           ("deadline", lambda: stats.flushes_deadline),
                           ("expired", lambda: stats.flushes_expired),
                           ("ingest", lambda: stats.flushes_ingest)):
            m.set_counter_fn("service_flush_total", fn, {"reason": reason},
                             help="Flushes by trigger reason")
        m.set_counter_fn("service_shed_total", lambda: srv.shed,
                         help="Requests refused at the admission queue")
        m.set_counter_fn("service_expired_total",
                         lambda: srv.expired_served,
                         help="Requests answered past their deadline "
                              "(dense fallback path)")
        m.set_counter_fn("service_records_ingested_total",
                         lambda: srv.records_ingested,
                         help="Records ingested through /ingest")
        m.set_gauge("service_inflight", lambda: srv.inflight,
                    help="Admission queue depth")
        m.set_gauge("service_mean_batch_occupancy",
                    lambda: stats.mean_batch,
                    help="Mean requests per flush")
        m.set_counter_fn("service_slow_queries_total",
                         lambda: srv.slow_queries,
                         help="Requests slower end-to-end than the "
                              "slow-query threshold")
        m.set_gauge("service_cost_model_drift",
                    lambda: srv.cost_drift.drift,
                    help="Predicted/measured seconds ratio for planned "
                         "flushes (1.0 = calibrated; 0 until measurable)")
        m.set_gauge("service_read_only", lambda: int(srv.read_only),
                    help="1 once the data dir failed a write and the "
                         "server degraded to read-only serving")
        m.set_counter_fn("service_ingest_deduped_total",
                         lambda: srv.deduped_total,
                         help="Ingest chunks skipped by the idempotency "
                              "window (safe client retries)")
        # Durability gauges — only when the server mounts a data dir.
        d = srv.durability
        if d is not None:
            m.set_info("service_durability_info",
                       {"fsync": d.wal.policy, "data_dir": d.data_dir},
                       help="Durability configuration")
            m.set_counter_fn("wal_appends_total",
                             lambda: d.wal.appends_total,
                             help="WAL entries appended")
            m.set_counter_fn("wal_fsyncs_total", lambda: d.wal.fsyncs_total,
                             help="WAL fsync(2) calls (group commit "
                                  "amortizes these across batches)")
            m.set_counter_fn("wal_rotations_total",
                             lambda: d.wal.rotations_total,
                             help="WAL segment rotations (epoch seals, "
                                  "size bounds, snapshots)")
            m.set_counter_fn("wal_truncated_segments_total",
                             lambda: d.wal.truncated_segments_total,
                             help="WAL segments dropped after snapshots")
            m.set_gauge("wal_segments", lambda: d.wal.segment_count,
                        help="Live WAL segment files")
            m.set_gauge("wal_nbytes", lambda: d.wal.nbytes(),
                        help="Bytes across live WAL segments")
            m.set_gauge("wal_last_seq", lambda: d.wal.last_seq,
                        help="Newest appended WAL sequence number")
            m.set_counter_fn("snapshot_total", lambda: d.snapshots_total,
                             help="Snapshots taken this process")
            m.set_gauge("snapshot_wal_seq", lambda: d.snap_seq,
                        help="WAL seq the newest snapshot covers through")
            m.set_gauge("snapshot_last_seconds",
                        lambda: d.snapshot_last_seconds,
                        help="Duration of the most recent snapshot")
            m.set_gauge("snapshot_last_nbytes",
                        lambda: d.snapshot_last_nbytes,
                        help="On-disk bytes of the most recent snapshot")
            m.set_gauge("recovery_replayed_entries",
                        lambda: d.replayed_entries,
                        help="WAL entries replayed at the last boot")
            m.set_gauge("recovery_replayed_records",
                        lambda: d.replayed_records,
                        help="Records re-ingested from the WAL at boot")
            m.set_gauge("recovery_failed_entries",
                        lambda: d.replay_failed_entries,
                        help="WAL entries whose replay raised (skipped)")
            m.set_gauge("recovery_torn_tail_bytes",
                        lambda: d.wal.torn_tail_bytes,
                        help="Torn-tail bytes truncated from the newest "
                             "WAL segment at boot (0 = clean shutdown)")
            m.set_gauge("recovery_seconds", lambda: d.recovery_seconds,
                        help="Wall time of the last WAL replay")
            m.set_gauge("recovery_invalid_snapshots_skipped",
                        lambda: d.invalid_snapshots_skipped,
                        help="Corrupt/torn snapshots skipped while "
                             "picking the newest valid one")
        if srv.profiler is not None:
            m.register_histogram_provider(
                "service_stage_latency_seconds", srv.profiler.histograms,
                help="Host-side stage latency from the flush-loop profiler")
        # Re-resolve the arena per scrape: ingest swaps the host index
        # (and its arena) underneath the ShardedIndex.
        def _sketch_b():
            a = self._arena()
            return a.sketch_nbytes() if a is not None else 0

        def _post_b():
            a = self._arena()
            return (a.postings_nbytes()
                    if a is not None and getattr(a, "_post", None) is not None
                    else 0)

        m.set_gauge("arena_sketch_nbytes", _sketch_b,
                    help="Packed sketch column bytes")
        m.set_gauge("arena_postings_nbytes", _post_b,
                    help="Block-compressed postings bytes (0 until first "
                         "planned query builds them)")

        # Time-windowed index gauges — only when the served index is a
        # WindowManager (feature-detected via its ``windowed`` attr).
        if getattr(srv.index, "windowed", False):
            def _win(key):
                return lambda: srv.index.window_stats()[key]

            m.set_gauge("window_epochs", _win("epochs"),
                        help="Live epoch snapshots in the window manager")
            m.set_gauge("window_records", _win("records"),
                        help="Records across all live epochs")
            m.set_gauge("window_cached_views", _win("cached_windows"),
                        help="Cached merged window views")
            m.set_gauge("window_nbytes", lambda: srv.index.nbytes(),
                        help="Bytes across epoch arenas and cached views")
            m.set_counter_fn("window_merges_total", _win("merges_total"),
                             help="Window merges performed (cache misses)")
            m.set_counter_fn("window_retired_epochs_total",
                             _win("retired_epochs_total"),
                             help="Epoch snapshots retired via "
                                  "retire()/admin endpoint")
            m.set_counter_fn("window_retired_records_total",
                             _win("retired_records_total"),
                             help="Records dropped with retired epochs")

        # Fused device-pipeline counters (repro.planner.device): compile
        # cache behaviour and staging-pool reuse. Lazy per scrape — the
        # stats dict is plain ints, no jax import on the scrape path.
        def _pipe(key):
            def fn():
                from repro import obs
                return obs.device_pipeline_stats()[key]
            return fn

        for key, hlp in (
            ("calls", "Fused device-pipeline invocations"),
            ("compiles",
             "Device-pipeline compile-cache misses (each one logged as a "
             "slow-path recompile)"),
            ("cache_hits", "Device-pipeline compile-cache hits"),
            ("staging_reuse",
             "Query batches staged through an existing pooled buffer"),
            ("staging_alloc", "Staging-pool buffer allocations"),
        ):
            m.set_counter_fn(f"device_pipeline_{key}_total", _pipe(key),
                             help=hlp)
        m.set_gauge("device_pipeline_staging_buffers",
                    _pipe("staging_buffers"),
                    help="Live pooled staging buffers (distinct "
                         "shape-bucket keys)")

    def _arena(self):
        """The live sketch arena, re-resolved per call — ingest swaps the
        host index under the ShardedIndex."""
        idx = self.server.index
        host = getattr(idx, "host", None) or getattr(idx, "core", None)
        sk = getattr(host, "sketches", None)
        return sk if sk is not None and hasattr(sk, "sketch_nbytes") else None

    @property
    def num_records(self) -> int:
        idx = self.server.index
        return int(getattr(idx, "num_records", 0))

    # -- request handling --------------------------------------------------

    def handle(self, method: str, path: str, headers, rfile) -> Response:
        """One request → one response. ``headers`` is mapping-like;
        ``rfile`` a binary stream positioned at the body."""
        raw, _, query = path.partition("?")
        endpoint = raw.rstrip("/") or "/"
        t0 = self.clock()
        body = _Body(rfile, headers)
        try:
            resp = self._route(method, endpoint, headers, body, query)
        except Exception as e:  # a handler crash must not kill the conn
            resp = _json_error(
                500, f"internal error: {type(e).__name__}: {e}")
        # Early errors (401/404/405/429) answer before reading the body;
        # drain it so leftover bytes don't corrupt the next keep-alive
        # request. An undecodable body forces a fresh connection instead.
        if not body.drain():
            resp.headers["Connection"] = "close"
        self.metrics.inc(
            "service_requests_total",
            {"endpoint": endpoint.lstrip("/") or "root",
             "status": str(resp.status)},
            help="Requests by endpoint and HTTP status")
        self.metrics.observe(
            "service_request_latency_seconds", self.clock() - t0,
            {"endpoint": endpoint.lstrip("/") or "root"},
            help="End-to-end in-service latency")
        return resp

    def _route(self, method: str, endpoint: str, headers,
               body: "_Body", query: str = "") -> Response:
        if endpoint == "/healthz":
            # Liveness: always 200 while the process serves — read-only
            # degradation is a readiness problem (/readyz), not death.
            return Response(200, {"status": "ok",
                                  "records": self.num_records,
                                  "inflight": self.server.inflight,
                                  "writable": not self.server.read_only})
        if endpoint == "/readyz":
            if self.server.read_only:
                return Response(503, {
                    "status": "read-only",
                    "reason": self.server.read_only_reason})
            return Response(200, {"status": "ok"})
        if endpoint == "/metrics":
            return Response(200, self.metrics.render(),
                            content_type="text/plain; version=0.0.4")
        if endpoint in ("/debug/traces", "/debug/slow"):
            if not self.auth.allows(headers):
                return _json_error(401, "missing or invalid auth token")
            if method != "GET":
                return _json_error(405, f"{endpoint} is GET-only")
            return self._debug(endpoint)
        if endpoint in ("/admin/retire", "/admin/snapshot"):
            # Admin paths: behind auth, outside the rate limits — window
            # retirement and snapshots must work while the service sheds.
            if not self.auth.allows(headers):
                return _json_error(401, "missing or invalid auth token")
            if method != "POST":
                return _json_error(405, f"{endpoint} is POST-only")
            try:
                if endpoint == "/admin/snapshot":
                    return self._snapshot()
                return self._retire(json.loads(b"".join(body) or b"{}"))
            except Overloaded as e:
                return _json_error(429, str(e),
                                   **{"Retry-After": f"{e.retry_after:.3f}"})
            except ReadOnly as e:
                return _json_error(503, f"read-only: {e}")
            except RuntimeError as e:
                return _json_error(400, f"bad request: {e}")
            except (ValueError, KeyError, TypeError,
                    json.JSONDecodeError) as e:
                return _json_error(400, f"bad request: {e}")
        if endpoint not in ("/query", "/topk", "/ingest", "/debug/explain"):
            return _json_error(404, f"no route {endpoint!r}")
        if method != "POST":
            return _json_error(405, f"{endpoint} is POST-only")
        if not self.auth.allows(headers):
            return _json_error(401, "missing or invalid auth token")
        if not self.bucket.allow():
            ra = self.bucket.retry_after()
            return _json_error(429, "rate limit exceeded",
                               **{"Retry-After": f"{ra:.3f}"})
        tid = tenant_id(headers)
        if not self.tenant_buckets.allow(tid):
            self.metrics.inc(
                "service_ratelimited_total", {"tenant": tid},
                help="Per-tenant rate-limit rejections")
            ra = self.tenant_buckets.retry_after(tid)
            return _json_error(429, "tenant rate limit exceeded",
                               **{"Retry-After": f"{ra:.3f}"})
        try:
            if endpoint == "/ingest":
                return self._ingest(headers, body, query)
            payload = json.loads(b"".join(body) or b"{}")
            if endpoint == "/debug/explain":
                payload = dict(payload)
                payload["explain"] = True
                return self._query(payload)
            if endpoint == "/query":
                return self._query(payload)
            return self._topk(payload)
        except Overloaded as e:
            return _json_error(429, str(e),
                               **{"Retry-After": f"{e.retry_after:.3f}"})
        except ReadOnly as e:
            # Graceful degradation: mutations 503 once the data dir
            # fails; queries never reach here (they don't mutate).
            return _json_error(503, f"read-only: {e}")
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            return _json_error(400, f"bad request: {e}")

    @staticmethod
    def _deadline_s(body) -> float | None:
        ms = body.get("deadline_ms")
        return None if ms is None else float(ms) / 1e3

    def _debug(self, endpoint: str) -> Response:
        srv = self.server
        if endpoint == "/debug/traces":
            if srv.tracer is None:
                return Response(200, {"traceEvents": [],
                                      "displayTimeUnit": "ms"})
            return Response(200, srv.tracer.chrome_trace())
        return Response(200, {"threshold_s": srv.slow_threshold,
                              "count": srv.slow_queries,
                              "recent": list(srv.slow_log)})

    def _query(self, body) -> Response:
        explain = bool(body.get("explain", False))
        p = self.server.submit_query(
            np.asarray(body["q"], np.int64),
            threshold=float(body.get("threshold", 0.5)),
            deadline=self._deadline_s(body), explain=explain)
        res = self.server.result(p, timeout=self.result_timeout)
        out = {"rid": p.rid,
               "hits": np.asarray(res["hits"]).tolist(),
               "expired": p.expired}
        if explain:
            out["explain"] = res.get("explain")
        return Response(200, out)

    def _topk(self, body) -> Response:
        p = self.server.submit_topk(
            np.asarray(body["q"], np.int64), k=int(body.get("k", 10)),
            deadline=self._deadline_s(body))
        res = self.server.result(p, timeout=self.result_timeout)
        return Response(200, {
            "rid": p.rid,
            "ids": np.asarray(res["topk_ids"]).tolist(),
            "scores": [float(s) for s in res["topk_scores"]],
            "expired": p.expired})

    def _ingest(self, headers, body: "_Body", query: str = "") -> Response:
        qs = parse_qs(query)
        epoch = int(qs["epoch"][0]) if qs.get("epoch") else None
        idem_key = headers.get("Idempotency-Key") or None
        ctype = headers.get("Content-Type", "")
        if "json" in ctype and "ndjson" not in ctype:
            payload = json.loads(b"".join(body) or b"{}")
            if epoch is None and payload.get("epoch") is not None:
                epoch = int(payload["epoch"])
            if idem_key is None and payload.get("idempotency_key"):
                idem_key = str(payload["idempotency_key"])
            lines = (json.dumps(r).encode()
                     for r in payload.get("records", []))
        else:
            lines = _iter_lines(body)
        if epoch is not None and \
                not getattr(self.server.index, "windowed", False):
            raise ValueError(
                "epoch requires a windowed index "
                "(build with api.build_index(..., windowed=True))")
        # Chunk-granular dedupe: the request key derives one key per
        # chunk (``key#i`` — chunking is deterministic for a given body
        # and ingest_chunk), so a retried stream skips exactly the
        # chunks the first attempt already committed, even when that
        # attempt died mid-stream.
        chunk: list[np.ndarray] = []
        pending = []
        total = 0

        def submit(c):
            idem = f"{idem_key}#{len(pending)}" if idem_key else None
            return self._submit_ingest_chunk(c, epoch, idem=idem)

        for line in lines:
            if not line.strip():
                continue
            chunk.append(np.asarray(json.loads(line), np.int64))
            if len(chunk) >= self.ingest_chunk:
                pending.append(submit(chunk))
                total += len(chunk)
                chunk = []
        if chunk:
            pending.append(submit(chunk))
            total += len(chunk)
        deduped = 0
        for p in pending:
            res = self.server.result(p, timeout=self.result_timeout)
            deduped += bool(res.get("deduped"))
        out = {"ingested": total, "chunks": len(pending)}
        if epoch is not None:
            out["epoch"] = epoch
        if idem_key is not None:
            out["deduped_chunks"] = deduped
        return Response(200, out)

    def _retire(self, body) -> Response:
        """Drop window epochs strictly below ``body["before"]``."""
        if not getattr(self.server.index, "windowed", False):
            raise ValueError(
                "/admin/retire requires a windowed index "
                "(build with api.build_index(..., windowed=True))")
        p = self.server.submit_retire(int(body["before"]))
        res = self.server.result(p, timeout=self.result_timeout)
        return Response(200, {"rid": p.rid, "retired": res["retired"],
                              "epochs": res["epochs"]})

    def _snapshot(self) -> Response:
        p = self.server.submit_snapshot()
        res = self.server.result(p, timeout=self.result_timeout)
        return Response(200, {"rid": p.rid, **res})

    def _submit_ingest_chunk(self, chunk, epoch: int | None = None,
                             idem: str | None = None):
        """Admit one chunk, waiting out transient overload: an ingest
        stream mid-flight can't be half-dropped, so backpressure here is
        wait-and-retry, bounded by ``result_timeout``."""
        give_up = time.monotonic() + self.result_timeout
        while True:
            try:
                return self.server.submit_ingest(chunk, epoch=epoch,
                                                 idem=idem)
            except Overloaded as e:
                if time.monotonic() >= give_up:
                    raise
                time.sleep(min(e.retry_after, 0.05))


# -- stdlib HTTP plumbing ----------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    app: ServiceApp = None          # set by make_http_server

    def _respond(self):
        try:
            resp = self.app.handle(self.command, self.path, self.headers,
                                   self.rfile)
        except Exception as e:      # a handler crash must not kill the conn
            resp = _json_error(500, f"internal error: {type(e).__name__}: {e}")
        self.send_response(resp.status)
        self.send_header("Content-Type", resp.content_type)
        self.send_header("Content-Length", str(len(resp.body)))
        for k, v in resp.headers.items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(resp.body)

    do_GET = do_POST = do_PUT = _respond

    def log_message(self, fmt, *args):  # noqa: A003 - quiet by default
        if getattr(self.app, "verbose", False):
            super().log_message(fmt, *args)


def make_http_server(app: ServiceApp, host: str = "127.0.0.1",
                     port: int = 0) -> ThreadingHTTPServer:
    """Bind a threading HTTP server (port 0 = ephemeral; the bound port
    is ``httpd.server_address[1]``). Caller owns ``serve_forever`` /
    ``shutdown`` and the flush worker's ``start()``/``stop()``."""
    handler = type("BoundHandler", (_Handler,), {"app": app})
    httpd = ThreadingHTTPServer((host, port), handler)
    httpd.daemon_threads = True
    return httpd


class ServiceHandle:
    """In-process service for tests and the load harness: flush worker +
    HTTP listener on an ephemeral port, context-managed."""

    def __init__(self, app: ServiceApp, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = app
        self.httpd = make_http_server(app, host, port)
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="http-listener",
            daemon=True)

    @property
    def address(self) -> tuple[str, int]:
        return self.httpd.server_address[:2]

    def __enter__(self) -> "ServiceHandle":
        self.app.server.start()
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self.httpd.shutdown()
        self.httpd.server_close()
        self.app.server.stop()
        return False
