"""Minimal stdlib HTTP client for the service — used by the load
harness, the CI smoke job, and the endpoint round-trip tests. One
persistent ``http.client`` connection per instance (callers wanting
concurrency open one client per worker thread)."""

from __future__ import annotations

import http.client
import json
import random
import time

import numpy as np


class ServiceError(RuntimeError):
    def __init__(self, status: int, body: dict, retry_after: float = 0.0):
        super().__init__(f"HTTP {status}: {body}")
        self.status = status
        self.body = body
        self.retry_after = retry_after


# Methods safe to replay on a dropped connection: the request either
# never mutates (GET/HEAD/OPTIONS) or mutates idempotently by contract
# (PUT/DELETE). POST is NOT here — a stale keep-alive can drop the
# connection *after* the server applied the request, and replaying a
# POST would then apply it twice. POSTs only retry when the caller
# marks them idempotent (e.g. /ingest with an Idempotency-Key, /query
# and /topk which are POST-shaped reads).
_IDEMPOTENT_METHODS = frozenset({"GET", "HEAD", "PUT", "DELETE", "OPTIONS"})


class ServiceClient:
    """One persistent keep-alive connection to a running service.

    Method-per-endpoint mirror of docs/SERVING.md §Endpoints: answers
    are decoded JSON with array fields lifted back to numpy, non-200
    responses raise :class:`ServiceError` (carrying the parsed body and
    any ``Retry-After`` hint, so callers can implement backoff). Not
    thread-safe — open one client per worker thread."""

    def __init__(self, host: str, port: int, token: str | None = None,
                 timeout: float = 60.0, retries: int = 0,
                 backoff_s: float = 0.05, jitter=random.random):
        """``retries`` > 0 turns on jittered exponential backoff for
        429 responses (honoring the server's ``Retry-After``) and, for
        requests that are safe to replay, reconnect-and-resend on a
        dropped connection. The default 0 preserves fail-fast behavior
        for callers doing their own load control (the bench harness)."""
        self.host, self.port, self.token = host, port, token
        self.timeout = timeout
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self._jitter = jitter
        self._conn: http.client.HTTPConnection | None = None

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        return self._conn

    def close(self):
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def _headers(self, extra: dict | None = None) -> dict:
        h = {"Content-Type": "application/json"}
        if self.token:
            h["Authorization"] = f"Bearer {self.token}"
        h.update(extra or {})
        return h

    def request(self, method: str, path: str, body: bytes | None = None,
                headers: dict | None = None,
                idempotent: bool | None = None) -> tuple[int, bytes, dict]:
        """(status, raw body, response headers) — one retry on a stale
        keep-alive connection, but ONLY for requests that are safe to
        replay. A keep-alive drop is ambiguous (the server may have
        applied the request before the socket died), so a
        non-idempotent POST propagates the error instead of silently
        applying twice. ``idempotent=None`` infers from the method;
        callers mark POST-shaped reads (/query, /topk) and keyed
        ingests idempotent explicitly."""
        if idempotent is None:
            idempotent = method in _IDEMPOTENT_METHODS
        for attempt in (0, 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=body,
                             headers=self._headers(headers))
                r = conn.getresponse()
                return r.status, r.read(), dict(r.getheaders())
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                if attempt or not idempotent:
                    raise
        raise AssertionError("unreachable")

    def _sleep_backoff(self, attempt: int, retry_after: float = 0.0):
        """Jittered exponential backoff, never shorter than the
        server's Retry-After hint."""
        delay = max(float(retry_after), self.backoff_s * (2 ** attempt))
        time.sleep(delay * (1.0 + 0.25 * self._jitter()))

    def _call(self, method: str, path: str, payload: dict | None = None,
              raw_body: bytes | None = None, headers: dict | None = None,
              idempotent: bool | None = None):
        body = raw_body if raw_body is not None else (
            json.dumps(payload).encode() if payload is not None else None)
        for i in range(self.retries + 1):
            status, raw, rhead = self.request(method, path, body, headers,
                                              idempotent=idempotent)
            try:
                data = json.loads(raw) if raw else {}
            except json.JSONDecodeError:
                data = {"raw": raw.decode(errors="replace")}
            if status == 200:
                return data
            ra = float(rhead.get("Retry-After", 0))
            # 429 retry is safe regardless of idempotency: the server
            # answered without applying anything.
            if status == 429 and i < self.retries:
                self._sleep_backoff(i, ra)
                continue
            raise ServiceError(status, data, retry_after=ra)
        raise AssertionError("unreachable")

    # -- endpoints ---------------------------------------------------------

    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    def metrics_text(self) -> str:
        status, raw, _ = self.request("GET", "/metrics")
        if status != 200:
            raise ServiceError(status, {"raw": raw.decode(errors="replace")})
        return raw.decode()

    def query(self, q_ids, threshold: float = 0.5,
              deadline_ms: float | None = None) -> np.ndarray:
        """Record ids with estimated containment ≥ ``threshold`` —
        bit-identical to the served index's direct ``batch_query``.
        ``deadline_ms`` opts into the dense-fallback path when the
        request waits longer than that in the flush queue."""
        payload = {"q": np.asarray(q_ids).tolist(), "threshold": threshold}
        if deadline_ms is not None:
            payload["deadline_ms"] = deadline_ms
        # POST-shaped read: replaying it cannot double-apply anything.
        return np.asarray(
            self._call("POST", "/query", payload, idempotent=True)["hits"],
            np.int64)

    def query_explain(self, q_ids, threshold: float = 0.5
                      ) -> tuple[np.ndarray, dict]:
        """Like :meth:`query` but also returns the per-query plan explain
        (EXPLAIN ANALYZE: chosen path, predicted vs measured cost, block
        and candidate accounting — see docs/OBSERVABILITY.md)."""
        d = self._call("POST", "/query",
                       {"q": np.asarray(q_ids).tolist(),
                        "threshold": threshold, "explain": True},
                       idempotent=True)
        return np.asarray(d["hits"], np.int64), d["explain"]

    def debug_traces(self) -> dict:
        """Chrome trace-event JSON of the server's recent request traces."""
        return self._call("GET", "/debug/traces")

    def debug_slow(self) -> dict:
        """The server's slow-query log."""
        return self._call("GET", "/debug/slow")

    def topk(self, q_ids, k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """Top-``k`` ``(ids, scores)`` under the deterministic
        (score desc, id asc) order shared by every execution route."""
        d = self._call("POST", "/topk",
                       {"q": np.asarray(q_ids).tolist(), "k": k},
                       idempotent=True)
        return (np.asarray(d["ids"], np.int64),
                np.asarray(d["scores"], np.float32))

    def ingest(self, records, stream: bool = True,
               epoch: int | None = None,
               idempotency_key: str | None = None) -> dict:
        """NDJSON ingest. ``stream=True`` (default) sends chunked
        transfer-encoding from a line generator — the full batch never
        exists as one buffer on either side; the server re-chunks it
        into flush-sized CSR ingests. ``epoch`` targets a window epoch
        on a windowed server (sent as the ``?epoch=N`` query param; the
        server answers 400 if its index is not windowed).

        ``idempotency_key`` makes the ingest retry-safe: the server
        dedupes chunks already applied under the key, so this method
        will reconnect-and-resend on a dropped connection and back off
        on 429 (up to ``retries``). Without a key, any transport error
        propagates — replaying an unkeyed POST could double-ingest.
        Keyed retries buffer ``records`` (a one-shot iterator can't be
        replayed)."""
        path = "/ingest" if epoch is None else f"/ingest?epoch={int(epoch)}"
        extra = {"Content-Type": "application/x-ndjson"}
        retries = 0
        if idempotency_key is not None:
            extra["Idempotency-Key"] = str(idempotency_key)
            records = [np.asarray(r) for r in records]
            retries = self.retries

        def make_lines():
            return (json.dumps(np.asarray(r).tolist()).encode() + b"\n"
                    for r in records)

        if not stream:
            return self._call("POST", path,
                              raw_body=b"".join(make_lines()),
                              headers=extra,
                              idempotent=idempotency_key is not None)
        headers = self._headers(extra)
        for i in range(retries + 1):
            conn = self._connection()
            try:
                # The generator is rebuilt per attempt: a retry must
                # stream the records again from the start, not resume a
                # half-consumed iterator from the failed attempt.
                conn.request("POST", path, body=make_lines(),
                             headers=headers, encode_chunked=True)
                r = conn.getresponse()
                status, raw = r.status, r.read()
                rhead = dict(r.getheaders())
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                if i >= retries:
                    raise
                self._sleep_backoff(i)
                continue
            data = json.loads(raw) if raw else {}
            if status == 200:
                return data
            ra = float(rhead.get("Retry-After", 0))
            if status == 429 and i < retries:
                self._sleep_backoff(i, ra)
                continue
            raise ServiceError(status, data, retry_after=ra)
        raise AssertionError("unreachable")

    def retire(self, before: int) -> dict:
        """Drop window epochs ``< before`` on a windowed server; returns
        ``{"retired": n, "epochs": [...]}`` (400 if not windowed)."""
        return self._call("POST", "/admin/retire", {"before": int(before)})

    def snapshot(self) -> dict:
        """Trigger an atomic snapshot + WAL truncation on a durable
        server (400 without --data-dir, 503 once read-only)."""
        return self._call("POST", "/admin/snapshot")

    def readyz(self) -> dict:
        """Readiness: raises ServiceError(503) once the server has
        degraded to read-only serving."""
        return self._call("GET", "/readyz")
