"""Minimal stdlib HTTP client for the service — used by the load
harness, the CI smoke job, and the endpoint round-trip tests. One
persistent ``http.client`` connection per instance (callers wanting
concurrency open one client per worker thread)."""

from __future__ import annotations

import http.client
import json

import numpy as np


class ServiceError(RuntimeError):
    def __init__(self, status: int, body: dict, retry_after: float = 0.0):
        super().__init__(f"HTTP {status}: {body}")
        self.status = status
        self.body = body
        self.retry_after = retry_after


class ServiceClient:
    """One persistent keep-alive connection to a running service.

    Method-per-endpoint mirror of docs/SERVING.md §Endpoints: answers
    are decoded JSON with array fields lifted back to numpy, non-200
    responses raise :class:`ServiceError` (carrying the parsed body and
    any ``Retry-After`` hint, so callers can implement backoff). Not
    thread-safe — open one client per worker thread."""

    def __init__(self, host: str, port: int, token: str | None = None,
                 timeout: float = 60.0):
        self.host, self.port, self.token = host, port, token
        self.timeout = timeout
        self._conn: http.client.HTTPConnection | None = None

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        return self._conn

    def close(self):
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def _headers(self, extra: dict | None = None) -> dict:
        h = {"Content-Type": "application/json"}
        if self.token:
            h["Authorization"] = f"Bearer {self.token}"
        h.update(extra or {})
        return h

    def request(self, method: str, path: str, body: bytes | None = None,
                headers: dict | None = None) -> tuple[int, bytes, dict]:
        """(status, raw body, response headers) — one retry on a stale
        keep-alive connection."""
        for attempt in (0, 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=body,
                             headers=self._headers(headers))
                r = conn.getresponse()
                return r.status, r.read(), dict(r.getheaders())
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def _call(self, method: str, path: str, payload: dict | None = None,
              raw_body: bytes | None = None, headers: dict | None = None):
        body = raw_body if raw_body is not None else (
            json.dumps(payload).encode() if payload is not None else None)
        status, raw, rhead = self.request(method, path, body, headers)
        try:
            data = json.loads(raw) if raw else {}
        except json.JSONDecodeError:
            data = {"raw": raw.decode(errors="replace")}
        if status != 200:
            raise ServiceError(status, data,
                               retry_after=float(rhead.get("Retry-After", 0)))
        return data

    # -- endpoints ---------------------------------------------------------

    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    def metrics_text(self) -> str:
        status, raw, _ = self.request("GET", "/metrics")
        if status != 200:
            raise ServiceError(status, {"raw": raw.decode(errors="replace")})
        return raw.decode()

    def query(self, q_ids, threshold: float = 0.5,
              deadline_ms: float | None = None) -> np.ndarray:
        """Record ids with estimated containment ≥ ``threshold`` —
        bit-identical to the served index's direct ``batch_query``.
        ``deadline_ms`` opts into the dense-fallback path when the
        request waits longer than that in the flush queue."""
        payload = {"q": np.asarray(q_ids).tolist(), "threshold": threshold}
        if deadline_ms is not None:
            payload["deadline_ms"] = deadline_ms
        return np.asarray(self._call("POST", "/query", payload)["hits"],
                          np.int64)

    def query_explain(self, q_ids, threshold: float = 0.5
                      ) -> tuple[np.ndarray, dict]:
        """Like :meth:`query` but also returns the per-query plan explain
        (EXPLAIN ANALYZE: chosen path, predicted vs measured cost, block
        and candidate accounting — see docs/OBSERVABILITY.md)."""
        d = self._call("POST", "/query",
                       {"q": np.asarray(q_ids).tolist(),
                        "threshold": threshold, "explain": True})
        return np.asarray(d["hits"], np.int64), d["explain"]

    def debug_traces(self) -> dict:
        """Chrome trace-event JSON of the server's recent request traces."""
        return self._call("GET", "/debug/traces")

    def debug_slow(self) -> dict:
        """The server's slow-query log."""
        return self._call("GET", "/debug/slow")

    def topk(self, q_ids, k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """Top-``k`` ``(ids, scores)`` under the deterministic
        (score desc, id asc) order shared by every execution route."""
        d = self._call("POST", "/topk",
                       {"q": np.asarray(q_ids).tolist(), "k": k})
        return (np.asarray(d["ids"], np.int64),
                np.asarray(d["scores"], np.float32))

    def ingest(self, records, stream: bool = True,
               epoch: int | None = None) -> dict:
        """NDJSON ingest. ``stream=True`` (default) sends chunked
        transfer-encoding from a line generator — the full batch never
        exists as one buffer on either side; the server re-chunks it
        into flush-sized CSR ingests. ``epoch`` targets a window epoch
        on a windowed server (sent as the ``?epoch=N`` query param; the
        server answers 400 if its index is not windowed)."""
        path = "/ingest" if epoch is None else f"/ingest?epoch={int(epoch)}"
        lines = (json.dumps(np.asarray(r).tolist()).encode() + b"\n"
                 for r in records)
        headers = self._headers({"Content-Type": "application/x-ndjson"})
        if not stream:
            return self._call("POST", path, raw_body=b"".join(lines),
                              headers={"Content-Type": "application/x-ndjson"})
        conn = self._connection()
        try:
            conn.request("POST", path, body=lines, headers=headers,
                         encode_chunked=True)
            r = conn.getresponse()
            status, raw = r.status, r.read()
        except (http.client.HTTPException, ConnectionError, OSError):
            self.close()
            raise
        data = json.loads(raw) if raw else {}
        if status != 200:
            raise ServiceError(status, data)
        return data

    def retire(self, before: int) -> dict:
        """Drop window epochs ``< before`` on a windowed server; returns
        ``{"retired": n, "epochs": [...]}`` (400 if not windowed)."""
        return self._call("POST", "/admin/retire", {"before": int(before)})
