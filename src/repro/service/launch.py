"""Service CLI: build (or load) a GB-KMV index and serve it over HTTP.

    PYTHONPATH=src python -m repro.service.launch \
        --dataset NETFLIX --scale 0.25 --port 8080 \
        --max-inflight 256 --rate-limit 500 --auth-token s3cret

``--rounds N`` runs a self-driven smoke instead of serving forever: N
batched rounds through the real HTTP stack on an ephemeral port, then
exits printing p50/p99 — the behavior the deprecated
``repro.launch.serve --mode sketch`` shim maps onto.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.launch.mesh import make_mesh
from repro.data import datasets
from repro.data.synth import make_query_workload
from repro.obs import Tracer
from repro.sketchindex import ShardedIndex
from repro.service import (
    AsyncSketchServer, Durability, ServiceApp, ServiceClient, ServiceHandle)


def add_service_args(ap: argparse.ArgumentParser):
    ap.add_argument("--port", type=int, default=8080,
                    help="listen port (0 = ephemeral)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--max-inflight", type=int, default=256,
                    help="admission queue bound; beyond it requests shed "
                         "with 429 + Retry-After")
    ap.add_argument("--rate-limit", type=float, default=None,
                    help="token-bucket rate limit, requests/s (default: off)")
    ap.add_argument("--burst", type=int, default=None,
                    help="token-bucket burst size (default: ~1s of rate)")
    ap.add_argument("--tenant-rate-limit", type=float, default=None,
                    help="per-tenant (per-auth-token) bucket rate, "
                         "requests/s (default: off)")
    ap.add_argument("--tenant-burst", type=int, default=None,
                    help="per-tenant bucket burst size")
    ap.add_argument("--auth-token", default=None,
                    help="require this bearer token on query/topk/ingest")
    ap.add_argument("--trace-capacity", type=int, default=0,
                    help="keep the last N request traces for /debug/traces "
                         "(0 = tracing off)")
    ap.add_argument("--slow-query-ms", type=float, default=1000.0,
                    help="slow-query log threshold; <= 0 disables the log")
    ap.add_argument("--no-profile", action="store_true",
                    help="disable the per-stage latency profiler")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="micro-batch deadline (flush age bound)")
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="default per-request SLO; expired requests take "
                         "the dense fallback path")
    ap.add_argument("--ingest-chunk", type=int, default=256,
                    help="records per streamed /ingest flush chunk")
    ap.add_argument("--plan", default="auto",
                    choices=("auto", "dense", "pruned"))
    ap.add_argument("--windowed", action="store_true",
                    help="serve a time-windowed index (WindowManager): "
                         "/ingest accepts ?epoch=N and /admin/retire "
                         "drops expired epochs")
    ap.add_argument("--data-dir", default=None,
                    help="durable state directory: ingest goes through a "
                         "WAL, snapshots land here, and on boot the newest "
                         "valid snapshot + WAL tail is recovered instead "
                         "of rebuilding from the dataset")
    ap.add_argument("--fsync", default="batch",
                    choices=("always", "batch", "off"),
                    help="WAL fsync policy: 'always' = one fsync per "
                         "append, 'batch' = one per mutation batch (group "
                         "commit, the default), 'off' = OS page cache only")
    ap.add_argument("--snapshot-interval-s", type=float, default=0.0,
                    help="background snapshot period in seconds "
                         "(0 = only on POST /admin/snapshot)")
    ap.add_argument("--snapshot-keep", type=int, default=2,
                    help="completed snapshots retained (older pruned)")
    ap.add_argument("--idem-window", type=int, default=1024,
                    help="idempotency-key dedupe window (entries)")


def _build_index(args):
    """Fresh build from the dataset (no durable state to recover)."""
    recs = datasets.load(args.dataset, scale=args.scale)
    total = sum(len(r) for r in recs)
    t0 = time.time()
    if getattr(args, "windowed", False):
        # Time-windowed serving: the WindowManager speaks the same
        # serve_batch protocol; /ingest?epoch=N opens epochs and
        # /admin/retire drops expired ones. No sharding layer — windows
        # merge lazily on the host before device queries.
        sharded = api.get_engine("gbkmv").build(
            recs, int(total * args.budget_frac), seed=0,
            backend=args.backend, windowed=True, epoch=0)
        desc = f"windowed index={sharded.nbytes()/1e6:.1f}MB"
    else:
        mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")),
                         ("data", "model"))
        index = api.get_engine("gbkmv").build(
            recs, int(total * args.budget_frac), seed=0,
            backend=args.backend)
        sharded = ShardedIndex(index, mesh, backend=args.backend)
        desc = f"index={index.nbytes()/1e6:.1f}MB"
    print(f"[service] {args.dataset}: m={len(recs)} "
          f"{desc} built in {time.time()-t0:.2f}s")
    return sharded


def build_service(args) -> ServiceApp:
    durability = None
    sharded = None
    if getattr(args, "data_dir", None):
        durability = Durability(
            args.data_dir, fsync=getattr(args, "fsync", "batch"),
            snapshot_keep=getattr(args, "snapshot_keep", 2),
            idem_window=getattr(args, "idem_window", 1024),
            snapshot_interval=getattr(args, "snapshot_interval_s", 0.0))
        t0 = time.time()
        loaded, manifest = durability.load_latest_index()
        if loaded is not None:
            if manifest.get("windowed"):
                sharded = loaded
            else:
                mesh = make_mesh(
                    tuple(int(x) for x in args.mesh.split("x")),
                    ("data", "model"))
                sharded = ShardedIndex(loaded, mesh, backend=args.backend)
            stats = durability.replay_into(sharded)
            print(f"[service] recovered from {args.data_dir}: snapshot "
                  f"wal_seq={durability.snap_seq}, replayed "
                  f"{stats['replayed_entries']} WAL entries "
                  f"({stats['replayed_records']} records, "
                  f"{stats['torn_tail_bytes']}B torn tail) "
                  f"in {time.time()-t0:.2f}s")
    if sharded is None:
        sharded = _build_index(args)
        if durability is not None:
            # A WAL without a snapshot (crash before the first one):
            # the dataset build is deterministic, so re-applying the
            # tail on top reproduces the pre-crash state.
            stats = durability.replay_into(sharded)
            if stats["replayed_entries"]:
                print(f"[service] replayed {stats['replayed_entries']} "
                      f"WAL entries onto the fresh build")
            # Baseline snapshot: the next boot recovers from disk
            # instead of rebuilding from the dataset.
            durability.snapshot(sharded)
    tracer = (Tracer(capacity=args.trace_capacity)
              if args.trace_capacity > 0 else None)
    server = AsyncSketchServer(
        sharded, max_batch=args.max_batch,
        max_wait=args.max_wait_ms / 1e3,
        max_inflight=args.max_inflight,
        default_deadline=args.deadline_ms / 1e3, plan=args.plan,
        tracer=tracer, profile=not args.no_profile,
        slow_threshold=(args.slow_query_ms / 1e3
                        if args.slow_query_ms > 0 else None),
        durability=durability)
    return ServiceApp(server, auth_token=args.auth_token,
                      rate_limit=args.rate_limit, burst=args.burst,
                      tenant_rate_limit=args.tenant_rate_limit,
                      tenant_burst=args.tenant_burst,
                      ingest_chunk=args.ingest_chunk)


def smoke_rounds(app: ServiceApp, args) -> None:
    """Self-driven rounds through the real HTTP stack (shim behavior)."""
    recs = datasets.load(args.dataset, scale=args.scale)
    queries = make_query_workload(recs, args.batch * args.rounds)
    with ServiceHandle(app, host=args.host, port=0) as handle:
        host, port = handle.address
        cli = ServiceClient(host, port, token=args.auth_token)
        lat = []
        for r in range(args.rounds):
            qs = queries[r * args.batch:(r + 1) * args.batch]
            t0 = time.time()
            hits = [cli.query(q, 0.5) for q in qs]
            lat.append(time.time() - t0)
            if r == 0:
                ids, scores = cli.topk(qs[0], args.topk)
                print(f"[service] round0 top1 score: "
                      f"{float(scores[0]):.3f} (id {int(ids[0])}), "
                      f"{len(hits[0])} hits at t=0.5")
        cli.close()
        lat = np.asarray(lat) * 1e3
        stats = app.server.stats
        print(f"[service] {args.rounds} rounds × {args.batch} queries over "
              f"HTTP: p50={np.percentile(lat, 50):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms "
              f"({args.batch / (np.mean(lat) / 1e3):.0f} q/s, "
              f"mean batch {stats.mean_batch:.1f})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--dataset", default="NETFLIX")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--budget-frac", type=float, default=0.1)
    ap.add_argument("--backend", default="jnp",
                    choices=("numpy", "jnp", "pallas"))
    ap.add_argument("--batch", type=int, default=16,
                    help="queries per round in --rounds smoke mode")
    ap.add_argument("--rounds", type=int, default=0,
                    help="run N smoke rounds and exit (0 = serve forever)")
    ap.add_argument("--topk", type=int, default=10)
    add_service_args(ap)
    args = ap.parse_args(argv)

    app = build_service(args)
    if args.rounds > 0:
        smoke_rounds(app, args)
        return
    with ServiceHandle(app, host=args.host, port=args.port) as handle:
        host, port = handle.address
        print(f"[service] listening on http://{host}:{port} "
              f"(auth={'on' if args.auth_token else 'off'}, "
              f"rate_limit={args.rate_limit or 'off'}, "
              f"max_inflight={args.max_inflight})")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("[service] shutting down")


if __name__ == "__main__":
    main()
