"""Prometheus-text-format metrics for the serving layer (stdlib only).

A tiny typed registry — counters, gauges (value or callback), and
fixed-bucket histograms (``repro.serving.Histogram``) — rendering the
text exposition format `/metrics` speaks:

    # HELP service_requests_total ...
    # TYPE service_requests_total counter
    service_requests_total{endpoint="query",status="200"} 42

Thread-safe under one lock; label sets are sorted tuples of ``(key,
value)`` pairs so a metric's series render deterministically.
"""

from __future__ import annotations

import threading
from typing import Callable

from repro.serving.histogram import Histogram


def _labels_str(labels: dict | None) -> str:
    if not labels:
        return ""
    return ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))


# Reserved series key marking a render-time histogram-family provider.
_PROVIDER_KEY = "\x00provider"


class Metrics:
    def __init__(self):
        self._lock = threading.Lock()
        # name -> (type, help, {labels_str: value|Histogram|callable})
        self._families: dict[str, tuple[str, str, dict]] = {}

    def _family(self, name: str, typ: str, help_: str) -> dict:
        fam = self._families.get(name)
        if fam is None:
            fam = (typ, help_, {})
            self._families[name] = fam
        elif fam[0] != typ:
            raise ValueError(f"metric {name!r} already registered as {fam[0]}")
        return fam[2]

    # -- write side --------------------------------------------------------

    def inc(self, name: str, labels: dict | None = None, value: float = 1,
            help: str = "") -> None:
        with self._lock:
            series = self._family(name, "counter", help)
            key = _labels_str(labels)
            series[key] = series.get(key, 0) + value

    def set_gauge(self, name: str, value, labels: dict | None = None,
                  help: str = "") -> None:
        """``value`` may be a number or a zero-arg callable sampled at
        render time (live gauges: queue depth, arena bytes)."""
        with self._lock:
            self._family(name, "gauge", help)[_labels_str(labels)] = value

    def set_counter_fn(self, name: str, fn: Callable[[], float],
                       labels: dict | None = None, help: str = "") -> None:
        """Expose a counter whose value lives elsewhere (e.g. the flush
        loop's ``BatchStats`` tallies) — sampled at render time."""
        with self._lock:
            self._family(name, "counter", help)[_labels_str(labels)] = fn

    def set_info(self, name: str, labels: dict, help: str = "") -> None:
        """Prometheus info idiom: a gauge fixed at 1 whose labels carry
        build/configuration strings (e.g. the WAL fsync policy)."""
        with self._lock:
            self._family(name, "gauge", help)[_labels_str(labels)] = 1

    def observe(self, name: str, value: float, labels: dict | None = None,
                help: str = "", bounds=None) -> None:
        with self._lock:
            series = self._family(name, "histogram", help)
            key = _labels_str(labels)
            h = series.get(key)
            if h is None:
                h = series[key] = (Histogram(bounds) if bounds is not None
                                   else Histogram())
            h.observe(value)

    def register_histogram(self, name: str, hist: Histogram,
                           labels: dict | None = None, help: str = "") -> None:
        """Expose an externally-owned histogram (e.g. the flush loop's
        ``BatchStats`` distributions) — rendered live, never copied."""
        with self._lock:
            self._family(name, "histogram", help)[_labels_str(labels)] = hist

    def register_histogram_provider(self, name: str,
                                    fn: Callable[[], dict], help: str = ""
                                    ) -> None:
        """Expose a *family* of histograms whose label sets appear at
        runtime (e.g. per-stage profiler latencies): ``fn()`` returns
        ``{labels_dict_or_str: Histogram}`` and is sampled at render."""
        with self._lock:
            self._family(name, "histogram", help)[_PROVIDER_KEY] = fn

    # -- read side ---------------------------------------------------------

    def get_counter(self, name: str, labels: dict | None = None) -> float:
        with self._lock:
            fam = self._families.get(name)
            return fam[2].get(_labels_str(labels), 0) if fam else 0

    def histogram(self, name: str, labels: dict | None = None
                  ) -> Histogram | None:
        with self._lock:
            fam = self._families.get(name)
            return fam[2].get(_labels_str(labels)) if fam else None

    def render(self) -> str:
        with self._lock:
            lines: list[str] = []
            for name in sorted(self._families):
                typ, help_, series = self._families[name]
                if help_:
                    lines.append(f"# HELP {name} {help_}")
                lines.append(f"# TYPE {name} {typ}")
                for key in sorted(series):
                    v = series[key]
                    if key == _PROVIDER_KEY:
                        fams = v()
                        for lk in sorted(fams, key=str):
                            ls = lk if isinstance(lk, str) else _labels_str(lk)
                            lines.extend(fams[lk].to_prometheus(name, ls))
                        continue
                    if isinstance(v, Histogram):
                        lines.extend(v.to_prometheus(name, key))
                        continue
                    if isinstance(v, Callable):
                        v = v()
                    brace = f"{{{key}}}" if key else ""
                    lines.append(f"{name}{brace} {float(v):g}")
            return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict[str, float]:
    """Inverse of :meth:`Metrics.render` for tests and the load harness:
    {"name{labels}": value} over every sample line."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        out[key] = float(val)
    return out
