"""Admission middleware for the HTTP service: auth token + token-bucket
rate limiting (global and per-tenant). All are hooks the app applies
before a request touches the flush loop — stdlib only, injectable
clocks, trivially composable.
"""

from __future__ import annotations

import hashlib
import hmac
import threading
import time
from collections import OrderedDict
from typing import Callable


class AuthToken:
    """Static bearer-token check (``Authorization: Bearer <t>`` or
    ``X-Auth-Token: <t>``). Constant-time comparison; a ``None`` token
    disables auth (open service)."""

    def __init__(self, token: str | None):
        self.token = token

    def allows(self, headers) -> bool:
        if self.token is None:
            return True
        got = headers.get("X-Auth-Token", "")
        if not got:
            auth = headers.get("Authorization", "")
            if auth.startswith("Bearer "):
                got = auth[len("Bearer "):]
        return bool(got) and hmac.compare_digest(got, self.token)


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` cap.

    ``allow()`` spends one token or refuses; ``retry_after()`` is the
    time until the next token exists. ``rate=None`` disables limiting.
    Thread-safe (the HTTP layer calls from per-connection threads).
    """

    def __init__(self, rate: float | None, burst: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = rate
        self.burst = float(burst if burst is not None
                           else max(1, int(rate or 1)))
        self.clock = clock
        self.tokens = self.burst
        self.last = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        if self.rate:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last) * self.rate)
        self.last = now

    def allow(self, n: float = 1.0) -> bool:
        if self.rate is None:
            return True
        with self._lock:
            now = self.clock()
            self._refill(now)
            if self.tokens >= n:
                self.tokens -= n
                return True
            return False

    def retry_after(self, n: float = 1.0) -> float:
        if self.rate is None:
            return 0.0
        with self._lock:
            deficit = max(0.0, n - self.tokens)
            return deficit / self.rate if self.rate > 0 else 1.0


def tenant_id(headers) -> str:
    """Stable, non-reversible tenant label from the request's auth
    credential: a short sha256 prefix of the presented token (never the
    raw secret — this string lands in Prometheus labels and logs), or
    ``"anon"`` for unauthenticated requests."""
    got = headers.get("X-Auth-Token", "")
    if not got:
        auth = headers.get("Authorization", "")
        if auth.startswith("Bearer "):
            got = auth[len("Bearer "):]
    if not got:
        return "anon"
    return hashlib.sha256(got.encode()).hexdigest()[:12]


class TenantBuckets:
    """Per-tenant token buckets sharing one (rate, burst) policy.

    Buckets materialize on a tenant's first request; ``max_tenants``
    bounds memory by evicting the least-recently-seen bucket (an evicted
    tenant simply restarts with a full burst — the failure mode is
    briefly *under*-limiting, never a leak). ``rate=None`` disables
    per-tenant limiting entirely.
    """

    def __init__(self, rate: float | None, burst: int | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 max_tenants: int = 1024):
        self.rate = rate
        self.burst = burst
        self.clock = clock
        self.max_tenants = int(max_tenants)
        self._buckets: OrderedDict[str, TokenBucket] = OrderedDict()
        self._lock = threading.Lock()

    def _bucket(self, tenant: str) -> TokenBucket:
        with self._lock:
            b = self._buckets.get(tenant)
            if b is None:
                b = self._buckets[tenant] = TokenBucket(
                    self.rate, self.burst, clock=self.clock)
                while len(self._buckets) > self.max_tenants:
                    self._buckets.popitem(last=False)
            else:
                self._buckets.move_to_end(tenant)
            return b

    def allow(self, tenant: str, n: float = 1.0) -> bool:
        if self.rate is None:
            return True
        return self._bucket(tenant).allow(n)

    def retry_after(self, tenant: str, n: float = 1.0) -> float:
        if self.rate is None:
            return 0.0
        return self._bucket(tenant).retry_after(n)
