"""Admission middleware for the HTTP service: auth token + token-bucket
rate limiting. Both are hooks the app applies before a request touches
the flush loop — stdlib only, injectable clocks, trivially composable.
"""

from __future__ import annotations

import hmac
import threading
import time
from typing import Callable


class AuthToken:
    """Static bearer-token check (``Authorization: Bearer <t>`` or
    ``X-Auth-Token: <t>``). Constant-time comparison; a ``None`` token
    disables auth (open service)."""

    def __init__(self, token: str | None):
        self.token = token

    def allows(self, headers) -> bool:
        if self.token is None:
            return True
        got = headers.get("X-Auth-Token", "")
        if not got:
            auth = headers.get("Authorization", "")
            if auth.startswith("Bearer "):
                got = auth[len("Bearer "):]
        return bool(got) and hmac.compare_digest(got, self.token)


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` cap.

    ``allow()`` spends one token or refuses; ``retry_after()`` is the
    time until the next token exists. ``rate=None`` disables limiting.
    Thread-safe (the HTTP layer calls from per-connection threads).
    """

    def __init__(self, rate: float | None, burst: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = rate
        self.burst = float(burst if burst is not None
                           else max(1, int(rate or 1)))
        self.clock = clock
        self.tokens = self.burst
        self.last = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        if self.rate:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last) * self.rate)
        self.last = now

    def allow(self, n: float = 1.0) -> bool:
        if self.rate is None:
            return True
        with self._lock:
            now = self.clock()
            self._refill(now)
            if self.tokens >= n:
                self.tokens -= n
                return True
            return False

    def retry_after(self, n: float = 1.0) -> float:
        if self.rate is None:
            return 0.0
        with self._lock:
            deficit = max(0.0, n - self.tokens)
            return deficit / self.rate if self.rate > 0 else 1.0
