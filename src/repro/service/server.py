"""Async flush loop in front of a sharded GB-KMV index.

:class:`repro.serving.SketchServer` executes a flush inline on the
submitting caller — accumulation *blocks* on the jitted device
score/topk pipeline. This module is the production refactor: submitters
only append to a **bounded admission queue** and a dedicated flush
worker drains it, so micro-batch accumulation overlaps device execution
(while a batch runs on device, the queue keeps filling for the next
one). Overload degrades gracefully instead of queueing unboundedly:

* queue full  → :class:`Overloaded` (the HTTP layer answers 429 with a
  ``Retry-After`` derived from the measured flush latency),
* request older than its deadline → flushed immediately and answered
  from the **dense fallback path** (``plan="dense"`` — one predictable
  index sweep, bit-identical results, no postings-probe variance),
* shutdown → the queue drains, nothing is dropped.

Everything is injectable-clock deterministic: tests drive the loop with
:meth:`AsyncSketchServer.step` and a fake clock, production calls
:meth:`start` for the background worker. Execution and flush accounting
are shared with the synchronous server (``serving.execute_batch`` /
``serving.BatchStats``).
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.serving.batcher import BatchStats, execute_batch
from repro.service.wal import IdempotencyCache, ReadOnly


class Overloaded(RuntimeError):
    """Admission queue full — shed with a retry hint (seconds)."""

    def __init__(self, retry_after: float):
        super().__init__(f"admission queue full; retry after "
                         f"{retry_after:.3f}s")
        self.retry_after = retry_after


@dataclasses.dataclass(eq=False)
class Pending:
    """One admitted request (identity equality — payloads are arrays).
    Field names mirror ``serving.Request`` so ``execute_batch`` consumes
    these directly."""

    kind: str          # "query" | "topk" | "ingest" | "retire" | "snapshot"
    q_ids: np.ndarray | None
    arrival: float
    rid: int = -1                  # assigned under the lock by _admit
    threshold: float = 0.5
    k: int = 0
    deadline: float | None = None  # absolute clock time, None = no SLO
    records: list | None = None    # ingest payload
    epoch: int | None = None       # windowed-index target epoch (ingest)
    idem: str | None = None        # idempotency key (ingest dedupe)
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: dict | None = None
    error: Exception | None = None
    expired: bool = False
    explain: bool = False          # attach a plan explain to the result
    trace: object | None = None    # obs.Trace when tracing is enabled

    def past_deadline(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline


class AsyncSketchServer:
    """Bounded-admission micro-batching server over ``index.serve_batch``.

    ``index`` is anything speaking the ``serve_batch(queries, thresholds,
    k, plan=)`` protocol (a :class:`repro.sketchindex.ShardedIndex` in
    production); ingest additionally needs ``index.insert``. The flush
    worker is the ONLY thread touching the index, so queries and ingest
    serialize in admission (FIFO) order — a client that ingests then
    queries observes its own writes.
    """

    def __init__(self, index, *, max_batch: int = 16, max_wait: float = 0.01,
                 max_inflight: int = 256, default_deadline: float | None = 0.5,
                 plan: str = "auto",
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None, profile: bool = True,
                 slow_threshold: float | None = 1.0,
                 slow_log_size: int = 128,
                 durability=None, idem_window: int = 1024):
        from repro.obs import CostDrift, StageProfiler
        from repro.planner import normalize_plan

        self.index = index
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.max_inflight = int(max_inflight)
        self.default_deadline = default_deadline
        self.plan = normalize_plan(plan)
        self.clock = clock
        self.stats = BatchStats()
        self.shed = 0                  # admissions refused (429s)
        self.expired_served = 0        # requests answered past deadline
        self.records_ingested = 0
        # Observability. ``tracer=None`` (the default) records no traces
        # and allocates nothing per request; the profiler's stage
        # histograms stay on (a few clock reads per *flush*, amortized
        # over the batch). ``slow_threshold`` seconds of total latency
        # (admission → answered) lands a request in the bounded slow log.
        self.tracer = tracer
        self.profiler = StageProfiler() if profile else None
        self.cost_drift = CostDrift()
        self.slow_threshold = slow_threshold
        self.slow_queries = 0
        self.slow_log: deque[dict] = deque(maxlen=int(slow_log_size))
        # Durability (PR 10). ``durability=None`` keeps the pre-WAL
        # behavior exactly: mutations apply in-memory only and the
        # idempotency window is process-local. With a
        # :class:`repro.service.wal.Durability` attached, the flush
        # worker logs every mutation to the WAL *before* applying it
        # (append batch → one fsync → apply → ack, i.e. group commit
        # under fsync="batch"), and an unwritable data dir flips the
        # server into sticky read-only instead of killing it.
        self.durability = durability
        self.idem = (durability.idem if durability is not None
                     else IdempotencyCache(idem_window))
        self.read_only = False
        self.read_only_reason: str | None = None
        self.deduped_total = 0
        self._queue: deque[Pending] = deque()
        self._cv = threading.Condition()
        self._next_rid = 0
        self._thread: threading.Thread | None = None
        self._stop = False

    # -- admission ---------------------------------------------------------

    @property
    def inflight(self) -> int:
        return len(self._queue)

    def retry_after(self) -> float:
        """Backoff hint for shed requests: the time the current backlog
        needs to drain at the measured flush latency (floor: one
        deadline window)."""
        per_flush = self.stats.flush_latency_hist.mean or self.max_wait
        backlog_flushes = math.ceil(
            max(len(self._queue), 1) / max(self.max_batch, 1))
        return max(self.max_wait, backlog_flushes * per_flush)

    def _admit(self, p: Pending) -> Pending:
        with self._cv:
            if len(self._queue) >= self.max_inflight:
                self.shed += 1
                raise Overloaded(self.retry_after())
            # rid minted under the lock: submitters run on concurrent HTTP
            # handler threads, and execute_batch keys results by rid — a
            # duplicate would hand two requests each other's answers.
            p.rid = self._next_rid
            self._next_rid += 1
            self._queue.append(p)
            self._cv.notify()
        if self.tracer is not None:
            # Begin after admission: shed requests never allocate a trace.
            p.trace = self.tracer.begin(p.kind, rid=p.rid)
        return p

    def _deadline(self, arrival: float, deadline: float | None):
        budget = self.default_deadline if deadline is None else deadline
        return None if budget is None else arrival + float(budget)

    def submit_query(self, q_ids, threshold: float = 0.5,
                     deadline: float | None = None,
                     explain: bool = False) -> Pending:
        now = self.clock()
        return self._admit(Pending(
            kind="query", q_ids=np.asarray(q_ids), arrival=now,
            threshold=float(threshold),
            deadline=self._deadline(now, deadline), explain=bool(explain)))

    def submit_topk(self, q_ids, k: int = 10,
                    deadline: float | None = None,
                    explain: bool = False) -> Pending:
        now = self.clock()
        return self._admit(Pending(
            kind="topk", q_ids=np.asarray(q_ids), arrival=now,
            threshold=math.inf, k=int(k),
            deadline=self._deadline(now, deadline), explain=bool(explain)))

    def _check_writable(self) -> None:
        if self.read_only:
            raise ReadOnly(self.read_only_reason or "data dir unwritable")

    def submit_ingest(self, records, epoch: int | None = None,
                      idem: str | None = None) -> Pending:
        self._check_writable()
        now = self.clock()
        return self._admit(Pending(
            kind="ingest", q_ids=None, arrival=now,
            records=[np.asarray(r) for r in records],
            epoch=None if epoch is None else int(epoch), idem=idem))

    def submit_retire(self, before: int) -> Pending:
        """Windowed-index admin: drop every epoch ``< before``. Routed
        through the mutation lane so the flush worker stays the only
        thread touching the index."""
        self._check_writable()
        now = self.clock()
        return self._admit(Pending(
            kind="retire", q_ids=None, arrival=now, epoch=int(before)))

    def submit_snapshot(self) -> Pending:
        """Admin: atomic snapshot + WAL truncation, routed through the
        mutation lane — the flush worker runs it, so the index is
        quiescent and FIFO order puts every prior ack inside it."""
        if self.durability is None:
            raise RuntimeError("snapshots need a data dir "
                               "(server started without durability)")
        self._check_writable()
        now = self.clock()
        return self._admit(Pending(kind="snapshot", q_ids=None, arrival=now))

    # -- flush loop --------------------------------------------------------

    def _gather(self, now: float, force: bool):
        """Pop the next executable batch (caller holds the lock), or
        (None, wait_hint). Kinds never mix across an ingest boundary —
        FIFO order is the consistency model."""
        if not self._queue:
            return None, None
        mutation = ("ingest", "retire", "snapshot")
        if self._queue[0].kind in mutation:
            batch = []
            while self._queue and self._queue[0].kind in mutation \
                    and len(batch) < self.max_batch:
                batch.append(self._queue.popleft())
            return batch, "ingest"
        run = 0
        expired = False
        for p in self._queue:
            if p.kind in mutation or run >= self.max_batch:
                break
            expired |= p.past_deadline(now)
            run += 1
        oldest_age = now - self._queue[0].arrival
        if run >= self.max_batch:
            reason = "full"
        elif expired:
            reason = "expired"
        elif oldest_age >= self.max_wait or force:
            reason = "deadline"
        else:
            return None, self.max_wait - oldest_age
        return [self._queue.popleft() for _ in range(run)], reason

    def step(self, block: bool = False, timeout: float | None = None,
             force: bool = False) -> int:
        """One flush-loop iteration: gather → execute → complete events.
        Returns the number of requests answered. ``block`` waits (real
        time) for a flushable batch; ``force`` flushes a partial batch
        immediately (drain/test hook)."""
        deadline = (time.monotonic() + timeout) if (block and timeout) else None
        with self._cv:
            while True:
                batch, hint = self._gather(self.clock(), force)
                if batch is not None:
                    break
                if not block:
                    return 0
                wait = hint if hint is not None else 0.05
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return 0
                    wait = min(wait, remaining)
                if not self._cv.wait(timeout=wait) and self._stop \
                        and not self._queue:
                    return 0
        # Lock released: submitters keep filling the queue while the
        # batch executes on device — the overlap this server exists for.
        if hint == "ingest":
            self._execute_ingest(batch)
        else:
            self._execute_serve(batch, reason=hint)
        return len(batch)

    def drain(self):
        """Flush until the queue is empty (shutdown / test barrier)."""
        while self.step(force=True):
            pass

    def _complete(self, batch: list[Pending], err: Exception | None = None):
        for p in batch:
            if err is not None and p.result is None:
                p.error = err
            p.done.set()

    def _record_drift(self, measured: float) -> None:
        """Fold one serve flush into the cost-model drift gauge: the
        planner's chosen-path estimate vs the flush's measured seconds."""
        decision = getattr(self.index, "last_plan", None)
        if decision is None:
            return
        predicted = (decision.est_pruned if decision.path == "pruned"
                     else decision.est_dense)
        self.cost_drift.record(float(predicted), measured)

    def _finish_request(self, p: Pending, why: str, plan: str,
                        flush_start: float, t0: float, t1: float,
                        batch_size: int) -> None:
        """Per-request observability at completion: trace spans, per-kind
        latency histogram, and the slow-query log."""
        total = t1 - p.arrival
        if self.profiler is not None:
            self.profiler.observe(f"request.{p.kind}", max(total, 0.0))
        if p.trace is not None:
            p.trace.add_span("queue_wait", p.arrival, flush_start)
            p.trace.add_span("execute", t0, t1, plan=plan, reason=why,
                             batch=batch_size)
            p.trace.end(kind=p.kind, expired=p.expired)
        if self.slow_threshold is not None and total >= self.slow_threshold:
            self.slow_queries += 1
            self.slow_log.append({
                "rid": p.rid, "kind": p.kind,
                "latency_s": round(total, 6),
                "queue_wait_s": round(flush_start - p.arrival, 6),
                "plan": plan, "reason": why, "expired": p.expired,
                "batch": batch_size,
                "n_ids": int(len(p.q_ids)) if p.q_ids is not None else 0,
            })

    def _execute_serve(self, batch: list[Pending], reason: str):
        from repro import obs

        now = self.clock()
        fresh = [p for p in batch if not p.past_deadline(now)]
        late = [p for p in batch if p.past_deadline(now)]
        ftrace = None
        if self.tracer is not None:
            ftrace = self.tracer.begin("flush", reason=reason,
                                       batch=len(batch),
                                       rids=[p.rid for p in batch])
            # Batch assembly: oldest admission → this flush starting.
            ftrace.add_span("assemble", min(p.arrival for p in batch), now,
                            batch=len(batch))
        try:
            # Deadline-expired requests take the dense fallback: one
            # predictable sweep, no postings-probe variance, answered
            # ahead of further accumulation. Results are bit-identical
            # (the planner's contract) — only the latency path differs.
            for sub, plan, why in ((late, "dense", "expired"),
                                   (fresh, self.plan, reason)):
                if not sub:
                    continue
                k = max((p.k for p in sub), default=0)
                self.stats.record_batch(
                    [now - p.arrival for p in sub], why)
                explain = any(p.explain for p in sub)
                t0 = self.clock()
                with obs.attach(ftrace, self.profiler):
                    with obs.stage(
                            "flush.execute", reason=why, plan=plan,
                            batch=len(sub),
                            queries=sum(p.kind == "query" for p in sub),
                            topks=sum(p.kind == "topk" for p in sub)):
                        out = execute_batch(self.index, sub, k, plan,
                                            stats=self.stats,
                                            clock=self.clock,
                                            explain=explain)
                t1 = self.clock()
                self._record_drift(t1 - t0)
                for p in sub:
                    res = out[p.rid]
                    if p.kind == "topk":
                        p.result = {
                            "topk_ids": res["topk_ids"][: p.k],
                            "topk_scores": res["topk_scores"][: p.k]}
                    else:
                        p.result = {"hits": res["hits"]}
                    if p.explain and "explain" in res:
                        p.result["explain"] = res["explain"]
                    p.expired = why == "expired"
                    self._finish_request(p, why, plan, now, t0, t1, len(sub))
                if why == "expired":
                    self.expired_served += len(sub)
            self._complete(batch)
        except Exception as e:                     # pragma: no cover - guard
            self._complete(batch, err=e)
        finally:
            if ftrace is not None:
                ftrace.end()

    def _enter_read_only(self, err: OSError) -> None:
        """Sticky degrade: the data dir failed a write (ENOSPC, EROFS,
        pulled volume). Mutations 503 from here on; queries keep
        serving from the in-memory index. Recovery is an operator
        restart against a healthy volume."""
        self.read_only = True
        self.read_only_reason = f"{type(err).__name__}: {err}"

    def _execute_ingest(self, batch: list[Pending]):
        """Drain one mutation batch in FIFO order. Contiguous
        ingest/retire runs group-commit through the WAL (append every
        entry → one fsync → apply → ack), so fsync="batch" amortizes
        the disk flush across the batch; a "snapshot" breaks the run
        and executes alone at its FIFO position."""
        now = self.clock()
        self.stats.record_batch([now - p.arrival for p in batch], "ingest")
        run: list[Pending] = []
        for p in batch:
            if p.kind == "snapshot":
                self._commit_run(run, now)
                run = []
                self._execute_snapshot(p)
            else:
                run.append(p)
        self._commit_run(run, now)

    def _commit_run(self, run: list[Pending], now: float):
        if not run:
            return
        # Phase 1 — dedupe + WAL append (ack nothing yet). The flush
        # worker is the only thread here, so the idempotency check and
        # the apply are atomic with respect to each other: two racing
        # retries can both pass admission, but only the first to reach
        # this loop applies.
        to_apply: list[Pending] = []
        for p in run:
            if self.read_only:
                p.error = ReadOnly(self.read_only_reason or "read-only")
                p.done.set()
                continue
            if p.idem is not None:
                prior = self.idem.get(p.idem)
                if prior is not None:
                    p.result = {**prior, "deduped": True}
                    self.deduped_total += 1
                    p.done.set()
                    continue
            if self.durability is not None:
                try:
                    if p.kind == "retire":
                        self.durability.log_retire(p.epoch)
                    else:
                        self.durability.log_ingest(p.records, p.epoch,
                                                   p.idem)
                except OSError as e:
                    self._enter_read_only(e)
                    p.error = ReadOnly(self.read_only_reason)
                    p.done.set()
                    continue
            to_apply.append(p)
        # Phase 2 — one group-commit fsync covering the whole run.
        if self.durability is not None and to_apply:
            try:
                self.durability.sync()
            except OSError as e:
                self._enter_read_only(e)
                for p in to_apply:
                    # Not durable → not acknowledged; the client's
                    # idempotency key makes its retry safe.
                    p.error = ReadOnly(self.read_only_reason)
                    p.done.set()
                return
        # Phase 3 — apply to the index and acknowledge.
        for p in to_apply:
            try:
                if p.kind == "retire":
                    retired = self.index.retire(p.epoch)
                    p.result = {"retired": int(retired),
                                "epochs": [int(e) for e in self.index.epochs]}
                    p.done.set()
                    continue
                t0 = self.clock()
                # Epoch only reaches windowed indexes; plain ShardedIndex
                # keeps its narrower insert(records) signature.
                if p.epoch is None:
                    self.index.insert(p.records)
                else:
                    self.index.insert(p.records, epoch=p.epoch)
                # Host insert latency stays out of flush_latency_hist —
                # that histogram is the device-flush basis for the 429
                # Retry-After hint.
                t1 = self.clock()
                self.stats.ingest_latency_hist.observe(t1 - t0)
                self.records_ingested += len(p.records)
                p.result = {"ingested": len(p.records)}
                if p.idem is not None:
                    self.idem.put(p.idem, {"ingested": len(p.records)})
                if self.profiler is not None:
                    self.profiler.observe("request.ingest",
                                          max(t1 - p.arrival, 0.0))
                if p.trace is not None:
                    p.trace.add_span("queue_wait", p.arrival, now)
                    p.trace.add_span("insert", t0, t1,
                                     records=len(p.records))
                    p.trace.end(kind="ingest")
            except Exception as e:
                p.error = e
            p.done.set()

    def _execute_snapshot(self, p: Pending):
        t0 = self.clock()
        try:
            if self.durability is None:
                raise RuntimeError("snapshots need a data dir")
            if self.read_only:
                raise ReadOnly(self.read_only_reason or "read-only")
            p.result = self.durability.snapshot(self.index)
        except OSError as e:
            self._enter_read_only(e)
            p.error = ReadOnly(self.read_only_reason)
        except Exception as e:
            p.error = e
        if self.profiler is not None:
            self.profiler.observe("request.snapshot",
                                  max(self.clock() - t0, 0.0))
        p.done.set()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "AsyncSketchServer":
        if self._thread is not None:
            return self
        self._stop = False

        # Background snapshots ride the flush loop itself: the worker
        # enqueues a "snapshot" pending at the interval and pops it on a
        # later step, so snapshots hold the same single-mutator
        # invariant as every other mutation.
        interval = (self.durability.snapshot_interval
                    if self.durability is not None else 0.0)
        next_snap = time.monotonic() + interval if interval > 0 else None

        def loop():
            nonlocal next_snap
            while not self._stop:
                self.step(block=True, timeout=0.1)
                if next_snap is not None and time.monotonic() >= next_snap:
                    next_snap = time.monotonic() + interval
                    try:
                        if not self.read_only:
                            self.submit_snapshot()
                    except (Overloaded, ReadOnly):
                        pass
            self.drain()

        self._thread = threading.Thread(target=loop, name="flush-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self):
        if self._thread is None:
            return
        self._stop = True
        with self._cv:
            self._cv.notify_all()
        self._thread.join(timeout=10.0)
        self._thread = None
        self.drain()

    def result(self, p: Pending, timeout: float | None = 30.0) -> dict:
        """Wait for a pending request; raises its execution error."""
        if not p.done.wait(timeout=timeout):
            raise TimeoutError(f"request {p.rid} not served in {timeout}s")
        if p.error is not None:
            raise p.error
        return p.result
