"""Durable ingest: write-ahead log, atomic snapshots, crash recovery.

The serving stack discards raw records after sketching, so every
acknowledged ``/ingest`` since the last save used to live only in
process memory — a crash silently lost state that is *not re-derivable*
(the sketch is lossy by design; that is the paper's whole point). This
module makes the mutation lane durable:

    WriteAheadLog    length-prefixed, per-record CRC32-checksummed
                     segment files. Appends are unbuffered (every byte
                     reaches the OS before the call returns) with a
                     configurable fsync policy; segments rotate at
                     window-epoch seals and size bounds, and are
                     truncated once a snapshot covers them.
    Durability       the lifecycle manager a server mounts on a
                     ``--data-dir``: log mutations before they apply,
                     write atomic snapshots (tmp dir → fsync → rename,
                     the ``ft/checkpoint.py`` pattern), and on boot load
                     the newest *valid* snapshot then replay the WAL
                     tail through the normal ingest path — tolerating a
                     torn final record.
    IdempotencyCache bounded dedupe window keyed by client-supplied
                     idempotency keys, persisted through the WAL and
                     snapshot manifests so retries stay safe across a
                     crash.

Write protocol (the invariant recovery relies on): WAL append → fsync
(per the policy) → apply to the index → acknowledge. An acknowledged
mutation is therefore always re-derivable from snapshot + WAL; an
unacknowledged one may or may not survive, and the idempotency window
makes the client's retry exact-once either way.

Frame format (little-endian)::

    +----+----+------------+------------+---------------+
    | 'W'| 'A'| len u32    | crc32 u32  | payload bytes |
    +----+----+------------+------------+---------------+

``payload`` is compact JSON carrying ``seq`` (contiguous, ascending
across segments), ``kind`` (``ingest`` / ``retire``), the records, the
target epoch, and the idempotency key. A decode stops at the first
frame that is short, mis-magicked, or CRC-mismatched: in the *newest*
segment that is the torn tail a crash mid-write leaves behind
(tolerated, truncated on reopen); anywhere else it is corruption and
recovery refuses rather than silently dropping acknowledged data.

Every dangerous IO step threads through a named fault point
(:mod:`repro.ft.chaos`), so the kill-and-recover matrix can crash this
code between any two instructions and assert recovery is bit-exact.
"""

from __future__ import annotations

import io
import json
import os
import re
import shutil
import threading
import time
import zlib
from collections import OrderedDict

import numpy as np

from repro.ft import chaos

_MAGIC = b"WA"
_HEADER = 10                    # magic(2) + len(4) + crc(4)
_MAX_FRAME = 64 << 20           # sanity cap: garbage lengths never allocate
_SEG_RE = re.compile(r"seg_(\d{16})\.wal$")
_SNAP_RE = re.compile(r"snap_(\d{16})$")
_SNAP_MANIFEST = "snap_manifest.json"

FSYNC_POLICIES = ("always", "batch", "off")


class WalCorruption(RuntimeError):
    """Mid-stream WAL damage (acknowledged data would be lost)."""


class ReadOnly(RuntimeError):
    """The data dir is unwritable — mutations refused, queries served."""


def encode_entry(entry: dict) -> bytes:
    """One framed WAL record (numpy ints/arrays JSON-normalized)."""
    payload = json.dumps(entry, separators=(",", ":"),
                         default=_json_default).encode()
    return (_MAGIC + len(payload).to_bytes(4, "little")
            + zlib.crc32(payload).to_bytes(4, "little") + payload)


def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"WAL entry field not serializable: {type(o)}")


def decode_segment(buf: bytes) -> tuple[list[dict], int]:
    """Decode every complete frame; returns ``(entries, dropped)`` where
    ``dropped`` is the byte count of the unparseable tail (0 = clean).
    A short header, short payload, bad magic, bad CRC, or undecodable
    JSON all stop the scan — the remainder is the torn tail."""
    entries: list[dict] = []
    off = 0
    n = len(buf)
    while off < n:
        if n - off < _HEADER or buf[off:off + 2] != _MAGIC:
            break
        length = int.from_bytes(buf[off + 2:off + 6], "little")
        if length > _MAX_FRAME or off + _HEADER + length > n:
            break
        crc = int.from_bytes(buf[off + 6:off + 10], "little")
        payload = buf[off + _HEADER:off + _HEADER + length]
        if zlib.crc32(payload) != crc:
            break
        try:
            entries.append(json.loads(payload))
        except json.JSONDecodeError:    # CRC passed but content garbage
            break
        off += _HEADER + length
    return entries, n - off


class WriteAheadLog:
    """Segmented, checksummed, crash-tolerant append log.

    ``fsync`` policy: ``"always"`` fsyncs inside every :meth:`append`
    (each ack costs a disk flush), ``"batch"`` fsyncs once per
    :meth:`sync` call — the flush worker calls it once per mutation
    batch, i.e. group commit — and ``"off"`` never fsyncs (the OS page
    cache is the only durability; survives a process kill, not a power
    cut). Appends are unbuffered regardless, so simulated-kill tests see
    exactly the bytes a real ``SIGKILL`` would leave.

    Not thread-safe by itself; the flush worker is the only writer
    (:class:`Durability` adds a lock for the read-side gauges).
    """

    def __init__(self, dirpath: str, fsync: str = "batch",
                 segment_bytes: int = 4 << 20):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync policy must be one of {FSYNC_POLICIES},"
                             f" got {fsync!r}")
        self.dir = dirpath
        self.policy = fsync
        self.segment_bytes = int(segment_bytes)
        self.appends_total = 0
        self.fsyncs_total = 0
        self.rotations_total = 0
        self.truncated_segments_total = 0
        self.torn_tail_bytes = 0        # garbage dropped at last reopen
        self.last_seq = 0               # 0 = empty log; first entry is 1
        self._f: io.RawIOBase | None = None
        self._path: str | None = None   # current segment path
        self._dirty = False             # bytes written since last fsync
        # Sealed + current segments: [path, first_seq, last_seq, nbytes].
        # first_seq is the seq the segment *starts at* (its filename);
        # last_seq == first_seq - 1 means it holds no complete entry.
        self._segments: list[list] = []
        os.makedirs(dirpath, exist_ok=True)
        self._scan()

    # -- startup scan ------------------------------------------------------

    def _scan(self) -> None:
        """Index existing segments, verify seq continuity, truncate the
        newest segment's torn tail so appends never follow garbage."""
        names = sorted(n for n in os.listdir(self.dir) if _SEG_RE.search(n))
        for i, name in enumerate(names):
            path = os.path.join(self.dir, name)
            first = int(_SEG_RE.search(name).group(1))
            with open(path, "rb") as f:
                buf = f.read()
            entries, dropped = decode_segment(buf)
            newest = i == len(names) - 1
            if dropped and not newest:
                raise WalCorruption(
                    f"{path}: {dropped} undecodable bytes mid-log (only "
                    "the newest segment may carry a torn tail)")
            seqs = [int(e["seq"]) for e in entries]
            want = list(range(first, first + len(seqs)))
            if seqs != want or (self._segments
                                and first != self._segments[-1][2] + 1):
                raise WalCorruption(
                    f"{path}: sequence discontinuity (have {seqs[:3]}..., "
                    f"want start {first})")
            if dropped:                 # torn tail on the newest segment
                self.torn_tail_bytes = dropped
                with open(path, "r+b") as f:
                    f.truncate(len(buf) - dropped)
                    f.flush()
                    os.fsync(f.fileno())
            self._segments.append(
                [path, first, first + len(seqs) - 1, len(buf) - dropped])
            self.last_seq = first + len(seqs) - 1 if seqs else self.last_seq
        if self._segments:
            self.last_seq = self._segments[-1][2]

    # -- write side --------------------------------------------------------

    def _open_segment(self) -> None:
        first = self.last_seq + 1
        self._path = os.path.join(self.dir, f"seg_{first:016d}.wal")
        # buffering=0: every write(2) reaches the OS before returning,
        # so a simulated kill loses nothing a real SIGKILL would keep.
        self._f = open(self._path, "ab", buffering=0)
        self._segments.append([self._path, first, first - 1, 0])
        _fsync_dir(self.dir)

    def _ensure_open(self, frame_len: int) -> io.RawIOBase:
        if self._f is None:
            # Reopen the newest scanned segment when it has room —
            # restarts must not leak one segment each.
            if self._segments and self._segments[-1][3] < self.segment_bytes:
                seg = self._segments[-1]
                self._path = seg[0]
                self._f = open(self._path, "ab", buffering=0)
            else:
                self._open_segment()
        elif (self._segments[-1][3] + frame_len > self.segment_bytes
              and self._segments[-1][3] > 0):
            self.rotate()
        return self._f

    def append(self, entry: dict) -> int:
        """Frame + write one entry; returns its seq. Fsyncs only under
        the ``always`` policy — callers batch :meth:`sync` otherwise."""
        chaos.point("wal.append.pre_write")
        seq = self.last_seq + 1
        frame = encode_entry({**entry, "seq": seq})
        f = self._ensure_open(len(frame))
        chaos.chaos_write(f, frame, "wal.append.write")
        self._dirty = True
        self.appends_total += 1
        self.last_seq = seq
        self._segments[-1][2] = seq
        self._segments[-1][3] += len(frame)
        if self.policy == "always":
            self.sync()
        return seq

    def sync(self) -> None:
        """Make appended entries durable (no-op under ``off`` / clean)."""
        if not self._dirty or self._f is None or self.policy == "off":
            return
        chaos.point("wal.append.pre_fsync")
        os.fsync(self._f.fileno())
        chaos.point("wal.append.post_fsync")
        self.fsyncs_total += 1
        self._dirty = False

    def rotate(self) -> None:
        """Seal the current segment and open the next — called at
        window-epoch seals, segment-size bounds, and snapshots."""
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None
        chaos.point("wal.rotate.pre_open")
        if not self._segments or self._segments[-1][3] > 0:
            self._open_segment()
        self.rotations_total += 1

    def truncate_through(self, seq: int) -> int:
        """Drop sealed segments whose every entry is ≤ ``seq`` (i.e. is
        covered by a snapshot); returns how many files were deleted."""
        chaos.point("wal.truncate.pre_unlink")
        keep, dropped = [], 0
        for seg in self._segments:
            sealed = seg[0] != self._path
            covered = seg[2] <= seq and seg[2] >= seg[1]
            empty = seg[2] < seg[1] and sealed
            if sealed and (covered or empty):
                os.unlink(seg[0])
                dropped += 1
            else:
                keep.append(seg)
        self._segments = keep
        self.truncated_segments_total += dropped
        if dropped:
            _fsync_dir(self.dir)
        return dropped

    def close(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None

    # -- read side ---------------------------------------------------------

    def entries(self, after_seq: int = 0):
        """Yield decoded entries with ``seq > after_seq`` across every
        live segment, oldest first (re-reads the files: replay runs
        once, at boot)."""
        for path, first, last, _ in self._segments:
            if last < first or last <= after_seq:
                continue
            with open(path, "rb") as f:
                seg_entries, _ = decode_segment(f.read())
            for e in seg_entries:
                if int(e["seq"]) > after_seq:
                    yield e

    @property
    def segment_count(self) -> int:
        return len(self._segments)

    def nbytes(self) -> int:
        return sum(seg[3] for seg in self._segments)


def _fsync_dir(dirpath: str) -> None:
    """Best-effort directory fsync (rename/create durability)."""
    try:
        fd = os.open(dirpath, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class IdempotencyCache:
    """Bounded LRU of ``idempotency key → prior result`` (thread-safe).

    The window makes client retries safe: a retried ``/ingest`` whose
    key (or per-chunk derived key) is still inside the window applies
    nothing and answers from the cached result. Keys ride inside WAL
    entries and snapshot manifests, so the window survives a crash.
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._d: OrderedDict[str, dict] = OrderedDict()

    def get(self, key: str) -> dict | None:
        with self._lock:
            hit = self._d.get(key)
            if hit is not None:
                self._d.move_to_end(key)
            return hit

    def put(self, key: str, result: dict) -> None:
        with self._lock:
            self._d[key] = result
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def export(self) -> list:
        with self._lock:
            return [[k, v] for k, v in self._d.items()]

    def load(self, items) -> None:
        for k, v in items:
            self.put(str(k), dict(v))

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


class Durability:
    """WAL + snapshot lifecycle over one ``--data-dir``.

    Layout::

        data_dir/
            wal/seg_<firstseq>.wal        append log segments
            snapshots/snap_<walseq>/      atomic index snapshots
                index.npz | window/       (plain vs windowed index)
                snap_manifest.json        wal_seq, engine info, idem window

    Boot: :meth:`load_latest_index` walks snapshots newest-first and
    skips invalid ones (a crash mid-snapshot leaves only a ``.tmp`` dir
    or nothing; a torn snapshot write raises
    :class:`repro.api.CorruptIndexError` and the scan falls back to the
    previous snapshot). :meth:`replay_into` then re-applies every WAL
    entry with ``seq > snapshot.wal_seq`` through the index's normal
    ``insert``/``retire`` path — entries at or below the snapshot seq
    are already inside it (the post-rename/pre-truncate crash window
    would otherwise double-apply them).
    """

    def __init__(self, data_dir: str, *, fsync: str = "batch",
                 segment_bytes: int = 4 << 20, snapshot_keep: int = 2,
                 idem_window: int = 1024, snapshot_interval: float = 0.0):
        self.data_dir = data_dir
        self.snap_dir = os.path.join(data_dir, "snapshots")
        os.makedirs(self.snap_dir, exist_ok=True)
        # A crashed snapshot's .tmp is garbage by definition (never
        # renamed => never valid); clear it before scanning.
        for name in os.listdir(self.snap_dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.snap_dir, name),
                              ignore_errors=True)
        self.wal = WriteAheadLog(os.path.join(data_dir, "wal"),
                                 fsync=fsync, segment_bytes=segment_bytes)
        self.idem = IdempotencyCache(idem_window)
        self.snapshot_keep = int(snapshot_keep)
        self.snapshot_interval = float(snapshot_interval)
        self.snap_seq = 0               # newest valid snapshot's wal_seq
        self.snapshots_total = 0
        self.snapshot_last_seconds = 0.0
        self.snapshot_last_nbytes = 0
        self.invalid_snapshots_skipped = 0
        self.replayed_entries = 0
        self.replayed_records = 0
        self.replay_failed_entries = 0
        self.recovery_seconds = 0.0
        self._max_epoch: int | None = None
        self._lock = threading.Lock()   # snapshot vs /metrics gauges

    # -- mutation lane (called by the flush worker only) -------------------

    def observe_epoch(self, epoch: int | None) -> None:
        """Rotate the WAL at a window-epoch seal: the first entry of a
        *new* (larger) epoch starts a fresh segment, so a whole epoch's
        tail can later be truncated as one unit."""
        if epoch is None:
            return
        epoch = int(epoch)
        if self._max_epoch is not None and epoch > self._max_epoch:
            self.wal.rotate()
        if self._max_epoch is None or epoch > self._max_epoch:
            self._max_epoch = epoch

    def log_ingest(self, records, epoch: int | None,
                   idem: str | None) -> int:
        self.observe_epoch(epoch)
        return self.wal.append({
            "kind": "ingest",
            "records": [np.asarray(r).tolist() for r in records],
            "epoch": epoch, "idem": idem})

    def log_retire(self, before: int) -> int:
        return self.wal.append({"kind": "retire", "before": int(before)})

    def sync(self) -> None:
        self.wal.sync()

    # -- snapshots ---------------------------------------------------------

    def _snapshots(self) -> list[tuple[int, str]]:
        """(wal_seq, path) of completed snapshot dirs, newest first."""
        out = []
        for name in os.listdir(self.snap_dir):
            m = _SNAP_RE.fullmatch(name)
            if m:
                out.append((int(m.group(1)),
                            os.path.join(self.snap_dir, name)))
        return sorted(out, reverse=True)

    def snapshot(self, index) -> dict:
        """Atomic snapshot of ``index`` at the current WAL position,
        then truncate covered WAL segments. Runs on the flush worker
        (the only mutator), so the index is quiescent throughout."""
        t0 = time.perf_counter()
        chaos.point("snapshot.pre_write")
        seq = self.wal.last_seq
        final = os.path.join(self.snap_dir, f"snap_{seq:016d}")
        if os.path.isdir(final) and seq == self.snap_seq:
            return {"path": final, "wal_seq": seq, "fresh": False,
                    "truncated_segments": 0}
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        windowed = bool(getattr(index, "windowed", False))
        if windowed:
            index.save(os.path.join(tmp, "window"))
        else:
            index.save(os.path.join(tmp, "index.npz"))
        manifest = {
            "version": 1, "wal_seq": seq, "windowed": windowed,
            "records": int(index.num_records),
            "idem": self.idem.export(),
        }
        mpath = os.path.join(tmp, _SNAP_MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        chaos.point("snapshot.pre_rename")
        if os.path.exists(final):       # re-snapshot at an old seq
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.snap_dir)
        chaos.point("snapshot.post_rename")
        # WAL entries ≤ seq are now redundant; seal the open segment so
        # it is truncatable too, then drop everything covered.
        self.wal.rotate()
        truncated = self.wal.truncate_through(seq)
        with self._lock:
            self.snap_seq = seq
            self.snapshots_total += 1
            self.snapshot_last_seconds = time.perf_counter() - t0
            self.snapshot_last_nbytes = _dir_nbytes(final)
        self._prune_snapshots()
        return {"path": final, "wal_seq": seq, "fresh": True,
                "truncated_segments": truncated,
                "nbytes": self.snapshot_last_nbytes}

    def _prune_snapshots(self) -> None:
        for _, path in self._snapshots()[self.snapshot_keep:]:
            shutil.rmtree(path, ignore_errors=True)

    # -- recovery ----------------------------------------------------------

    def load_latest_index(self):
        """(index, manifest) from the newest *valid* snapshot, or
        (None, None) when no snapshot loads. Invalid snapshots (torn
        manifest, corrupt npz — see :class:`repro.api.CorruptIndexError`)
        are skipped, falling back to the next-older one."""
        for seq, path in self._snapshots():
            try:
                with open(os.path.join(path, _SNAP_MANIFEST)) as f:
                    manifest = json.load(f)
                index = self._load_snapshot_index(path, manifest)
            except Exception:
                self.invalid_snapshots_skipped += 1
                continue
            self.snap_seq = int(manifest["wal_seq"])
            self.idem.load(manifest.get("idem", []))
            return index, manifest
        return None, None

    @staticmethod
    def _load_snapshot_index(path: str, manifest: dict):
        if manifest.get("windowed"):
            from repro.sketchindex.windows import WindowManager

            return WindowManager.load(os.path.join(path, "window"))
        from repro import api

        return api.load_index(os.path.join(path, "index.npz"))

    def replay_into(self, index) -> dict:
        """Re-apply the WAL tail (``seq > snap_seq``) through the
        index's normal mutation path; rebuilds the idempotency window
        from the entries' keys. An entry whose apply raises is counted
        and skipped (it failed identically before the crash)."""
        t0 = time.perf_counter()
        replayed = records = failed = 0
        windowed = bool(getattr(index, "windowed", False))
        for e in self.wal.entries(after_seq=self.snap_seq):
            try:
                if e["kind"] == "ingest":
                    recs = [np.asarray(r, np.int64) for r in e["records"]]
                    if windowed and e.get("epoch") is not None:
                        index.insert(recs, epoch=int(e["epoch"]))
                    else:
                        index.insert(recs)
                    records += len(recs)
                    if e.get("idem"):
                        self.idem.put(str(e["idem"]),
                                      {"ingested": len(recs)})
                elif e["kind"] == "retire":
                    index.retire(int(e["before"]))
                else:
                    failed += 1
                    continue
                replayed += 1
                if e.get("epoch") is not None:
                    self.observe_epoch(int(e["epoch"]))
            except Exception:
                failed += 1
        self.replayed_entries = replayed
        self.replayed_records = records
        self.replay_failed_entries = failed
        self.recovery_seconds = time.perf_counter() - t0
        return {"replayed_entries": replayed, "replayed_records": records,
                "failed_entries": failed,
                "torn_tail_bytes": self.wal.torn_tail_bytes,
                "snapshot_wal_seq": self.snap_seq,
                "seconds": self.recovery_seconds}

    def close(self) -> None:
        self.wal.close()


def _dir_nbytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for name in files:
            try:
                total += os.path.getsize(os.path.join(root, name))
            except OSError:
                pass
    return total
