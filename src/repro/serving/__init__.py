from repro.serving.batcher import MicroBatcher, Request, SketchServer  # noqa: F401
