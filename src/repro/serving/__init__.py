from repro.serving.batcher import (  # noqa: F401
    BatchStats, MicroBatcher, Request, SketchServer, execute_batch)
from repro.serving.histogram import Histogram  # noqa: F401
