"""Online serving front end for containment search: deadline-aware
request micro-batching over the distributed sketch index.

The roofline says one index sweep costs the same for 1 or Gq queries
(with the fused kernel — EXPERIMENTS.md §Perf); the batcher's job is to
*fill* Gq without blowing the latency SLO:

    flush when  batch == max_batch                      (full)
            or  oldest request age ≥ max_wait           (deadline)

Event-driven with an injectable clock: deterministic in tests, wall-clock
in production. Single-threaded by design — on a real pod the batcher
runs on the coordinator host; device work is the jitted score+topk.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.serving.histogram import Histogram


@dataclasses.dataclass
class Request:
    rid: int
    q_ids: np.ndarray
    arrival: float
    threshold: float = 0.5


@dataclasses.dataclass
class BatchStats:
    """Flush accounting + real distributions (fixed-bucket histograms).

    The means survive for quick prints; the histograms are what the
    service layer's ``/metrics`` endpoint exports — per-request queue
    wait (submit → flush) and per-flush execution latency — so tail
    percentiles come from counts, not from a mean that hides them.
    ``flushes_expired`` counts flushes forced because a request blew its
    deadline while queued (the async server's dense-fallback path).
    ``flushes_ingest`` counts ingest flushes separately: they never touch
    the device pipeline, so ``flushes``/``mean_batch`` (device-batch
    occupancy) and ``flush_latency_hist`` (device execution latency, the
    429 Retry-After basis) stay serve-only; host insert latency goes to
    ``ingest_latency_hist``.
    """

    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_expired: int = 0
    flushes_ingest: int = 0
    served: int = 0
    total_wait: float = 0.0
    total_batch: int = 0
    queue_wait_hist: Histogram = dataclasses.field(default_factory=Histogram)
    flush_latency_hist: Histogram = dataclasses.field(
        default_factory=Histogram)
    ingest_latency_hist: Histogram = dataclasses.field(
        default_factory=Histogram)

    @property
    def flushes(self) -> int:
        return self.flushes_full + self.flushes_deadline + self.flushes_expired

    @property
    def mean_batch(self) -> float:
        n = self.flushes
        return self.total_batch / n if n else 0.0

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.served if self.served else 0.0

    def record_batch(self, waits, reason: str = "deadline") -> None:
        """Account one flushed batch: per-request queue waits (seconds)
        + the flush reason ∈ {"full", "deadline", "expired", "ingest"}."""
        waits = np.asarray(waits, np.float64)
        if reason == "full":
            self.flushes_full += 1
        elif reason == "expired":
            self.flushes_expired += 1
        elif reason == "ingest":
            self.flushes_ingest += 1
        else:
            self.flushes_deadline += 1
        self.served += len(waits)
        self.total_wait += float(waits.sum())
        if reason != "ingest":             # mean_batch is device occupancy
            self.total_batch += len(waits)
        self.queue_wait_hist.observe_many(waits)


def execute_batch(index, batch: list[Request], topk: int, plan: str,
                  stats: BatchStats | None = None,
                  clock: Callable[[], float] = time.monotonic,
                  explain: bool = False) -> dict:
    """One device execution for a flushed batch: ``index.serve_batch``
    over the batch's queries/thresholds, flush latency recorded into
    ``stats``. Returns {rid: result dict} — shared by the synchronous
    :class:`SketchServer` and the service layer's async flush loop.
    ``explain=True`` asks the index for per-query plan explains (only
    passed down when requested, so indexes without the kwarg still
    work)."""
    t0 = clock()
    kw = {"explain": True} if explain else {}
    results = index.serve_batch(
        [r.q_ids for r in batch],
        np.asarray([r.threshold for r in batch]), topk, plan=plan, **kw)
    if stats is not None:
        stats.flush_latency_hist.observe(clock() - t0)
    return {req.rid: res for req, res in zip(batch, results)}


class MicroBatcher:
    def __init__(self, max_batch: int = 16, max_wait: float = 0.01,
                 clock: Callable[[], float] = time.monotonic):
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.clock = clock
        self.pending: list[Request] = []
        self.stats = BatchStats()

    def submit(self, req: Request) -> list[Request] | None:
        """Enqueue; returns a batch to execute when the size bound hits."""
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            return self.flush(full=True)
        return None

    def poll(self) -> list[Request] | None:
        """Deadline check — call on a timer (or between device steps)."""
        if not self.pending:
            return None
        if self.clock() - self.pending[0].arrival >= self.max_wait:
            return self.flush(full=False)
        return None

    def flush(self, full: bool = False, reason: str | None = None
              ) -> list[Request]:
        """Drain and return the pending batch (public — drivers drain
        stragglers through this, not through a private hook)."""
        batch, self.pending = self.pending, []
        now = self.clock()
        self.stats.record_batch([now - r.arrival for r in batch],
                                reason or ("full" if full else "deadline"))
        return batch


class SketchServer:
    """Batcher + sharded GB-KMV index + global top-k, end to end.

    This is the *synchronous, in-process* embedding: submit executes the
    flush inline when the size bound hits. The production door — an
    async flush loop with bounded admission, deadlines, and an HTTP
    front — is :class:`repro.service.AsyncSketchServer`, which shares
    this module's :func:`execute_batch` and :class:`BatchStats`.

    ``index`` may be a host GBKMVIndex, a ``repro.api`` GB-KMV index, or
    an already-placed :class:`repro.sketchindex.ShardedIndex` — device
    placement is the ShardedIndex's job, not the server's. Every flush
    executes against the ShardedIndex's resident sketch arena (columns,
    postings, and device mirrors are owned there and persist across
    flushes — nothing is repacked per flush; only the query batch moves).

    ``plan`` is the planner hint every flush passes down ("auto" |
    "dense" | "pruned"). Threshold serving routes through the planner's
    filter-and-verify; ``plan="pruned"`` additionally routes top-k
    answers through postings-driven upper-bound pruning (exact parity
    with the dense ranking), while "auto" keeps top-k on the dense sweep
    the batch already amortizes.
    """

    def __init__(self, index, mesh=None, max_batch: int = 16,
                 max_wait: float = 0.01, topk: int = 10,
                 clock: Callable[[], float] = time.monotonic,
                 backend: str = "jnp", plan: str = "auto"):
        from repro.planner import normalize_plan
        from repro.sketchindex import ShardedIndex

        if isinstance(index, ShardedIndex):
            self.index = index
        else:
            if mesh is None:
                raise ValueError("mesh is required unless index is already "
                                 "a ShardedIndex")
            self.index = ShardedIndex(index, mesh, backend=backend)
        self.topk = topk
        self.plan = normalize_plan(plan)
        self.batcher = MicroBatcher(max_batch, max_wait, clock)
        self._next_rid = 0
        self.results: dict[int, dict] = {}

    def submit(self, q_ids: np.ndarray, threshold: float = 0.5) -> int:
        rid = self._next_rid
        self._next_rid += 1
        batch = self.batcher.submit(
            Request(rid, np.asarray(q_ids), self.batcher.clock(), threshold))
        if batch is not None:
            self._execute(batch)
        return rid

    def poll(self):
        batch = self.batcher.poll()
        if batch is not None:
            self._execute(batch)

    def flush(self):
        if self.batcher.pending:
            self._execute(self.batcher.flush(full=False))

    def _execute(self, batch: list[Request]):
        self.results.update(execute_batch(
            self.index, batch, self.topk, self.plan,
            stats=self.batcher.stats, clock=self.batcher.clock))
