"""Fixed-bucket latency histograms (numpy counts, Prometheus-exportable).

One primitive shared by the batcher's :class:`BatchStats` (queue-wait and
flush-latency distributions) and the service layer's request metrics.
Buckets are fixed at construction — observation is one ``searchsorted``
per value (or one vectorized pass per batch), merge is elementwise add,
and the Prometheus text rendering is the standard cumulative ``le``
series. Quantiles interpolate linearly inside the owning bucket, which
is exactly the estimate a Prometheus ``histogram_quantile`` would give
for the same buckets.
"""

from __future__ import annotations

import threading

import numpy as np

# Log-spaced seconds: 100µs … 10s. Covers a sub-millisecond device flush
# through a badly overloaded queue; the +Inf bucket catches the rest.
DEFAULT_LATENCY_BOUNDS = tuple(
    float(f"{b:.6g}") for b in np.logspace(-4, 1, 21))


class Histogram:
    """Fixed upper-bound buckets + an implicit +Inf overflow bucket.

    Writers (the flush worker) and readers (a concurrent ``/metrics``
    scrape) share ``_lock``: every read goes through :meth:`snapshot`,
    so a scrape never sees ``counts`` torn against ``sum``.
    """

    def __init__(self, bounds=DEFAULT_LATENCY_BOUNDS):
        self.bounds = np.asarray(bounds, np.float64)
        if len(self.bounds) == 0 or np.any(np.diff(self.bounds) <= 0):
            raise ValueError("bounds must be non-empty and increasing")
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.sum = 0.0
        self._lock = threading.Lock()

    def snapshot(self) -> tuple[np.ndarray, float]:
        """Mutually consistent ``(counts copy, sum)``."""
        with self._lock:
            return self.counts.copy(), self.sum

    @property
    def count(self) -> int:
        return int(self.snapshot()[0].sum())

    def observe(self, value: float) -> None:
        # side="left": bucket i holds value <= bounds[i], the Prometheus
        # ``le`` convention.
        i = np.searchsorted(self.bounds, value, side="left")
        with self._lock:
            self.counts[i] += 1
            self.sum += float(value)

    def observe_many(self, values) -> None:
        v = np.asarray(values, np.float64).reshape(-1)
        if v.size == 0:
            return
        idx = np.searchsorted(self.bounds, v, side="left")
        add = np.bincount(idx, minlength=len(self.counts))
        with self._lock:
            self.counts += add
            self.sum += float(v.sum())

    def merge(self, other: "Histogram") -> "Histogram":
        if not np.array_equal(self.bounds, other.bounds):
            raise ValueError("cannot merge histograms with different buckets")
        counts, total = other.snapshot()
        with self._lock:
            self.counts += counts
            self.sum += total
        return self

    @property
    def mean(self) -> float:
        counts, total = self.snapshot()
        n = int(counts.sum())
        return total / n if n else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0..1) by linear interpolation inside the
        owning bucket (lower edge 0 for the first, last finite bound for
        the +Inf bucket — the conservative Prometheus convention).

        Edge cases: an empty histogram returns 0.0 (there is no data to
        estimate from); ``q=0`` returns the lower edge of the first
        *occupied* bucket (not bucket 0, which may be empty); ``q=1``
        returns the upper edge of the last occupied bucket. A single
        observation interpolates inside its own bucket for any q."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        counts, _ = self.snapshot()
        n = int(counts.sum())
        if n == 0:
            return 0.0
        rank = q * n
        cum = np.cumsum(counts)
        # side="right" when rank == 0: skip leading empty buckets (cum==0)
        # so q=0 lands in the first occupied bucket, not bucket 0.
        side = "right" if rank <= 0 else "left"
        i = int(np.searchsorted(cum, rank, side=side))
        i = min(i, len(counts) - 1)
        if i >= len(self.bounds):          # overflow bucket: no upper edge
            return float(self.bounds[-1])
        lo = float(self.bounds[i - 1]) if i > 0 else 0.0
        hi = float(self.bounds[i])
        below = float(cum[i - 1]) if i > 0 else 0.0
        inside = float(counts[i])
        frac = (rank - below) / inside if inside else 0.0
        return lo + (hi - lo) * min(max(frac, 0.0), 1.0)

    def to_prometheus(self, name: str, labels: str = "") -> list[str]:
        """Cumulative ``le`` series + ``_sum``/``_count`` text lines.
        ``labels`` is a pre-rendered ``key="value"`` list (no braces)."""
        counts, total = self.snapshot()
        sep = labels + "," if labels else ""
        lines = []
        cum = 0
        for b, c in zip(self.bounds, counts[:-1]):
            cum += int(c)
            lines.append(f'{name}_bucket{{{sep}le="{b:g}"}} {cum}')
        cum += int(counts[-1])
        lines.append(f'{name}_bucket{{{sep}le="+Inf"}} {cum}')
        brace = f"{{{labels}}}" if labels else ""
        lines.append(f"{name}_sum{brace} {total:g}")
        lines.append(f"{name}_count{brace} {cum}")
        return lines
