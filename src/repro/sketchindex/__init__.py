from repro.sketchindex.distributed import (  # noqa: F401
    DeviceIndex,
    ShardedIndex,
    batch_queries,
    distributed_search,
    distributed_topk,
    score_batch,
    to_device_index,
)
from repro.sketchindex.build import distributed_tau  # noqa: F401
from repro.sketchindex.windows import (  # noqa: F401
    ArenaSnapshot,
    WindowManager,
)
