"""Distributed GB-KMV construction primitives.

At 1000 nodes the records stream in sharded; per-record hashing/filtering/
sorting is purely local (kernels/hash_threshold.py is the device hot
path). Two quantities need global agreement and both reduce to fixed-size
collective reductions — never a data shuffle:

  * the global threshold τ (budget-th smallest hash over ALL elements):
    a two-level histogram refine — psum a 4096-bin histogram of the top
    12 hash bits, locate the budget-crossing bin, psum a second 4096-bin
    histogram *within* that bin. τ lands within 2^8 hash values of exact
    (≪ one element of budget error in expectation).
  * the top-r frequent elements: psum of per-shard element-count
    histograms (or count-min at 10⁹-element universes — noted in
    DESIGN.md); top-r is then a local argsort of the reduced counts.

``distributed_tau`` below is the shard_map reduction; ``histogram_tau``
is the single-device core both the tests and the launcher share.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

_LEVEL_BITS = 12
_BINS = 1 << _LEVEL_BITS


def _hist(hashes, shift: int, mask_base, mask_width: int):
    """Histogram of ((h >> shift) & (BINS-1)) restricted to a bin prefix."""
    h = hashes
    if mask_width:
        keep = (h >> jnp.uint32(shift + _LEVEL_BITS)) == mask_base
    else:
        keep = jnp.ones(h.shape, bool)
    idx = ((h >> jnp.uint32(shift)) & jnp.uint32(_BINS - 1)).astype(jnp.int32)
    return jnp.zeros(_BINS, jnp.int32).at[idx].add(keep.astype(jnp.int32))


def histogram_tau(hashes, budget: int):
    """Two-level histogram τ-selection on one device (jnp).

    Returns a uint32 upper bound of the bin containing the budget-th
    smallest hash (exact to 2^8 = 256 hash values on a 32-bit space).
    """
    hashes = jnp.asarray(hashes, jnp.uint32)
    h1 = _hist(hashes, 32 - _LEVEL_BITS, None, 0)
    c1 = jnp.cumsum(h1)
    b1 = jnp.argmax(c1 >= budget).astype(jnp.uint32)       # first crossing bin

    h2 = _hist(hashes, 32 - 2 * _LEVEL_BITS, b1, _LEVEL_BITS)
    below1 = jnp.where(b1 > 0, c1[jnp.maximum(b1, 1) - 1], 0)
    c2 = below1 + jnp.cumsum(h2)
    b2 = jnp.argmax(c2 >= budget).astype(jnp.uint32)

    rem_bits = 32 - 2 * _LEVEL_BITS
    tau = ((b1 << jnp.uint32(32 - _LEVEL_BITS))
           | (b2 << jnp.uint32(rem_bits))
           | jnp.uint32((1 << rem_bits) - 1))
    return tau


def distributed_tau(hashes_sharded, budget: int, mesh: Mesh, row_axes):
    """τ over a mesh-sharded flat hash stream: local hist → psum → select.

    ``hashes_sharded`` u32[N] sharded on ``row_axes``. Collective cost:
    two psums of 4096×4B — independent of data size and node count.
    """
    axes = row_axes if isinstance(row_axes, tuple) else (row_axes,)

    def local(h):
        h1 = _hist(h, 32 - _LEVEL_BITS, None, 0)
        h1 = jax.lax.psum(h1, axes)
        c1 = jnp.cumsum(h1)
        b1 = jnp.argmax(c1 >= budget).astype(jnp.uint32)

        h2 = _hist(h, 32 - 2 * _LEVEL_BITS, b1, _LEVEL_BITS)
        h2 = jax.lax.psum(h2, axes)
        below1 = jnp.where(b1 > 0, c1[jnp.maximum(b1, 1) - 1], 0)
        c2 = below1 + jnp.cumsum(h2)
        b2 = jnp.argmax(c2 >= budget).astype(jnp.uint32)

        rem_bits = 32 - 2 * _LEVEL_BITS
        return ((b1 << jnp.uint32(32 - _LEVEL_BITS))
                | (b2 << jnp.uint32(rem_bits))
                | jnp.uint32((1 << rem_bits) - 1))

    fn = compat.shard_map(local, mesh=mesh, in_specs=(P(row_axes),),
                          out_specs=P())
    return fn(jnp.asarray(hashes_sharded, jnp.uint32))
