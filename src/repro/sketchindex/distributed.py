"""Device-resident, mesh-sharded GB-KMV index (the paper at cluster scale).

Layout: the packed sketch matrices (core/sketches.py) with the *record*
dim sharded over every mesh axis — P(("pod","data","model")) — because
containment scoring is embarrassingly parallel over records. Queries are
replicated (a query batch is KBs).

Search = one sweep of the sharded matrix:
    scores[M, Gq] = kernel/jnp scoring   (records stay put, zero collective)
    then either
      * threshold mask (Algorithm 2)     — zero-collective output, or
      * global top-k: per-shard lax.top_k → all_gather of (devices × k × Gq)
        candidates (tiny) → final top_k — the ONLY collective in the
        query path, bytes = devices·k·8 per query.

Query batching (beyond-paper): scoring Gq queries per sweep divides the
HBM-bound roofline term by Gq — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.estimators import buffer_intersection, gkmv_pair_estimate
from repro.core.hashing import PAD
from repro.core.sketches import PackedSketches
from repro.obs.trace import stage as obs_stage
from repro.parallel.sharding import logical_to_spec


@dataclasses.dataclass
class DeviceIndex:
    """Sharded PackedSketches + the metadata needed to sketch queries."""

    values: jax.Array    # u32[Mp, C]   rows sharded
    lengths: jax.Array   # i32[Mp]
    thresh: jax.Array    # u32[Mp]
    buf: jax.Array       # u32[Mp, W]
    sizes: jax.Array     # i32[Mp]
    num_records: int     # true M (before padding)
    tau: int             # hashable metadata (jit cache key)
    top_elems: tuple
    seed: int

    @property
    def padded_records(self) -> int:
        return self.values.shape[0]


def _pad_rows(a: np.ndarray, target: int, fill):
    if a.shape[0] == target:
        return a
    pad = np.full((target - a.shape[0],) + a.shape[1:], fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=0)


def to_device_index(index, mesh: Mesh) -> DeviceIndex:
    """Place a host GBKMVIndex onto the mesh, record-dim fully sharded.

    Rows are padded to a multiple of the mesh size; padded rows get
    thresh=0 (nothing live → score 0, never a false candidate).
    """
    s: PackedSketches = index.sketches
    n_dev = mesh.devices.size
    m = s.num_records
    mp = -(-m // n_dev) * n_dev

    row_spec = logical_to_spec(("records",), mesh)
    rows2d = NamedSharding(mesh, P(row_spec[0], None))
    rows1d = NamedSharding(mesh, P(row_spec[0]))

    return DeviceIndex(
        values=jax.device_put(_pad_rows(np.asarray(s.values), mp, PAD), rows2d),
        lengths=jax.device_put(_pad_rows(np.asarray(s.lengths), mp, 0), rows1d),
        thresh=jax.device_put(_pad_rows(np.asarray(s.thresh), mp, 0), rows1d),
        buf=jax.device_put(
            _pad_rows(np.asarray(s.buf if s.buf.shape[1] else
                                 np.zeros((m, 1), np.uint32)), mp, 0), rows2d),
        sizes=jax.device_put(_pad_rows(np.asarray(s.sizes), mp, 0), rows1d),
        num_records=m,
        tau=int(index.tau),
        top_elems=tuple(int(e) for e in index.top_elems),
        seed=index.seed,
    )


def batch_queries(index, queries) -> PackedSketches:
    """Sketch a list of query id-arrays into one replicated query pack.

    One vectorized pass for the whole batch (CSR ingest + one hash pass +
    one lexsort-pack) — ``repro.core.gbkmv.sketch_query_batch``, the same
    packer the api query path uses."""
    from repro.core.gbkmv import sketch_query_batch

    return sketch_query_batch(index, [np.asarray(q) for q in queries])


def _scores_jnp(values, lengths, thresh, buf, q_values, q_thresh, q_buf, q_sizes):
    """Pure-jnp scoring [Mshard, Gq] — the pjit/dry-run lowering path."""
    def one_query(qv, qt, qb, qs):
        d_hat, _, _ = gkmv_pair_estimate(qv, None, qt, values, lengths, thresh)
        o1 = buffer_intersection(qb, buf)
        return (o1.astype(jnp.float32) + d_hat) / jnp.maximum(
            qs.astype(jnp.float32), 1.0)

    return jax.vmap(one_query)(q_values, q_thresh, q_buf, q_sizes).T


@functools.partial(jax.jit, static_argnames=("backend",))
def _score_batch_jit(didx: DeviceIndex, q: PackedSketches, backend: str):
    qv = jnp.asarray(q.values, jnp.uint32)
    qt = jnp.asarray(q.thresh, jnp.uint32)
    qb = jnp.asarray(q.buf, jnp.uint32)
    qs = jnp.asarray(q.sizes, jnp.int32)
    if qb.shape[1] != didx.buf.shape[1]:
        qb = jnp.pad(qb, ((0, 0), (0, didx.buf.shape[1] - qb.shape[1])))
    if backend == "pallas":
        from repro.kernels.ops import score_index
        return score_index(didx.values, didx.thresh, didx.buf,
                           qv, qt, qb, qs)
    return _scores_jnp(didx.values, didx.lengths, didx.thresh, didx.buf,
                       qv, qt, qb, qs)


def score_batch(didx: DeviceIndex, q: PackedSketches,
                backend: str | None = None, impl: str | None = None):
    """Containment scores f32[Mp, Gq]; records sharded, queries replicated.

    ``backend`` ∈ {"numpy", "jnp", "pallas"} — the one option threaded
    through every scoring layer (``impl=`` is the deprecated spelling;
    "kernel" → "pallas"). "numpy" computes on host from fetched shards —
    a debug/parity path, not a serving path.
    """
    from repro.core.estimators import containment_matrix, normalize_backend

    backend = normalize_backend(backend, impl)
    if backend == "numpy":
        x = PackedSketches(
            values=np.asarray(didx.values), lengths=np.asarray(didx.lengths),
            thresh=np.asarray(didx.thresh), buf=np.asarray(didx.buf),
            sizes=np.asarray(didx.sizes))
        qh = PackedSketches(
            values=np.asarray(q.values), lengths=np.asarray(q.lengths),
            thresh=np.asarray(q.thresh), buf=np.asarray(q.buf),
            sizes=np.asarray(q.sizes))
        return containment_matrix(qh, x, backend="numpy")
    return _score_batch_jit(didx, q, backend)


jax.tree_util.register_dataclass(
    DeviceIndex,
    data_fields=["values", "lengths", "thresh", "buf", "sizes"],
    meta_fields=["num_records", "tau", "top_elems", "seed"],
)


def distributed_topk(scores, k: int, mesh: Mesh):
    """Global top-k over the sharded record dim via shard_map.

    scores f32[Mp, Gq] (rows sharded) -> (vals f32[Gq, k], ids i32[Gq, k]).
    Per-shard top-k then one tiny all_gather of (n_dev · k) candidates.
    """
    row_axes = logical_to_spec(("records",), mesh)[0]
    n_shards = int(np.prod([mesh.shape[a] for a in (
        row_axes if isinstance(row_axes, tuple) else (row_axes,))]))
    mp = scores.shape[0]
    shard_rows = mp // n_shards

    def local(scores_blk):                       # [mp/n, Gq]
        v, i = jax.lax.top_k(scores_blk.T, min(k, shard_rows))  # [Gq, k]
        # Shard-local row ids -> global ids.
        if isinstance(row_axes, tuple):
            pos = 0
            stride = 1
            for a in reversed(row_axes):
                pos = pos + jax.lax.axis_index(a) * stride
                stride = stride * mesh.shape[a]
        else:
            pos = jax.lax.axis_index(row_axes)
        gid = i + pos * shard_rows
        vg = jax.lax.all_gather(v, row_axes, axis=0, tiled=False)
        ig = jax.lax.all_gather(gid, row_axes, axis=0, tiled=False)
        vg = vg.reshape((-1,) + v.shape)          # [n, Gq, k]
        ig = ig.reshape((-1,) + gid.shape)
        vflat = jnp.moveaxis(vg, 0, 1).reshape(v.shape[0], -1)   # [Gq, n*k]
        iflat = jnp.moveaxis(ig, 0, 1).reshape(v.shape[0], -1)
        vtop, sel = jax.lax.top_k(vflat, k)
        return vtop, jnp.take_along_axis(iflat, sel, axis=-1)

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(row_axes, None),),
        out_specs=(P(), P()),
    )
    return fn(scores)


def distributed_search(didx: DeviceIndex, q: PackedSketches, threshold: float,
                       backend: str | None = None, impl: str | None = None):
    """Algorithm 2 at cluster scale: boolean candidate mask [Mp, Gq]."""
    scores = score_batch(didx, q, backend=backend, impl=impl)
    return scores >= threshold, scores


class ShardedIndex:
    """Device-sharded GB-KMV index implementing the ``repro.api`` protocol.

    Wraps a host :class:`GBKMVIndex` placed on a mesh (``to_device_index``)
    so serving, benchmarks, and the api registry talk to sharded and host
    indexes through the same surface — ``SketchServer`` no longer
    special-cases device placement.
    """

    engine = "gbkmv"

    def __init__(self, index, mesh: Mesh, backend: str = "jnp",
                 budget: int | None = None):
        from repro.core.arena import SketchArena

        core = getattr(index, "core", index)       # api wrapper or core index
        core.sketches = SketchArena.from_pack(core.sketches)
        self.host = core
        self.mesh = mesh
        self.backend = backend
        self.budget = budget if budget is not None else getattr(
            index, "budget", None)
        self.didx = to_device_index(core, mesh)
        self.last_plan = None
        # Explain/observability bookkeeping from the most recent planned
        # batch: per-query CandidateSets (pruned path only) and the
        # planner inputs needed for upper-bound stats.
        self.last_candidates = None
        self._last_plan_inputs = None

    @property
    def num_records(self) -> int:
        return self.host.num_records

    # -- planner plumbing: per-shard postings, candidates unioned --
    def _shard_postings(self):
        """(postings, row_offsets) over the arena's record slices.

        One block-compressed postings index per record-offset slice,
        built from column *views* of the shared arena (no per-shard
        host copies) and cached on the arena itself — so the host api
        index and the sharded view maintain ONE postings store.
        Candidate generation probes every slice (block headers first —
        skipping applies per shard) and unions the (disjoint) results —
        the host-side mirror of the mesh's all_gather. After inserts
        the slices update in place (τ-truncation of each slice's blocks
        + re-encoding only the appended rows); their boundaries may
        then lag the mesh's ceil-partition, which is harmless because
        the union reports global record ids either way.
        """
        return self.host.sketches.shard_postings(self.mesh.devices.size)

    def _device_route(self) -> bool:
        """True when the fused all-device pipeline can serve this index:
        a single-device mesh (the fused program is unsharded) plus a
        device scoring backend. Multi-device meshes keep the per-shard
        host merge — its block skipping applies shard by shard."""
        from repro.core.arena import SketchArena

        return (self.mesh.devices.size == 1
                and self.backend in ("jnp", "pallas")
                and isinstance(self.host.sketches, SketchArena))

    def _pruned_batch(self, queries, thresholds, plan: str):
        """Planner route for a batch. Returns (hits, qp): hits is None
        when the cost model (or a guard) sends the batch dense, and qp
        is the already-sketched query pack (or None) so the dense path
        never re-sketches the batch."""
        from repro import planner
        from repro.planner.plan import gbkmv_plan_queries

        plan = planner.normalize_plan(plan)
        thr = np.asarray(thresholds, np.float64)
        t_min = float(thr.min()) if thr.size else 0.0
        self.last_candidates = None
        self._last_plan_inputs = None
        if plan == "dense" or t_min <= 0.0 or not queries:
            # A decision was still made — record it so explain and the
            # drift gauge always have something to read.
            self.last_plan = planner.QueryPlan(
                "dense", np.nan, np.nan, 0,
                "forced" if plan == "dense" else "threshold <= 0")
            return None, None
        qp, hash_rows, bit_rows, sizes = gbkmv_plan_queries(
            self.host, queries)
        with obs_stage("shard.postings", shards=self.mesh.devices.size):
            posts, offs = self._shard_postings()
        s: PackedSketches = self.host.sketches
        decision = planner.choose_plan(
            posts, hash_rows, bit_rows, t_min,
            s.num_records, s.capacity, plan=plan)
        self.last_plan = decision
        self._last_plan_inputs = (hash_rows, sizes, posts)
        if decision.path == "dense":
            return None, qp

        if self._device_route():
            from repro.planner import device as planner_device

            # Fused probe→decode→score→threshold entirely on device:
            # per-query candidate sets never materialize on host, so
            # explain carries the probe breakdown only.
            ids = planner_device.pruned_batch_device(
                self.host.sketches, qp, thresholds,
                plan=decision, backend=self.backend)
            return ids, qp

        from repro.kernels import gather_score

        def score_fn(cand_rec, cand_q):
            return gather_score.score_pairs(
                s, qp, cand_rec, cand_q, backend=self.backend)

        ids, cands = planner.pruned_batch(
            posts, hash_rows, bit_rows, sizes, thresholds, score_fn,
            row_offsets=offs)
        self.last_candidates = cands
        return ids, qp

    # -- scoring --
    def batch_scores(self, queries) -> np.ndarray:
        """f32[m, Gq] (padding rows trimmed) — one sharded index sweep."""
        qp = batch_queries(self.host, [np.asarray(q) for q in queries])
        s = score_batch(self.didx, qp, backend=self.backend)
        return np.asarray(s)[: self.num_records]

    def _serve_explains(self, hits, thr, t0) -> list[dict]:
        """Per-query explain dicts for the batch just served, built from
        the planner bookkeeping ``_pruned_batch`` left behind."""
        import time

        from repro import obs

        hash_rows, sizes, posts = self._last_plan_inputs or (None, None, None)
        ex = obs.build_explain(
            self.last_plan, engine=self.engine, backend=self.backend,
            n_queries=len(hits), hits=hits, cands=self.last_candidates,
            hash_rows=hash_rows, sizes=sizes, posts=posts,
            measured_seconds=time.perf_counter() - t0)
        for g, e in enumerate(ex):
            e["threshold"] = float(thr[g])
        return ex

    def serve_batch(self, queries, thresholds, k: int, plan: str = "auto",
                    explain: bool = False):
        """One sweep answering threshold + top-k for a whole batch.

        ``thresholds`` is scalar or per-query. Returns one dict per query:
        {"hits", "topk_ids", "topk_scores"}. ``plan`` routes both halves:
        threshold hits through the pruned filter-and-verify and — when
        forced "pruned" — top-k through the planner-aware upper-bound
        pruning as well. ``plan="auto"`` keeps top-k on the dense sweep
        (the batch amortizes it and the hit masks fall out of the same
        scores), matching it bit for bit. With ``explain=True`` each
        dict gains an ``"explain"`` entry (:mod:`repro.obs.explain`).
        """
        import time

        from repro.planner.prune import threshold_hits_packed

        t0 = time.perf_counter()
        queries = [np.asarray(q) for q in queries]
        thr = np.broadcast_to(np.asarray(thresholds, np.float64),
                              (len(queries),))
        empty_ids = np.zeros(0, np.int64)
        empty_scores = np.zeros(0, np.float32)
        if k <= 0 or plan == "pruned":
            hits, qp = self._pruned_batch(queries, thr, plan)
            if hits is None:
                if qp is None:
                    with obs_stage("serve.sketch", queries=len(queries)):
                        qp = batch_queries(self.host, queries)
                with obs_stage("serve.score", queries=len(queries)) as span:
                    scores = span.sync(score_batch(
                        self.didx, qp, backend=self.backend))
                with obs_stage("serve.hits"):
                    hits = threshold_hits_packed(
                        scores[: self.num_records], thr)
            if k <= 0:
                out = [{"hits": h, "topk_ids": empty_ids,
                        "topk_scores": empty_scores} for h in hits]
                if explain:
                    for res, e in zip(out, self._serve_explains(
                            hits, thr, t0)):
                        res["explain"] = e
                return out
            # Reuse the batch's query pack: one sketching pass serves
            # both the threshold hits and every pruned top-k.
            ex = self._serve_explains(hits, thr, t0) if explain else None
            with obs_stage("serve.topk", k=k):
                tops = self._pruned_topk_batch(queries, k, qp=qp)
            out = [{"hits": h, "topk_ids": t[0], "topk_scores": t[1]}
                   for h, t in zip(hits, tops)]
            if ex is not None:
                for res, e in zip(out, ex):
                    res["explain"] = e
            return out

        # Dense sweep route (top-k batches on plan="auto"): the planner
        # is never consulted, but a routing decision still happened —
        # record it so explain/drift always have the current batch.
        from repro import planner
        from repro.core import cost_model

        s = self.host.sketches
        self.last_candidates = None
        self._last_plan_inputs = None
        self.last_plan = planner.QueryPlan(
            "dense", cost_model.dense_sweep_cost(
                s.num_records, s.capacity, len(queries)), np.nan, 0,
            "topk batch: dense sweep amortized")
        with obs_stage("serve.sketch", queries=len(queries)):
            qp = batch_queries(self.host, queries)
        with obs_stage("serve.score", queries=len(queries)):
            scores = score_batch(self.didx, qp, backend=self.backend)
        with obs_stage("serve.topk", k=k):
            vals, ids = distributed_topk(scores, k, self.mesh)
            jax.block_until_ready(vals)
        with obs_stage("serve.hits"):
            hits = threshold_hits_packed(scores[: self.num_records], thr)
        out = [
            {"hits": hits[j],
             "topk_ids": np.asarray(ids)[j],
             "topk_scores": np.asarray(vals)[j]}
            for j in range(len(queries))
        ]
        if explain:
            for res, e in zip(out, self._serve_explains(hits, thr, t0)):
                res["explain"] = e
        return out

    # -- repro.api protocol --
    def query(self, q_ids, threshold: float, *, plan: str = "auto") -> np.ndarray:
        return self.batch_query([q_ids], threshold, plan=plan)[0]

    def batch_query(self, queries, threshold: float, *,
                    plan: str = "auto") -> list[np.ndarray]:
        from repro import planner

        plan = planner.normalize_plan(plan)
        queries = [np.asarray(q) for q in queries]
        if not queries:
            return []
        hits, qp = self._pruned_batch(queries, float(threshold), plan)
        if hits is not None:
            return hits
        if qp is None:
            qp = batch_queries(self.host, queries)
        s = score_batch(self.didx, qp, backend=self.backend)
        return planner.threshold_hits_packed(s[: self.num_records], threshold)

    def _pruned_topk_batch(self, queries, k: int, qp=None):
        """Planner-aware top-k for a whole batch over ONE query pack
        (``qp`` reuses a pack the caller already sketched)."""
        from repro import planner
        from repro.kernels import gather_score
        from repro.planner.plan import unpack_query_rows

        if qp is None:
            qp = batch_queries(self.host, queries)
        if self._device_route():
            from repro.planner import device as planner_device

            return planner_device.pruned_topk_device(
                self.host.sketches, qp, k, backend=self.backend)
        hash_rows, bit_rows, sizes = unpack_query_rows(qp)
        posts, offs = self._shard_postings()
        s: PackedSketches = self.host.sketches
        out = []
        for g in range(len(queries)):
            def score_fn(cand_rec, _cand_q, g=g):
                return gather_score.score_pairs(
                    s, qp, cand_rec,
                    np.full(len(cand_rec), g, np.int32),
                    backend=self.backend)

            out.append(planner.pruned_topk(
                posts, hash_rows[g], bit_rows[g], int(sizes[g]), k,
                score_fn, s.num_records, row_offsets=offs))
        return out

    def topk(self, q_ids, k: int, *, plan: str = "auto"):
        """Global top-k. ``plan="pruned"`` routes through the planner's
        postings-driven upper-bound pruning (host merge over the shard
        slices + device gather-scoring) with exact parity against the
        dense mesh sweep; "auto"/"dense" run the sharded sweep +
        all_gather (``lax.top_k`` breaks ties lower-id-first, the same
        deterministic order the pruned path produces)."""
        from repro import planner

        plan = planner.normalize_plan(plan)
        if plan == "pruned" and k > 0:
            return self._pruned_topk_batch([np.asarray(q_ids)], k)[0]
        qp = batch_queries(self.host, [np.asarray(q_ids)])
        scores = score_batch(self.didx, qp, backend=self.backend)
        vals, ids = distributed_topk(scores, k, self.mesh)
        return (np.asarray(ids)[0].astype(np.int64),
                np.asarray(vals)[0].astype(np.float32))

    def insert(self, new_records, budget: int | None = None):
        """Dynamic insert on the host sketch (delegated to the api index so
        budget semantics live in one place), then re-place on the mesh.
        The arena carries the per-shard postings across the insert
        incrementally (τ-truncation + append on each slice) — no lazy
        rebuild."""
        from repro import api

        wrapper = api.GBKMVEngine.wrap(
            self.host, budget=budget if budget is not None else self.budget)
        wrapper.insert(new_records)
        self.host = wrapper.core
        self.stats = wrapper.stats
        self.didx = to_device_index(self.host, self.mesh)
        return self

    def save(self, path: str) -> None:
        from repro import api

        api.GBKMVEngine.wrap(self.host, budget=self.budget).save(path)

    def nbytes(self) -> int:
        return self.host.nbytes()
