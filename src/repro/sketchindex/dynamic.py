"""Dynamic GB-KMV index maintenance (paper §IV-B, "Processing Dynamic
Data"): insert records under a FIXED space budget by re-tightening the
global threshold τ.

Correctness argument (the paper sketches it; we make it exact): every
record's sketch holds ALL hashes ≤ its effective threshold. For a new,
lower τ' ≤ min(thresholds), each stored row filtered at τ' is again a
complete τ'-sketch — so re-selecting τ' from the *kept* hash multiset
(plus the new records' hashes) yields a valid G-KMV index without
touching the raw data. Only τ-INCREASES would need raw records; under a
fixed budget and growing data τ only ever decreases.

The buffer's top-r element set is frozen between rebuilds (new elements
hash into the G-KMV tail); a frequency drift counter triggers a full
rebuild when the frozen set no longer covers the head mass — the same
amortized-rebuild pattern production inverted indexes use.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.gbkmv import GBKMVIndex
from repro.core.hashing import PAD, hash_u32_np
from repro.core.sketches import PackedSketches, make_bitmaps, pack_rows


@dataclasses.dataclass
class DynamicStats:
    inserts: int = 0
    tau_retightens: int = 0
    drift: float = 0.0          # head-mass fraction hashing outside buffer


def _kept_hash_rows(s: PackedSketches) -> list[np.ndarray]:
    vals = np.asarray(s.values)
    lens = np.asarray(s.lengths)
    return [vals[i, : lens[i]] for i in range(s.num_records)]


def insert_records(
    index: GBKMVIndex,
    new_records: list[np.ndarray],
    budget: int,
    stats: DynamicStats | None = None,
) -> tuple[GBKMVIndex, DynamicStats]:
    """Insert ``new_records`` keeping total slots ≤ ``budget``.

    Steps (all on kept hashes only — no raw-data access for old rows):
      1. hash + buffer-split the new records at the CURRENT τ / top-r;
      2. if the total kept hashes exceed the tail budget, re-select
         τ' = budget-th smallest kept hash and refilter every row;
      3. repack. Rows keep per-row effective thresholds (min(τ', old)).
    """
    stats = stats or DynamicStats()
    s = index.sketches
    top = index.top_elems
    top_set = set(int(e) for e in np.asarray(top))
    r = index.buffer_bits
    m_old = s.num_records

    # 1. new rows: split buffer head / hashed tail, filter at current τ.
    new_tails, new_kept, new_sizes = [], [], []
    drift_hits = 0
    drift_total = 0
    for rec in new_records:
        rec = np.asarray(rec)
        if top_set:
            mask = np.asarray([int(e) not in top_set for e in rec], bool)
            tail = rec[mask]
            drift_hits += int(mask.sum())
            drift_total += len(rec)
        else:
            tail = rec
            drift_total += len(rec)
            drift_hits += len(rec)
        h = np.sort(hash_u32_np(tail, seed=index.seed))
        new_tails.append(tail)
        new_kept.append(h[h <= index.tau])
        new_sizes.append(len(rec))

    old_rows = _kept_hash_rows(s)
    all_rows = old_rows + new_kept
    m = len(all_rows)

    # 2. budget check on the tail (buffer words charged per record).
    words = -(-r // 32) if r else 0
    tail_budget = max(budget - m * words, m)
    total_kept = sum(len(x) for x in all_rows)
    old_thr = np.asarray(s.thresh)
    new_thr = np.concatenate(
        [old_thr, np.full(len(new_records), index.tau, np.uint32)])
    tau = np.uint32(index.tau)
    if total_kept > tail_budget:
        allh = np.concatenate([r_ for r_ in all_rows if len(r_)]) \
            if total_kept else np.zeros(0, np.uint32)
        tau = np.uint32(np.partition(allh, tail_budget - 1)[tail_budget - 1])
        all_rows = [r_[r_ <= tau] for r_ in all_rows]
        new_thr = np.minimum(new_thr, tau)
        stats.tau_retightens += 1

    # 3. repack (buffer bitmaps: old rows copied, new rows computed).
    sizes = np.concatenate(
        [np.asarray(s.sizes), np.asarray(new_sizes, np.int32)])
    if r and len(top):
        new_maps = make_bitmaps(new_records, np.asarray(top))
        bitmaps = np.concatenate([np.asarray(s.buf), new_maps], axis=0)
    else:
        bitmaps = np.zeros((m, s.buf.shape[1]), np.uint32)
        if s.buf.shape[1]:
            bitmaps[:m_old] = np.asarray(s.buf)
    from repro.core.arena import SketchArena

    packed = SketchArena.from_pack(pack_rows(all_rows, new_thr, sizes,
                                             bitmaps=bitmaps))
    # Carry cached postings (global + per-shard) forward incrementally:
    # τ-truncation + append on the BLOCKED stores — key prefix slices
    # plus re-encoding only the rows the new records touch, never a
    # rebuild of old rows (and block-for-block identical to one).
    packed.adopt_postings_from(SketchArena.from_pack(s), tau)

    stats.inserts += len(new_records)
    if drift_total:
        stats.drift = drift_hits / drift_total
    return GBKMVIndex(sketches=packed, tau=tau, top_elems=index.top_elems,
                      seed=index.seed, buffer_bits=r), stats


def needs_rebuild(stats: DynamicStats, drift_threshold: float = 0.98) -> bool:
    """True when the frozen top-r buffer stopped covering the head mass
    (new data's elements almost entirely bypass the buffer)."""
    return stats.drift > drift_threshold and stats.inserts > 0
