"""Dynamic GB-KMV index maintenance (paper §IV-B, "Processing Dynamic
Data"): insert records under a FIXED space budget by re-tightening the
global threshold τ.

Correctness argument (the paper sketches it; we make it exact): every
record's sketch holds ALL hashes ≤ its effective threshold. For a new,
lower τ' ≤ min(thresholds), each stored row filtered at τ' is again a
complete τ'-sketch — so re-selecting τ' from the *kept* hash multiset
(plus the new records' hashes) yields a valid G-KMV index without
touching the raw data. Only τ-INCREASES would need raw records; under a
fixed budget and growing data τ only ever decreases.

The buffer's top-r element set is frozen between rebuilds (new elements
hash into the G-KMV tail); a frequency drift counter triggers a full
rebuild when the frozen set no longer covers the head mass — the same
amortized-rebuild pattern production inverted indexes use.

The insert path is vectorized end-to-end: new records ingest once into a
ragged CSR batch (one hash pass, sorted-search buffer membership), the
old rows' kept hashes flatten straight out of the packed columns, and
the repack is one lexsort+scatter (``pack_csr``) — no per-record Python
on either side of the τ-retightening.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.gbkmv import GBKMVIndex
from repro.core.hashing import hash_u32_np
from repro.core.sketches import (PackedSketches, RaggedBatch, make_bitmaps,
                                 pack_csr, top_membership)


@dataclasses.dataclass
class DynamicStats:
    inserts: int = 0
    tau_retightens: int = 0
    drift: float = 0.0          # head-mass fraction hashing outside buffer


def _kept_hash_rows(s: PackedSketches) -> list[np.ndarray]:
    vals = np.asarray(s.values)
    lens = np.asarray(s.lengths)
    return [vals[i, : lens[i]] for i in range(s.num_records)]


def _flat_kept(s: PackedSketches) -> tuple[np.ndarray, np.ndarray]:
    """All live hashes of a packed index as flat (hash, row) arrays —
    row-major, ascending within each row (the packed order)."""
    vals = np.asarray(s.values)
    lens = np.asarray(s.lengths)
    live = np.arange(s.capacity, dtype=np.int64)[None, :] < lens[:, None]
    h = vals[live]
    row = np.broadcast_to(
        np.arange(s.num_records, dtype=np.int64)[:, None], vals.shape)[live]
    return h.astype(np.uint32), row


def insert_records(
    index: GBKMVIndex,
    new_records: list[np.ndarray],
    budget: int,
    stats: DynamicStats | None = None,
) -> tuple[GBKMVIndex, DynamicStats]:
    """Insert ``new_records`` keeping total slots ≤ ``budget``.

    Steps (all on kept hashes only — no raw-data access for old rows):
      1. hash + buffer-split the new records at the CURRENT τ / top-r
         (one CSR batch: one hash pass, sorted-search membership);
      2. if the total kept hashes exceed the tail budget, re-select
         τ' = budget-th smallest kept hash and refilter every row;
      3. repack (one lexsort+scatter). Rows keep per-row effective
         thresholds (min(τ', old)).
    """
    stats = stats or DynamicStats()
    s = index.sketches
    top = np.asarray(index.top_elems)
    r = index.buffer_bits
    m_old = s.num_records

    # 1. new rows: split buffer head / hashed tail, filter at current τ.
    batch = RaggedBatch.from_records([np.asarray(rec) for rec in new_records])
    if len(top):
        is_top, _ = top_membership(batch.ids, top)
        tail_mask = ~is_top
    else:
        tail_mask = np.ones(batch.total, bool)
    drift_hits = int(tail_mask.sum())
    drift_total = batch.total

    h_new = hash_u32_np(batch.ids, seed=index.seed)
    keep_new = tail_mask & (h_new <= index.tau)
    new_h = h_new[keep_new]
    new_row = batch.row_index()[keep_new] + m_old

    old_h, old_row = _flat_kept(s)
    m = m_old + batch.num_records

    # 2. budget check on the tail (buffer words charged per record).
    words = -(-r // 32) if r else 0
    tail_budget = max(budget - m * words, m)
    total_kept = len(old_h) + len(new_h)
    old_thr = np.asarray(s.thresh)
    new_thr = np.concatenate(
        [old_thr, np.full(batch.num_records, index.tau, np.uint32)])
    tau = np.uint32(index.tau)
    flat_h = np.concatenate([old_h, new_h])
    flat_row = np.concatenate([old_row, new_row])
    if total_kept > tail_budget:
        tau = np.uint32(np.partition(flat_h, tail_budget - 1)[tail_budget - 1])
        keep = flat_h <= tau
        flat_h, flat_row = flat_h[keep], flat_row[keep]
        new_thr = np.minimum(new_thr, tau)
        stats.tau_retightens += 1

    # 3. repack (buffer bitmaps: old rows copied, new rows computed).
    sizes = np.concatenate([np.asarray(s.sizes), batch.sizes])
    if r and len(top):
        new_maps = make_bitmaps(batch, top)
        bitmaps = np.concatenate([np.asarray(s.buf), new_maps], axis=0)
    else:
        bitmaps = np.zeros((m, s.buf.shape[1]), np.uint32)
        if s.buf.shape[1]:
            bitmaps[:m_old] = np.asarray(s.buf)
    from repro.core.arena import SketchArena

    packed = SketchArena.from_pack(pack_csr(
        flat_h, flat_row, m, new_thr, sizes, bitmaps=bitmaps))
    # Carry cached postings (global + per-shard) forward incrementally:
    # τ-truncation + append on the BLOCKED stores — key prefix slices
    # plus re-encoding only the rows the new records touch, never a
    # rebuild of old rows (and block-for-block identical to one).
    packed.adopt_postings_from(SketchArena.from_pack(s), tau)

    stats.inserts += len(new_records)
    if drift_total:
        stats.drift = drift_hits / drift_total
    return GBKMVIndex(sketches=packed, tau=tau, top_elems=index.top_elems,
                      seed=index.seed, buffer_bits=r), stats


def needs_rebuild(stats: DynamicStats, drift_threshold: float = 0.98) -> bool:
    """True when the frozen top-r buffer stopped covering the head mass
    (new data's elements almost entirely bypass the buffer)."""
    return stats.drift > drift_threshold and stats.inserts > 0
