"""Time-windowed containment index: sealed per-epoch arenas, merged
lazily into sliding-window views.

The KMV family is mergeable by construction — both halves of a GB-KMV
sketch are order-independent (the bitmap buffer is a union of bits, the
G-KMV tail a union of hash sets re-tightened to the budget's k-th
smallest) — so a moving-data index never needs to re-hash history:

    ArenaSnapshot   one sealed epoch: an immutable api-level index over
                    the records ingested during that epoch
    WindowManager   the lifecycle: ``ingest(records, epoch=e)`` appends
                    to the open epoch (or seals it and opens ``e``),
                    ``query(..., window=(lo, hi))`` answers over any
                    contiguous epoch range by *merging* the snapshots
                    (`repro.core.{gbkmv,gkmv,kmv}.merge_*`, bit-identical
                    to rebuilding from the concatenated records),
                    ``retire(before)`` drops expired epochs, and
                    ``save``/``load`` round-trip the snapshot directory

Merged window views are cached per epoch-tuple and invalidated whenever
a member epoch changes (new ingest) or disappears (retirement) — the
DAU/MAU day-snapshot pattern, with containment-search semantics.

Budget semantics: ``budget`` is the per-window space target. Every epoch
is built with the full budget (that is what makes the merge bit-identical
to a rebuild — see :func:`repro.core.arena.merge_arenas`), and every
merged window re-tightens to the same budget, so a served window never
exceeds the configured sketch size no matter how many epochs it spans.

The manager implements the :class:`repro.api.ContainmentIndex` protocol
plus ``serve_batch``, so :class:`repro.service.AsyncSketchServer` can sit
directly on it (``repro.service.launch --windowed``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil

import numpy as np


def _fsync_dir(dirpath: str) -> None:
    """Best-effort directory fsync so the save's rename is durable."""
    try:
        fd = os.open(dirpath, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


_MANIFEST = "window_manifest.json"
_SKETCH_ENGINES = ("gbkmv", "gkmv", "kmv")


@dataclasses.dataclass
class ArenaSnapshot:
    """One epoch's records as an immutable api-level sketch index.

    ``sealed`` flips when a later epoch opens: a sealed snapshot never
    changes again, which is what makes the merged-window caches safe.
    """

    epoch: int
    index: object               # repro.api sketch index over this epoch
    sealed: bool = False

    @property
    def num_records(self) -> int:
        return int(self.index.num_records)

    def nbytes(self) -> int:
        return int(self.index.nbytes())

    def arena(self):
        """The snapshot's :class:`~repro.core.arena.SketchArena`."""
        host = getattr(self.index, "core", None) or self.index
        return getattr(host, "sketches", None)


class WindowManager:
    """Sliding-window union index over per-epoch arena snapshots.

    Usage::

        wm = WindowManager(engine="gbkmv", budget=4096, backend="numpy")
        wm.ingest(day0_records, epoch=0)
        wm.ingest(day1_records, epoch=1)
        hits = wm.query(q, threshold=0.5)                # all live epochs
        hits = wm.query(q, threshold=0.5, window=(1, 1)) # day 1 only
        wm.retire(before=1)                              # drop day 0
        wm.save("snapshots/"); WindowManager.load("snapshots/")

    Epochs open in non-decreasing order: ingesting into the newest epoch
    extends it in place (GB-KMV via τ-retightening dynamic inserts);
    ingesting a *larger* epoch seals the current one forever; ingesting
    a smaller (sealed) epoch raises. ``query``/``batch_query``/``topk``
    /``scores`` take ``window=(lo, hi)`` (inclusive epoch bounds,
    default: every live epoch) and answer through a merged index that is
    bit-identical to one built from the window's records in one shot —
    merged views are cached per epoch-tuple and invalidated on ingest
    and retirement.

    GB-KMV epochs pin the first epoch's buffer element set (``top_elems``)
    so every epoch's bitmaps stay merge-compatible — the same frozen-
    buffer philosophy as the dynamic-insert path.
    """

    #: feature-detect flag for the serving layer (`/ingest` epoch field,
    #: `/admin/retire`) — plain api indexes don't have it.
    windowed = True

    def __init__(self, engine: str = "gbkmv", budget: int = 4096,
                 backend: str = "jnp", **build_cfg):
        if engine not in _SKETCH_ENGINES:
            raise ValueError(f"windowed index supports {_SKETCH_ENGINES}, "
                             f"got {engine!r}")
        self.engine = engine
        self.budget = int(budget)
        self.backend = backend
        self.build_cfg = dict(build_cfg)
        self._snaps: dict[int, ArenaSnapshot] = {}
        self._cache: dict[tuple[int, ...], object] = {}
        self._frozen_top: np.ndarray | None = None   # gbkmv buffer pin
        self._frozen_r: int | None = None
        self.last_plan = None
        self.merges_total = 0
        self.retired_epochs_total = 0
        self.retired_records_total = 0

    # -- epoch lifecycle ---------------------------------------------------

    @property
    def epochs(self) -> list[int]:
        """Live epoch ids, ascending."""
        return sorted(self._snaps)

    @property
    def num_records(self) -> int:
        return sum(s.num_records for s in self._snaps.values())

    def ingest(self, records, epoch: int | None = None) -> "WindowManager":
        """Add records to ``epoch`` (default: the newest open epoch, or 0).

        A new epoch id seals every older epoch; re-ingesting the open
        epoch extends it in place; a sealed epoch id raises.
        """
        records = [np.asarray(r) for r in records]
        cur = self.epochs[-1] if self._snaps else None
        epoch = int(epoch) if epoch is not None else (
            cur if cur is not None else 0)
        if cur is not None and epoch < cur:
            raise ValueError(
                f"epoch {epoch} is sealed (current epoch is {cur}); "
                "windowed ingest is append-only")
        if not records:
            return self
        if epoch == cur:
            self._snaps[cur].index.insert(records)
        else:
            for s in self._snaps.values():
                s.sealed = True
            self._snaps[epoch] = ArenaSnapshot(
                epoch=epoch, index=self._build_epoch(records))
        self._invalidate({epoch})
        return self

    def insert(self, new_records, epoch: int | None = None
               ) -> "WindowManager":
        """:class:`repro.api.ContainmentIndex` spelling of :meth:`ingest`
        (the serving layer's ``/ingest`` lands here)."""
        return self.ingest(new_records, epoch=epoch)

    def retire(self, before: int) -> int:
        """Drop every epoch ``< before``; returns how many were retired.

        Retired snapshots and every cached merged view that contained
        them are released; subsequent queries whose window still names a
        retired epoch simply see the surviving slice (an entirely
        retired window raises).
        """
        gone = [e for e in self.epochs if e < int(before)]
        for e in gone:
            self.retired_records_total += self._snaps[e].num_records
            del self._snaps[e]
        self.retired_epochs_total += len(gone)
        if gone:
            self._invalidate(set(gone))
        return len(gone)

    def _invalidate(self, epochs: set[int]) -> None:
        for key in [k for k in self._cache if epochs.intersection(k)]:
            del self._cache[key]

    # -- per-engine build / merge ------------------------------------------

    def _build_epoch(self, records):
        from repro import api

        cfg = self.build_cfg
        if self.engine == "gbkmv":
            from repro.core import gbkmv as gbkmv_mod

            core = gbkmv_mod.build_gbkmv(
                records, self.budget,
                r=(self._frozen_r if self._frozen_r is not None
                   else cfg.get("r", "auto")),
                seed=cfg.get("seed", 0), capacity=cfg.get("capacity"),
                tau_mode=cfg.get("tau_mode", "exact"),
                build_backend=cfg.get("build_backend"),
                top_elems=self._frozen_top)
            if self._frozen_top is None:
                self._frozen_top = np.asarray(core.top_elems, np.int64)
                self._frozen_r = int(core.buffer_bits)
            return api.GBKMVEngine.wrap(core, budget=self.budget,
                                        backend=self.backend)
        # gkmv/kmv go through Engine.build so the epoch retains its
        # records — their in-epoch insert is the rebuild fallback.
        keys = (("seed", "capacity", "tau_mode", "build_backend")
                if self.engine == "gkmv" else ("seed", "build_backend"))
        kw = {k: cfg[k] for k in keys if k in cfg}
        return api.get_engine(self.engine).build(
            records, self.budget, backend=self.backend, **kw)

    def _merge(self, snaps: list[ArenaSnapshot]):
        from repro import api

        self.merges_total += 1
        seed = int(self.build_cfg.get("seed", 0))
        if self.engine == "gbkmv":
            from repro.core import gbkmv as gbkmv_mod

            core = gbkmv_mod.merge_gbkmv(
                [s.index.core for s in snaps], self.budget,
                capacity=self.build_cfg.get("capacity"))
            return api.GBKMVEngine.wrap(core, budget=self.budget,
                                        backend=self.backend)
        if self.engine == "gkmv":
            from repro.core import gkmv as gkmv_mod

            merged = gkmv_mod.merge_gkmv(
                [s.index.sketches for s in snaps], self.budget,
                capacity=self.build_cfg.get("capacity"))
            return api.GKMVEngine.wrap(merged, seed=seed,
                                       backend=self.backend)
        from repro.core import kmv as kmv_mod

        merged = kmv_mod.merge_kmv([s.index.sketches for s in snaps],
                                   self.budget)
        return api.KMVEngine.wrap(merged, seed=seed, backend=self.backend)

    # -- window resolution -------------------------------------------------

    def _select(self, window) -> list[ArenaSnapshot]:
        eps = self.epochs
        if window is not None:
            lo, hi = int(window[0]), int(window[1])
            eps = [e for e in eps if lo <= e <= hi]
        if not eps:
            raise ValueError(
                f"window {window} selects no live epochs "
                f"(live: {self.epochs or 'none'})")
        return [self._snaps[e] for e in eps]

    def index(self, window=None):
        """The api-level index answering for ``window`` (inclusive epoch
        bounds; default all live epochs). Single-epoch windows return the
        snapshot's own index; multi-epoch windows return the cached
        merged union (built lazily, bit-identical to a one-shot build
        over the window's records)."""
        snaps = self._select(window)
        if len(snaps) == 1:
            return snaps[0].index
        key = tuple(s.epoch for s in snaps)
        idx = self._cache.get(key)
        if idx is None:
            idx = self._cache[key] = self._merge(snaps)
        return idx

    # -- ContainmentIndex protocol (window-parameterized) ------------------

    def query(self, q_ids, threshold: float, *, window=None,
              plan: str = "auto", explain: bool = False):
        """Record ids with estimated containment ≥ ``threshold`` inside
        ``window`` — same planner routing (``plan=``) and ``explain=``
        semantics as the underlying engine's ``query``."""
        idx = self.index(window)
        out = idx.query(q_ids, threshold, plan=plan, explain=explain)
        self.last_plan = idx.last_plan
        return out

    def batch_query(self, queries, threshold: float, *, window=None,
                    plan: str = "auto", explain: bool = False):
        idx = self.index(window)
        out = idx.batch_query(queries, threshold, plan=plan, explain=explain)
        self.last_plan = idx.last_plan
        return out

    def topk(self, q_ids, k: int, *, window=None, plan: str = "auto"):
        """Top-k (ids, scores) inside ``window`` under the deterministic
        (score desc, id asc) order. Ids are window-relative row numbers:
        position within the concatenation of the window's epochs."""
        idx = self.index(window)
        out = idx.topk(q_ids, k, plan=plan)
        self.last_plan = idx.last_plan
        return out

    def scores(self, q_ids, *, window=None) -> np.ndarray:
        return self.index(window).scores(q_ids)

    def nbytes(self) -> int:
        """Live snapshot bytes plus every cached merged view."""
        return (sum(s.nbytes() for s in self._snaps.values())
                + sum(ix.nbytes() for ix in self._cache.values()))

    # -- serving protocol --------------------------------------------------

    def serve_batch(self, queries, thresholds, k: int, plan: str = "auto",
                    explain: bool = False):
        """One sweep answering threshold + top-k for a whole batch over
        every live epoch — the ``AsyncSketchServer`` execution protocol
        (same result shape as ``ShardedIndex.serve_batch``): one dict
        per query with "hits", "topk_ids", "topk_scores" (+ "explain").
        """
        idx = self.index()
        queries = [np.asarray(q) for q in queries]
        n = len(queries)
        thr = np.broadcast_to(np.asarray(thresholds, np.float64), (n,))
        hits: list = [None] * n
        exs: list = [None] * n
        for t in np.unique(thr):
            sel = np.nonzero(thr == t)[0]
            sub = [queries[i] for i in sel]
            if explain:
                h, e = idx.batch_query(sub, float(t), plan=plan,
                                       explain=True)
                for i, j in enumerate(sel):
                    exs[j] = e[i]
            else:
                h = idx.batch_query(sub, float(t), plan=plan)
            for i, j in enumerate(sel):
                hits[j] = h[i]
        self.last_plan = idx.last_plan
        empty = (np.zeros(0, np.int64), np.zeros(0, np.float32))
        tops = [idx.topk(q, k, plan=plan) if k > 0 else empty
                for q in queries]
        out = [{"hits": h, "topk_ids": t[0], "topk_scores": t[1]}
               for h, t in zip(hits, tops)]
        if explain:
            for res, e in zip(out, exs):
                res["explain"] = e
        return out

    # -- observability -----------------------------------------------------

    def window_stats(self) -> dict:
        """Gauge/counter snapshot for the ``/metrics`` exporter."""
        return {
            "epochs": len(self._snaps),
            "records": self.num_records,
            "cached_windows": len(self._cache),
            "merges_total": self.merges_total,
            "retired_epochs_total": self.retired_epochs_total,
            "retired_records_total": self.retired_records_total,
        }

    # -- persistence -------------------------------------------------------

    def save(self, dirpath: str) -> None:
        """Write the snapshot directory **atomically**: build the full
        tree in ``<dir>.tmp``, fsync the manifest, then swap it in with
        ``os.rename`` (the ``ft/checkpoint.py`` pattern). A reader — or
        a crash — never observes a half-written directory, and because
        the tree is rebuilt from scratch, ``epoch_*.npz`` files left
        behind by since-retired epochs cannot survive the swap."""
        tmp = dirpath.rstrip("/\\") + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for e, snap in self._snaps.items():
            snap.index.save(os.path.join(tmp, f"epoch_{e:08d}.npz"))
        cfg = {k: v for k, v in self.build_cfg.items()
               if isinstance(v, (int, float, str, bool, type(None)))}
        manifest = {
            "version": 1, "engine": self.engine, "budget": self.budget,
            "backend": self.backend, "build_cfg": cfg,
            "epochs": self.epochs,
            "retired_epochs_total": self.retired_epochs_total,
            "retired_records_total": self.retired_records_total,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        # Swap: rename can't clobber a non-empty dir, so an existing
        # target steps aside first; its removal only happens after the
        # fresh tree is fully in place.
        old = dirpath.rstrip("/\\") + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        if os.path.exists(dirpath):
            os.rename(dirpath, old)
        os.rename(tmp, dirpath)
        _fsync_dir(os.path.dirname(os.path.abspath(dirpath)))
        if os.path.exists(old):
            shutil.rmtree(old)

    @classmethod
    def load(cls, dirpath: str) -> "WindowManager":
        """Reload a snapshot directory. Sealed epochs stay sealed; the
        newest epoch re-opens for GB-KMV (dynamic inserts need no raw
        records) — gkmv/kmv epochs reload query-only, so continue those
        in fresh epochs."""
        from repro import api

        with open(os.path.join(dirpath, _MANIFEST)) as f:
            manifest = json.load(f)
        wm = cls(engine=manifest["engine"], budget=manifest["budget"],
                 backend=manifest["backend"], **manifest["build_cfg"])
        wm.retired_epochs_total = manifest.get("retired_epochs_total", 0)
        wm.retired_records_total = manifest.get("retired_records_total", 0)
        epochs = manifest["epochs"]
        for e in epochs:
            idx = api.load_index(os.path.join(dirpath, f"epoch_{e:08d}.npz"))
            wm._snaps[e] = ArenaSnapshot(epoch=e, index=idx,
                                         sealed=e != epochs[-1])
        if wm.engine == "gbkmv" and epochs:
            first = wm._snaps[epochs[0]].index.core
            wm._frozen_top = np.asarray(first.top_elems, np.int64)
            wm._frozen_r = int(first.buffer_bits)
        return wm
