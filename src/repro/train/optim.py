"""Pure-JAX AdamW with warmup-cosine schedule.

Optimizer state is a pytree shaped like the params, so it inherits the
params' NamedShardings (TP dims over "model", FSDP dim over "data") — the
moments are fully sharded with zero extra code, which is the ZeRO-3-
equivalent placement (strictly stronger than ZeRO-1's data-axis-only
sharding). ``moment_dtype`` lets memory-tight giants (llama4-maverick
train) drop the moments to bf16 — see DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.clip(prog, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: OptConfig):
    """(grads, state, params) -> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        step_dir = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step_dir + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    # Chain leaves through optimization_barrier: the f32 upcast temps of
    # one leaf are dead before the next leaf starts, so peak optimizer
    # memory is one leaf's working set, not the whole model's (matters at
    # 400B params: each stacked-expert leaf is 2 GB/device in f32).
    out = []
    prev = None
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        if prev is not None:
            # Tie this leaf's inputs to the previous leaf's outputs so XLA
            # cannot overlap their lifetimes.
            p, g, mu, nu, *_ = jax.lax.optimization_barrier(
                (p, g, mu, nu) + prev)
        res = upd(p, g, mu, nu)
        prev = res
        out.append(res)
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_axes(params_axes: Any):
    """Logical axes of the optimizer state (moments mirror the params)."""
    return {"mu": params_axes, "nu": params_axes, "step": ()}
