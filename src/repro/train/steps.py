"""Step builders: jit-able train / eval steps with microbatch accumulation.

``make_train_step(loss_fn, opt_cfg, microbatches=k)`` returns
``step(params, opt_state, batch) -> (params, opt_state, metrics)``:

  * microbatches == 1 — single fused fwd/bwd.
  * microbatches  > 1 — ``lax.scan`` over k microbatches accumulating f32
    grads (keeps the transient activation + logits footprint at 1/k; the
    XLA-inserted DP gradient all-reduce happens once, on the accumulated
    tree, not per microbatch).

Distribution is carried entirely by in/out shardings at the jit boundary
plus the models' internal with_sharding_constraints; the step body itself
is mesh-agnostic.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.train import optim


def _split_micro(batch, k: int):
    def sp(x):
        assert x.shape[0] % k == 0, (x.shape, k)
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(
    loss_fn: Callable,
    opt_cfg: optim.OptConfig,
    *,
    microbatches: int = 1,
    accum_dtype: str = "float32",
    grad_transform: Callable | None = None,
):
    """loss_fn(params, batch) -> (loss, metrics_dict).

    ``accum_dtype="bfloat16"`` halves the gradient-accumulator footprint —
    needed to fit 400B-class training in 16 GB/chip (DESIGN.md §6); at
    ≤8 microbatches the bf16 summation error is ~2⁻⁸ relative, well under
    gradient noise.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dt = jnp.dtype(accum_dtype)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, met), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, microbatches)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(acc_dt), acc, g)
                return acc, (l, m)

            grads, (losses, mets) = lax.scan(body, acc0, micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            met = jax.tree.map(lambda x: x.mean(), mets)

        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, omet = optim.update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **met, **omet}

    return train_step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        loss, met = loss_fn(params, batch)
        return {"loss": loss, **met}
    return eval_step
