import os
import sys

# Tests see the single real CPU device (the 512-device override belongs to
# launch/dryrun.py ONLY — never set it here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
