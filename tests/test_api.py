"""Unified-API tests: registry completeness, cross-engine parity with the
legacy doors, backend agreement, serialization round-trips, inserts, and
the ShardedIndex protocol implementation."""

import os

import numpy as np
import pytest

from repro import api
from repro.core import exact as exact_mod
from repro.core import gbkmv as gbkmv_mod
from repro.core import lshe as lshe_mod
from repro.core.search import run_search
from repro.data.synth import generate_dataset, make_query_workload

ENGINES = ("gbkmv", "gkmv", "kmv", "lshe", "exact", "prefix")


@pytest.fixture(scope="module")
def corpus():
    recs = generate_dataset(m=120, n_elems=4000, alpha_freq=1.1,
                            alpha_size=2.0, seed=0)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, 6, seed=1)
    return recs, total, queries


def test_registry_lists_all_engines():
    assert set(ENGINES) <= set(api.list_engines())


@pytest.mark.parametrize("engine", ENGINES)
def test_every_engine_constructible_and_queryable(corpus, engine):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1))
    assert isinstance(idx, api.ContainmentIndex)
    hits = idx.query(queries[0], 0.5)
    assert hits.ndim == 1
    batched = idx.batch_query(queries[:3], 0.5)
    assert len(batched) == 3
    np.testing.assert_array_equal(batched[0], hits)
    ids, scores = idx.topk(queries[0], 5)
    assert len(ids) == 5 and len(scores) == 5
    assert all(a >= b for a, b in zip(scores, scores[1:]))
    assert idx.nbytes() > 0


@pytest.mark.parametrize("engine,legacy", [
    ("gbkmv", lambda recs, b, q, t: gbkmv_mod.search(
        gbkmv_mod.build_gbkmv(recs, budget=b), q, t)),
    ("lshe", lambda recs, b, q, t: lshe_mod.query_lshe(
        lshe_mod.build_lshe(recs, num_hashes=max(8, b // len(recs))), q, t)),
    ("exact", lambda recs, b, q, t: exact_mod.exact_search(
        exact_mod.build_inverted(recs), q, t)),
    ("prefix", lambda recs, b, q, t: exact_mod.prefix_filter_search(
        exact_mod.build_inverted(recs), q, t)),
])
def test_new_api_matches_legacy_door(corpus, engine, legacy):
    """repro.api results == the pre-registry per-engine entry points."""
    recs, total, queries = corpus
    budget = int(total * 0.1)
    idx = api.get_engine(engine).build(recs, budget)
    for q in queries:
        got = idx.query(q, 0.5)
        want = legacy(recs, budget, q, 0.5)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("engine", ENGINES)
def test_run_search_shim_matches_api(corpus, engine):
    """The legacy run_search front door now covers ALL engines and agrees
    with the api path, including the previously unreachable kmv/gkmv."""
    recs, total, queries = corpus
    budget = int(total * 0.1)
    idx = api.get_engine(engine).build(recs, budget)
    for q in queries[:3]:
        np.testing.assert_array_equal(
            run_search(engine, idx, q, 0.5), idx.query(q, 0.5))


def test_backends_agree_on_gbkmv_scores(corpus):
    recs, total, queries = corpus
    for r in ("auto", 0):          # with and without the bitmap buffer
        idx = api.get_engine("gbkmv").build(recs, int(total * 0.1), r=r)
        for q in queries[:3]:
            ref = None
            for backend in ("numpy", "jnp", "pallas"):
                idx.backend = backend
                s = idx.scores(q)
                if ref is None:
                    ref = s
                else:
                    np.testing.assert_allclose(s, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def _roundtrip_scores(idx, tmp_path, queries, name):
    path = os.path.join(tmp_path, f"{name}.npz")
    idx.save(path)
    idx2 = api.load_index(path)
    assert idx2.engine == idx.engine
    assert idx2.nbytes() == idx.nbytes()
    for q in queries:
        np.testing.assert_array_equal(np.asarray(idx.scores(q)),
                                      np.asarray(idx2.scores(q)))
        np.testing.assert_array_equal(idx.query(q, 0.5), idx2.query(q, 0.5))


@pytest.mark.parametrize("engine", ("gbkmv", "gkmv", "kmv", "lshe"))
def test_save_load_roundtrip_bit_exact(corpus, tmp_path, engine):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1))
    _roundtrip_scores(idx, str(tmp_path), queries[:4], engine)


def test_save_load_roundtrip_r0_and_capacity(corpus, tmp_path):
    """GB-KMV edge cases: r=0 (no buffer words) and capacity truncation
    (per-row effective thresholds below the global τ)."""
    recs, total, queries = corpus
    r0 = api.get_engine("gbkmv").build(recs, int(total * 0.1), r=0)
    assert r0.core.sketches.buf_words == 0
    _roundtrip_scores(r0, str(tmp_path), queries[:3], "gbkmv_r0")

    capped = api.get_engine("gbkmv").build(recs, int(total * 0.2), r=32,
                                           capacity=8)
    thr = np.asarray(capped.core.sketches.thresh)
    assert (thr < np.asarray(capped.core.tau)).any(), "no truncated rows"
    _roundtrip_scores(capped, str(tmp_path), queries[:3], "gbkmv_cap")


def test_exact_engine_save_raises(corpus, tmp_path):
    recs, _, _ = corpus
    idx = api.get_engine("exact").build(recs)
    with pytest.raises(NotImplementedError):
        idx.save(os.path.join(str(tmp_path), "x.npz"))


# ---------------------------------------------------------------------------
# inserts
# ---------------------------------------------------------------------------


def test_insert_after_load_keeps_sketch_intact(corpus, tmp_path):
    """Regression: an index saved with no recorded budget (budget=-1
    sentinel in the npz) must derive the budget from its current size on
    insert — not run dynamic maintenance with budget=-1, which would
    retighten τ to ~1 hash/record and silently destroy the sketch."""
    recs, total, _ = corpus
    idx = api.GBKMVEngine.wrap(          # wrap() records no budget
        api.get_engine("gbkmv").build(recs, int(total * 0.1)).core)
    path = os.path.join(str(tmp_path), "nobudget.npz")
    idx.save(path)
    loaded = api.load_index(path)
    assert loaded.budget is None
    kept_before = int(np.asarray(loaded.core.sketches.lengths).sum())
    loaded.insert(recs[:2])
    kept_after = int(np.asarray(loaded.core.sketches.lengths).sum())
    assert kept_after >= kept_before * 0.9, (kept_before, kept_after)


def test_gbkmv_insert_is_dynamic(corpus):
    """GB-KMV inserts ride sketchindex.dynamic (τ only ever tightens)."""
    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.1))
    tau0 = int(idx.core.tau)
    m0 = idx.num_records
    idx.insert(recs[:10])
    assert idx.num_records == m0 + 10
    assert int(idx.core.tau) <= tau0
    # new rows answer queries
    assert idx.query(recs[0], 0.99).size >= 0


@pytest.mark.parametrize("engine", ("gkmv", "kmv", "lshe", "exact"))
def test_rebuild_insert_fallback(corpus, engine):
    recs, total, _ = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1))
    m0 = idx.num_records
    idx.insert(recs[:5])
    assert idx.num_records == m0 + 5


# ---------------------------------------------------------------------------
# ShardedIndex implements the same protocol
# ---------------------------------------------------------------------------


def test_sharded_index_protocol(corpus):
    import jax

    from repro.sketchindex import ShardedIndex

    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.1))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sharded = ShardedIndex(idx, mesh)
    assert isinstance(sharded, api.ContainmentIndex)
    for q in queries[:3]:
        np.testing.assert_array_equal(sharded.query(q, 0.5),
                                      idx.query(q, 0.5))
    ids, scores = sharded.topk(queries[0], 5)
    host_scores = idx.scores(queries[0])
    np.testing.assert_allclose(scores, np.sort(host_scores)[::-1][:5],
                               rtol=1e-5, atol=1e-5)
    m0 = sharded.num_records
    sharded.insert(recs[:4])
    assert sharded.num_records == m0 + 4
    assert sharded.batch_scores(queries[:2]).shape == (m0 + 4, 2)
