"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward /
train step on CPU, asserting output shapes and no NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.train import optim, steps

LM_ARCHS = ["qwen3-0.6b", "stablelm-12b", "chatglm3-6b",
            "llama4-maverick-400b-a17b", "moonshot-v1-16b-a3b"]
RECSYS_ARCHS = ["din", "fm", "mind", "wide-deep"]


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    cfg = registry.get_module(arch).reduced()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    ocfg = optim.OptConfig(total_steps=10, warmup_steps=2)
    opt = optim.init(params, ocfg)
    step = jax.jit(steps.make_train_step(
        lambda p, b: tfm.loss_fn(p, b, cfg), ocfg, microbatches=2))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}
    p2, o2, met = step(params, opt, batch)
    assert np.isfinite(float(met["loss"]))
    assert _finite(p2), arch
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode(arch):
    """Decode after prefill must reproduce full-forward logits."""
    cfg = registry.get_module(arch).reduced()
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)

    logits_last, caches = tfm.prefill(params, toks[:, :s], cfg)
    assert logits_last.shape == (b, cfg.vocab)
    assert _finite(logits_last)

    # Grow the cache buffers so decode has a slot to write into.
    def grow(c):
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, 4)
        return jnp.pad(c, pad)
    caches = jax.tree.map(grow, caches)

    lengths = jnp.full((b,), s, jnp.int32)
    dec_logits, _, new_len = tfm.decode_step(
        params, caches, toks[:, s:s + 1], lengths, cfg)
    assert dec_logits.shape == (b, cfg.vocab)
    assert _finite(dec_logits)
    assert int(new_len[0]) == s + 1

    # Cross-check: full forward over s+1 tokens; its logits at position s
    # must match the decode-step logits (same params, same prefix).
    full_logits, _, _ = tfm.forward(params, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits[:, s], np.float32), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# GNN: all four shape regimes
# ---------------------------------------------------------------------------

def _gnn_cfg():
    return registry.get_module("graphsage-reddit").reduced()


def test_gnn_full_graph():
    cfg = _gnn_cfg()
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n, e = 30, 80
    batch = {
        "feats": jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32),
        "edges": jnp.asarray(rng.integers(0, n, (e, 2)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, (n,)), jnp.int32),
        "mask": jnp.ones((n,), jnp.float32).at[-3:].set(0.0),
    }
    logits = gnn_mod.forward_full(params, batch["feats"], batch["edges"], cfg)
    assert logits.shape == (n, cfg.n_classes)
    loss, _ = gnn_mod.loss_full(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_gnn_sampled_and_molecule():
    cfg = _gnn_cfg()
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    bn, f1, f2 = 6, 4, 3
    batch = {
        "seed_feats": jnp.asarray(rng.normal(size=(bn, cfg.d_feat)), jnp.float32),
        "h1": jnp.asarray(rng.normal(size=(bn, f1, cfg.d_feat)), jnp.float32),
        "h2": jnp.asarray(rng.normal(size=(bn, f1, f2, cfg.d_feat)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, (bn,)), jnp.int32),
    }
    loss, _ = gnn_mod.loss_sampled(params, batch, cfg)
    assert np.isfinite(float(loss))

    bsz, n = 5, 7
    mol = {
        "feats": jnp.asarray(rng.normal(size=(bsz, n, cfg.d_feat)), jnp.float32),
        "adj": jnp.asarray(rng.integers(0, 2, (bsz, n, n)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, (bsz,)), jnp.int32),
    }
    loss2, _ = gnn_mod.loss_molecule(params, mol, cfg)
    assert np.isfinite(float(loss2))


def test_gnn_train_step():
    cfg = _gnn_cfg()
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg)
    ocfg = optim.OptConfig(total_steps=5)
    opt = optim.init(params, ocfg)
    step = jax.jit(steps.make_train_step(
        lambda p, b: gnn_mod.loss_full(p, b, cfg), ocfg))
    rng = np.random.default_rng(1)
    n, e = 24, 60
    batch = {
        "feats": jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32),
        "edges": jnp.asarray(rng.integers(0, n, (e, 2)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, (n,)), jnp.int32),
        "mask": jnp.ones((n,), jnp.float32),
    }
    p2, _, met = step(params, opt, batch)
    assert np.isfinite(float(met["loss"])) and _finite(p2)


# ---------------------------------------------------------------------------
# RecSys: train + serve + retrieval per arch
# ---------------------------------------------------------------------------

def _recsys_batch(cfg, b, rng):
    if cfg.kind in ("fm", "wide_deep"):
        return {"ids": jnp.asarray(
                    rng.integers(0, cfg.vocab_rows, (b, cfg.n_fields)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 2, (b,)), jnp.float32)}
    return {"hist_ids": jnp.asarray(
                rng.integers(0, cfg.vocab_rows, (b, cfg.seq_len)), jnp.int32),
            "hist_mask": jnp.asarray(rng.integers(0, 2, (b, cfg.seq_len)), bool),
            "target_ids": jnp.asarray(rng.integers(0, cfg.vocab_rows, (b,)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 2, (b,)), jnp.float32)}


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_train_and_serve(arch):
    cfg = registry.get_module(arch).reduced()
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = _recsys_batch(cfg, 16, rng)
    ocfg = optim.OptConfig(total_steps=5)
    opt = optim.init(params, ocfg)
    step = jax.jit(steps.make_train_step(
        lambda p, b: recsys_mod.loss_fn(p, b, cfg), ocfg))
    p2, _, met = step(params, opt, batch)
    assert np.isfinite(float(met["loss"])) and _finite(p2)

    logits = recsys_mod.forward(params, batch, cfg)
    assert logits.shape == (16,) and bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_retrieval_chunk_equivalence(arch):
    """Chunked and single-pass retrieval scoring must agree exactly."""
    cfg = registry.get_module(arch).reduced()
    params = recsys_mod.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    user = _recsys_batch(cfg, 1, rng)
    user.pop("labels")
    if cfg.kind in ("fm", "wide_deep"):
        user["ids"] = user["ids"][:, : cfg.n_fields - 1]
    n = cfg.cand_chunk * 3
    cand = jnp.asarray(rng.integers(0, cfg.vocab_rows, (n,)), jnp.int32)
    s1 = recsys_mod.retrieval_scores(params, user, cand, cfg, chunked=True)
    s2 = recsys_mod.retrieval_scores(params, user, cand, cfg, chunked=False)
    assert s1.shape == (n,)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_all_archs_registered():
    assert len(registry.ARCH_IDS) == 10
    for arch in registry.ARCH_IDS:
        mod = registry.get_module(arch)
        assert hasattr(mod, "config") and hasattr(mod, "reduced")
        assert registry.family(arch) in ("lm", "gnn", "recsys")
