"""Sketch arena: one packed store from engines to planner to serving.

Covers the arena's ownership contract (postings shared across layers,
incremental maintenance through inserts — global and per-shard), the
arena serialization format (round-trips with postings; legacy
postings-less files still load), device residency of the pruned query
path (transfer-guarded), and pruned-vs-dense top-k parity.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import api, planner
from repro.core.arena import SketchArena
from repro.data.synth import generate_dataset, make_query_workload

ENGINES = ("gbkmv", "gkmv", "kmv")
BACKENDS = ("numpy", "jnp", "pallas")


@pytest.fixture(scope="module")
def corpus():
    recs = generate_dataset(m=120, n_elems=3000, alpha_freq=1.0,
                            alpha_size=1.6, seed=10)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, 5, seed=11)
    rng = np.random.default_rng(12)
    queries += [rng.choice(3000, size=s, replace=False) for s in (6, 50)]
    return recs, total, queries


@pytest.fixture(scope="module")
def gb_index(corpus):
    recs, total, _ = corpus
    return api.get_engine("gbkmv").build(recs, int(total * 0.1))


# ---------------------------------------------------------------------------
# arena ownership: every layer views ONE store
# ---------------------------------------------------------------------------


def test_builds_return_arenas(corpus):
    recs, total, _ = corpus
    for engine in ENGINES:
        idx = api.get_engine(engine).build(recs, int(total * 0.1))
        assert isinstance(idx._sketch_pack(), SketchArena)


def test_postings_shared_between_host_and_sharded(gb_index):
    from repro.sketchindex import ShardedIndex

    arena = gb_index._sketch_pack()
    post = gb_index._postings()
    assert arena._post is post                    # owned by the arena
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = ShardedIndex(gb_index, mesh)
    assert sh.host.sketches is arena              # same store, no copy
    posts, offs = sh._shard_postings()
    assert offs[0] == 0
    # Shard slices live on the arena too (served to any future viewer).
    posts2, _ = arena.shard_postings(mesh.devices.size)
    assert posts2 is posts


def test_device_mirrors_cached(gb_index):
    arena = gb_index._sketch_pack()
    assert arena.device_pack() is arena.device_pack()
    assert arena.device_postings() is arena.device_postings()


def test_dataclasses_replace_resets_caches(gb_index):
    arena = gb_index._sketch_pack()
    arena.postings()
    clone = dataclasses.replace(arena)
    assert isinstance(clone, SketchArena) and clone._post is None


# ---------------------------------------------------------------------------
# incremental maintenance: global + per-shard postings across insert
# ---------------------------------------------------------------------------


def test_sharded_insert_maintains_shard_postings(corpus):
    from repro.sketchindex import ShardedIndex

    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.06))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = ShardedIndex(idx, mesh)
    posts_before, offs_before = sh._shard_postings()   # build the cache
    extra = generate_dataset(m=40, n_elems=3000, alpha_freq=1.0,
                             alpha_size=1.6, seed=13)
    sh.insert(extra)
    assert sh.stats.tau_retightens >= 1                # deletion exercised
    arena = sh.host.sketches
    bounds, posts = arena._shard_posts                 # maintained, not None
    assert bounds[-1][1] == arena.num_records
    # Incrementally-maintained slices == fresh rebuilds on the same cuts.
    for (lo, hi), post in zip(bounds, posts):
        fresh = planner.build_postings(arena._column_view(lo, hi))
        assert planner.postings_equal(post, fresh)
    # And the planner still answers identically through them.
    for t in (0.4, 0.8):
        dense = sh.batch_query(queries, t, plan="dense")
        pruned = sh.batch_query(queries, t, plan="pruned")
        for d, p in zip(dense, pruned):
            np.testing.assert_array_equal(d, p)


def test_shard_slices_update_without_retighten(corpus):
    recs, total, _ = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 10))
    arena = idx._sketch_pack()
    arena.shard_postings(3)
    idx.insert([np.asarray([1, 2, 3]), np.asarray([7, 8])])
    assert idx.stats.tau_retightens == 0
    arena = idx._sketch_pack()                         # post-insert arena
    bounds, posts = arena._shard_posts
    for (lo, hi), post in zip(bounds, posts):
        fresh = planner.build_postings(arena._column_view(lo, hi))
        assert planner.postings_equal(post, fresh)


# ---------------------------------------------------------------------------
# serialization: arena round-trip + legacy compatibility
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_arena_save_load_roundtrip_with_postings(corpus, tmp_path, engine):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1))
    idx.batch_query(queries, 0.6, plan="pruned")       # builds postings
    path = str(tmp_path / f"{engine}.npz")
    idx.save(path)
    loaded = api.load_index(path)
    # Postings travel with the arena: no rebuild on first pruned query.
    assert loaded._post is not None
    assert planner.postings_equal(loaded._post, idx._post)
    for t in (0.4, 0.8):
        for a, b in zip(idx.batch_query(queries, t),
                        loaded.batch_query(queries, t)):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("backend", BACKENDS)
def test_arena_roundtrip_across_backends(corpus, tmp_path, backend):
    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                        backend=backend)
    idx.batch_query(queries, 0.6, plan="pruned")
    path = str(tmp_path / f"gb_{backend}.npz")
    idx.save(path)
    loaded = api.load_index(path)
    assert loaded.backend == backend
    for t in (0.5, 0.9):
        dense = loaded.batch_query(queries, t, plan="dense")
        pruned = loaded.batch_query(queries, t, plan="pruned")
        want = idx.batch_query(queries, t, plan="dense")
        for d, p, w in zip(dense, pruned, want):
            np.testing.assert_array_equal(d, p)
            np.testing.assert_array_equal(d, w)


def test_v2_flat_postings_npz_still_loads(corpus, tmp_path, gb_index):
    """Files written by the v2 (flat-CSR postings) format re-encode into
    blocks on load and answer identically — with the same blocked
    structure a fresh rebuild produces."""
    recs, total, queries = corpus
    gb_index.batch_query(queries, 0.6, plan="pruned")   # build postings
    core = gb_index.core
    s = core.sketches
    post = gb_index._post
    path = str(tmp_path / "v2_flat.npz")
    np.savez_compressed(                    # the exact v2 field set
        path, engine="gbkmv", tau=np.uint32(core.tau),
        top_elems=np.asarray(core.top_elems, np.int64),
        seed=np.int64(core.seed), buffer_bits=np.int64(core.buffer_bits),
        budget=np.int64(-1), arena_version=np.int64(2),
        values=np.asarray(s.values), lengths=np.asarray(s.lengths),
        thresh=np.asarray(s.thresh), buf=np.asarray(s.buf),
        sizes=np.asarray(s.sizes),
        post_keys=post.keys, post_offsets=post.offsets,
        post_rec_ids=post.rec_ids, post_buf_offsets=post.buf_offsets,
        post_buf_rec_ids=post.buf_rec_ids, post_tau=np.uint32(post.tau))
    loaded = api.load_index(path)
    assert loaded._post is not None         # postings traveled, re-encoded
    assert planner.postings_equal(loaded._post, post)
    for t in (0.4, 0.8):
        for a, b in zip(gb_index.batch_query(queries, t),
                        loaded.batch_query(queries, t, plan="pruned")):
            np.testing.assert_array_equal(a, b)


def test_legacy_packed_npz_still_loads(corpus, tmp_path, gb_index):
    """Files written by the v1 (postings-less) format keep loading."""
    recs, total, queries = corpus
    path = str(tmp_path / "legacy.npz")
    core = gb_index.core
    s = core.sketches
    np.savez_compressed(                    # the exact pre-arena field set
        path, engine="gbkmv", tau=np.uint32(core.tau),
        top_elems=np.asarray(core.top_elems, np.int64),
        seed=np.int64(core.seed), buffer_bits=np.int64(core.buffer_bits),
        budget=np.int64(-1),
        values=np.asarray(s.values), lengths=np.asarray(s.lengths),
        thresh=np.asarray(s.thresh), buf=np.asarray(s.buf),
        sizes=np.asarray(s.sizes))
    loaded = api.load_index(path)
    assert isinstance(loaded._sketch_pack(), SketchArena)
    assert loaded._post is None             # postings lazy, not persisted
    for a, b in zip(gb_index.batch_query(queries, 0.6),
                    loaded.batch_query(queries, 0.6)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# device residency: candidate-gen → score → packed threshold, no transfers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_pruned_path_device_resident(corpus, backend):
    """The acceptance contract: between staging and the packed result
    there is NO host transfer — probe, block decode, scoring, the packed
    threshold words, AND top-k all run under jax's transfer guard. No
    host header probe feeds the device program: the guard starts right
    after staging."""
    from repro.planner import device as planner_device

    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                        backend=backend)
    t = 0.7
    want = idx.batch_query(queries, t, plan="pruned")  # warmup: compile
    idx.topk(queries[0], 8, plan="pruned")             # warmup: topk jit
    wt_ids, wt_s = idx.topk(queries[0], 8, plan="dense")
    arena = idx._sketch_pack()
    m = arena.num_records
    qp, _, _, _ = idx._plan_queries(queries)
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp, t)
    with jax.transfer_guard("disallow"):
        words = planner_device.fused_mask_words(
            dpost, dpack, sq, m=m, backend=backend)
        assert not isinstance(words, np.ndarray)       # still on device
    mask = planner_device.unpack_hit_words(words, m)[:, : qp.num_records]
    got = planner.prune.mask_to_hits(mask)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # top-k: same residency contract on the fused top-k head (fresh
    # staging — the previous call donated the query blob).
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp)
    with jax.transfer_guard("disallow"):
        vals, ids = planner_device.fused_topk_scores(
            dpost, dpack, sq, k=8, m=m, backend=backend)
        assert not isinstance(vals, np.ndarray)
    np.testing.assert_array_equal(np.asarray(ids)[0], wt_ids)
    np.testing.assert_allclose(np.asarray(vals)[0], wt_s, rtol=1e-6)


def test_device_route_is_taken(corpus):
    """batch_query with a device backend actually uses the device path:
    host candidate accounting stays None (nothing was materialized on
    host); the probe breakdown lives on the plan instead."""
    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                        backend="jnp")
    idx.batch_query(queries, 0.7, plan="pruned")
    assert idx.last_candidate_sizes is None
    per = idx.last_plan.per_query_hits
    assert per is not None and len(per) == len(queries)
    assert int(per.sum()) == idx.last_plan.hits
    # The numpy backend takes the host path and does account candidates.
    idx_np = api.get_engine("gbkmv").build(recs, int(total * 0.1),
                                           backend="numpy")
    idx_np.batch_query(queries, 0.7, plan="pruned")
    assert idx_np.last_candidate_sizes is not None
    assert len(idx_np.last_candidate_sizes) == len(queries)


# ---------------------------------------------------------------------------
# planner-aware top-k: pruned == dense, engines × backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_pruned_topk_matches_dense(corpus, engine, backend):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1),
                                       backend=backend)
    for k in (1, 5, 37, 2 * len(recs)):
        for q in queries[:4]:
            di, ds = idx.topk(q, k, plan="dense")
            pi, ps = idx.topk(q, k, plan="pruned")
            ai, as_ = idx.topk(q, k)                    # auto
            np.testing.assert_array_equal(di, pi)
            np.testing.assert_array_equal(ds, ps)
            np.testing.assert_array_equal(di, ai)
            np.testing.assert_array_equal(ds, as_)


def test_pruned_topk_small_chunks_early_stop(gb_index, corpus):
    """Chunked scoring with the running k-th threshold stops early yet
    stays exact (tiny chunks force multiple rounds + the cutoff)."""
    _, _, queries = corpus
    q = queries[0]
    qp, hash_rows, bit_rows, sizes = gb_index._plan_queries([np.asarray(q)])
    for k in (1, 3, 10):
        want = gb_index.topk(q, k, plan="dense")
        got = planner.pruned_topk(
            gb_index._postings(), hash_rows[0], bit_rows[0], int(sizes[0]),
            k, gb_index._pair_score_fn(qp), gb_index.num_records, chunk=4)
        np.testing.assert_array_equal(want[0], got[0])
        np.testing.assert_array_equal(want[1], got[1])


def test_topk_deterministic_tie_break():
    """Equal scores rank by ascending record id on every path."""
    recs = [np.asarray([1, 2, 3, 4]) for _ in range(12)]   # identical sets
    idx = api.get_engine("gbkmv").build(recs, budget=200)
    q = np.asarray([1, 2, 3, 4])
    for k in (3, 7):
        di, ds = idx.topk(q, k, plan="dense")
        pi, ps = idx.topk(q, k, plan="pruned")
        np.testing.assert_array_equal(di, np.arange(k))
        np.testing.assert_array_equal(di, pi)
        np.testing.assert_array_equal(ds, ps)


def test_pruned_topk_after_insert(corpus):
    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.06))
    idx._postings()
    extra = generate_dataset(m=30, n_elems=3000, alpha_freq=1.0,
                             alpha_size=1.6, seed=14)
    idx.insert(extra)
    for q in queries[:3]:
        di, ds = idx.topk(q, 8, plan="dense")
        pi, ps = idx.topk(q, 8, plan="pruned")
        np.testing.assert_array_equal(di, pi)
        np.testing.assert_array_equal(ds, ps)


def test_sharded_pruned_topk_matches_dense(gb_index, corpus):
    from repro.sketchindex import ShardedIndex

    _, _, queries = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = ShardedIndex(gb_index, mesh)
    for q in queries[:3]:
        di, ds = sh.topk(q, 6, plan="dense")
        pi, ps = sh.topk(q, 6, plan="pruned")
        np.testing.assert_array_equal(di, pi)
        np.testing.assert_allclose(ds, ps, rtol=1e-6)


def test_server_pruned_topk_flush(gb_index, corpus):
    """topk>0 flushes honor plan="pruned" (carve-out removed) and match
    the dense server bit for bit."""
    from repro.serving.batcher import SketchServer

    _, _, queries = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = {}
    for plan in ("pruned", "dense"):
        srv = SketchServer(gb_index, mesh, topk=5, plan=plan, max_batch=3)
        rids = [srv.submit(q, 0.5) for q in queries[:3]]
        srv.flush()
        out[plan] = [srv.results[r] for r in rids]
    for a, b in zip(out["pruned"], out["dense"]):
        np.testing.assert_array_equal(a["hits"], b["hits"])
        np.testing.assert_array_equal(a["topk_ids"], b["topk_ids"])
        np.testing.assert_allclose(a["topk_scores"], b["topk_scores"],
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# cost-model calibration
# ---------------------------------------------------------------------------


def test_calibration_fit_and_plan_usage(tmp_path):
    import json

    from repro.core import cost_model

    # Synthesize rows from known constants; the fit must recover the
    # pruned/dense cost *ratio* (that is all the planner consumes).
    m, cap = 5000, 64
    a = 2e-9                                  # seconds per dense slot
    fixed_s, per_hit_s = 3e-4, 5e-7
    rows = []
    for t, hits in ((0.5, 900.0), (0.7, 400.0), (0.9, 80.0)):
        rows.append({
            "threshold": t,
            "qps_dense": 1.0 / (a * m * cap),
            "qps_pruned": 1.0 / (fixed_s + per_hit_s * hits),
            "mean_probe_hits": hits,
        })
    cal = cost_model.fit_query_constants(rows, m, cap)
    assert cal["dense_cost_per_slot"] == 1.0
    np.testing.assert_allclose(cal["prune_fixed_per_query"], fixed_s / a,
                               rtol=1e-6)
    g_units = (cal["prune_cost_per_hit"]
               + cal["prune_cost_per_cand_slot"] * cap)
    np.testing.assert_allclose(g_units, per_hit_s / a, rtol=1e-6)

    # Round-trip through the artifact format and drive choose_plan.
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        json.dump({"rows": rows, "calibration": cal}, f)
    try:
        cost_model.load_calibration(path)
        assert cost_model.calibration() is not None
        # Fitted units make the model exact: equal costs at the
        # break-even hit count, dense cheaper above it.
        dense_units = cost_model.dense_sweep_cost(m, cap, 1)
        hits_even = (dense_units - cal["prune_fixed_per_query"]) / g_units
        assert cost_model.pruned_path_cost(int(hits_even * 0.5), cap, 1) \
            < dense_units
        assert cost_model.pruned_path_cost(int(hits_even * 2.0), cap, 1) \
            > dense_units
    finally:
        cost_model.set_calibration(None)


def test_calibration_degenerate_hit_spread_keeps_default_fixed():
    """Constant probe hits across rows (the threshold sweep alone) make
    the fixed/per-hit split unidentifiable — the fit must fall back to
    the default fixed cost instead of a minimum-norm artifact."""
    from repro.core import cost_model

    m, cap = 5000, 64
    a = 2e-9
    rows = [{"qps_dense": 1.0 / (a * m * cap), "qps_pruned": 500.0,
             "mean_probe_hits": 1200.0} for _ in range(3)]
    cal = cost_model.fit_query_constants(rows, m, cap)
    np.testing.assert_allclose(cal["prune_fixed_per_query"],
                               cost_model.PRUNE_FIXED_PER_QUERY)
    assert cal["prune_cost_per_hit"] > 0


def test_calibration_validates_keys():
    from repro.core import cost_model

    with pytest.raises(ValueError):
        cost_model.set_calibration({"dense_cost_per_slot": 1.0})
    assert cost_model.calibration() is None
