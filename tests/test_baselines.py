import numpy as np

from repro.core import exact, lshe, minhash, search
from repro.data.synth import generate_dataset, make_query_workload


def _data(seed=0, m=200):
    return generate_dataset(m=m, n_elems=5000, alpha_freq=1.1, alpha_size=2.5,
                            size_min=20, size_max=400, seed=seed)


def test_exact_vs_prefix_agree():
    records = _data(1)
    idx = exact.build_inverted(records)
    for q in make_query_workload(records, 10, seed=3):
        for t in (0.3, 0.5, 0.8):
            a = exact.exact_search(idx, q, t)
            b = exact.prefix_filter_search(idx, q, t)
            np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_exact_self_hit():
    records = _data(2)
    idx = exact.build_inverted(records)
    hits = exact.exact_search(idx, records[3], 1.0)
    assert 3 in hits


def test_minhash_jaccard_estimate():
    rng = np.random.default_rng(0)
    a = rng.choice(10_000, size=400, replace=False)
    b = np.concatenate([a[:200], rng.choice(np.arange(10_000, 20_000), 200, False)])
    sigs = minhash.build_signatures([a, b], num_hashes=512)
    s = minhash.jaccard_estimate(sigs[0], sigs[1:])[0]
    true_j = 200 / 600
    assert abs(s - true_j) < 0.08  # ~3σ of s(1-s)/k


def test_lshe_query_recall_bias():
    # LSH-E is recall-heavy (paper §III-B): on a self-query workload it
    # should retrieve the query record itself nearly always.
    records = _data(3, m=150)
    idx = lshe.build_lshe(records, num_hashes=128, num_partitions=8, seed=0)
    found_self = 0
    queries = list(range(0, 150, 10))
    for qi in queries:
        cands = lshe.query_lshe(idx, records[qi], threshold=0.5, seed=0)
        found_self += int(qi in cands)
    assert found_self >= int(0.9 * len(queries))


def test_lshe_vs_exact_eval_runs():
    records = _data(4, m=120)
    einv = exact.build_inverted(records)
    idx = lshe.build_lshe(records, num_hashes=64, num_partitions=4, seed=0)
    res = search.evaluate_engine("lshe", idx, einv,
                                 make_query_workload(records, 6, seed=5), 0.5)
    assert 0.0 <= res["f"] <= 1.0
    assert res["recall"] >= res["precision"] * 0.5  # recall-leaning
