"""Vectorized construction pipeline vs the seed-era per-record oracles.

The contract of the fast build path is BIT-IDENTITY: same values /
lengths / thresh / buf / sizes, same postings blocks, same query
results — across the three sketch engines, the host and device
(numpy / jnp / pallas) construction paths, and the degenerate shapes
(empty records, capacity overflow, r=0). τ-selection gets its own
checks: exact mode is bit-equal to the oracle's partition; histogram
mode lands on the documented 2^8-wide bin bound.
"""

import numpy as np
import pytest

from repro import api
from repro.core import gbkmv, gkmv, kmv, lshe, minhash
from repro.core.gkmv import select_global_threshold, select_tau_flat
from repro.core.hashing import (PAD, minhash_signature_np,
                                minhash_signature_oracle)
from repro.core.sketches import (RaggedBatch, make_bitmaps,
                                 make_bitmaps_oracle, pack_csr, pack_rows)
from repro.data.synth import generate_dataset
from repro.planner.postings import build_postings, postings_equal

BUILD_BACKENDS = ("numpy", "jnp", "pallas")


def _dataset(seed=11, m=60):
    return generate_dataset(m, 900, alpha_freq=0.9, alpha_size=1.0,
                            size_min=4, size_max=40, seed=seed)


def assert_packs_equal(fast, oracle):
    for field in ("values", "lengths", "thresh", "buf", "sizes"):
        a = np.asarray(getattr(fast, field))
        b = np.asarray(getattr(oracle, field))
        assert a.shape == b.shape, (field, a.shape, b.shape)
        assert np.array_equal(a, b), field
        assert a.dtype == b.dtype, field


# ---------------------------------------------------------------------------
# Bit-identity: 3 engines × build backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BUILD_BACKENDS)
def test_gbkmv_fast_matches_oracle(backend):
    recs = _dataset()
    budget = int(sum(len(r) for r in recs) * 0.2)
    bb = None if backend == "numpy" else backend
    fast = gbkmv.build_gbkmv(recs, budget, r="auto", seed=5, build_backend=bb)
    oracle = gbkmv.build_gbkmv_oracle(recs, budget, r="auto", seed=5)
    assert int(fast.tau) == int(oracle.tau)
    assert fast.buffer_bits == oracle.buffer_bits
    assert np.array_equal(fast.top_elems, oracle.top_elems)
    assert_packs_equal(fast.sketches, oracle.sketches)


@pytest.mark.parametrize("backend", BUILD_BACKENDS)
def test_gkmv_fast_matches_oracle(backend):
    recs = _dataset(seed=12)
    budget = int(sum(len(r) for r in recs) * 0.15)
    bb = None if backend == "numpy" else backend
    fast = gkmv.build_gkmv(recs, budget, seed=2, build_backend=bb)
    oracle = gkmv.build_gkmv_oracle(recs, budget, seed=2)
    assert_packs_equal(fast, oracle)


@pytest.mark.parametrize("backend", BUILD_BACKENDS)
def test_kmv_fast_matches_oracle(backend):
    recs = _dataset(seed=13)
    budget = int(sum(len(r) for r in recs) * 0.15)
    bb = None if backend == "numpy" else backend
    fast = kmv.build_kmv(recs, budget, seed=1, build_backend=bb)
    oracle = kmv.build_kmv_oracle(recs, budget, seed=1)
    assert_packs_equal(fast, oracle)


@pytest.mark.parametrize("backend", ("numpy", "jnp"))
def test_postings_blocks_identical_after_fast_build(backend):
    """The blocked postings encode from the packed columns — fast and
    oracle builds must produce block-for-block equal stores."""
    recs = _dataset(seed=14)
    budget = int(sum(len(r) for r in recs) * 0.2)
    bb = None if backend == "numpy" else backend
    fast = gbkmv.build_gbkmv(recs, budget, r=32, seed=4, build_backend=bb)
    oracle = gbkmv.build_gbkmv_oracle(recs, budget, r=32, seed=4)
    assert postings_equal(build_postings(fast.sketches),
                          build_postings(oracle.sketches))


# ---------------------------------------------------------------------------
# Degenerate shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BUILD_BACKENDS)
def test_empty_and_degenerate_records(backend):
    recs = [np.zeros(0, np.int64), np.asarray([7, 9, 123]),
            np.zeros(0, np.int64), np.asarray([5]), np.asarray([7])]
    bb = None if backend == "numpy" else backend
    for budget in (3, 1000):
        fast = gbkmv.build_gbkmv(recs, budget, r=8, seed=0, build_backend=bb)
        oracle = gbkmv.build_gbkmv_oracle(recs, budget, r=8, seed=0)
        assert_packs_equal(fast.sketches, oracle.sketches)
        assert int(fast.tau) == int(oracle.tau)
        f2 = gkmv.build_gkmv(recs, budget, seed=0, build_backend=bb)
        o2 = gkmv.build_gkmv_oracle(recs, budget, seed=0)
        assert_packs_equal(f2, o2)
        f3 = kmv.build_kmv(recs, budget, seed=0, build_backend=bb)
        o3 = kmv.build_kmv_oracle(recs, budget, seed=0)
        assert_packs_equal(f3, o3)


def test_all_records_empty():
    recs = [np.zeros(0, np.int64)] * 4
    fast = gbkmv.build_gbkmv(recs, 16, r=0, seed=0)
    oracle = gbkmv.build_gbkmv_oracle(recs, 16, r=0, seed=0)
    assert_packs_equal(fast.sketches, oracle.sketches)
    assert_packs_equal(gkmv.build_gkmv(recs, 16),
                       gkmv.build_gkmv_oracle(recs, 16))


def test_zero_buffer_bits():
    recs = _dataset(seed=15, m=20)
    budget = int(sum(len(r) for r in recs) * 0.3)
    fast = gbkmv.build_gbkmv(recs, budget, r=0, seed=3)
    oracle = gbkmv.build_gbkmv_oracle(recs, budget, r=0, seed=3)
    assert fast.sketches.buf.shape == oracle.sketches.buf.shape
    assert_packs_equal(fast.sketches, oracle.sketches)


@pytest.mark.parametrize("backend", BUILD_BACKENDS)
def test_capacity_overflow_rows(backend):
    """Rows longer than the capacity truncate to their smallest values
    and lower their effective threshold — identically on every path."""
    recs = _dataset(seed=16, m=30)
    budget = 10**9            # τ = PAD-1: every hash kept → rows overflow
    bb = None if backend == "numpy" else backend
    fast = gkmv.build_gkmv(recs, budget, seed=7, capacity=5, build_backend=bb)
    oracle = gkmv.build_gkmv_oracle(recs, budget, seed=7, capacity=5)
    assert (np.asarray(oracle.thresh) != np.uint32(PAD - np.uint32(1))).any()
    assert_packs_equal(fast, oracle)
    f2 = gbkmv.build_gbkmv(recs, budget, r=16, seed=7, capacity=5,
                           build_backend=bb)
    o2 = gbkmv.build_gbkmv_oracle(recs, budget, r=16, seed=7, capacity=5)
    assert_packs_equal(f2.sketches, o2.sketches)


def test_pack_csr_matches_pack_rows():
    rng = np.random.default_rng(0)
    rows = [np.sort(rng.integers(0, 2**32, size=n).astype(np.uint32))
            for n in (0, 3, 17, 1, 0, 8)]
    thr = np.full(len(rows), PAD - np.uint32(1), np.uint32)
    sizes = np.asarray([len(r) for r in rows], np.int32)
    flat = np.concatenate(rows).astype(np.uint32)
    row_ids = np.repeat(np.arange(len(rows)), [len(r) for r in rows])
    for cap in (None, 4):
        a = pack_csr(flat, row_ids, len(rows), thr, sizes, capacity=cap)
        b = pack_rows(rows, thr, sizes, capacity=cap)
        assert_packs_equal(a, b)


def test_make_bitmaps_matches_oracle():
    recs = _dataset(seed=17, m=25)
    top = np.unique(np.concatenate(recs))[:40][::-1]     # arbitrary order
    assert np.array_equal(make_bitmaps(recs, top),
                          make_bitmaps_oracle(recs, top))
    assert np.array_equal(make_bitmaps(RaggedBatch.from_records(recs), top),
                          make_bitmaps_oracle(recs, top))


# ---------------------------------------------------------------------------
# τ-selection: exact bit-equality + the documented histogram bound
# ---------------------------------------------------------------------------


def test_tau_exact_matches_oracle_selector():
    rng = np.random.default_rng(4)
    rows = [rng.integers(0, 2**32, size=n).astype(np.uint32)
            for n in (5, 0, 40, 13)]
    flat = np.concatenate([r for r in rows if len(r)])
    for budget in (1, 7, 30, 57, 58, 1000):
        assert select_tau_flat(flat, budget) == \
            select_global_threshold(rows, budget)


def test_tau_histogram_within_documented_bound():
    """τ_hist is the upper bound of the 2^8-wide bin holding the exact
    τ: τ_hist == (τ_exact | 0xFF) whenever the budget binds."""
    rng = np.random.default_rng(9)
    flat = rng.integers(0, 2**32, size=5000).astype(np.uint32)
    for budget in (1, 10, 499, 4999):
        te = int(select_tau_flat(flat, budget))
        th = int(select_tau_flat(flat, budget, tau_mode="histogram"))
        assert th == (te | 0xFF)
        assert te <= th <= te + 255
    # Budget beyond the data: both keep everything.
    assert select_tau_flat(flat, 10**9, tau_mode="histogram") == \
        np.uint32(PAD - np.uint32(1))


def test_tau_mode_rejects_unknown():
    with pytest.raises(ValueError):
        select_tau_flat(np.zeros(4, np.uint32), 2, tau_mode="approx")


def test_postings_arg_rejected_before_building():
    recs = [np.asarray([1, 2, 3])]
    for engine in ("gbkmv", "gkmv", "kmv"):
        with pytest.raises(ValueError, match="postings"):
            api.get_engine(engine).build(recs, 8, postings="eagre")


def test_query_buffer_wider_than_index_raises():
    recs = _dataset(seed=23, m=20)
    budget = int(sum(len(r) for r in recs) * 0.3)
    idx = gbkmv.build_gbkmv(recs, budget, r=48, seed=1)
    # Corrupt the invariant: more top elements than the packed width.
    idx.top_elems = np.unique(np.concatenate(recs))[:40]
    idx.sketches.buf = np.asarray(idx.sketches.buf)[:, :1]
    with pytest.raises(ValueError, match="buffer"):
        gbkmv.sketch_query(idx, recs[0])


# ---------------------------------------------------------------------------
# Query sketching + end-to-end pruned-path identity
# ---------------------------------------------------------------------------


def test_sketch_query_batch_matches_oracle():
    recs = _dataset(seed=18)
    budget = int(sum(len(r) for r in recs) * 0.2)
    idx = gbkmv.build_gbkmv(recs, budget, r=32, seed=1)
    queries = [recs[0], np.zeros(0, np.int64), recs[7][:3], recs[11]]
    qb = gbkmv.sketch_query_batch(idx, queries)
    assert qb.num_records == len(queries)
    for g, q in enumerate(queries):
        qo = gkmv.sketch_query_oracle(
            np.asarray(q), idx.tau, seed=idx.seed,
            capacity=idx.sketches.capacity, top_elems=idx.top_elems)
        assert np.array_equal(np.asarray(qb.values)[g],
                              np.asarray(qo.values)[0])
        assert int(qb.lengths[g]) == int(qo.lengths[0])
        assert int(qb.thresh[g]) == int(qo.thresh[0])
        assert int(qb.sizes[g]) == int(qo.sizes[0])
        w = min(qb.buf.shape[1], qo.buf.shape[1])
        assert np.array_equal(np.asarray(qb.buf)[g, :w],
                              np.asarray(qo.buf)[0, :w])


@pytest.mark.parametrize("engine", ("gbkmv", "gkmv", "kmv"))
def test_pruned_batch_query_identical_to_oracle_built_index(engine):
    """build → batch_query(plan="pruned") returns bit-identical hits
    whether the index came from the vectorized or the per-record path."""
    recs = _dataset(seed=19)
    budget = int(sum(len(r) for r in recs) * 0.2)
    fast = api.get_engine(engine).build(recs, budget, seed=2,
                                        backend="numpy")
    if engine == "gbkmv":
        core = gbkmv.build_gbkmv_oracle(recs, budget, r="auto", seed=2)
        oracle = api.get_engine(engine).wrap(core, budget=budget,
                                             backend="numpy")
    elif engine == "gkmv":
        oracle = api.get_engine(engine).wrap(
            gkmv.build_gkmv_oracle(recs, budget, seed=2), seed=2,
            backend="numpy")
    else:
        oracle = api.get_engine(engine).wrap(
            kmv.build_kmv_oracle(recs, budget, seed=2), seed=2,
            backend="numpy")
    queries = [recs[3], recs[9], recs[20][:5]]
    for t in (0.3, 0.7):
        a = fast.batch_query(queries, t, plan="pruned")
        b = oracle.batch_query(queries, t, plan="pruned")
        for x, y in zip(a, b):
            assert np.array_equal(x, y)


def test_device_built_index_queries_and_saves(tmp_path):
    """Device-resident columns flow through postings, pruned queries and
    the npz round-trip unchanged."""
    recs = _dataset(seed=20, m=40)
    budget = int(sum(len(r) for r in recs) * 0.2)
    idx = api.get_engine("gbkmv").build(recs, budget, seed=1,
                                        build_backend="jnp",
                                        postings="eager")
    ref = api.get_engine("gbkmv").build(recs, budget, seed=1)
    q = [recs[2], recs[5]]
    for t in (0.4, 0.8):
        for a, b in zip(idx.batch_query(q, t, plan="pruned"),
                        ref.batch_query(q, t, plan="pruned")):
            assert np.array_equal(a, b)
    path = str(tmp_path / "dev.npz")
    idx.save(path)
    loaded = api.load_index(path)
    assert_packs_equal(loaded.core.sketches, ref.core.sketches)


# ---------------------------------------------------------------------------
# MinHash / LSH-E vectorization
# ---------------------------------------------------------------------------


def test_minhash_signature_batched_matches_oracle():
    rng = np.random.default_rng(2)
    for n in (0, 1, 37):
        ids = rng.integers(0, 10**6, size=n)
        assert np.array_equal(minhash_signature_np(ids, 19, seed=3),
                              minhash_signature_oracle(ids, 19, seed=3))


def test_build_signatures_vectorized_matches_oracle():
    recs = _dataset(seed=21, m=30)
    recs[4] = np.zeros(0, np.int64)            # empty row mid-batch
    recs[-1] = np.zeros(0, np.int64)           # trailing empty row
    k = 70                                     # > chunk: exercises chunking
    assert np.array_equal(minhash.build_signatures(recs, k, seed=5),
                          minhash.build_signatures_oracle(recs, k, seed=5))


def test_lshe_build_uses_vectorized_signatures():
    recs = _dataset(seed=22, m=30)
    ens = lshe.build_lshe(recs, num_hashes=32, seed=1)
    assert np.array_equal(
        ens.signatures, minhash.build_signatures_oracle(recs, 32, seed=1))
    # Query path is unchanged semantically.
    hits = lshe.query_lshe(ens, recs[3], 0.5, seed=1)
    assert 3 in hits


# ---------------------------------------------------------------------------
# Hypothesis property: τ-selection
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci_build", max_examples=30, deadline=None)
    settings.load_profile("ci_build")

    @given(hashes=st.lists(st.integers(0, 2**32 - 1), min_size=1,
                           max_size=200),
           budget=st.integers(1, 250))
    def test_tau_property(hashes, budget):
        flat = np.asarray(hashes, np.uint32)
        te = int(select_tau_flat(flat, budget))
        th = int(select_tau_flat(flat, budget, tau_mode="histogram"))
        if budget >= len(flat):
            assert te == th == int(PAD - np.uint32(1))
            return
        # Exact mode: bit-equal to the sorted-order statistic...
        assert te == int(np.sort(flat)[budget - 1])
        # ...and the per-row oracle selector.
        assert te == int(select_global_threshold([flat], budget))
        # Histogram mode: the documented 2^8 bin bound, never below exact.
        assert th == (te | 0xFF) and te <= th <= te + 255
except ImportError:                             # pragma: no cover
    pass
