"""Launch-layer and data-pipeline tests: cell construction for all 40
(arch × shape) pairs on a host mesh, shape-aware sharding fallback,
pipeline determinism, neighbor sampler, HLO parser units.
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import FAMILY_SHAPES
from repro.data.pipeline import BatchCursor, dedup_corpus, shingle, token_batches
from repro.data.sampler import CSRGraph, sample_batch
from repro.launch.cells import all_cells, build_cell
from repro.parallel.sharding import spec_for_shape


def test_all_cells_enumerate_40():
    cells = all_cells()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10


def test_build_every_cell_host_mesh():
    """Cell construction (fn, abstract args, shardings) for all 40 pairs.

    Construction must not allocate any full-config tensors — only
    ShapeDtypeStructs — so it runs instantly on the 1-CPU host mesh.
    """
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch, shape_id in all_cells():
        cell = build_cell(arch, shape_id, mesh)
        n_args = len(jax.tree.leaves(cell.args))
        n_sh = len(jax.tree.leaves(cell.in_shardings,
                                   is_leaf=lambda x: hasattr(x, "spec")))
        assert n_args == n_sh, (arch, shape_id)
        for leaf in jax.tree.leaves(cell.args):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape_id)


def test_spec_for_shape_divisibility_fallback():
    # AbstractMesh: spec resolution needs only shape/axis names, so the
    # 1-CPU container can reason about a 2×2 mesh.
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((2, 2), ("data", "model"))
    # 8 % 2 == 0 → sharded; 7 % 2 != 0 → dropped.
    # (older PartitionSpec does not normalize ("data",) to "data" — compare
    # against the single-axis spelling, which every version accepts)
    assert spec_for_shape((8, 7), ("batch", "heads"), mesh) == P("data", None)
    # multi-axis entries degrade from the right.
    assert spec_for_shape((2,), ("records",), mesh) == P("data")
    assert spec_for_shape((4,), ("records",), mesh) == P(("data", "model"))


def test_token_batches_deterministic_resume():
    docs = [np.arange(100) + i for i in range(5)]
    c1 = BatchCursor(seed=7)
    s1 = token_batches(docs, 4, 16, c1)
    first = [next(s1) for _ in range(5)]
    # resume from step 3
    c2 = BatchCursor(seed=7, step=3)
    s2 = token_batches(docs, 4, 16, c2)
    resumed = next(s2)
    np.testing.assert_array_equal(first[3]["tokens"], resumed["tokens"])


def test_dedup_drops_planted_superset():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 5000, size=200)
    docs = [base,
            rng.integers(0, 5000, size=150),
            np.concatenate([base, rng.integers(0, 5000, size=10)])]  # superset
    kept, stats = dedup_corpus(docs, threshold=0.8, budget_frac=0.5)
    assert stats["dropped"] == 1
    assert 0 in kept and 1 in kept and 2 not in kept


def test_shingle_basic():
    t = np.asarray([1, 2, 3, 4, 5])
    s3 = shingle(t, q=3)
    assert len(s3) == 3                       # 3 trigrams, all distinct
    assert len(shingle(t[:2], q=3)) == 2      # shorter than q → unigrams


def test_neighbor_sampler_shapes_and_membership():
    rng = np.random.default_rng(0)
    n, e = 50, 400
    edges = rng.integers(0, n, (e, 2)).astype(np.int32)
    g = CSRGraph.from_edges(edges, n)
    feats = rng.normal(size=(n, 8)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    batch = sample_batch(g, feats, labels, batch_nodes=6, fanout=(4, 3),
                         rng=rng)
    assert batch["h1"].shape == (6, 4, 8)
    assert batch["h2"].shape == (6, 4, 3, 8)
    # sampled hop-1 nodes must be true in-neighbors (or self for isolated)
    seeds = np.argwhere((feats[:, None] == batch["seed_feats"][None])
                        .all(-1))[:, 0]
    del seeds  # membership asserted via CSR directly below
    nodes = rng.integers(0, n, 10).astype(np.int32)
    neigh = g.sample_neighbors(nodes, 5, rng)
    for i, node in enumerate(nodes):
        lo, hi = g.indptr[node], g.indptr[node + 1]
        allowed = set(g.indices[lo:hi].tolist()) or {int(node)}
        assert set(neigh[i].tolist()) <= allowed


def test_hlo_parse_shape_bytes():
    sys.path.insert(0, ".")
    from benchmarks.hlo_parse import _shape_bytes

    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[2], s8[4])") == 12
    assert _shape_bytes("pred[]") == 1


@pytest.mark.parametrize("fam,count", [("lm", 4), ("gnn", 4), ("recsys", 4)])
def test_family_shape_tables(fam, count):
    assert len(FAMILY_SHAPES[fam]) == count


def test_registry_full_configs_instantiate():
    """Full (not reduced) configs build their dataclasses (no arrays)."""
    for arch in registry.ARCH_IDS:
        mod = registry.get_module(arch)
        cfg = (mod.config(d_feat=100, n_classes=10)
               if registry.family(arch) == "gnn" else mod.config())
        assert cfg.name == arch
