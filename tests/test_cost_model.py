import numpy as np

from repro.core import cost_model


def test_pair_variance_eq11_hand_value():
    # k=10, D∪=100, D∩=20: Var = 20*(10*100-100-100+10+20)/(10*8)
    v = cost_model.pair_variance(20, 100, 10)
    assert np.isclose(v, 20 * (1000 - 100 - 100 + 10 + 20) / 80.0)


def test_pair_variance_k_too_small_is_bounded_worst_case():
    # Eq. 11 is undefined at k <= 2; the model charges the squared-error
    # worst case D∩² (missing the tail entirely) instead of +inf — see
    # EXPERIMENTS.md §Claims C1.
    assert float(cost_model.pair_variance(5, 50, 2)) == 25.0
    assert np.isfinite(cost_model.pair_variance(5, 50, 1))


def test_skewed_data_wants_buffer():
    # Extremely skewed element frequency: a handful of elements dominate →
    # the cost model should allocate a nonzero buffer.
    freqs = np.asarray([10_000] * 32 + [1] * 5000)
    sizes = np.full(500, 200)
    r = cost_model.choose_buffer_size(freqs, sizes, budget=8000, m=500)
    assert r > 0


def test_uniform_data_wants_no_buffer():
    freqs = np.full(5000, 3)
    sizes = np.full(500, 60)
    r = cost_model.choose_buffer_size(freqs, sizes, budget=8000, m=500)
    assert r == 0


def test_variance_decreases_with_budget():
    freqs = np.asarray([1000] * 50 + [2] * 3000)
    sizes = np.full(300, 100)
    v_small = cost_model.gbkmv_variance(freqs, sizes, budget=2000, m=300, r=0)
    v_big = cost_model.gbkmv_variance(freqs, sizes, budget=8000, m=300, r=0)
    assert v_big < v_small


def test_powerlaw_wrapper_finite():
    v = cost_model.powerlaw_variance(r=64, alpha1=1.2, alpha2=2.5,
                                     budget=50_000, n_elems=10_000, m=1000)
    assert np.isfinite(v) and v >= 0


def test_fit_power_law():
    rng = np.random.default_rng(0)
    x = rng.pareto(1.5, size=20_000) + 1.0  # tail exponent α = 2.5
    a = cost_model.fit_power_law_exponent(x, x_min=1.0)
    assert 2.2 < a < 2.8
